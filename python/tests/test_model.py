"""L2 correctness: the jitted FFCz loop vs the eager reference, dual-bound
properties, and pallas/jnp path equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ffcz_correct, ffcz_correct_reference

jax.config.update("jax_enable_x64", False)


def rand_eps(shape, e, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-e, e, size=shape), jnp.float32)


def dual_bound_violation(eps, e_bound, d_bound):
    """Return (spatial ratio, frequency ratio); ≤1 means in-bound."""
    s = float(jnp.max(jnp.abs(eps))) / e_bound
    delta = jnp.fft.fftn(eps)
    f = float(jnp.max(jnp.maximum(jnp.abs(delta.real), jnp.abs(delta.imag)))) / d_bound
    return s, f


class TestFfczCorrect:
    @pytest.mark.parametrize("shape", [(256,), (1024,), (32, 32), (8, 8, 8)])
    def test_dual_bounds_hold(self, shape):
        e, d = 0.05, 0.3
        eps0 = rand_eps(shape, e, 1)
        eps, _spat, _fr, _fi, iters, done = ffcz_correct(eps0, e, d, max_iters=400)
        assert bool(done), f"not converged in {int(iters)} iterations"
        s, f = dual_bound_violation(eps, e, d)
        # f32 FFT roundoff tolerance.
        assert s <= 1.0 + 3e-4 and f <= 1.0 + 3e-4, (s, f)

    def test_feasible_input_is_untouched(self):
        eps0 = rand_eps((512,), 0.01, 2)
        eps, spat, fr, fi, iters, done = ffcz_correct(eps0, 0.01, 1e6)
        assert bool(done) and int(iters) == 1
        np.testing.assert_array_equal(eps, eps0)
        assert float(jnp.sum(jnp.abs(spat))) == 0.0
        assert float(jnp.sum(jnp.abs(fr))) + float(jnp.sum(jnp.abs(fi))) == 0.0

    def test_matches_eager_reference(self):
        e, d = 0.05, 0.25
        eps0 = rand_eps((256,), e, 3)
        eps_j, spat_j, fr_j, fi_j, it_j, done_j = ffcz_correct(
            eps0, e, d, max_iters=300
        )
        eps_r, spat_r, fr_r, fi_r, it_r, done_r = ffcz_correct_reference(
            np.asarray(eps0), e, d, max_iters=300
        )
        assert bool(done_j) == bool(done_r)
        # f32 vs f64 drift across tens of FFT iterations: modest tolerance.
        np.testing.assert_allclose(eps_j, eps_r, atol=2e-4)
        np.testing.assert_allclose(spat_j, spat_r, atol=2e-4)
        np.testing.assert_allclose(fr_j, fr_r, atol=2e-3)
        np.testing.assert_allclose(fi_j, fi_r, atol=2e-3)

    def test_pallas_and_jnp_paths_agree(self):
        e, d = 0.05, 0.3
        eps0 = rand_eps((1024,), e, 4)
        out_p = ffcz_correct(eps0, e, d, max_iters=200, use_pallas=True)
        out_j = ffcz_correct(eps0, e, d, max_iters=200, use_pallas=False)
        for a, b in zip(out_p[:4], out_j[:4]):
            np.testing.assert_allclose(a, b, atol=1e-5)
        assert int(out_p[4]) == int(out_j[4])

    def test_edits_reconstruct_correction(self):
        e, d = 0.05, 0.2
        eps0 = rand_eps((512,), e, 5)
        eps, spat, fr, fi, _it, done = ffcz_correct(eps0, e, d, max_iters=400)
        assert bool(done)
        freq_part = jnp.real(jnp.fft.ifftn(fr + 1j * fi))
        rebuilt = eps0 + spat + freq_part
        np.testing.assert_allclose(rebuilt, eps, atol=1e-5)

    def test_tiny_delta_regime(self):
        # Paper Table III: tiny Δ ⇒ one pass of pure frequency clipping.
        eps0 = rand_eps((2048,), 0.1, 6)
        eps, spat, fr, fi, iters, done = ffcz_correct(
            eps0, 0.1, 1e-6, max_iters=50
        )
        assert bool(done)
        assert int(iters) <= 3
        assert float(jnp.sum(jnp.abs(spat))) < 1e-3
        active_freq = int(jnp.sum((jnp.abs(fr) > 0) | (jnp.abs(fi) > 0)))
        assert active_freq > 1024

    def test_pointwise_bounds(self):
        shape = (256,)
        e_b = jnp.full(shape, 0.05, jnp.float32)
        d_b = jnp.asarray(
            np.where(np.arange(256) % 2 == 0, 0.5, 0.1), jnp.float32
        )
        eps0 = rand_eps(shape, 0.05, 7)
        eps, *_rest, done = ffcz_correct(eps0, e_b, d_b, max_iters=500)
        assert bool(done)
        delta = jnp.fft.fftn(eps)
        linf = jnp.maximum(jnp.abs(delta.real), jnp.abs(delta.imag))
        assert float(jnp.max(linf / d_b)) <= 1.0 + 3e-4
