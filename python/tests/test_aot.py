"""AOT contract tests: variants lower to HLO text that contains the pieces
the Rust runtime depends on, and the manifests agree with each other."""

import json
import os

import pytest

from compile import aot


class TestLowering:
    def test_variant_lowers_to_hlo_text(self):
        lowered = aot.lower_variant((256,), 8)
        text = aot.to_hlo_text(lowered)
        # HLO text essentials the Rust loader parses.
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # The loop and the transforms must be present.
        assert "while" in text
        assert "fft" in text.lower()

    def test_variant_signature_shapes(self):
        lowered = aot.lower_variant((64,), 4)
        text = aot.to_hlo_text(lowered)
        # 3 parameters: eps f32[64], two f32[] scalars.
        assert "f32[64]" in text
        assert text.count("parameter(") >= 3

    def test_2d_variant(self):
        lowered = aot.lower_variant((16, 16), 4)
        text = aot.to_hlo_text(lowered)
        assert "f32[16,16]" in text


class TestManifest:
    @pytest.fixture()
    def built(self, tmp_path):
        import sys

        argv = sys.argv
        sys.argv = [
            "aot",
            "--out-dir",
            str(tmp_path),
            "--only",
            "ffcz_correct_1d_4096",
        ]
        try:
            aot.main()
        finally:
            sys.argv = argv
        return tmp_path

    def test_manifests_agree(self, built):
        with open(built / "manifest.json") as f:
            j = json.load(f)
        txt = (built / "manifest.txt").read_text().strip().splitlines()
        assert len(j["variants"]) == len(txt) == 1
        v = j["variants"][0]
        name, shape_s, iters, fname = txt[0].split("|")
        assert name == v["name"]
        assert [int(x) for x in shape_s.split(",")] == v["shape"]
        assert int(iters) == v["max_iters"]
        assert fname == v["file"]
        assert os.path.exists(built / fname)

    def test_hlo_file_nonempty(self, built):
        p = built / "ffcz_correct_1d_4096.hlo.txt"
        assert p.stat().st_size > 1000
        assert p.read_text().startswith("HloModule")
