"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes, bounds, and value scales; every kernel must match
its ref.py oracle to float32 tolerance. This is the CORE correctness signal
for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import projection, ref
from compile.kernels import dft as dftk

jax.config.update("jax_enable_x64", False)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


shapes = st.sampled_from([(16,), (100,), (1024,), (1025,), (4096,), (32, 32), (7, 13), (8, 8, 8)])


class TestProjectOntoSCube:
    @settings(max_examples=20, deadline=None)
    @given(shape=shapes, bound=st.floats(1e-4, 10.0), seed=st.integers(0, 2**16))
    def test_matches_ref_scalar_bound(self, shape, bound, seed):
        eps = rand(shape, seed)
        got = projection.project_onto_scube(eps, bound)
        want = ref.project_onto_scube_ref(eps, bound)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_pointwise_bounds(self):
        eps = rand((512,), 1, scale=2.0)
        bounds = jnp.abs(rand((512,), 2)) + 0.01
        got = projection.project_onto_scube(eps, bounds)
        want = ref.project_onto_scube_ref(eps, bounds)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_idempotent(self):
        eps = rand((256,), 3)
        once = projection.project_onto_scube(eps, 0.5)
        twice = projection.project_onto_scube(once, 0.5)
        np.testing.assert_array_equal(once, twice)

    def test_result_within_bound(self):
        eps = rand((333,), 4, scale=5.0)
        out = projection.project_onto_scube(eps, 0.25)
        assert float(jnp.max(jnp.abs(out))) <= 0.25 + 1e-7


class TestProjectOntoFCube:
    @settings(max_examples=20, deadline=None)
    @given(shape=shapes, bound=st.floats(1e-4, 10.0), seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, bound, seed):
        re = rand(shape, seed)
        im = rand(shape, seed + 1)
        got_re, got_im = projection.project_onto_fcube(re, im, bound)
        want_re, want_im = ref.project_onto_fcube_ref(re, im, bound)
        np.testing.assert_allclose(got_re, want_re, rtol=1e-6)
        np.testing.assert_allclose(got_im, want_im, rtol=1e-6)

    def test_planes_clipped_independently(self):
        re = jnp.asarray([2.0, 0.1], jnp.float32)
        im = jnp.asarray([0.1, -2.0], jnp.float32)
        got_re, got_im = projection.project_onto_fcube(re, im, 1.0)
        np.testing.assert_allclose(got_re, [1.0, 0.1], rtol=1e-6)
        np.testing.assert_allclose(got_im, [0.1, -1.0], rtol=1e-6)


class TestCheckConvergence:
    @settings(max_examples=20, deadline=None)
    @given(shape=shapes, bound=st.floats(1e-3, 10.0), seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, bound, seed):
        re = rand(shape, seed)
        im = rand(shape, seed + 7)
        got = projection.check_convergence(re, im, bound)
        want = ref.check_convergence_ref(re, im, bound)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_inside_cube_is_below_one(self):
        re = jnp.full((2048,), 0.4, jnp.float32)
        im = jnp.full((2048,), -0.4, jnp.float32)
        assert float(projection.check_convergence(re, im, 0.5)) <= 1.0

    def test_single_violation_detected(self):
        re = jnp.zeros((4096,), jnp.float32).at[1234].set(3.0)
        im = jnp.zeros((4096,), jnp.float32)
        assert float(projection.check_convergence(re, im, 1.0)) == pytest.approx(3.0)


class TestQuantizeEdits:
    @settings(max_examples=20, deadline=None)
    @given(
        shape=shapes,
        step=st.floats(1e-6, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, shape, step, seed):
        edits = rand(shape, seed, scale=0.1)
        got = projection.quantize_edits(edits, step)
        want = ref.quantize_edits_ref(edits, step)
        np.testing.assert_array_equal(got, want)

    def test_roundtrip_error_below_half_step(self):
        edits = rand((1024,), 9, scale=0.01)
        step = 1e-3
        q = projection.quantize_edits(edits, step)
        back = ref.dequantize_edits_ref(q, step)
        assert float(jnp.max(jnp.abs(back - edits))) <= step / 2 + 1e-7


class TestMatmulDft:
    @pytest.mark.parametrize("n", [16, 64, 100, 256, 1024])
    def test_forward_matches_fft(self, n):
        x = rand((n,), n)
        xr, xi = dftk.dft_four_step(x, jnp.zeros_like(x))
        want = jnp.fft.fft(x)
        np.testing.assert_allclose(xr, jnp.real(want), rtol=1e-3, atol=1e-3 * n**0.5)
        np.testing.assert_allclose(xi, jnp.imag(want), rtol=1e-3, atol=1e-3 * n**0.5)

    @pytest.mark.parametrize("n", [64, 256])
    def test_roundtrip(self, n):
        x = rand((n,), n + 1)
        fr, fi = dftk.dft_four_step(x, jnp.zeros_like(x))
        br, bi = dftk.dft_four_step(fr, fi, inverse=True)
        np.testing.assert_allclose(br, x, atol=1e-4)
        np.testing.assert_allclose(bi, jnp.zeros_like(x), atol=1e-4)

    def test_complex_matmul_matches_ref(self):
        a_r, a_i = rand((96, 64), 1), rand((96, 64), 2)
        b_r, b_i = rand((64, 80), 3), rand((64, 80), 4)
        got_r, got_i = dftk.complex_matmul(a_r, a_i, b_r, b_i)
        want_r, want_i = ref.complex_matmul_ref(a_r, a_i, b_r, b_i)
        np.testing.assert_allclose(got_r, want_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_i, want_i, rtol=1e-4, atol=1e-4)

    def test_factorization_is_balanced(self):
        assert dftk.factor_n(4096) == (64, 64)
        assert dftk.factor_n(100) == (10, 10)
        n1, n2 = dftk.factor_n(24)
        assert n1 * n2 == 24 and n1 <= n2
