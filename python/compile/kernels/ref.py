"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an oracle here; pytest sweeps shapes,
dtypes, and bounds (via hypothesis) asserting allclose between the Pallas
interpret-mode kernel and these references. This is the core correctness
signal for Layer 1.
"""

import jax.numpy as jnp


def project_onto_scube_ref(eps, bound):
    """Clip a real vector to the s-cube [-bound, bound] (paper Eq. 4c)."""
    return jnp.clip(eps, -bound, bound)


def project_onto_fcube_ref(re, im, bound):
    """Clip Re/Im of a frequency error vector to the f-cube (Eq. 4a/4b).

    ``bound`` may be a scalar or an array broadcastable to ``re``/``im``
    (pointwise Δ_k, used in power-spectrum mode).
    """
    return jnp.clip(re, -bound, bound), jnp.clip(im, -bound, bound)


def check_convergence_ref(re, im, bound):
    """Max violation ratio max_k(‖δ_k‖∞ / Δ_k); ≤ 1 means converged."""
    linf = jnp.maximum(jnp.abs(re), jnp.abs(im))
    return jnp.max(linf / bound)


def quantize_edits_ref(edits, step):
    """Uniform quantization to signed grid indices (paper §IV-B, m=16)."""
    q = jnp.round(edits / step)
    return jnp.clip(q, -32767, 32767).astype(jnp.int32)


def dequantize_edits_ref(q, step):
    """Inverse of :func:`quantize_edits_ref`."""
    return q.astype(jnp.float32) * step


def complex_matmul_ref(ar, ai, br, bi):
    """(ar + i·ai) @ (br + i·bi) as two real planes."""
    return ar @ br - ai @ bi, ar @ bi + ai @ br


def dft_ref(x):
    """Forward unnormalized DFT of a real or complex 1-D signal."""
    return jnp.fft.fft(x)
