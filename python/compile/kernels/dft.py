"""Four-step matmul DFT — the TPU adaptation of cuFFT (DESIGN.md
§Hardware-Adaptation).

The paper's dominant GPU kernel is the cuFFT batched C2C transform. On a
TPU the efficient formulation of a Fourier transform is *matrix form* on
the MXU systolic array: factor N = N1·N2 and compute

    A[k1, n2] = Σ_{n1} x[n1, n2] · ω_{N1}^{n1·k1}        (MXU matmul)
    B[k1, n2] = A[k1, n2] · ω_N^{n2·k1}                  (VPU twiddle)
    C[k1, k2] = Σ_{n2} B[k1, n2] · ω_{N2}^{n2·k2}        (MXU matmul)
    X[N1·k2 + k1] = C[k1, k2]

Complex arithmetic is carried as separate Re/Im planes (4 real matmuls per
complex matmul), implemented as a Pallas kernel tiled for VMEM. The DFT
matrices are O(N1²)+O(N2²) and live comfortably in VMEM for N1,N2 ≤ 256,
the regime used by the AOT artifacts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import numpy as np

# MXU-shaped tile. 128 matches the systolic array edge.
TM = 128


def _cmatmul_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    # Four real matmuls; on TPU these hit the MXU, f32 accumulation.
    or_ref[...] = ar @ br - ai @ bi
    oi_ref[...] = ar @ bi + ai @ br


def _pad2(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@jax.jit
def complex_matmul(ar, ai, br, bi):
    """(ar + i·ai) @ (br + i·bi) via a VMEM-tiled Pallas kernel.

    Tiles: output (TM, TM) blocks; the full K dimension is streamed per
    block (K ≤ 256 in the DFT use case, so one (TM, K) + (K, TM) pair of
    operands per plane fits VMEM with room to spare).
    """
    m, k = ar.shape
    k2, n = br.shape
    assert k == k2, "inner dims must agree"
    a_r, a_i = _pad2(ar, TM, 1), _pad2(ai, TM, 1)
    b_r, b_i = _pad2(br, 1, TM), _pad2(bi, 1, TM)
    gm = a_r.shape[0] // TM
    gn = b_r.shape[1] // TM
    out_r, out_i = pl.pallas_call(
        _cmatmul_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((TM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((TM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, TM), lambda i, j: (0, j)),
            pl.BlockSpec((k, TM), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((TM, TM), lambda i, j: (i, j)),
            pl.BlockSpec((TM, TM), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((a_r.shape[0], b_r.shape[1]), ar.dtype),
            jax.ShapeDtypeStruct((a_r.shape[0], b_r.shape[1]), ar.dtype),
        ],
        interpret=True,
    )(a_r, a_i, b_r, b_i)
    return out_r[:m, :n], out_i[:m, :n]


def _dft_matrix(n, sign):
    """Dense n×n DFT matrix as (re, im) numpy planes (built at trace time)."""
    idx = np.arange(n)
    ang = sign * 2.0 * np.pi * np.outer(idx, idx) / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def factor_n(n):
    """Pick N1·N2 = n with N1, N2 as square as possible."""
    best = (1, n)
    d = 1
    while d * d <= n:
        if n % d == 0:
            best = (d, n // d)
        d += 1
    return best


@functools.partial(jax.jit, static_argnames=("inverse",))
def _four_step(xr, xi, w1r, w1i, twr, twi, w2r, w2i, inverse):
    n1 = w1r.shape[0]
    n2 = w2r.shape[0]
    # Step 1: column DFT — W1[k1, n1] @ X[n1, n2].
    x_r = xr.reshape(n1, n2)
    x_i = xi.reshape(n1, n2)
    a_r, a_i = complex_matmul(w1r, w1i, x_r, x_i)
    # Step 2: twiddle (elementwise complex multiply).
    b_r = a_r * twr - a_i * twi
    b_i = a_r * twi + a_i * twr
    # Step 3: row DFT — B[k1, n2] @ W2[n2, k2].
    c_r, c_i = complex_matmul(b_r, b_i, w2r, w2i)
    # Step 4: transpose-gather to the flat output layout X[n1·k2 + k1].
    out_r = c_r.T.reshape(-1)
    out_i = c_i.T.reshape(-1)
    if inverse:
        scale = 1.0 / (n1 * n2)
        out_r = out_r * scale
        out_i = out_i * scale
    return out_r, out_i


def dft_four_step(xr, xi, inverse=False):
    """Forward/inverse DFT of a flat complex vector held as (re, im) planes.

    Matrices and twiddles are built at trace time (they are compile-time
    constants of the AOT artifact, the analogue of cuFFT's plan).
    """
    n = xr.shape[0]
    n1, n2 = factor_n(n)
    sign = 1.0 if inverse else -1.0
    w1r, w1i = _dft_matrix(n1, sign)
    w2r, w2i = _dft_matrix(n2, sign)
    k1 = np.arange(n1)
    nn2 = np.arange(n2)
    ang = sign * 2.0 * np.pi * np.outer(k1, nn2) / n
    twr = np.cos(ang).astype(np.float32)
    twi = np.sin(ang).astype(np.float32)
    return _four_step(xr, xi, w1r, w1i, twr, twi, w2r, w2i, inverse)
