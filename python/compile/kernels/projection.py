"""Layer-1 Pallas kernels for the FFCz projection loop.

These are the paper's CUDA kernels (ProjectOntoFCube, ProjectOntoSCube,
CheckConvergence, QuantizeEdits) rethought for the TPU programming model:

* elementwise clips stream HBM→VMEM tiles through the VPU — the BlockSpec
  plays the role the CUDA threadblock decomposition plays on the GPU;
* the convergence check is a two-level reduction: a Pallas kernel produces
  per-tile partial maxima, a tiny jnp reduction finishes;
* all kernels run with ``interpret=True`` so they lower to plain HLO that
  the CPU PJRT client can execute (real-TPU lowering would emit a Mosaic
  custom-call; see DESIGN.md §Hardware-Adaptation).

All kernels treat inputs as flat vectors padded to a multiple of the tile;
wrappers handle padding/unpadding so callers see exact shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size: 8·128 f32 lanes = one (8, 128) VPU tile worth of work per
# program instance. Flat vectors are processed in (TILE,) blocks.
TILE = 1024


def _pad_to_tile(x):
    n = x.shape[0]
    pad = (-n) % TILE
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, n


# ---------------------------------------------------------------- s-cube


def _scube_kernel(eps_ref, bound_ref, out_ref):
    b = bound_ref[...]
    out_ref[...] = jnp.clip(eps_ref[...], -b, b)


@functools.partial(jax.jit, static_argnames=())
def project_onto_scube(eps, bound):
    """Clip ``eps`` (any shape, f32) to ±bound. ``bound`` scalar or
    broadcastable array (pointwise E_n)."""
    shape = eps.shape
    flat = eps.reshape(-1)
    b_arr = jnp.asarray(bound, flat.dtype)
    b_arr = b_arr.reshape(-1) if b_arr.ndim > 0 else b_arr
    bounds = jnp.broadcast_to(b_arr, flat.shape)
    x, n = _pad_to_tile(flat)
    # Pad bounds with 1s so padded lanes stay zero after the clip of zeros.
    b, _ = _pad_to_tile(bounds)
    b = jnp.where(jnp.arange(x.shape[0]) < n, b, 1.0)
    grid = x.shape[0] // TILE
    out = pl.pallas_call(
        _scube_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, b)
    return out[:n].reshape(shape)


# ---------------------------------------------------------------- f-cube


def _fcube_kernel(re_ref, im_ref, bound_ref, out_re_ref, out_im_ref):
    b = bound_ref[...]
    out_re_ref[...] = jnp.clip(re_ref[...], -b, b)
    out_im_ref[...] = jnp.clip(im_ref[...], -b, b)


@functools.partial(jax.jit, static_argnames=())
def project_onto_fcube(re, im, bound):
    """Clip Re/Im planes of a frequency error vector to the f-cube."""
    shape = re.shape
    fre, fim = re.reshape(-1), im.reshape(-1)
    b_arr = jnp.asarray(bound, fre.dtype)
    b_arr = b_arr.reshape(-1) if b_arr.ndim > 0 else b_arr
    bounds = jnp.broadcast_to(b_arr, fre.shape)
    xr, n = _pad_to_tile(fre)
    xi, _ = _pad_to_tile(fim)
    b, _ = _pad_to_tile(bounds)
    b = jnp.where(jnp.arange(xr.shape[0]) < n, b, 1.0)
    grid = xr.shape[0] // TILE
    out_re, out_im = pl.pallas_call(
        _fcube_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xr.shape, xr.dtype),
            jax.ShapeDtypeStruct(xi.shape, xi.dtype),
        ],
        interpret=True,
    )(xr, xi, b)
    return out_re[:n].reshape(shape), out_im[:n].reshape(shape)


# ------------------------------------------------------ convergence check


def _conv_kernel(re_ref, im_ref, bound_ref, out_ref):
    linf = jnp.maximum(jnp.abs(re_ref[...]), jnp.abs(im_ref[...]))
    out_ref[0] = jnp.max(linf / bound_ref[...])


@functools.partial(jax.jit, static_argnames=())
def check_convergence(re, im, bound):
    """Max violation ratio max_k(‖δ_k‖∞ / Δ_k) — ≤ 1 means inside f-cube.

    Two-level reduction: per-tile maxima in the Pallas kernel, final max in
    jnp (mirrors the paper's blockwise CUDA reduction).
    """
    fre, fim = re.reshape(-1), im.reshape(-1)
    b_arr = jnp.asarray(bound, fre.dtype)
    b_arr = b_arr.reshape(-1) if b_arr.ndim > 0 else b_arr
    bounds = jnp.broadcast_to(b_arr, fre.shape)
    xr, n = _pad_to_tile(fre)
    xi, _ = _pad_to_tile(fim)
    b, _ = _pad_to_tile(bounds)
    # Padded lanes: value 0, bound 1 ⇒ ratio 0, never the max.
    b = jnp.where(jnp.arange(xr.shape[0]) < n, b, 1.0)
    grid = xr.shape[0] // TILE
    partial = pl.pallas_call(
        _conv_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), xr.dtype),
        interpret=True,
    )(xr, xi, b)
    return jnp.max(partial)


# ------------------------------------------------------------- quantize


def _quant_kernel(edits_ref, step_ref, out_ref):
    q = jnp.round(edits_ref[...] / step_ref[0])
    out_ref[...] = jnp.clip(q, -32767.0, 32767.0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def quantize_edits(edits, step):
    """Uniform quantization of an edit vector to 16-bit grid indices."""
    flat = edits.reshape(-1)
    x, n = _pad_to_tile(flat)
    grid = x.shape[0] // TILE
    step_arr = jnp.full((grid,), step, x.dtype)
    out = pl.pallas_call(
        _quant_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        interpret=True,
    )(x, step_arr)
    return out[:n].reshape(edits.shape)
