"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

Emits one HLO-text module per (shape, max_iters) variant of the FFCz
correction loop, plus a manifest that the Rust artifact registry reads.

HLO *text* (not ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Python runs ONCE at build time; the Rust binary is self-contained after
``make artifacts``.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ffcz_correct

# (name, shape, max_iters): the variants the coordinator loads. Shapes are
# chosen to cover 1D/2D/3D; the Rust side pads instances to the nearest
# variant or falls back to the native engine for odd shapes.
VARIANTS = [
    ("ffcz_correct_1d_4096", (4096,), 64),
    ("ffcz_correct_1d_16384", (16384,), 64),
    ("ffcz_correct_2d_64x64", (64, 64), 64),
    ("ffcz_correct_2d_128x128", (128, 128), 64),
    ("ffcz_correct_3d_16", (16, 16, 16), 64),
    ("ffcz_correct_3d_32", (32, 32, 32), 64),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(shape, max_iters):
    """Lower one ffcz_correct variant to HLO text.

    Signature: (eps f32[shape], e_bound f32[], d_bound f32[]) →
    (corrected, spat_edits, freq_re, freq_im, iterations, converged).

    The AOT path uses the pure-jnp projections (`use_pallas=False`): the
    interpret-mode Pallas wrappers lower through `jax.experimental.callback`
    machinery that cannot be serialized into a standalone HLO module. The
    Pallas kernels are exercised and validated by pytest (L1 correctness);
    the lowered loop is numerically identical (see test_model.py which
    asserts pallas == jnp paths).
    """
    eps = jax.ShapeDtypeStruct(shape, jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    def fn(e, eb, db):
        return ffcz_correct(e, eb, db, max_iters=max_iters, use_pallas=False)

    return jax.jit(fn).lower(eps, scalar, scalar)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated variant names to build"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text", "variants": []}
    for name, shape, max_iters in VARIANTS:
        if only and name not in only:
            continue
        lowered = lower_variant(shape, max_iters)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append(
            {
                "name": name,
                "shape": list(shape),
                "max_iters": max_iters,
                "file": f"{name}.hlo.txt",
                "inputs": ["eps f32[shape]", "e_bound f32[]", "d_bound f32[]"],
                "outputs": [
                    "corrected f32[shape]",
                    "spat_edits f32[shape]",
                    "freq_re f32[shape]",
                    "freq_im f32[shape]",
                    "iterations i32[]",
                    "converged pred[]",
                ],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Line-based twin of the JSON manifest for the Rust artifact registry
    # (no JSON parser in the offline crate set):
    #   name|dim0,dim1,…|max_iters|file
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        for v in manifest["variants"]:
            shape_s = ",".join(str(d) for d in v["shape"])
            f.write(f"{v['name']}|{shape_s}|{v['max_iters']}|{v['file']}\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')} (+.txt)")


if __name__ == "__main__":
    main()
