"""Layer-2 JAX model: the FFCz alternating projection-correction loop.

``ffcz_correct`` is the jitted POCS loop (paper Alg. 1 lines 4–14) built
from the Layer-1 Pallas kernels plus ``jnp.fft`` for the basis changes.
It is lowered once per (shape,) variant by :mod:`compile.aot` to HLO text
that the Rust runtime executes via PJRT — Python never runs on the
request path.

Semantics mirror the Rust CPU reference (`rust/src/correction/pocs.rs`)
exactly, so either engine can serve the coordinator:

* per-iteration: ``δ = FFT(ε)``; if ``‖δ‖∞ ≤ Δ`` componentwise, stop;
  else clip δ (f-cube), accumulate frequency edits, ``ε = Re(IFFT(δ))``,
  clip ε (s-cube), accumulate spatial edits;
* bounds may be scalars or pointwise arrays (broadcast);
* the loop runs under ``lax.while_loop`` with an iteration cap, so the
  compiled artifact is shape- and iteration-generic up to the cap.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import projection


@functools.partial(jax.jit, static_argnames=("max_iters", "use_pallas"))
def ffcz_correct(eps0, e_bound, d_bound, max_iters=64, use_pallas=True):
    """Drive ``eps0`` into the s-cube ∩ f-cube intersection.

    Args:
      eps0: real error vector, any shape, f32.
      e_bound: scalar or array (s-cube half-widths E_n).
      d_bound: scalar or array (f-cube half-widths Δ_k, applied to Re and
        Im independently).
      max_iters: iteration cap (static).
      use_pallas: route the projections through the Pallas kernels
        (interpret mode); pure-jnp fallback otherwise (static).

    Returns:
      (corrected_eps, spat_edits, freq_edits_re, freq_edits_im,
       iterations, converged)
    """
    shape = eps0.shape
    e_b = jnp.broadcast_to(jnp.asarray(e_bound, eps0.dtype), shape)
    d_b = jnp.broadcast_to(jnp.asarray(d_bound, eps0.dtype), shape)

    def project_f(re, im):
        if use_pallas:
            return projection.project_onto_fcube(re, im, d_b)
        return jnp.clip(re, -d_b, d_b), jnp.clip(im, -d_b, d_b)

    def project_s(eps):
        if use_pallas:
            return projection.project_onto_scube(eps, e_b)
        return jnp.clip(eps, -e_b, e_b)

    # A violation only keeps the loop running when it exceeds the bound
    # beyond f32 FFT roundoff; without this the loop chases 1-ulp
    # exceedances forever (same tolerance rule as the Rust engine).
    VIOLATION_TOL = 1.0 + 1e-4

    def violation(re, im):
        if use_pallas:
            return projection.check_convergence(re, im, d_b)
        return jnp.max(jnp.maximum(jnp.abs(re), jnp.abs(im)) / d_b)

    def cond(state):
        _eps, _s, _fr, _fi, it, done = state
        return jnp.logical_and(it < max_iters, jnp.logical_not(done))

    def body(state):
        eps, spat, f_re, f_im, it, _done = state
        delta = jnp.fft.fftn(eps)
        d_re, d_im = jnp.real(delta), jnp.imag(delta)
        viol = violation(d_re, d_im) > VIOLATION_TOL
        c_re, c_im = project_f(d_re, d_im)
        # Only commit the projection when violated (else terminate clean).
        f_re = jnp.where(viol, f_re + (c_re - d_re), f_re)
        f_im = jnp.where(viol, f_im + (c_im - d_im), f_im)
        eps_f = jnp.real(jnp.fft.ifftn((c_re + 1j * c_im).astype(delta.dtype)))
        eps_s = project_s(eps_f)
        spat = jnp.where(viol, spat + (eps_s - eps_f), spat)
        eps_out = jnp.where(viol, eps_s, eps)
        return eps_out, spat, f_re, f_im, it + 1, jnp.logical_not(viol)

    zeros = jnp.zeros_like(eps0)
    init = (eps0, zeros, zeros, zeros, jnp.int32(0), jnp.bool_(False))
    eps, spat, f_re, f_im, iters, done = lax.while_loop(cond, body, init)
    return eps, spat, f_re, f_im, iters, done


def ffcz_correct_reference(eps0, e_bound, d_bound, max_iters=64):
    """Eager numpy-style reference of the same loop (used by pytest)."""
    import numpy as np

    eps = np.asarray(eps0, dtype=np.float64)
    shape = eps.shape
    e_b = np.broadcast_to(np.asarray(e_bound, np.float64), shape)
    d_b = np.broadcast_to(np.asarray(d_bound, np.float64), shape)
    spat = np.zeros_like(eps)
    f_re = np.zeros_like(eps)
    f_im = np.zeros_like(eps)
    it = 0
    converged = False
    while it < max_iters:
        it += 1
        delta = np.fft.fftn(eps)
        linf = np.maximum(np.abs(delta.real), np.abs(delta.imag))
        if np.all(linf <= d_b * (1.0 + 1e-4)):
            # Terminate without committing the (sub-tolerance) projection —
            # exactly what the jitted path's `where(viol, …)` does.
            converged = True
            break
        c_re = np.clip(delta.real, -d_b, d_b)
        c_im = np.clip(delta.imag, -d_b, d_b)
        f_re += c_re - delta.real
        f_im += c_im - delta.imag
        eps_f = np.fft.ifftn(c_re + 1j * c_im).real
        eps_s = np.clip(eps_f, -e_b, e_b)
        spat += eps_s - eps_f
        eps = eps_s
    return eps, spat, f_re, f_im, it, converged
