//! End-to-end driver (the paper's headline workload): stream a sequence of
//! cosmology-like snapshots through the pipelined compression–editing
//! coordinator with power-spectrum preservation, and report the paper's
//! headline metric — every power-spectrum bin within the ±0.1% ribbon —
//! plus throughput and the pipeline timeline.
//!
//! ```bash
//! cargo run --release --example cosmology_spectrum [scale] [snapshots]
//! ```
//!
//! This is the EXPERIMENTS.md §End-to-end run.

use ffcz::compressors::szlike::SzLike;
use ffcz::coordinator::{run_pipeline, ExecMode, PipelineConfig};
use ffcz::correction::{decompress, FfczConfig};
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::fourier::power_spectrum;
use ffcz::metrics;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(32);
    let n_snaps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(5);

    println!("== FFCz cosmology pipeline: {n_snaps} snapshots of {scale}³ ==");
    // Simulated snapshot sequence: growing structure (rising σ) like a
    // cosmology run's scale-factor evolution.
    let snapshots: Vec<_> = (0..n_snaps)
        .map(|i| {
            let sigma = 1.6 + 0.2 * i as f64;
            (
                format!("a{:.2}", 0.2 + 0.2 * i as f64),
                GrfBuilder::new(&[scale, scale, scale])
                    .spectral_index(1.8)
                    .lognormal(sigma)
                    .seed(1000 + i as u64)
                    .build(),
            )
        })
        .collect();
    let originals: Vec<_> = snapshots.iter().map(|(n, f)| (n.clone(), f.clone())).collect();
    let total_bytes: usize = snapshots.iter().map(|(_, f)| f.original_bytes()).sum();

    // Power-spectrum preservation mode: every P(k) bin within ±0.1%.
    let cfg = PipelineConfig::new(FfczConfig::power_spectrum(1e-3, 1e-3));
    let base = SzLike::default();

    let t0 = std::time::Instant::now();
    let report = run_pipeline(snapshots.clone(), &base, &cfg)?;
    let wall = t0.elapsed();

    println!("\n-- pipeline timeline (compress i+1 ∥ edit i) --");
    print!("{}", report.timeline_text());

    // Sequential comparison (the pipeline-hiding claim).
    let mut seq_cfg = cfg.clone();
    seq_cfg.mode = ExecMode::Sequential;
    let seq = run_pipeline(snapshots, &base, &seq_cfg)?;
    println!(
        "sequential {:.1} ms vs pipelined {:.1} ms → editing {:.0}% hidden",
        seq.makespan.as_secs_f64() * 1e3,
        report.makespan.as_secs_f64() * 1e3,
        100.0 * (1.0 - (report.makespan.as_secs_f64() - seq.compress_total.as_secs_f64())
            .max(0.0)
            / seq.edit_total.as_secs_f64().max(1e-12)),
    );

    // Headline metric: spectrum ribbon per snapshot.
    println!("\n-- power-spectrum ribbon (±0.1%) --");
    let mut compressed_total = 0usize;
    let mut worst = 0.0f64;
    for ((name, orig), (_, archive)) in originals.iter().zip(&report.archives) {
        let recon = decompress(archive)?;
        let ps0 = power_spectrum(orig);
        let ps1 = power_spectrum(&recon);
        let max_rel = ps1.max_relative_error(&ps0);
        worst = worst.max(max_rel);
        compressed_total += archive.total_bytes();
        println!(
            "{name}: max |ΔP/P| = {max_rel:.3e} {}  ratio {:.1}  PSNR {:.1} dB",
            if max_rel <= 1e-3 { "(in ribbon)" } else { "(OUT OF RIBBON)" },
            metrics::compression_ratio(orig, archive.total_bytes()),
            metrics::psnr(orig, &recon),
        );
    }
    println!(
        "\ntotal: {} → {} (ratio {:.1}), wall {:.2} s, throughput {:.1} MB/s",
        ffcz::util::human_bytes(total_bytes),
        ffcz::util::human_bytes(compressed_total),
        total_bytes as f64 / compressed_total as f64,
        wall.as_secs_f64(),
        total_bytes as f64 / 1e6 / wall.as_secs_f64(),
    );
    anyhow::ensure!(worst <= 1e-3, "ribbon violated: {worst:.3e}");
    println!("cosmology_spectrum OK — all snapshots inside the ±0.1% ribbon");
    Ok(())
}
