//! Quickstart: compress a synthetic cosmology-like field with a base
//! compressor + FFCz dual-domain correction, then verify both bounds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ffcz::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. A small Nyx-like baryon density field (log-normal GRF with a
    //    power-law spectrum). Real data would arrive via ffcz::data::io.
    let field = ffcz::data::synth::grf::GrfBuilder::new(&[32, 32, 32])
        .spectral_index(1.8)
        .lognormal(2.4)
        .seed(42)
        .build();
    println!(
        "field: shape {:?}, {} ({} precision)",
        field.shape(),
        ffcz::util::human_bytes(field.original_bytes()),
        field.precision().name(),
    );

    // 2. Dual-domain bounds: 0.1% spatial (relative to the value span) and
    //    0.5% frequency (relative to the largest Fourier magnitude) — a
    //    tail-clipping operating point where edits stay sparse (paper
    //    Fig. 5); tighter Δ trades edit storage for spectral accuracy.
    let cfg = FfczConfig::relative(1e-3, 5e-3);

    // 3. Compress with the SZ3-style base compressor + FFCz edits.
    let base = SzLike::default();
    let archive = ffcz::correction::compress(&field, &base, &cfg)?;
    println!(
        "archive: {} total ({} base + {} edits), ratio {:.1}",
        ffcz::util::human_bytes(archive.total_bytes()),
        ffcz::util::human_bytes(archive.base_bytes()),
        ffcz::util::human_bytes(archive.edit_bytes()),
        field.original_bytes() as f64 / archive.total_bytes() as f64,
    );
    println!(
        "POCS: {} iterations, {} spatial + {} frequency active edits",
        archive.stats.iterations, archive.stats.active_spat, archive.stats.active_freq,
    );

    // 4. Decompress and verify: both domains are now bounded.
    let recon = ffcz::correction::decompress(&archive)?;
    let report = ffcz::correction::verify(&field, &recon, &cfg);
    let quality = QualityReport::compute(&field, &recon);
    println!(
        "verify: spatial {} (ratio {:.3}), frequency {} (ratio {:.3})",
        if report.spatial_ok { "OK" } else { "FAIL" },
        report.max_spatial_ratio,
        if report.frequency_ok { "OK" } else { "FAIL" },
        report.max_frequency_ratio,
    );
    println!(
        "quality: PSNR {:.1} dB, SSNR {:.1} dB, max RFE {:.2e}",
        quality.psnr_db, quality.ssnr_db, quality.max_rfe
    );
    assert!(report.spatial_ok && report.frequency_ok);
    println!("quickstart OK");
    Ok(())
}
