//! Run the FFCz correction through the AOT-compiled JAX/Pallas artifact
//! (the PJRT "accelerator path") and cross-check it against the native
//! Rust engine on the same workload — the reproduction of the paper's
//! GPU-vs-CPU engine comparison (Table IV / Fig. 9), with PJRT playing the
//! accelerator role.
//!
//! Requires `make artifacts` first.
//!
//! ```bash
//! cargo run --release --example accelerated_correction
//! ```

use std::path::Path;
use std::time::Instant;

use ffcz::correction::{alternating_projection, check_dual_bounds, Bounds, PocsParams};
use ffcz::runtime::PjrtEngine;
use ffcz::util::XorShift;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let mut engine = match PjrtEngine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts/ not built ({e:#}); run `make artifacts` first");
            return Ok(());
        }
    };
    println!("PJRT platform: {}", engine.platform());
    println!("variants:");
    for v in engine.registry().variants() {
        println!("  {:<24} shape {:?} (≤{} iters)", v.name, v.shape, v.max_iters);
    }

    // Workload: a 4096-point error vector in the mixed POCS regime.
    let n = 4096usize;
    let (e, d) = (0.05, 1.2);
    let mut rng = XorShift::new(2024);
    let eps0: Vec<f64> = (0..n).map(|_| rng.uniform(-e, e)).collect();

    // Accelerator path (first call compiles the executable — excluded).
    let _warm = engine.correct(&eps0, &[n], e, d)?;
    let t0 = Instant::now();
    let pjrt = engine.correct(&eps0, &[n], e, d)?;
    let t_pjrt = t0.elapsed();

    // Native engine.
    let params = PocsParams {
        spatial: Bounds::Global(e),
        frequency: Bounds::Global(d),
        max_iters: 64,
    };
    let t0 = Instant::now();
    let native = alternating_projection(&eps0, &[n], &params);
    let t_native = t0.elapsed();

    println!(
        "\nPJRT artifact : {:>10}  {} iters, {}+{} edits, converged {}",
        ffcz::util::human_duration(t_pjrt),
        pjrt.iterations,
        pjrt.active_spat,
        pjrt.active_freq,
        pjrt.converged
    );
    println!(
        "native engine : {:>10}  {} iters, {}+{} edits, converged {}",
        ffcz::util::human_duration(t_native),
        native.iterations,
        native.active_spat,
        native.active_freq,
        native.converged
    );

    // Cross-check: both engines end inside the dual bounds, and their
    // corrected vectors agree to f32 precision.
    let mut max_dev = 0.0f64;
    for (a, b) in pjrt.corrected_eps.iter().zip(&native.corrected_eps) {
        max_dev = max_dev.max((a - b).abs());
    }
    let (s_ok, f_ok, ..) = check_dual_bounds(
        &pjrt.corrected_eps,
        &[n],
        &Bounds::Global(e * (1.0 + 1e-3)),
        &Bounds::Global(d * (1.0 + 1e-3)),
    );
    println!("engines agree to {max_dev:.2e} (f32 artifact vs f64 native)");
    println!("dual bounds (PJRT result): spatial {s_ok}, frequency {f_ok}");
    anyhow::ensure!(pjrt.converged && native.converged && s_ok && f_ok && max_dev < 5e-4);
    println!("accelerated_correction OK");
    Ok(())
}
