//! Streaming 1-D medical time-series scenario: chunk a long EEG-like
//! recording into windows, shard them through the coordinator, and show
//! that FFCz preserves the clinically-relevant band powers (delta / theta /
//! alpha / beta) that plain error-bounded compression distorts.
//!
//! ```bash
//! cargo run --release --example eeg_stream
//! ```

use ffcz::compressors::{szlike::SzLike, Compressor, ErrorBound};
use ffcz::coordinator::{run_pipeline, PipelineConfig};
use ffcz::correction::{decompress, FfczConfig};
use ffcz::data::synth::eeg::EegBuilder;
use ffcz::data::Field;
use ffcz::fourier::power_spectrum;

const SAMPLE_RATE: f64 = 250.0;
const BANDS: [(&str, f64, f64); 4] = [
    ("delta", 0.5, 4.0),
    ("theta", 4.0, 8.0),
    ("alpha", 8.0, 13.0),
    ("beta", 13.0, 30.0),
];

fn band_powers(field: &Field) -> Vec<f64> {
    let n = field.len();
    let ps = power_spectrum(field);
    let hz = |k: usize| k as f64 * SAMPLE_RATE / n as f64;
    BANDS
        .iter()
        .map(|&(_, lo, hi)| {
            (1..ps.len())
                .filter(|&k| hz(k) >= lo && hz(k) < hi)
                .map(|k| ps.power[k])
                .sum()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    // A 2-minute recording at 250 Hz, processed in 8 windows.
    let recording = EegBuilder::new(30_720).sample_rate(SAMPLE_RATE).seed(7).build();
    let windows = ffcz::coordinator::shard_field(&recording, 8);
    println!(
        "EEG recording: {} samples ({:.1} s), {} windows",
        recording.len(),
        recording.len() as f64 / SAMPLE_RATE,
        windows.len()
    );

    let instances: Vec<_> = windows
        .iter()
        .enumerate()
        .map(|(i, w)| (format!("win{i}"), w.clone()))
        .collect();
    let cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-4));
    let base = SzLike::default();
    let report = run_pipeline(instances, &base, &cfg)?;

    println!("\n-- per-window band-power distortion (% error vs original) --");
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10}   method",
        "window", "delta", "theta", "alpha", "beta"
    );
    let mut worst_ffcz = 0.0f64;
    let mut worst_base = 0.0f64;
    for (i, ((_, archive), window)) in report.archives.iter().zip(&windows).enumerate() {
        let truth = band_powers(window);
        // Base compressor alone, same spatial bound.
        let payload = base.compress(window, ErrorBound::Relative(1e-3))?;
        let recon_base = base.decompress(&payload)?;
        let bp_base = band_powers(&recon_base);
        // FFCz-corrected.
        let recon_ffcz = decompress(archive)?;
        let bp_ffcz = band_powers(&recon_ffcz);
        let perc = |bp: &[f64]| -> Vec<f64> {
            bp.iter()
                .zip(&truth)
                .map(|(a, t)| 100.0 * (a - t).abs() / t.max(1e-30))
                .collect()
        };
        let pb = perc(&bp_base);
        let pf = perc(&bp_ffcz);
        worst_base = worst_base.max(pb.iter().copied().fold(0.0, f64::max));
        worst_ffcz = worst_ffcz.max(pf.iter().copied().fold(0.0, f64::max));
        println!(
            "win{i:<5} {:>9.4}% {:>9.4}% {:>9.4}% {:>9.4}%   sz-like",
            pb[0], pb[1], pb[2], pb[3]
        );
        println!(
            "{:<8} {:>9.4}% {:>9.4}% {:>9.4}% {:>9.4}%   sz-like+FFCz",
            "", pf[0], pf[1], pf[2], pf[3]
        );
    }
    println!(
        "\nworst band-power error: base {worst_base:.4}% vs FFCz {worst_ffcz:.4}%"
    );
    println!("pipeline makespan: {:.1} ms", report.makespan.as_secs_f64() * 1e3);
    anyhow::ensure!(
        worst_ffcz <= worst_base,
        "FFCz must not distort bands more than the base"
    );
    println!("eeg_stream OK");
    Ok(())
}
