//! Write-path fault injection and crash-consistency tests.
//!
//! The central proof obligation of the crash-consistent write path: an
//! archive write killed at *any* operation boundary must leave the
//! final path untouched, and `resume_store_write` must salvage the
//! staging files and complete the archive **bit-identically** to an
//! uninterrupted write — with zero panics anywhere on the way.
//!
//! Three families:
//!
//! 1. **Crash-point sweep** — kill the staged write at every injectable
//!    operation (head magic, each chunk payload, manifest, trailer),
//!    then salvage + resume and byte-compare against the clean archive.
//!    A second sweep arms `short_writes` so failures also land at
//!    *intra-payload* byte boundaries.
//! 2. **Replay determinism** — the same seeded write-fault plan over
//!    the same encode produces identical fault tallies, identical
//!    healed-retry counts, and identical committed bytes on every run.
//! 3. **Atomic-commit properties** — a failure mid-manifest (the
//!    simulated ENOSPC) never leaves a file under the final name, a
//!    *clean* error removes the staging pair entirely, and transient
//!    write faults heal invisibly under `RetryPolicy`.
//!
//! Set `FFCZ_CRASH_SWEEP=quick` to sample every third crash point (the
//! CI chaos step does); the default sweeps all of them.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::time::Duration;

use ffcz::codec::CodecChainSpec;
use ffcz::correction::FfczConfig;
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::Field;
use ffcz::encoding::varint;
use ffcz::store::manifest::JOURNAL_MAGIC;
use ffcz::store::{
    resume_store_write, staging_paths, write_store, write_store_faulted, FaultPlan, MemStorage,
    RetryPolicy, Store, StoreWriteOptions,
};

fn grf(shape: &[usize], seed: u64) -> Field {
    GrfBuilder::new(shape)
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(seed)
        .build()
}

fn temp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ffcz_wfault_{name}_{}.ffcz", std::process::id()))
}

fn remove_with_staging(path: &PathBuf) {
    let (tmp, jrn) = staging_paths(path);
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(tmp);
    let _ = std::fs::remove_file(jrn);
}

/// A mixed-chain fixture: lossless default with one FFCz-corrected
/// override chunk, so salvage also has to preserve per-chunk chain
/// assignment to stay byte-identical.
fn fixture() -> (Field, CodecChainSpec, StoreWriteOptions) {
    let field = grf(&[16, 14], 77);
    let chain = CodecChainSpec::lossless();
    let opts = StoreWriteOptions::new(&[5, 6]).workers(1).override_chunk(
        "c/1/1",
        CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)),
    );
    (field, chain, opts)
}

fn sweep_step() -> u64 {
    match std::env::var("FFCZ_CRASH_SWEEP") {
        Ok(v) if v == "quick" => 3,
        _ => 1,
    }
}

/// Run one crash/salvage/resume cycle: kill the write with `plan`,
/// assert the final path stayed untouched, resume, and byte-compare.
/// Returns (salvaged, reencoded).
fn crash_and_recover(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &PathBuf,
    plan: FaultPlan,
    want: &[u8],
    label: &str,
) -> (usize, usize) {
    remove_with_staging(path);
    let err = write_store_faulted(field, chain, opts, path, plan).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected storage failure"), "{label}: {msg}");
    assert!(
        !path.exists(),
        "{label}: a failed write left a file under the final name"
    );
    let (tmp, jrn) = staging_paths(path);
    assert!(tmp.exists(), "{label}: simulated crash kept no staging file");

    let report = resume_store_write(field, chain, opts, path).expect(label);
    let got = std::fs::read(path).expect(label);
    assert_eq!(
        got, want,
        "{label}: resumed archive differs from the uninterrupted write"
    );
    assert!(
        !tmp.exists() && !jrn.exists(),
        "{label}: commit left staging files behind"
    );
    assert_eq!(
        report.salvaged_chunks + report.reencoded_chunks,
        report.write.chunk_count,
        "{label}: salvage accounting does not cover the archive"
    );
    // The recovered archive must verify end to end, not just byte-match.
    let verify = Store::open(path).expect(label).verify(1).expect(label);
    assert!(verify.ok(), "{label}: {}", verify.to_json());
    remove_with_staging(path);
    (report.salvaged_chunks, report.reencoded_chunks)
}

/// Proof obligation: kill the write at every operation boundary — head
/// magic, every payload, manifest, trailer — and salvage + resume to a
/// bit-identical archive. Zero panics.
#[test]
fn crash_point_sweep_resumes_bit_identically() {
    let (field, chain, opts) = fixture();
    let path = temp_path("sweep");

    // The uninterrupted reference bytes.
    let clean_path = temp_path("sweep_ref");
    remove_with_staging(&clean_path);
    let clean_report = write_store(&field, &chain, &opts, &clean_path).unwrap();
    assert!(clean_report.all_chunks_ok);
    let want = std::fs::read(&clean_path).unwrap();

    // A fault-free probe run through the injector learns the op count
    // (and proves the injector itself is transparent).
    remove_with_staging(&path);
    let (_, probe) = write_store_faulted(&field, &chain, &opts, &path, FaultPlan::none()).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), want, "probe diverged");
    assert!(probe.ops >= clean_report.chunk_count as u64 + 3);

    let mut salvaged_total = 0usize;
    let mut k = 1u64;
    while k <= probe.ops {
        let plan = FaultPlan {
            fail_ops: vec![k],
            ..FaultPlan::none()
        };
        let (salvaged, _) = crash_and_recover(
            &field,
            &chain,
            &opts,
            &path,
            plan,
            &want,
            &format!("fail at op {k}/{}", probe.ops),
        );
        salvaged_total += salvaged;
        k += sweep_step();
    }
    // Failing the last ops (manifest/trailer) must salvage every chunk;
    // failing the first must salvage none. In between, monotone growth
    // means the sweep genuinely exercised partial prefixes.
    assert!(
        salvaged_total > 0,
        "no crash point ever salvaged a chunk — the sweep is vacuous"
    );
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// Same sweep with `short_writes` armed: payload writes split at seeded
/// byte boundaries, so the kill lands *inside* chunk payloads and the
/// salvage has to discard torn partial chunks via the CRC.
#[test]
fn crash_point_sweep_with_short_writes_resumes_bit_identically() {
    let (field, chain, opts) = fixture();
    let path = temp_path("short_sweep");

    let clean_path = temp_path("short_sweep_ref");
    remove_with_staging(&clean_path);
    write_store(&field, &chain, &opts, &clean_path).unwrap();
    let want = std::fs::read(&clean_path).unwrap();

    let short_plan = |fail: Vec<u64>| FaultPlan {
        seed: 1234,
        short_writes: true,
        fail_ops: fail,
        ..FaultPlan::none()
    };
    remove_with_staging(&path);
    let (_, probe) = write_store_faulted(&field, &chain, &opts, &path, short_plan(vec![])).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), want, "short-write probe diverged");
    assert!(
        probe.short_writes > 0,
        "the seeded schedule never split a write"
    );

    // Short writes multiply the op count; sample at twice the base step
    // to keep the sweep brisk while still landing mid-payload.
    let mut k = 1u64;
    while k <= probe.ops {
        crash_and_recover(
            &field,
            &chain,
            &opts,
            &path,
            short_plan(vec![k]),
            &want,
            &format!("short-write fail at op {k}/{}", probe.ops),
        );
        k += sweep_step() * 2;
    }
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// Seeded write-fault replay determinism: the same plan over the same
/// encode yields identical fault tallies, identical healed-retry
/// counts, and identical committed bytes, run after run.
#[test]
fn seeded_write_fault_schedules_replay_identically() {
    let (field, chain, base_opts) = fixture();
    let opts = base_opts.retry_policy(RetryPolicy::transient(3, Duration::ZERO));
    let path = temp_path("replay");
    let run = || {
        remove_with_staging(&path);
        let plan = FaultPlan {
            seed: 42,
            short_writes: true,
            transient_every: 3,
            ..FaultPlan::none()
        };
        let (report, counts) = write_store_faulted(&field, &chain, &opts, &path, plan).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        (bytes, counts, report.write_retries)
    };
    let (bytes_a, counts_a, retries_a) = run();
    let (bytes_b, counts_b, retries_b) = run();
    assert_eq!(bytes_a, bytes_b, "committed bytes diverged across replays");
    assert_eq!(counts_a, counts_b, "fault tallies diverged across replays");
    assert_eq!(retries_a, retries_b);
    assert!(counts_a.transients > 0, "the schedule never faulted");
    assert_eq!(
        retries_a, counts_a.transients,
        "every transient write fault must cost exactly one healed retry"
    );

    // And the healed archive is the clean archive, byte for byte.
    let clean_path = temp_path("replay_ref");
    remove_with_staging(&clean_path);
    write_store(&field, &chain, &opts, &clean_path).unwrap();
    assert_eq!(bytes_a, std::fs::read(&clean_path).unwrap());
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// The simulated ENOSPC mid-manifest: the staged write fails *after*
/// every payload but before the commit record. Nothing may appear under
/// the final name, every chunk must salvage, and the resume re-encodes
/// nothing yet still commits bit-identically.
#[test]
fn enospc_mid_manifest_never_leaves_a_partial_archive() {
    let (field, chain, opts) = fixture();
    let path = temp_path("enospc");

    let clean_path = temp_path("enospc_ref");
    remove_with_staging(&clean_path);
    let clean_report = write_store(&field, &chain, &opts, &clean_path).unwrap();
    let want = std::fs::read(&clean_path).unwrap();

    remove_with_staging(&path);
    let (_, probe) = write_store_faulted(&field, &chain, &opts, &path, FaultPlan::none()).unwrap();
    // Ops: head magic, one per chunk payload, manifest, trailer — the
    // manifest write is op `ops - 1`.
    let manifest_op = probe.ops - 1;
    let (salvaged, reencoded) = crash_and_recover(
        &field,
        &chain,
        &opts,
        &path,
        FaultPlan {
            fail_ops: vec![manifest_op],
            ..FaultPlan::none()
        },
        &want,
        "ENOSPC mid-manifest",
    );
    assert_eq!(
        salvaged, clean_report.chunk_count,
        "every payload was durable before the manifest failed"
    );
    assert_eq!(reencoded, 0, "nothing should be re-encoded after the payloads");
    remove_with_staging(&clean_path);
}

/// A *clean* error (not a crash) on the atomic-commit path removes the
/// staging pair: misconfiguration never strands `.tmp`/`.tmp.jrn` files.
#[test]
fn clean_write_errors_remove_the_staging_pair() {
    let (field, chain, _) = fixture();
    let path = temp_path("clean_err");
    remove_with_staging(&path);
    // An override naming a chunk outside the grid fails after the
    // staging files are created.
    let bad = StoreWriteOptions::new(&[5, 6])
        .workers(1)
        .override_chunk("c/9/9", CodecChainSpec::lossless());
    let err = write_store(&field, &chain, &bad, &path).unwrap_err();
    assert!(format!("{err:#}").contains("c/9/9"));
    let (tmp, jrn) = staging_paths(&path);
    assert!(!path.exists() && !tmp.exists() && !jrn.exists());
}

/// Transient write faults heal invisibly under the writer's
/// `RetryPolicy` and are reported per write; without a policy the same
/// schedule is a hard, clean error.
#[test]
fn transient_write_faults_heal_under_retry_policy() {
    let (field, chain, base_opts) = fixture();
    let path = temp_path("transient");

    let clean_path = temp_path("transient_ref");
    remove_with_staging(&clean_path);
    write_store(&field, &chain, &base_opts, &clean_path).unwrap();
    let want = std::fs::read(&clean_path).unwrap();

    let plan = FaultPlan {
        transient_every: 2,
        ..FaultPlan::none()
    };
    let before = ffcz::telemetry::snapshot();

    // With a policy: heals, commits, bit-identical, retries surfaced in
    // the report and the `store.write.retries` counter.
    remove_with_staging(&path);
    let opts = base_opts.clone().retry_policy(RetryPolicy::transient(3, Duration::ZERO));
    let (report, counts) = write_store_faulted(&field, &chain, &opts, &path, plan.clone()).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), want);
    assert!(counts.transients > 0);
    assert_eq!(report.write_retries, counts.transients);
    let after = ffcz::telemetry::snapshot();
    assert!(
        after.counter_delta(&before, "store.write.retries") >= counts.transients,
        "registry must aggregate healed write retries"
    );
    assert!(
        after.counter_delta(&before, "store.write.commits") >= 1,
        "a committed write must count a commit"
    );

    // Without a policy the first transient is a hard error; the final
    // name stays untouched (the chaos variant keeps the staging pair
    // for salvage, unlike `write_store`'s clean-error cleanup).
    let fresh = temp_path("transient_nopolicy");
    remove_with_staging(&fresh);
    let err = write_store_faulted(&field, &chain, &base_opts, &fresh, plan).unwrap_err();
    assert!(format!("{err:#}").contains("injected transient storage fault"));
    assert!(!fresh.exists());
    remove_with_staging(&fresh);
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// Stage an interrupted write that completed every chunk payload (the
/// simulated ENOSPC lands on the manifest write), leaving `<path>.tmp` +
/// `<path>.tmp.jrn` with one journal record per chunk. Returns the
/// uninterrupted reference bytes.
fn stage_full_payload_crash(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &PathBuf,
    clean_path: &PathBuf,
) -> Vec<u8> {
    remove_with_staging(clean_path);
    write_store(field, chain, opts, clean_path).unwrap();
    let want = std::fs::read(clean_path).unwrap();

    remove_with_staging(path);
    let (_, probe) = write_store_faulted(field, chain, opts, path, FaultPlan::none()).unwrap();
    remove_with_staging(path);
    // Ops: head magic, one per chunk payload, manifest, trailer.
    let plan = FaultPlan {
        fail_ops: vec![probe.ops - 1],
        ..FaultPlan::none()
    };
    write_store_faulted(field, chain, opts, path, plan).unwrap_err();
    want
}

/// Byte spans of the journal's records (past the head magic), walked
/// through the documented framing: LEB128 body length, body, CRC-32.
fn journal_record_spans(journal: &[u8]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut pos = JOURNAL_MAGIC.len();
    while pos < journal.len() {
        let start = pos;
        let body_len = varint::read(journal, &mut pos).expect("record length varint") as usize;
        pos += body_len + 4;
        assert!(pos <= journal.len(), "journal record overruns the file");
        spans.push((start, pos));
    }
    spans
}

/// An *interior* corrupted journal record — damage past the prefix, with
/// intact records after it — must stop the salvaged prefix exactly at the
/// damaged record. Salvage never resynchronises on later records: the
/// contiguous-prefix rule is what keeps a resumed write bit-identical.
#[test]
fn salvage_stops_at_an_interior_corrupted_journal_record() {
    let (field, chain, opts) = fixture();
    let path = temp_path("interior");
    let clean_path = temp_path("interior_ref");
    let want = stage_full_payload_crash(&field, &chain, &opts, &path, &clean_path);

    let (tmp, jrn) = staging_paths(&path);
    let container = std::fs::read(&tmp).unwrap();
    let journal = std::fs::read(&jrn).unwrap();
    let spans = journal_record_spans(&journal);
    assert!(spans.len() >= 4, "fixture must journal several chunks");

    // Control: the intact journal salvages every chunk.
    let s = Store::salvage(&MemStorage::new(container.clone()), &journal).unwrap();
    assert_eq!(s.chunks(), spans.len());

    // Flip one byte in the middle of record 2. Records 0 and 1 survive;
    // records 3.. are intact but unreachable past the damage.
    let mut corrupt = journal.clone();
    let (start, end) = spans[2];
    corrupt[(start + end) / 2] ^= 0x01;
    let s = Store::salvage(&MemStorage::new(container), &corrupt).unwrap();
    assert_eq!(
        s.chunks(),
        2,
        "salvage must stop at the damaged interior record, not resync"
    );

    // End to end: resume over the damaged journal re-encodes everything
    // past the prefix and still commits bit-identically.
    std::fs::write(&jrn, &corrupt).unwrap();
    let report = resume_store_write(&field, &chain, &opts, &path).unwrap();
    assert_eq!(report.salvaged_chunks, 2);
    assert_eq!(report.reencoded_chunks, spans.len() - 2);
    assert_eq!(std::fs::read(&path).unwrap(), want);
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// A duplicated chunk record — byte-identical, framing CRC valid — must
/// break the prefix at the duplicate: its index does not continue the
/// contiguous run, and accepting it would double-count a payload.
#[test]
fn salvage_rejects_duplicate_chunk_records() {
    let (field, chain, opts) = fixture();
    let path = temp_path("duprec");
    let clean_path = temp_path("duprec_ref");
    let want = stage_full_payload_crash(&field, &chain, &opts, &path, &clean_path);

    let (tmp, jrn) = staging_paths(&path);
    let container = std::fs::read(&tmp).unwrap();
    let journal = std::fs::read(&jrn).unwrap();
    let spans = journal_record_spans(&journal);
    assert!(spans.len() >= 3, "fixture must journal several chunks");

    // Replay record 1 between records 1 and 2 — the shape a re-appended
    // or doubly-flushed journal tail would take.
    let (r1_start, r1_end) = spans[1];
    let mut duped = journal[..r1_end].to_vec();
    duped.extend_from_slice(&journal[r1_start..r1_end]);
    duped.extend_from_slice(&journal[r1_end..]);

    let s = Store::salvage(&MemStorage::new(container), &duped).unwrap();
    assert_eq!(
        s.chunks(),
        2,
        "a duplicate record must end the salvageable prefix"
    );

    // Resume truncates the journal at the end of the kept prefix (the
    // duplicate goes with it) and still commits bit-identically.
    std::fs::write(&jrn, &duped).unwrap();
    let report = resume_store_write(&field, &chain, &opts, &path).unwrap();
    assert_eq!(report.salvaged_chunks, 2);
    assert_eq!(report.reencoded_chunks, spans.len() - 2);
    assert_eq!(std::fs::read(&path).unwrap(), want);
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// Collect the JSON object keys `ffcz archive verify --json` emits:
/// top-level keys (object depth 1) and per-failure row keys (depth 3,
/// inside the `failures` array). A tiny scanner, not a JSON parser —
/// enough to pin the schema without trusting the producer's formatting.
fn json_keys(json: &str) -> (BTreeSet<String>, BTreeSet<String>) {
    let chars: Vec<char> = json.chars().collect();
    let (mut top, mut row) = (BTreeSet::new(), BTreeSet::new());
    let mut depth = 0usize;
    let mut i = 0;
    while i < chars.len() {
        match chars[i] {
            '{' | '[' => depth += 1,
            '}' | ']' => depth -= 1,
            '"' => {
                let start = i + 1;
                let mut j = start;
                while chars[j] != '"' {
                    j += if chars[j] == '\\' { 2 } else { 1 };
                }
                let mut k = j + 1;
                while k < chars.len() && chars[k].is_whitespace() {
                    k += 1;
                }
                if k < chars.len() && chars[k] == ':' {
                    let key: String = chars[start..j].iter().collect();
                    match depth {
                        1 => {
                            top.insert(key);
                        }
                        3 => {
                            row.insert(key);
                        }
                        _ => {}
                    }
                }
                i = j;
            }
            _ => {}
        }
        i += 1;
    }
    (top, row)
}

/// The `archive verify --json` schema is normative in `docs/STORAGE.md`:
/// the emitted keys must match the documented table exactly, in both
/// directions — a key added to the code without a doc row (or vice
/// versa) fails here.
#[test]
fn verify_json_schema_matches_docs_storage_md() {
    let doc_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/STORAGE.md");
    let doc = std::fs::read_to_string(doc_path).expect("docs/STORAGE.md is part of the repository");
    let (mut doc_top, mut doc_row) = (BTreeSet::new(), BTreeSet::new());
    // Only the schema section's table rows count — the document has other
    // tables (backend matrix, metric glossary) with backticked cells.
    let section = doc
        .lines()
        .skip_while(|l| !(l.starts_with('#') && l.contains("verify --json")))
        .skip(1)
        .take_while(|l| !l.starts_with('#'));
    for line in section {
        let Some(rest) = line.trim().strip_prefix("| `") else {
            continue;
        };
        let Some((key, _)) = rest.split_once('`') else {
            continue;
        };
        if let Some(field) = key.strip_prefix("failures[].") {
            doc_row.insert(field.to_string());
        } else {
            doc_top.insert(key.to_string());
        }
    }
    assert!(
        !doc_top.is_empty() && !doc_row.is_empty(),
        "docs/STORAGE.md must document the verify --json schema"
    );

    // An archive with one corrupted payload: the report carries both the
    // summary keys and at least one failure row.
    let (field, chain, opts) = fixture();
    let path = temp_path("json_schema");
    remove_with_staging(&path);
    write_store(&field, &chain, &opts, &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[8] ^= 0xFF; // first payload byte, past the head magic
    let report = Store::from_bytes(bytes).unwrap().verify(1).unwrap();
    assert!(report.failed() >= 1, "the corrupted chunk must fail verify");

    let (top, row) = json_keys(&report.to_json());
    assert_eq!(top, doc_top, "top-level verify --json keys drifted from docs");
    assert_eq!(row, doc_row, "failure-row verify --json keys drifted from docs");
    remove_with_staging(&path);
}
