//! Write-path fault injection and crash-consistency tests.
//!
//! The central proof obligation of the crash-consistent write path: an
//! archive write killed at *any* operation boundary must leave the
//! final path untouched, and `resume_store_write` must salvage the
//! staging files and complete the archive **bit-identically** to an
//! uninterrupted write — with zero panics anywhere on the way.
//!
//! Three families:
//!
//! 1. **Crash-point sweep** — kill the staged write at every injectable
//!    operation (head magic, each chunk payload, manifest, trailer),
//!    then salvage + resume and byte-compare against the clean archive.
//!    A second sweep arms `short_writes` so failures also land at
//!    *intra-payload* byte boundaries.
//! 2. **Replay determinism** — the same seeded write-fault plan over
//!    the same encode produces identical fault tallies, identical
//!    healed-retry counts, and identical committed bytes on every run.
//! 3. **Atomic-commit properties** — a failure mid-manifest (the
//!    simulated ENOSPC) never leaves a file under the final name, a
//!    *clean* error removes the staging pair entirely, and transient
//!    write faults heal invisibly under `RetryPolicy`.
//!
//! Set `FFCZ_CRASH_SWEEP=quick` to sample every third crash point (the
//! CI chaos step does); the default sweeps all of them.

use std::path::PathBuf;
use std::time::Duration;

use ffcz::codec::CodecChainSpec;
use ffcz::correction::FfczConfig;
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::Field;
use ffcz::store::{
    resume_store_write, staging_paths, write_store, write_store_faulted, FaultPlan, RetryPolicy,
    Store, StoreWriteOptions,
};

fn grf(shape: &[usize], seed: u64) -> Field {
    GrfBuilder::new(shape)
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(seed)
        .build()
}

fn temp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ffcz_wfault_{name}_{}.ffcz", std::process::id()))
}

fn remove_with_staging(path: &PathBuf) {
    let (tmp, jrn) = staging_paths(path);
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(tmp);
    let _ = std::fs::remove_file(jrn);
}

/// A mixed-chain fixture: lossless default with one FFCz-corrected
/// override chunk, so salvage also has to preserve per-chunk chain
/// assignment to stay byte-identical.
fn fixture() -> (Field, CodecChainSpec, StoreWriteOptions) {
    let field = grf(&[16, 14], 77);
    let chain = CodecChainSpec::lossless();
    let opts = StoreWriteOptions::new(&[5, 6]).workers(1).override_chunk(
        "c/1/1",
        CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)),
    );
    (field, chain, opts)
}

fn sweep_step() -> u64 {
    match std::env::var("FFCZ_CRASH_SWEEP") {
        Ok(v) if v == "quick" => 3,
        _ => 1,
    }
}

/// Run one crash/salvage/resume cycle: kill the write with `plan`,
/// assert the final path stayed untouched, resume, and byte-compare.
/// Returns (salvaged, reencoded).
fn crash_and_recover(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &PathBuf,
    plan: FaultPlan,
    want: &[u8],
    label: &str,
) -> (usize, usize) {
    remove_with_staging(path);
    let err = write_store_faulted(field, chain, opts, path, plan).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected storage failure"), "{label}: {msg}");
    assert!(
        !path.exists(),
        "{label}: a failed write left a file under the final name"
    );
    let (tmp, jrn) = staging_paths(path);
    assert!(tmp.exists(), "{label}: simulated crash kept no staging file");

    let report = resume_store_write(field, chain, opts, path).expect(label);
    let got = std::fs::read(path).expect(label);
    assert_eq!(
        got, want,
        "{label}: resumed archive differs from the uninterrupted write"
    );
    assert!(
        !tmp.exists() && !jrn.exists(),
        "{label}: commit left staging files behind"
    );
    assert_eq!(
        report.salvaged_chunks + report.reencoded_chunks,
        report.write.chunk_count,
        "{label}: salvage accounting does not cover the archive"
    );
    // The recovered archive must verify end to end, not just byte-match.
    let verify = Store::open(path).expect(label).verify(1).expect(label);
    assert!(verify.ok(), "{label}: {}", verify.to_json());
    remove_with_staging(path);
    (report.salvaged_chunks, report.reencoded_chunks)
}

/// Proof obligation: kill the write at every operation boundary — head
/// magic, every payload, manifest, trailer — and salvage + resume to a
/// bit-identical archive. Zero panics.
#[test]
fn crash_point_sweep_resumes_bit_identically() {
    let (field, chain, opts) = fixture();
    let path = temp_path("sweep");

    // The uninterrupted reference bytes.
    let clean_path = temp_path("sweep_ref");
    remove_with_staging(&clean_path);
    let clean_report = write_store(&field, &chain, &opts, &clean_path).unwrap();
    assert!(clean_report.all_chunks_ok);
    let want = std::fs::read(&clean_path).unwrap();

    // A fault-free probe run through the injector learns the op count
    // (and proves the injector itself is transparent).
    remove_with_staging(&path);
    let (_, probe) = write_store_faulted(&field, &chain, &opts, &path, FaultPlan::none()).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), want, "probe diverged");
    assert!(probe.ops >= clean_report.chunk_count as u64 + 3);

    let mut salvaged_total = 0usize;
    let mut k = 1u64;
    while k <= probe.ops {
        let plan = FaultPlan {
            fail_ops: vec![k],
            ..FaultPlan::none()
        };
        let (salvaged, _) = crash_and_recover(
            &field,
            &chain,
            &opts,
            &path,
            plan,
            &want,
            &format!("fail at op {k}/{}", probe.ops),
        );
        salvaged_total += salvaged;
        k += sweep_step();
    }
    // Failing the last ops (manifest/trailer) must salvage every chunk;
    // failing the first must salvage none. In between, monotone growth
    // means the sweep genuinely exercised partial prefixes.
    assert!(
        salvaged_total > 0,
        "no crash point ever salvaged a chunk — the sweep is vacuous"
    );
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// Same sweep with `short_writes` armed: payload writes split at seeded
/// byte boundaries, so the kill lands *inside* chunk payloads and the
/// salvage has to discard torn partial chunks via the CRC.
#[test]
fn crash_point_sweep_with_short_writes_resumes_bit_identically() {
    let (field, chain, opts) = fixture();
    let path = temp_path("short_sweep");

    let clean_path = temp_path("short_sweep_ref");
    remove_with_staging(&clean_path);
    write_store(&field, &chain, &opts, &clean_path).unwrap();
    let want = std::fs::read(&clean_path).unwrap();

    let short_plan = |fail: Vec<u64>| FaultPlan {
        seed: 1234,
        short_writes: true,
        fail_ops: fail,
        ..FaultPlan::none()
    };
    remove_with_staging(&path);
    let (_, probe) = write_store_faulted(&field, &chain, &opts, &path, short_plan(vec![])).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), want, "short-write probe diverged");
    assert!(
        probe.short_writes > 0,
        "the seeded schedule never split a write"
    );

    // Short writes multiply the op count; sample at twice the base step
    // to keep the sweep brisk while still landing mid-payload.
    let mut k = 1u64;
    while k <= probe.ops {
        crash_and_recover(
            &field,
            &chain,
            &opts,
            &path,
            short_plan(vec![k]),
            &want,
            &format!("short-write fail at op {k}/{}", probe.ops),
        );
        k += sweep_step() * 2;
    }
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// Seeded write-fault replay determinism: the same plan over the same
/// encode yields identical fault tallies, identical healed-retry
/// counts, and identical committed bytes, run after run.
#[test]
fn seeded_write_fault_schedules_replay_identically() {
    let (field, chain, base_opts) = fixture();
    let opts = base_opts.retry_policy(RetryPolicy::transient(3, Duration::ZERO));
    let path = temp_path("replay");
    let run = || {
        remove_with_staging(&path);
        let plan = FaultPlan {
            seed: 42,
            short_writes: true,
            transient_every: 3,
            ..FaultPlan::none()
        };
        let (report, counts) = write_store_faulted(&field, &chain, &opts, &path, plan).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        (bytes, counts, report.write_retries)
    };
    let (bytes_a, counts_a, retries_a) = run();
    let (bytes_b, counts_b, retries_b) = run();
    assert_eq!(bytes_a, bytes_b, "committed bytes diverged across replays");
    assert_eq!(counts_a, counts_b, "fault tallies diverged across replays");
    assert_eq!(retries_a, retries_b);
    assert!(counts_a.transients > 0, "the schedule never faulted");
    assert_eq!(
        retries_a, counts_a.transients,
        "every transient write fault must cost exactly one healed retry"
    );

    // And the healed archive is the clean archive, byte for byte.
    let clean_path = temp_path("replay_ref");
    remove_with_staging(&clean_path);
    write_store(&field, &chain, &opts, &clean_path).unwrap();
    assert_eq!(bytes_a, std::fs::read(&clean_path).unwrap());
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}

/// The simulated ENOSPC mid-manifest: the staged write fails *after*
/// every payload but before the commit record. Nothing may appear under
/// the final name, every chunk must salvage, and the resume re-encodes
/// nothing yet still commits bit-identically.
#[test]
fn enospc_mid_manifest_never_leaves_a_partial_archive() {
    let (field, chain, opts) = fixture();
    let path = temp_path("enospc");

    let clean_path = temp_path("enospc_ref");
    remove_with_staging(&clean_path);
    let clean_report = write_store(&field, &chain, &opts, &clean_path).unwrap();
    let want = std::fs::read(&clean_path).unwrap();

    remove_with_staging(&path);
    let (_, probe) = write_store_faulted(&field, &chain, &opts, &path, FaultPlan::none()).unwrap();
    // Ops: head magic, one per chunk payload, manifest, trailer — the
    // manifest write is op `ops - 1`.
    let manifest_op = probe.ops - 1;
    let (salvaged, reencoded) = crash_and_recover(
        &field,
        &chain,
        &opts,
        &path,
        FaultPlan {
            fail_ops: vec![manifest_op],
            ..FaultPlan::none()
        },
        &want,
        "ENOSPC mid-manifest",
    );
    assert_eq!(
        salvaged, clean_report.chunk_count,
        "every payload was durable before the manifest failed"
    );
    assert_eq!(reencoded, 0, "nothing should be re-encoded after the payloads");
    remove_with_staging(&clean_path);
}

/// A *clean* error (not a crash) on the atomic-commit path removes the
/// staging pair: misconfiguration never strands `.tmp`/`.tmp.jrn` files.
#[test]
fn clean_write_errors_remove_the_staging_pair() {
    let (field, chain, _) = fixture();
    let path = temp_path("clean_err");
    remove_with_staging(&path);
    // An override naming a chunk outside the grid fails after the
    // staging files are created.
    let bad = StoreWriteOptions::new(&[5, 6])
        .workers(1)
        .override_chunk("c/9/9", CodecChainSpec::lossless());
    let err = write_store(&field, &chain, &bad, &path).unwrap_err();
    assert!(format!("{err:#}").contains("c/9/9"));
    let (tmp, jrn) = staging_paths(&path);
    assert!(!path.exists() && !tmp.exists() && !jrn.exists());
}

/// Transient write faults heal invisibly under the writer's
/// `RetryPolicy` and are reported per write; without a policy the same
/// schedule is a hard, clean error.
#[test]
fn transient_write_faults_heal_under_retry_policy() {
    let (field, chain, base_opts) = fixture();
    let path = temp_path("transient");

    let clean_path = temp_path("transient_ref");
    remove_with_staging(&clean_path);
    write_store(&field, &chain, &base_opts, &clean_path).unwrap();
    let want = std::fs::read(&clean_path).unwrap();

    let plan = FaultPlan {
        transient_every: 2,
        ..FaultPlan::none()
    };
    let before = ffcz::telemetry::snapshot();

    // With a policy: heals, commits, bit-identical, retries surfaced in
    // the report and the `store.write.retries` counter.
    remove_with_staging(&path);
    let opts = base_opts.clone().retry_policy(RetryPolicy::transient(3, Duration::ZERO));
    let (report, counts) = write_store_faulted(&field, &chain, &opts, &path, plan.clone()).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), want);
    assert!(counts.transients > 0);
    assert_eq!(report.write_retries, counts.transients);
    let after = ffcz::telemetry::snapshot();
    assert!(
        after.counter_delta(&before, "store.write.retries") >= counts.transients,
        "registry must aggregate healed write retries"
    );
    assert!(
        after.counter_delta(&before, "store.write.commits") >= 1,
        "a committed write must count a commit"
    );

    // Without a policy the first transient is a hard error; the final
    // name stays untouched (the chaos variant keeps the staging pair
    // for salvage, unlike `write_store`'s clean-error cleanup).
    let fresh = temp_path("transient_nopolicy");
    remove_with_staging(&fresh);
    let err = write_store_faulted(&field, &chain, &base_opts, &fresh, plan).unwrap_err();
    assert!(format!("{err:#}").contains("injected transient storage fault"));
    assert!(!fresh.exists());
    remove_with_staging(&fresh);
    remove_with_staging(&clean_path);
    remove_with_staging(&path);
}
