//! Integration tests for the telemetry subsystem: snapshot JSON
//! round-trip, trace-event validity on a real store write, and global
//! counter correctness on a known 8-chunk encode.
//!
//! Telemetry state (the metrics registry and the trace collector) is
//! process-global; every test here serializes on one lock and — where it
//! drains spans — clears leftovers first, so the tests stay order- and
//! parallelism-independent within this binary.

use std::collections::HashMap;
use std::sync::Mutex;

use ffcz::codec::CodecChainSpec;
use ffcz::correction::FfczConfig;
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::Field;
use ffcz::store::{encode_store, Store, StoreWriteOptions};
use ffcz::telemetry::{self, trace, Snapshot};
use ffcz::util::json::Json;

fn guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn grf_3d(shape: &[usize], seed: u64) -> Field {
    GrfBuilder::new(shape)
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(seed)
        .build()
}

fn ffcz_spec(base: &str) -> CodecChainSpec {
    CodecChainSpec::ffcz(base, &FfczConfig::relative(1e-3, 1e-3))
}

fn hist_count(snap: &Snapshot, name: &str) -> u64 {
    snap.histograms.get(name).map(|h| h.count).unwrap_or(0)
}

#[test]
fn snapshot_json_round_trips_exactly() {
    let _g = guard();
    telemetry::counter("itest.telemetry.roundtrip.count").add(42);
    telemetry::gauge("itest.telemetry.roundtrip.gauge").set(9001);
    let h = telemetry::histogram("itest.telemetry.roundtrip.hist");
    h.record(0);
    h.record(17);
    h.record(1 << 40);
    // No other thread mutates the registry while the guard is held, so
    // the parse of to_json() must reproduce the snapshot *exactly* —
    // every counter, gauge, and sparse histogram bucket.
    let snap = telemetry::snapshot();
    let parsed = Snapshot::from_json(&snap.to_json()).unwrap();
    assert_eq!(parsed, snap);
    assert_eq!(parsed.counter("itest.telemetry.roundtrip.count"), 42);
    assert_eq!(parsed.gauge("itest.telemetry.roundtrip.gauge"), 9001);
    let hist = &parsed.histograms["itest.telemetry.roundtrip.hist"];
    assert_eq!(hist.count, 3);
    assert_eq!(hist.sum, 17 + (1 << 40));
    assert_eq!(hist.buckets.len(), 3);
}

#[test]
fn store_write_trace_nests_stage_spans_under_chunk_spans() {
    let _g = guard();
    trace::disable();
    let _ = trace::drain(); // clear leftovers from other tests

    trace::enable();
    let field = grf_3d(&[16, 16, 16], 77);
    let opts = StoreWriteOptions::new(&[8, 8, 8]).workers(2);
    let (_, _, report) = encode_store(&field, &ffcz_spec("sz-like"), &opts).unwrap();
    trace::disable();
    assert!(report.all_chunks_ok);

    let events: Vec<_> = trace::drain()
        .into_iter()
        .filter(|e| e.name.starts_with("store."))
        .collect();
    let by_id: HashMap<u64, &trace::SpanEvent> = events.iter().map(|e| (e.id, e)).collect();

    // Exactly one root write span carrying the chunk count.
    let roots: Vec<_> = events.iter().filter(|e| e.name == "store.write").collect();
    assert_eq!(roots.len(), 1, "expected one store.write span");
    let root = roots[0];
    assert_eq!(root.parent, 0);
    assert!(root.args.contains(&("chunks", 8)), "args: {:?}", root.args);

    // Eight chunk spans, one per chunk index, cross-thread-parented to
    // the root.
    let chunks: Vec<_> = events.iter().filter(|e| e.name == "store.chunk.encode").collect();
    assert_eq!(chunks.len(), 8);
    let mut chunk_args: Vec<u64> = chunks
        .iter()
        .map(|e| {
            assert_eq!(e.parent, root.id, "chunk span not parented to root");
            e.args.iter().find(|(k, _)| *k == "chunk").expect("chunk arg").1
        })
        .collect();
    chunk_args.sort_unstable();
    assert_eq!(chunk_args, (0..8).collect::<Vec<u64>>());

    // Each pipeline stage ran once per chunk, implicitly nested (same
    // thread) inside its chunk span and contained within it in time.
    let chunk_ids: Vec<u64> = chunks.iter().map(|e| e.id).collect();
    for stage in [
        "store.chunk.base_compress",
        "store.chunk.pocs_correct",
        "store.chunk.verify",
    ] {
        let spans: Vec<_> = events.iter().filter(|e| e.name == stage).collect();
        assert_eq!(spans.len(), 8, "{stage}: expected one span per chunk");
        for s in &spans {
            assert!(chunk_ids.contains(&s.parent), "{stage} parent not a chunk");
            let parent = by_id[&s.parent];
            assert_eq!(s.tid, parent.tid, "{stage} on a different thread");
            assert!(parent.start_ns <= s.start_ns);
            assert!(s.start_ns + s.dur_ns <= parent.start_ns + parent.dur_ns);
        }
    }

    // Worker threads announce themselves; every parent id resolves.
    assert!(events.iter().any(|e| e.name == "store.worker"));
    for e in &events {
        assert!(e.parent == 0 || by_id.contains_key(&e.parent));
    }

    // The Chrome export of these events is valid JSON, sorted by start
    // time, and carries the span/parent ids in args.
    let json = trace::to_chrome_json(&events);
    let doc = Json::parse(&json).unwrap();
    let arr = doc.as_arr().unwrap();
    assert_eq!(arr.len(), events.len());
    let mut last_ts = f64::MIN;
    for e in arr {
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        assert!(ts >= last_ts, "events not sorted by start time");
        last_ts = ts;
        let args = e.get("args").unwrap();
        assert!(args.get("span_id").unwrap().as_u64().unwrap() > 0);
        assert!(args.get("parent").is_some());
    }
}

#[test]
fn trace_file_round_trips_through_write_chrome_json() {
    let _g = guard();
    trace::disable();
    let _ = trace::drain();

    trace::enable();
    {
        let root = trace::span("itest.file.root").arg("k", 5);
        let _child = trace::span_with_parent("itest.file.child", root.id());
    }
    trace::disable();

    let path = std::env::temp_dir().join("ffcz_telemetry_trace_test.json");
    let written = trace::write_chrome_json(&path).unwrap();
    assert!(written >= 2, "expected at least the two spans, got {written}");
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let doc = Json::parse(&text).unwrap();
    let arr = doc.as_arr().unwrap();
    let root = arr
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("itest.file.root"))
        .expect("root span in file");
    let child = arr
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("itest.file.child"))
        .expect("child span in file");
    assert_eq!(root.get("args").unwrap().get("k").unwrap().as_u64(), Some(5));
    assert_eq!(
        child.get("args").unwrap().get("parent").unwrap().as_u64(),
        root.get("args").unwrap().get("span_id").unwrap().as_u64()
    );
    // The file write drained the collector: a second write sees nothing.
    let again = std::env::temp_dir().join("ffcz_telemetry_trace_test2.json");
    assert_eq!(trace::write_chrome_json(&again).unwrap(), 0);
    std::fs::remove_file(&again).ok();
}

#[test]
fn global_counters_match_write_report_on_known_encode() {
    let _g = guard();
    trace::disable();
    let field = grf_3d(&[16, 16, 16], 21);

    let before = telemetry::snapshot();
    let opts = StoreWriteOptions::new(&[8, 8, 8]).workers(2);
    let (bytes, manifest, report) = encode_store(&field, &ffcz_spec("sz-like"), &opts).unwrap();
    let after = telemetry::snapshot();

    // 16³ field in 8³ chunks: exactly 8 chunk encodes, each seen once by
    // the registry and once in the per-chunk report.
    assert_eq!(report.chunk_reports.len(), 8);
    assert_eq!(after.counter_delta(&before, "store.encode.chunks"), 8);
    assert_eq!(after.counter_delta(&before, "store.encode.bytes_in"), (16 * 16 * 16 * 8) as u64);
    let iters: u64 = report.chunk_reports.iter().map(|r| r.pocs_iterations as u64).sum();
    assert_eq!(after.counter_delta(&before, "store.encode.pocs_iters"), iters);
    let attempts: u64 = report.chunk_reports.iter().map(|r| r.quant_attempts as u64).sum();
    assert_eq!(after.counter_delta(&before, "store.encode.quant_attempts"), attempts);
    let fallbacks = report.chunk_reports.iter().filter(|r| r.used_raw_fallback).count() as u64;
    assert_eq!(after.counter_delta(&before, "store.encode.raw_fallbacks"), fallbacks);
    // bytes_out agrees chunk-by-chunk with the manifest payload.
    let out: u64 = report.chunk_reports.iter().map(|r| r.bytes_out as u64).sum();
    assert_eq!(after.counter_delta(&before, "store.encode.bytes_out"), out);
    assert_eq!(out, manifest.payload_bytes());
    let hist_delta =
        hist_count(&after, "store.encode.chunk_ns") - hist_count(&before, "store.encode.chunk_ns");
    assert_eq!(hist_delta, 8);

    // Decode side: with the LRU enabled, a repeated same-window read is
    // one miss then one hit, and the per-store accessors agree with the
    // global registry deltas.
    let store = Store::from_bytes(bytes).unwrap();
    store.set_cache_budget(8 * 8 * 8 * 8); // room for one decoded chunk
    let b = telemetry::snapshot();
    store.read_region(&[0, 0, 0], &[8, 8, 8], 1).unwrap();
    store.read_region(&[0, 0, 0], &[8, 8, 8], 1).unwrap();
    let a = telemetry::snapshot();
    assert_eq!(store.cache_misses(), 1);
    assert_eq!(store.cache_hits(), 1);
    assert_eq!(a.counter_delta(&b, "store.read.lru_misses"), store.cache_misses() as u64);
    assert_eq!(a.counter_delta(&b, "store.read.lru_hits"), store.cache_hits() as u64);
    assert_eq!(a.counter_delta(&b, "store.decode.chunks"), 1);
    assert!(a.gauge("store.read.lru_bytes") >= (8 * 8 * 8 * 8) as u64);
}
