//! Storage-backend property and fault-injection tests.
//!
//! Two families, matching the two promises the `ReadableStorage`
//! abstraction makes:
//!
//! 1. **Backend equivalence** — `Store::read_region` through the local
//!    file backend, the in-memory backend, and a fault-free
//!    `FaultInjector` wrapper is *bit-identical* (and, for lossless
//!    chains, identical to ground truth extracted from the original
//!    field). The storage layer may change how bytes arrive, never
//!    which bytes arrive.
//! 2. **Fault surfacing** — every injected failure mode (short reads,
//!    transient I/O errors, hard I/O errors, byte corruption, latency)
//!    either heals invisibly (short reads; transients under a retry
//!    policy) or surfaces as a precise `Err` — never a panic, never
//!    silently wrong data. The schedules are seeded and single-threaded,
//!    so every assertion is deterministic.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use ffcz::codec::CodecChainSpec;
use ffcz::correction::FfczConfig;
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::Field;
use ffcz::store::{
    encode_store, extract_subarray, FaultHandle, FaultInjector, FaultPlan, FileStorage,
    MemStorage, RetryPolicy, Store, StoreWriteOptions,
};
use ffcz::util::XorShift;

fn grf(shape: &[usize], seed: u64) -> Field {
    GrfBuilder::new(shape)
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(seed)
        .build()
}

fn temp_file(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ffcz_storage_{name}_{}.ffcz", std::process::id()))
}

/// Encode `field` into a container with the given chain and chunk shape.
fn container(field: &Field, spec: &CodecChainSpec, chunk: &[usize]) -> Vec<u8> {
    let opts = StoreWriteOptions::new(chunk).workers(2);
    let (bytes, manifest, _) = encode_store(field, spec, &opts).unwrap();
    assert!(manifest.all_chunks_ok());
    bytes
}

/// Open the same container through every backend.
fn all_backends(bytes: &[u8], path: &PathBuf) -> Vec<(&'static str, Store)> {
    std::fs::write(path, bytes).expect("writing the backend-equivalence fixture container");
    let shared = Arc::new(bytes.to_vec());
    vec![
        ("file", Store::open(path).unwrap()),
        ("from_bytes", Store::from_bytes(bytes.to_vec()).unwrap()),
        (
            "mem_storage",
            Store::open_storage(Arc::new(MemStorage::shared(Arc::clone(&shared)))).unwrap(),
        ),
        (
            "fault_free_injector",
            Store::open_storage(Arc::new(FaultInjector::new(
                MemStorage::shared(shared),
                FaultPlan::none(),
            )))
            .unwrap(),
        ),
        (
            "fault_free_injector_over_file",
            Store::open_storage(Arc::new(FaultInjector::new(
                FileStorage::open(path).unwrap(),
                FaultPlan::none(),
            )))
            .unwrap(),
        ),
    ]
}

/// Random region inside `shape` (every axis extent ≥ 1).
fn random_region(rng: &mut XorShift, shape: &[usize]) -> (Vec<usize>, Vec<usize>) {
    let origin: Vec<usize> = shape.iter().map(|&n| rng.below(n)).collect();
    let extent: Vec<usize> = shape
        .iter()
        .zip(&origin)
        .map(|(&n, &o)| 1 + rng.below(n - o))
        .collect();
    (origin, extent)
}

/// Property: for random fields, chunk grids, and regions, every backend
/// returns bit-identical samples — and for lossless chains, exactly the
/// ground-truth subarray of the original field.
#[test]
fn read_region_is_bit_identical_across_backends() {
    let cases: [(&[usize], &[usize]); 3] =
        [(&[24, 20], &[7, 6]), (&[16, 12, 10], &[8, 5, 4]), (&[37], &[8])];
    let path = temp_file("prop");
    let mut rng = XorShift::new(0xBACC);
    for (ci, (shape, chunk)) in cases.iter().enumerate() {
        let field = grf(shape, 40 + ci as u64);
        for (si, spec) in [
            CodecChainSpec::lossless(),
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)),
        ]
        .iter()
        .enumerate()
        {
            let bytes = container(&field, spec, chunk);
            let stores = all_backends(&bytes, &path);
            for round in 0..6 {
                let (origin, extent) = random_region(&mut rng, shape);
                let mut want: Option<Vec<u64>> = None;
                for (backend, store) in &stores {
                    let got = store.read_region(&origin, &extent, 2).unwrap();
                    assert_eq!(got.shape(), &extent[..], "case {ci} {backend}");
                    let bits: Vec<u64> = got.data().iter().map(|v| v.to_bits()).collect();
                    match &want {
                        None => want = Some(bits),
                        Some(want) => assert_eq!(
                            &bits, want,
                            "case {ci} chain {si} round {round}: backend {backend} \
                             disagrees at origin {origin:?} shape {extent:?}"
                        ),
                    }
                }
                if si == 0 {
                    // Lossless chain: the shared answer must equal the
                    // ground-truth slice of the original field, bitwise.
                    let truth = extract_subarray(field.data(), shape, &origin, &extent);
                    let truth_bits: Vec<u64> = truth.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(want.as_deref(), Some(&truth_bits[..]), "case {ci} round {round}");
                }
            }
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// Short reads are a legal backend behaviour, not a fault: reads heal
/// through the `read_exact_at` loop and the decoded bytes are identical.
#[test]
fn short_reads_are_invisible_to_the_reader() {
    let field = grf(&[20, 18], 7);
    let bytes = container(&field, &CodecChainSpec::lossless(), &[6, 5]);
    let clean = Store::from_bytes(bytes.clone()).unwrap();
    let injector = FaultInjector::new(
        MemStorage::new(bytes),
        FaultPlan {
            seed: 99,
            short_reads: true,
            ..FaultPlan::none()
        },
    );
    let handle = injector.handle();
    let store = Store::open_storage(Arc::new(injector)).unwrap();
    let want = clean.read_region(&[2, 3], &[15, 11], 1).unwrap();
    let got = store.read_region(&[2, 3], &[15, 11], 1).unwrap();
    assert_eq!(got.data(), want.data());
    assert!(
        handle.counts().short_reads > 0,
        "the schedule never actually split a read"
    );
    assert_eq!(store.retries(), 0, "short reads must not count as retries");
}

/// A transient fault with no retry policy surfaces as a precise error
/// naming the chunk — the default store never retries silently.
#[test]
fn transient_fault_without_policy_is_a_precise_error() {
    let field = grf(&[12, 12], 8);
    let bytes = container(&field, &CodecChainSpec::lossless(), &[6, 6]);
    let injector = FaultInjector::new(MemStorage::new(bytes), FaultPlan::none());
    let handle = injector.handle();
    let store = Store::open_storage(Arc::new(injector)).unwrap();
    // Arm transients only after the clean open (ops 1-3 are header,
    // trailer, manifest): with `transient_every: 1` every subsequent op
    // faults, so the very next payload read must error.
    handle.set_plan(FaultPlan {
        transient_every: 1,
        ..FaultPlan::none()
    });
    let err = store.read_region(&[0, 0], &[12, 12], 1).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected transient storage fault"), "{msg}");
    assert!(msg.contains("reading chunk c/"), "{msg}");
    assert_eq!(store.retries(), 0);
}

/// Under `RetryPolicy::transient` a seeded `transient_every ≥ 2`
/// schedule always heals: the retry is the next op index, which cannot
/// fault again. The read succeeds bit-identically and the retries are
/// accounted on the handle and in the registry.
#[test]
fn transient_faults_heal_deterministically_under_retry_policy() {
    let field = grf(&[18, 14], 9);
    let bytes = container(&field, &CodecChainSpec::lossless(), &[5, 5]);
    let clean = Store::from_bytes(bytes.clone()).unwrap();
    let injector = FaultInjector::new(MemStorage::new(bytes), FaultPlan::none());
    let handle = injector.handle();
    let mut store = Store::open_storage(Arc::new(injector)).unwrap();
    store.set_retry_policy(RetryPolicy::transient(3, Duration::ZERO));
    handle.set_plan(FaultPlan {
        transient_every: 2,
        ..FaultPlan::none()
    });
    let before = ffcz::telemetry::snapshot();
    let want = clean.read_region(&[1, 1], &[16, 12], 1).unwrap();
    let got = store.read_region(&[1, 1], &[16, 12], 1).unwrap();
    assert_eq!(got.data(), want.data());
    let transients = handle.counts().transients;
    assert!(transients > 0, "the schedule never faulted");
    assert_eq!(store.retries(), transients, "every transient cost one retry");
    let after = ffcz::telemetry::snapshot();
    assert!(
        after.counter_delta(&before, "store.read.retries") >= transients,
        "registry retries must aggregate the handle's"
    );
}

/// Hard I/O failures are never retried, even under a retry policy, and
/// surface with the chunk key in the error chain.
#[test]
fn hard_io_failure_is_not_retried() {
    let field = grf(&[12, 12], 10);
    let bytes = container(&field, &CodecChainSpec::lossless(), &[6, 6]);
    let injector = FaultInjector::new(MemStorage::new(bytes), FaultPlan::none());
    let handle = injector.handle();
    let mut store = Store::open_storage(Arc::new(injector)).unwrap();
    store.set_retry_policy(RetryPolicy::transient(5, Duration::ZERO));
    handle.set_plan(FaultPlan {
        fail_ops: (1..100).collect(),
        ..FaultPlan::none()
    });
    let err = store.read_region(&[0, 0], &[12, 12], 1).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected storage failure"), "{msg}");
    assert!(msg.contains("reading chunk c/"), "{msg}");
    assert_eq!(store.retries(), 0, "hard faults must not burn retries");
    assert!(handle.counts().failures >= 1, "the hard fault never fired");
}

/// A corrupted payload byte is caught by the CRC-32 check with a precise
/// error — it never reaches a codec and never panics.
#[test]
fn corruption_is_caught_by_crc32() {
    let field = grf(&[16, 16], 11);
    let bytes = container(&field, &CodecChainSpec::lossless(), &[8, 8]);
    let injector = FaultInjector::new(MemStorage::new(bytes), FaultPlan::none());
    let handle = injector.handle();
    let store = Store::open_storage(Arc::new(injector)).unwrap();
    // Corrupt every payload read from here on.
    handle.set_plan(FaultPlan {
        seed: 5,
        corrupt_ops: (1..100).collect(),
        ..FaultPlan::none()
    });
    let err = store.read_region(&[0, 0], &[16, 16], 1).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("CRC-32"), "{msg}");
    assert!(handle.counts().corruptions > 0);
    // Clearing the plan heals the store: nothing was cached corrupt.
    handle.set_plan(FaultPlan::none());
    let clean = store.read_region(&[0, 0], &[16, 16], 1).unwrap();
    assert_eq!(clean.data().len(), 256);
}

/// Seeded sweep over random fault plans: every read either succeeds
/// bit-identically to the clean store or fails with an `Err` — no
/// panics, no silent corruption escaping the CRC, across many seeds.
#[test]
fn random_fault_schedules_never_panic_or_corrupt() {
    let field = grf(&[20, 16], 12);
    let bytes = container(
        &field,
        &CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)),
        &[7, 6],
    );
    let clean = Store::from_bytes(bytes.clone()).unwrap();
    let mut rng = XorShift::new(0xFA17);
    for seed in 0..24u64 {
        let injector = FaultInjector::new(MemStorage::new(bytes.clone()), FaultPlan::none());
        let handle = injector.handle();
        let mut store = match Store::open_storage(Arc::new(injector)) {
            Ok(s) => s,
            Err(_) => continue,
        };
        store.set_retry_policy(RetryPolicy::transient(3, Duration::ZERO));
        let plan = FaultPlan {
            seed,
            short_reads: seed % 2 == 0,
            transient_every: [0, 2, 3, 5][(seed % 4) as usize],
            fail_ops: if seed % 5 == 0 { vec![2 + seed % 7] } else { vec![] },
            corrupt_ops: if seed % 3 == 0 { vec![1 + seed % 5] } else { vec![] },
            latency: Duration::ZERO,
        };
        handle.set_plan(plan);
        let (origin, extent) = random_region(&mut rng, &[20, 16]);
        match store.read_region(&origin, &extent, 1) {
            Ok(got) => {
                let want = clean.read_region(&origin, &extent, 1).unwrap();
                let got_bits: Vec<u64> = got.data().iter().map(|v| v.to_bits()).collect();
                let want_bits: Vec<u64> = want.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_bits, want_bits, "seed {seed}: healed read disagrees");
            }
            Err(err) => {
                // Must be attributable: a fault the schedule injected or
                // the CRC catching its corruption.
                let msg = format!("{err:#}");
                let counts = handle.counts();
                assert!(
                    counts.failures > 0 || counts.corruptions > 0 || counts.transients > 0,
                    "seed {seed}: error without any injected fault: {msg}"
                );
            }
        }
    }
}

/// The retry schedule is deterministic end to end: two identical runs
/// of the same plan over the same reads inject identical fault counts
/// and leave identical retry tallies.
#[test]
fn seeded_schedules_replay_identically() {
    let field = grf(&[14, 14], 13);
    let bytes = container(&field, &CodecChainSpec::lossless(), &[7, 7]);
    let run = |_: u64| -> (Vec<u64>, ffcz::store::FaultCounts, u64) {
        let injector = FaultInjector::new(
            MemStorage::new(bytes.clone()),
            FaultPlan::none(),
        );
        let handle: FaultHandle = injector.handle();
        let mut store = Store::open_storage(Arc::new(injector)).unwrap();
        store.set_retry_policy(RetryPolicy::transient(3, Duration::ZERO));
        handle.set_plan(FaultPlan {
            seed: 77,
            short_reads: true,
            transient_every: 3,
            ..FaultPlan::none()
        });
        let region = store.read_region(&[0, 0], &[14, 14], 1).unwrap();
        let bits = region.data().iter().map(|v| v.to_bits()).collect();
        (bits, handle.counts(), store.retries())
    };
    let (bits_a, counts_a, retries_a) = run(0);
    let (bits_b, counts_b, retries_b) = run(1);
    assert_eq!(bits_a, bits_b);
    assert_eq!(counts_a, counts_b);
    assert_eq!(retries_a, retries_b);
}
