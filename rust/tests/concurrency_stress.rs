//! Concurrency stress tests for the crate's shared mutable state: the
//! process-wide FFT plan caches, the store's decoded-chunk LRU, the
//! ordered-sink worker pool, the archive read server's shared caches and
//! connection threads, and the trace collector's flush-on-thread-exit
//! path.
//!
//! These tests are the designated workload for the ThreadSanitizer CI job
//! (see `.github/workflows/ci.yml`): each one drives many OS threads
//! through a shared structure hard enough that a missing acquire/release
//! edge or an unlocked mutation shows up as a TSan report. Under plain
//! `cargo test` they still assert the *logical* invariants — metric
//! accounting, LRU budget, sink ordering, buffer flushing — so races that
//! corrupt bookkeeping are caught even without a sanitizer.
//!
//! Every test serializes on [`stress_guard`]: they mutate process-global
//! state (plan-cache budgets, telemetry counters, the trace collector)
//! and would otherwise read each other's deltas.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::Field;
use ffcz::fourier::{
    ndrplan_for, plan_for, rplan_for, set_plan_cache_budget, DEFAULT_PLAN_CACHE_BUDGET,
};
use ffcz::store::{
    encode_store, extract_subarray, par_try_map_ordered_sink, read_exact_at, FaultInjector,
    FaultPlan, MemStorage, Store, StoreWriteOptions,
};
use ffcz::telemetry;
use ffcz::util::XorShift;

/// Serializes tests that touch process-global state. Poison is irrelevant
/// here (a failed test already failed); recover the guard and continue.
fn stress_guard() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn grf_3d(shape: &[usize], seed: u64) -> Field {
    GrfBuilder::new(shape)
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(seed)
        .build()
}

/// Hammer the real-FFT plan cache from many threads while the byte budget
/// is small enough to force constant LRU eviction, then check that the
/// hit/miss counters account for every single fetch and that the cache
/// quiesces within budget.
#[test]
fn plan_cache_lru_consistent_under_thread_churn() {
    let _guard = stress_guard();
    // Mixed radix and prime (Bluestein) lengths so plans differ in size.
    const SIZES: [usize; 8] = [96, 100, 101, 120, 144, 211, 240, 250];
    const THREADS: usize = 8;
    const ROUNDS: usize = 40;

    set_plan_cache_budget(64 << 10); // tiny: a handful of plans at most
    let before = telemetry::snapshot();
    let fetches = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let fetches = &fetches;
            scope.spawn(move || {
                let mut rng = XorShift::new(0x5EED + t as u64);
                for _ in 0..ROUNDS {
                    let n = SIZES[(rng.next_f64() * SIZES.len() as f64) as usize % SIZES.len()];
                    let plan = rplan_for(n);
                    assert_eq!(plan.len(), n);
                    fetches.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let after = telemetry::snapshot();

    // Every fetch is exactly one hit or one miss — a lost update under
    // contention breaks this equality.
    let hits = after.counter_delta(&before, "fourier.plan_cache.rfft.hits");
    let misses = after.counter_delta(&before, "fourier.plan_cache.rfft.misses");
    assert_eq!(
        hits + misses,
        fetches.load(Ordering::Relaxed) as u64,
        "hit/miss accounting lost fetches under contention"
    );
    assert!(misses >= 1, "distinct sizes must miss at least once");

    // Quiesced cache respects the budget (the MRU plan is never evicted,
    // so a single oversized plan may stand alone).
    let bytes = after.gauge("fourier.plan_cache.rfft.bytes");
    let entries = after.gauge("fourier.plan_cache.rfft.entries");
    assert!(entries >= 1);
    assert!(
        bytes <= (64 << 10) || entries == 1,
        "cache quiesced over budget: {bytes} bytes in {entries} entries"
    );

    // Second phase: all three caches at once (ndrplan_for nests rplan_for
    // and plan_for), racing pure fetches — TSan fodder, logic asserted by
    // the shape checks.
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            scope.spawn(move || {
                for r in 0..12 {
                    let shape = [4 + (t + r) % 5, 6 + r % 3, 8];
                    let nd = ndrplan_for(&shape);
                    assert_eq!(nd.shape(), &shape[..]);
                    let c = plan_for(32 + (t * 7 + r) % 9);
                    assert_eq!(c.len(), 32 + (t * 7 + r) % 9);
                }
            });
        }
    });

    set_plan_cache_budget(DEFAULT_PLAN_CACHE_BUDGET);
}

/// Churn the store's decoded-chunk LRU from many readers at once with a
/// budget that holds only ~2 of 27 chunks, comparing every window against
/// a ground-truth full decompress.
#[test]
fn store_chunk_lru_churn_under_concurrent_read_region() {
    let _guard = stress_guard();
    let field = grf_3d(&[12, 10, 8], 99);
    let spec = ffcz::codec::CodecChainSpec::ffcz(
        "sz-like",
        &ffcz::correction::FfczConfig::relative(1e-3, 1e-3),
    );
    let opts = StoreWriteOptions::new(&[5, 4, 3]).workers(3);
    let (bytes, _, report) = encode_store(&field, &spec, &opts).unwrap();
    assert!(report.all_chunks_ok);
    let store = Store::from_bytes(bytes).unwrap();
    let full = store.decompress_all(2).unwrap();

    // Each decoded [5,4,3] chunk caches ≤ 480 bytes of f64s; 1000 bytes
    // keeps ~2 of the 27 chunks resident, so readers evict constantly.
    const BUDGET: usize = 1000;
    store.set_cache_budget(BUDGET);

    const THREADS: usize = 8;
    const WINDOWS: usize = 15;
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let (store, field, full) = (&store, &field, &full);
            scope.spawn(move || {
                let mut rng = XorShift::new(0xCAFE + t as u64);
                for _ in 0..WINDOWS {
                    let mut origin = Vec::new();
                    let mut shape = Vec::new();
                    for &d in field.shape() {
                        let o = (rng.next_f64() * d as f64) as usize % d;
                        let max_len = d - o;
                        let s = 1 + (rng.next_f64() * max_len as f64) as usize % max_len.max(1);
                        origin.push(o);
                        shape.push(s.min(max_len));
                    }
                    let region = store.read_region(&origin, &shape, 1).unwrap();
                    let expect = extract_subarray(full.data(), full.shape(), &origin, &shape);
                    assert_eq!(
                        region.data(),
                        &expect[..],
                        "window {origin:?}+{shape:?} diverged under LRU churn"
                    );
                }
            });
        }
    });

    // Quiesced cache bookkeeping: within budget, and the hit/miss
    // counters saw at least one decode per chunk the windows touched.
    assert!(
        store.cache_bytes() <= BUDGET,
        "decoded-chunk LRU over budget after churn: {} bytes",
        store.cache_bytes()
    );
    let touched = store.cache_hits() + store.cache_misses();
    assert!(
        touched >= THREADS * WINDOWS,
        "every window decodes at least one chunk, saw only {touched} lookups"
    );
}

/// Force the ordered sink to reorder: late indices finish first (their
/// delay shrinks with the index), yet the sink must still observe strict
/// index order for a byte stream that is identical to a sequential run.
#[test]
fn ordered_sink_stays_ordered_under_forced_reordering() {
    let _guard = stress_guard();
    const N: usize = 64;
    for (workers, window) in [(4usize, 2usize), (8, 4)] {
        let mut seen = Vec::with_capacity(N);
        par_try_map_ordered_sink(
            N,
            workers,
            window,
            |i| {
                // Invert completion order within each stripe of 8.
                std::thread::sleep(Duration::from_micros(((8 - i % 8) * 300) as u64));
                Ok(i * 3)
            },
            |i, v| {
                seen.push((i, v));
                Ok(())
            },
        )
        .unwrap();
        let expect: Vec<(usize, usize)> = (0..N).map(|i| (i, i * 3)).collect();
        assert_eq!(seen, expect, "workers={workers} window={window}");
    }
}

/// Hammer the archive read server with ≥ 8 concurrent clients requesting
/// overlapping windows of the same archive while the shared decoded-chunk
/// LRU is squeezed hard enough to evict constantly. Every response must be
/// bit-identical to a ground-truth full decompress, the request accounting
/// must balance, and a clean shutdown must leave no thread behind.
///
/// This is the server's entry in the nightly TSan run: the shared state
/// under attack is the archive map (`RwLock`), the per-archive LRU, the
/// scratch pool, and the telemetry registry, all crossed by one OS thread
/// per connection.
#[test]
fn server_read_region_consistent_under_concurrent_clients() {
    use ffcz::server::{ArchiveServer, Client, ServeOptions};

    let _guard = stress_guard();
    let field = grf_3d(&[12, 10, 8], 4242);
    let spec = ffcz::codec::CodecChainSpec::ffcz(
        "sz-like",
        &ffcz::correction::FfczConfig::relative(1e-3, 1e-3),
    );
    let opts = StoreWriteOptions::new(&[5, 4, 3]).workers(3);
    let (bytes, _, report) = encode_store(&field, &spec, &opts).unwrap();
    assert!(report.all_chunks_ok);
    let store = Store::from_bytes(bytes).unwrap();
    let full = store.decompress_all(2).unwrap();
    // ~2 of 27 decoded chunks fit: every request churns the shared LRU.
    store.set_cache_budget(1000);

    let before = telemetry::snapshot();
    let server = ArchiveServer::start(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        ..ServeOptions::default()
    })
    .unwrap();
    server.register("stress", std::sync::Arc::new(store));
    let addr = server.local_addr().to_string();

    const CLIENTS: usize = 8;
    const WINDOWS: usize = 12;
    let served = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..CLIENTS {
            let (addr, field, full, served) = (&addr, &field, &full, &served);
            scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                client.ping().unwrap();
                let stat = client.stat("stress").unwrap();
                assert_eq!(stat.shape, vec![12, 10, 8]);
                let mut rng = XorShift::new(0x5E7E + t as u64);
                for _ in 0..WINDOWS {
                    let mut origin = Vec::new();
                    let mut shape = Vec::new();
                    for &d in field.shape() {
                        let o = (rng.next_f64() * d as f64) as usize % d;
                        let max_len = d - o;
                        let s = 1 + (rng.next_f64() * max_len as f64) as usize % max_len.max(1);
                        origin.push(o);
                        shape.push(s.min(max_len));
                    }
                    let region = client.read_region("stress", &origin, &shape).unwrap();
                    let expect = extract_subarray(full.data(), full.shape(), &origin, &shape);
                    let got: Vec<u64> = region.data().iter().map(|v| v.to_bits()).collect();
                    let want: Vec<u64> = expect.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        got, want,
                        "window {origin:?}+{shape:?} diverged through the server"
                    );
                    served.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(served.load(Ordering::Relaxed), CLIENTS * WINDOWS);

    // One shutdown request stops the accept loop; `join` returns only
    // after every connection thread exited.
    let mut closer = Client::connect(&addr).unwrap();
    closer.shutdown_server().unwrap();
    server.join();

    // Request accounting balanced: ping + stat per client, all windows,
    // plus the shutdown, and zero errors.
    let after = telemetry::snapshot();
    let reads = after.counter_delta(&before, "server.requests.read_region");
    let total = after.counter_delta(&before, "server.requests.total");
    let errors = after.counter_delta(&before, "server.requests.errors");
    assert_eq!(reads, (CLIENTS * WINDOWS) as u64);
    assert_eq!(total, (CLIENTS * (WINDOWS + 2) + 1) as u64);
    assert_eq!(errors, 0, "no request may have errored under churn");
}

/// Injected latency must sleep *outside* the [`FaultInjector`]'s plan
/// lock: concurrent readers each pay their own simulated storage delay,
/// they do not queue behind one another's sleeps. With 6 readers and a
/// 100 ms per-op latency, a sleep held under the lock would serialize to
/// ≥ 600 ms of wall clock; overlapping sleeps finish in ~100 ms. The
/// bound asserted here (450 ms) stays generous enough for the TSan run
/// this suite feeds while being impossible to meet serialized — and the
/// shared op counter/RNG stream must still account every op exactly.
#[test]
fn fault_injector_latency_overlaps_across_concurrent_readers() {
    let _guard = stress_guard();
    const READERS: usize = 6;
    const LATENCY: Duration = Duration::from_millis(100);
    let bytes: Vec<u8> = (0..4096).map(|i| (i % 251) as u8).collect();
    let injector = FaultInjector::new(
        MemStorage::new(bytes.clone()),
        FaultPlan {
            latency: LATENCY,
            ..FaultPlan::none()
        },
    );
    let handle = injector.handle();

    let started = std::time::Instant::now();
    std::thread::scope(|scope| {
        for t in 0..READERS {
            let (injector, bytes) = (&injector, &bytes);
            scope.spawn(move || {
                let offset = t * 512;
                let mut buf = vec![0u8; 512];
                read_exact_at(injector, offset as u64, &mut buf).unwrap();
                assert_eq!(&buf[..], &bytes[offset..offset + 512]);
            });
        }
    });
    let elapsed = started.elapsed();

    assert!(
        elapsed >= LATENCY,
        "every reader must pay the injected latency (finished in {elapsed:?})"
    );
    assert!(
        elapsed < LATENCY * 9 / 2,
        "injected latency serialized readers: {READERS} concurrent reads \
         of a {LATENCY:?} backend took {elapsed:?}"
    );
    // The shared op counter under the (briefly held) lock lost nothing.
    assert_eq!(handle.counts().ops, READERS as u64);
}

/// Spans buffered on a worker thread must reach the collector when the
/// thread exits, even if an enclosing span is leaked and never closes
/// (the thread-local buffer's `Drop` is the flush of last resort).
#[test]
fn trace_buffer_flushes_on_thread_exit() {
    let _guard = stress_guard();
    telemetry::trace::enable();
    let _ = telemetry::trace::drain(); // discard other tests' leftovers

    const THREADS: usize = 6;
    const SPANS: usize = 10;
    std::thread::scope(|scope| {
        for _ in 0..THREADS {
            scope.spawn(|| {
                // Leak the outer span: the stack never empties, so the
                // eager flush (on root-span close) never fires on this
                // thread and only the exit flush can save the events.
                let outer = telemetry::span("stress.trace.outer");
                for _ in 0..SPANS {
                    let _inner = telemetry::span("stress.trace.inner");
                }
                std::mem::forget(outer);
            });
        }
    });

    let events = telemetry::trace::drain();
    telemetry::trace::disable();
    let inner = events
        .iter()
        .filter(|e| e.name == "stress.trace.inner")
        .count();
    assert_eq!(
        inner,
        THREADS * SPANS,
        "thread-exit flush dropped buffered spans"
    );
    // The leaked outer spans never closed, so they must not appear.
    assert!(!events.iter().any(|e| e.name == "stress.trace.outer"));
}
