//! Integration tests for the chunked spectral archive store: partial
//! decode equivalence, per-base-compressor roundtrips, corruption
//! rejection, and the per-chunk dual-domain guarantee on a GRF field.

use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::{Field, Precision};
use ffcz::store::{encode_store, extract_subarray, CodecSpec, Store, StoreWriteOptions};
use ffcz::util::XorShift;

fn grf_3d(shape: &[usize], seed: u64) -> Field {
    GrfBuilder::new(shape)
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(seed)
        .build()
}

fn ffcz_spec(base: &str) -> CodecSpec {
    CodecSpec::Ffcz {
        base: base.into(),
        spatial_rel: 1e-3,
        frequency_rel: Some(1e-3),
    }
}

#[test]
fn read_region_equals_full_decompress_slice_random_windows() {
    // Property test (proptest is unavailable offline; cases are drawn with
    // the crate's seeded XorShift): for random origins and shapes, a
    // partial read must be bit-identical to slicing a full decompress.
    let field = grf_3d(&[12, 10, 8], 42);
    let opts = StoreWriteOptions::new(&[5, 4, 3]).workers(3);
    let (bytes, _, report) = encode_store(&field, &ffcz_spec("sz-like"), &opts).unwrap();
    assert!(report.all_chunks_ok);
    let store = Store::from_bytes(bytes).unwrap();
    let full = store.decompress_all(2).unwrap();

    let mut rng = XorShift::new(7);
    for _ in 0..25 {
        let mut origin = Vec::new();
        let mut shape = Vec::new();
        for &d in field.shape() {
            let o = (rng.next_f64() * d as f64) as usize % d;
            let max_len = d - o;
            let s = 1 + (rng.next_f64() * max_len as f64) as usize % max_len.max(1);
            origin.push(o);
            shape.push(s.min(max_len));
        }
        let region = store.read_region(&origin, &shape, 2).unwrap();
        let expect = extract_subarray(full.data(), full.shape(), &origin, &shape);
        assert_eq!(
            region.data(),
            &expect[..],
            "window origin {origin:?} shape {shape:?} diverges from full decompress"
        );
    }
}

#[test]
fn partial_decode_touches_only_intersecting_chunks() {
    let field = grf_3d(&[12, 10, 8], 5);
    let opts = StoreWriteOptions::new(&[4, 5, 4]).workers(2);
    let (bytes, _, _) = encode_store(&field, &ffcz_spec("sz-like"), &opts).unwrap();
    // Grid is 3 × 2 × 2 = 12 chunks.
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(store.grid().chunk_count(), 12);

    // Window inside a single chunk.
    store.read_region(&[0, 0, 0], &[3, 4, 3], 1).unwrap();
    assert_eq!(store.chunks_decoded(), 1, "single-chunk window");

    // Window spanning exactly two chunks along axis 0.
    store.read_region(&[2, 0, 0], &[4, 5, 4], 1).unwrap();
    assert_eq!(store.chunks_decoded(), 1 + 2, "two-chunk window");

    // Full read touches all 12.
    store.decompress_all(4).unwrap();
    assert_eq!(store.chunks_decoded(), 3 + 12);
}

#[test]
fn roundtrip_with_every_base_compressor() {
    let field = grf_3d(&[8, 8, 8], 11);
    for base in ["sz-like", "zfp-like", "sperr-like", "identity"] {
        let opts = StoreWriteOptions::new(&[4, 8, 8]).workers(2);
        let (bytes, manifest, report) =
            encode_store(&field, &ffcz_spec(base), &opts).unwrap();
        assert!(report.all_chunks_ok, "{base}: chunk bound violated");
        assert!(manifest.all_chunks_ok());
        let store = Store::from_bytes(bytes).unwrap();
        let recon = store.decompress_all(2).unwrap();
        assert_eq!(recon.shape(), field.shape());
        assert_eq!(recon.precision(), field.precision());
        // Per-chunk spatial bound: |err| ≤ eb · chunk_span ≤ eb · field_span.
        let e = 1e-3 * field.value_span() * (1.0 + 1e-9);
        for (a, b) in field.data().iter().zip(recon.data()) {
            assert!((a - b).abs() <= e, "{base}: |{a} - {b}| > {e}");
        }
    }
}

#[test]
fn lossless_codec_roundtrip_is_bit_exact() {
    let field = grf_3d(&[9, 7, 5], 13);
    let opts = StoreWriteOptions::new(&[4, 4, 4]).workers(2);
    let (bytes, _, _) = encode_store(&field, &CodecSpec::Lossless, &opts).unwrap();
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(store.decompress_all(3).unwrap().data(), field.data());
}

#[test]
fn grf_manifest_records_dual_domain_ok_for_every_chunk() {
    // Acceptance criterion: on a GRF test field, the per-chunk dual-domain
    // stats recorded in the manifest show spatial_ok && frequency_ok
    // everywhere, with in-bound ratios.
    let field = grf_3d(&[16, 16, 16], 77);
    let opts = StoreWriteOptions::new(&[8, 8, 8]).workers(4);
    let (_, manifest, _) = encode_store(&field, &ffcz_spec("sz-like"), &opts).unwrap();
    assert_eq!(manifest.chunks.len(), 8);
    for (i, c) in manifest.chunks.iter().enumerate() {
        assert!(
            c.stats.spatial_ok && c.stats.frequency_ok,
            "chunk {i}: stats {:?}",
            c.stats
        );
        assert!(c.stats.max_spatial_ratio <= 1.0 + 1e-9);
        assert!(c.stats.max_frequency_ratio <= 1.0 + 1e-9);
    }
}

#[test]
fn corrupt_and_truncated_stores_are_rejected() {
    let field = grf_3d(&[8, 6, 4], 3);
    let opts = StoreWriteOptions::new(&[4, 3, 2]).workers(1);
    let (bytes, _, _) = encode_store(&field, &CodecSpec::Lossless, &opts).unwrap();

    // Every truncation of the container fails to open.
    for frac in [0.1, 0.5, 0.9, 0.999] {
        let cut = (bytes.len() as f64 * frac) as usize;
        assert!(
            Store::from_bytes(bytes[..cut].to_vec()).is_err(),
            "truncated to {cut} bytes unexpectedly opened"
        );
    }

    // Corrupting the footer (manifest offset/length fields or the end
    // magic) must always fail to open. (Flips inside the manifest's stats
    // fields only change recorded stats; structural manifest corruption is
    // covered by the truncation sweep above and the manifest unit tests.)
    for i in [
        bytes.len() - 24, // manifest offset
        bytes.len() - 12, // manifest length
        bytes.len() - 4,  // footer magic
        0,                // head magic
    ] {
        let mut bad = bytes.clone();
        bad[i] ^= 0x5A;
        assert!(
            Store::from_bytes(bad).is_err(),
            "byte flip at {i} went unnoticed"
        );
    }

    // A payload flip is caught at decode time (entropy-coded chunks fail to
    // parse or decode to the wrong length).
    let mut bad = bytes.clone();
    bad[10] ^= 0xFF;
    if let Ok(store) = Store::from_bytes(bad) {
        assert!(store.decompress_all(1).is_err() || {
            // Lossless payloads checksum-free: accept a successful decode
            // only if it differs from the original (corruption visible).
            let out = store.decompress_all(1).unwrap();
            out.data() != field.data()
        });
    }
}

#[test]
fn store_preserves_precision_tag() {
    let data: Vec<f64> = (0..24).map(|i| (i as f64) * 0.5).collect();
    let field = Field::new(&[4, 6], data, Precision::Single);
    let opts = StoreWriteOptions::new(&[2, 3]).workers(1);
    let (bytes, manifest, _) = encode_store(&field, &CodecSpec::Lossless, &opts).unwrap();
    assert_eq!(manifest.precision, Precision::Single);
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(
        store.read_region(&[1, 2], &[2, 2], 1).unwrap().precision(),
        Precision::Single
    );
}
