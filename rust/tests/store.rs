//! Integration tests for the chunked spectral archive store: partial
//! decode equivalence, per-base-compressor roundtrips, corruption
//! rejection, per-chunk codec chains, manifest v1 backward compatibility,
//! runtime codec registration, and the per-chunk dual-domain guarantee on
//! a GRF field.

use anyhow::Result;

use ffcz::codec::{register_codec, CodecChainSpec};
use ffcz::compressors::{Compressor, ErrorBound};
use ffcz::correction::{BoundSpec, FfczConfig};
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::{Field, Precision};
use ffcz::encoding::{lossless_compress, pack_flags, varint};
use ffcz::store::{
    encode_store, extract_subarray, stream_store_to, write_store, write_store_in_memory,
    ChunkGrid, Store, StoreWriteOptions,
};
use ffcz::util::XorShift;

fn grf_3d(shape: &[usize], seed: u64) -> Field {
    GrfBuilder::new(shape)
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(seed)
        .build()
}

fn ffcz_spec(base: &str) -> CodecChainSpec {
    CodecChainSpec::ffcz(base, &FfczConfig::relative(1e-3, 1e-3))
}

#[test]
fn read_region_equals_full_decompress_slice_random_windows() {
    // Property test (proptest is unavailable offline; cases are drawn with
    // the crate's seeded XorShift): for random origins and shapes, a
    // partial read must be bit-identical to slicing a full decompress.
    let field = grf_3d(&[12, 10, 8], 42);
    let opts = StoreWriteOptions::new(&[5, 4, 3]).workers(3);
    let (bytes, _, report) = encode_store(&field, &ffcz_spec("sz-like"), &opts).unwrap();
    assert!(report.all_chunks_ok);
    let store = Store::from_bytes(bytes).unwrap();
    let full = store.decompress_all(2).unwrap();

    let mut rng = XorShift::new(7);
    for _ in 0..25 {
        let mut origin = Vec::new();
        let mut shape = Vec::new();
        for &d in field.shape() {
            let o = (rng.next_f64() * d as f64) as usize % d;
            let max_len = d - o;
            let s = 1 + (rng.next_f64() * max_len as f64) as usize % max_len.max(1);
            origin.push(o);
            shape.push(s.min(max_len));
        }
        let region = store.read_region(&origin, &shape, 2).unwrap();
        let expect = extract_subarray(full.data(), full.shape(), &origin, &shape);
        assert_eq!(
            region.data(),
            &expect[..],
            "window origin {origin:?} shape {shape:?} diverges from full decompress"
        );
    }
}

#[test]
fn partial_decode_touches_only_intersecting_chunks() {
    let field = grf_3d(&[12, 10, 8], 5);
    let opts = StoreWriteOptions::new(&[4, 5, 4]).workers(2);
    let (bytes, _, _) = encode_store(&field, &ffcz_spec("sz-like"), &opts).unwrap();
    // Grid is 3 × 2 × 2 = 12 chunks.
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(store.grid().chunk_count(), 12);

    // Window inside a single chunk.
    store.read_region(&[0, 0, 0], &[3, 4, 3], 1).unwrap();
    assert_eq!(store.chunks_decoded(), 1, "single-chunk window");

    // Window spanning exactly two chunks along axis 0.
    store.read_region(&[2, 0, 0], &[4, 5, 4], 1).unwrap();
    assert_eq!(store.chunks_decoded(), 1 + 2, "two-chunk window");

    // Full read touches all 12.
    store.decompress_all(4).unwrap();
    assert_eq!(store.chunks_decoded(), 3 + 12);
}

#[test]
fn roundtrip_with_every_base_compressor() {
    let field = grf_3d(&[8, 8, 8], 11);
    for base in ["sz-like", "zfp-like", "sperr-like", "identity"] {
        let opts = StoreWriteOptions::new(&[4, 8, 8]).workers(2);
        let (bytes, manifest, report) =
            encode_store(&field, &ffcz_spec(base), &opts).unwrap();
        assert!(report.all_chunks_ok, "{base}: chunk bound violated");
        assert!(manifest.all_chunks_ok());
        let store = Store::from_bytes(bytes).unwrap();
        let recon = store.decompress_all(2).unwrap();
        assert_eq!(recon.shape(), field.shape());
        assert_eq!(recon.precision(), field.precision());
        // Per-chunk spatial bound: |err| ≤ eb · chunk_span ≤ eb · field_span.
        let e = 1e-3 * field.value_span() * (1.0 + 1e-9);
        for (a, b) in field.data().iter().zip(recon.data()) {
            assert!((a - b).abs() <= e, "{base}: |{a} - {b}| > {e}");
        }
    }
}

#[test]
fn lossless_codec_roundtrip_is_bit_exact() {
    let field = grf_3d(&[9, 7, 5], 13);
    let opts = StoreWriteOptions::new(&[4, 4, 4]).workers(2);
    let (bytes, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(store.decompress_all(3).unwrap().data(), field.data());
}

#[test]
fn grf_manifest_records_dual_domain_ok_for_every_chunk() {
    // Acceptance criterion: on a GRF test field, the per-chunk dual-domain
    // stats recorded in the manifest show spatial_ok && frequency_ok
    // everywhere, with in-bound ratios.
    let field = grf_3d(&[16, 16, 16], 77);
    let opts = StoreWriteOptions::new(&[8, 8, 8]).workers(4);
    let (_, manifest, _) = encode_store(&field, &ffcz_spec("sz-like"), &opts).unwrap();
    assert_eq!(manifest.chunks.len(), 8);
    for (i, c) in manifest.chunks.iter().enumerate() {
        assert!(
            c.stats.spatial_ok && c.stats.frequency_ok,
            "chunk {i}: stats {:?}",
            c.stats
        );
        assert!(c.stats.max_spatial_ratio <= 1.0 + 1e-9);
        assert!(c.stats.max_frequency_ratio <= 1.0 + 1e-9);
        assert!(c.crc32.is_some(), "chunk {i} missing checksum");
    }
}

/// Acceptance criterion: one store carrying two different per-chunk codec
/// chains — lossless boundary chunk + FFCz power-spectrum interior —
/// round-trips via `read_region` with correct per-chunk stats.
#[test]
fn mixed_per_chunk_chains_roundtrip_with_stats() {
    let field = grf_3d(&[12, 8, 8], 21);
    // Chunk shape [6, 8, 8] → two chunks: c/0/0/0 (lossless override) and
    // c/1/0/0 (default FFCz power-spectrum chain).
    let ffcz_ps = CodecChainSpec::ffcz("sz-like", &FfczConfig::power_spectrum(1e-2, 1e-3));
    let opts = StoreWriteOptions::new(&[6, 8, 8])
        .workers(2)
        .override_chunk("c/0/0/0", CodecChainSpec::lossless());
    let (bytes, manifest, report) = encode_store(&field, &ffcz_ps, &opts).unwrap();
    assert!(report.all_chunks_ok);
    assert_eq!(manifest.chains.len(), 2);
    assert_eq!(manifest.chains[0], ffcz_ps);
    assert_eq!(manifest.chains[1], CodecChainSpec::lossless());
    assert_eq!(manifest.chunks[0].chain, 1, "boundary chunk on lossless chain");
    assert_eq!(manifest.chunks[1].chain, 0, "interior chunk on default chain");
    // Per-chunk stats: the lossless chunk is exact, the FFCz chunk ran
    // POCS and stayed in bound.
    assert_eq!(manifest.chunks[0].stats.max_spatial_ratio, 0.0);
    assert_eq!(manifest.chunks[0].stats.pocs_iterations, 0);
    assert!(manifest.chunks[1].stats.pocs_iterations >= 1);
    assert!(manifest.chunks[1].stats.spatial_ok && manifest.chunks[1].stats.frequency_ok);

    let store = Store::from_bytes(bytes).unwrap();
    // The lossless chunk's region decodes bit-exactly.
    let r0 = store.read_region(&[0, 0, 0], &[6, 8, 8], 2).unwrap();
    let expect0 = extract_subarray(field.data(), field.shape(), &[0, 0, 0], &[6, 8, 8]);
    assert_eq!(r0.data(), &expect0[..]);
    // The FFCz chunk's region preserves its power spectrum per bin.
    let r1 = store.read_region(&[6, 0, 0], &[6, 8, 8], 2).unwrap();
    let chunk1 = Field::new(
        &[6, 8, 8],
        extract_subarray(field.data(), field.shape(), &[6, 0, 0], &[6, 8, 8]),
        field.precision(),
    );
    let ps0 = ffcz::fourier::power_spectrum(&chunk1);
    let ps1 = ffcz::fourier::power_spectrum(&r1);
    let max_rel = ps1.max_relative_error(&ps0);
    assert!(max_rel <= 1.1e-3, "power-spectrum rel err {max_rel}");
    // And a full decode agrees with the per-region reads.
    let full = store.decompress_all(2).unwrap();
    let full0 = extract_subarray(full.data(), full.shape(), &[0, 0, 0], &[6, 8, 8]);
    assert_eq!(&full0[..], r0.data());
}

/// A minimal runtime-registered base compressor: stores halved samples
/// exactly (halving/doubling a finite f64 is an exponent shift, so the
/// roundtrip is bit-exact for these fields). Its `name()` matches the
/// registry key, as the `Compressor` contract requires for archives.
struct DoublingCodec;

impl Compressor for DoublingCodec {
    fn name(&self) -> &'static str {
        "test-doubling"
    }

    fn compress(&self, field: &Field, _bound: ErrorBound) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        out.push(field.shape().len() as u8);
        for &d in field.shape() {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        out.push(match field.precision() {
            Precision::Single => 0u8,
            Precision::Double => 1u8,
        });
        for &v in field.data() {
            out.extend_from_slice(&(v / 2.0).to_le_bytes());
        }
        Ok(out)
    }

    fn decompress(&self, payload: &[u8]) -> Result<Field> {
        let ndim = payload[0] as usize;
        let mut pos = 1usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u64::from_le_bytes(payload[pos..pos + 8].try_into().unwrap()) as usize);
            pos += 8;
        }
        let precision = if payload[pos] == 0 {
            Precision::Single
        } else {
            Precision::Double
        };
        pos += 1;
        let data: Vec<f64> = payload[pos..]
            .chunks_exact(8)
            .map(|c| 2.0 * f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Field::new(&shape, data, precision))
    }
}

/// Acceptance criterion: a codec registered at runtime round-trips through
/// a store encode/decode and through `CodecChainSpec` bytes; unknown names
/// fail with actionable errors.
#[test]
fn registered_codec_roundtrips_through_store_and_spec_bytes() {
    register_codec("test-doubling", || Box::new(DoublingCodec) as Box<dyn Compressor>).unwrap();

    let field = grf_3d(&[8, 6, 4], 31);
    let chain = CodecChainSpec::base_only("test-doubling", BoundSpec::Relative(1e-6));
    // Spec bytes round-trip with the custom name.
    let spec_bytes = chain.to_bytes();
    let mut pos = 0;
    assert_eq!(
        CodecChainSpec::from_bytes(&spec_bytes, &mut pos).unwrap(),
        chain
    );

    let opts = StoreWriteOptions::new(&[4, 3, 2]).workers(2);
    let (bytes, manifest, report) = encode_store(&field, &chain, &opts).unwrap();
    assert!(report.all_chunks_ok);
    assert_eq!(manifest.chains[0], chain);
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(
        store.decompress_all(2).unwrap().data(),
        field.data(),
        "doubling codec is bit-exact"
    );

    // Unknown names fail with the registry's actionable error.
    let unknown = CodecChainSpec::base_only("not-a-codec", BoundSpec::Relative(1e-3));
    let err = encode_store(&field, &unknown, &opts).unwrap_err().to_string();
    assert!(
        err.contains("not-a-codec") && err.contains("register_codec"),
        "{err}"
    );
}

/// Frozen manifest v1 writer: byte-for-byte the layout the v1 store
/// encoder produced for a lossless archive (single store-wide codec spec,
/// no chain table, no checksums). The new reader must keep opening these.
fn v1_lossless_container(field: &Field, chunk_shape: &[usize]) -> Vec<u8> {
    let grid = ChunkGrid::new(field.shape(), chunk_shape).unwrap();
    let mut out = Vec::new();
    out.extend_from_slice(b"FFCZSTR1");
    let mut entries: Vec<(u64, u64)> = Vec::new();
    for i in 0..grid.chunk_count() {
        let coords = grid.chunk_coords(i);
        let origin = grid.chunk_origin(&coords);
        let extent = grid.chunk_extent(&coords);
        let sub = extract_subarray(field.data(), field.shape(), &origin, &extent);
        let mut raw = Vec::with_capacity(sub.len() * 8);
        for v in sub {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let payload = lossless_compress(&raw);
        entries.push((out.len() as u64, payload.len() as u64));
        out.extend_from_slice(&payload);
    }
    // Manifest v1.
    let mut m = Vec::new();
    varint::write(&mut m, 1); // version
    m.push(match field.precision() {
        Precision::Single => 0u8,
        Precision::Double => 1u8,
    });
    varint::write(&mut m, field.shape().len() as u64);
    for &d in field.shape() {
        varint::write(&mut m, d as u64);
    }
    for &d in chunk_shape {
        varint::write(&mut m, d as u64);
    }
    m.push(0u8); // legacy CodecSpec::Lossless
    varint::write(&mut m, entries.len() as u64);
    let flags = vec![true; entries.len()];
    m.extend_from_slice(&pack_flags(&flags)); // spatial_ok
    m.extend_from_slice(&pack_flags(&flags)); // frequency_ok
    for &(offset, length) in &entries {
        varint::write(&mut m, offset);
        varint::write(&mut m, length);
        m.extend_from_slice(&0.0f64.to_le_bytes()); // max_spatial_ratio
        m.extend_from_slice(&0.0f64.to_le_bytes()); // max_frequency_ratio
        varint::write(&mut m, 0); // pocs_iterations
    }
    let manifest_offset = out.len() as u64;
    out.extend_from_slice(&m);
    out.extend_from_slice(&manifest_offset.to_le_bytes());
    out.extend_from_slice(&(m.len() as u64).to_le_bytes());
    out.extend_from_slice(b"FFCZEND1");
    out
}

/// Acceptance criterion: a manifest v1 `.ffcz` fixture still opens,
/// inspects, and `read_region`s correctly under the new reader.
#[test]
fn manifest_v1_fixture_remains_readable() {
    let field = grf_3d(&[10, 6, 4], 19);
    let bytes = v1_lossless_container(&field, &[4, 4, 4]);

    // In-memory open.
    let store = Store::from_bytes(bytes.clone()).unwrap();
    let m = store.manifest();
    assert_eq!(m.shape, field.shape());
    assert_eq!(m.chains.len(), 1);
    assert_eq!(m.chains[0], CodecChainSpec::lossless());
    assert!(m.chunks.iter().all(|c| c.chain == 0 && c.crc32.is_none()));
    assert!(m.all_chunks_ok());

    // Full decode and partial reads are bit-exact.
    assert_eq!(store.decompress_all(2).unwrap().data(), field.data());
    let region = store.read_region(&[3, 1, 0], &[5, 4, 3], 2).unwrap();
    let expect = extract_subarray(field.data(), field.shape(), &[3, 1, 0], &[5, 4, 3]);
    assert_eq!(region.data(), &expect[..]);

    // File-based open (the `archive inspect` / `extract` path).
    let path = std::env::temp_dir().join("ffcz_v1_fixture_test.ffcz");
    std::fs::write(&path, &bytes).unwrap();
    let store = Store::open(&path).unwrap();
    assert_eq!(store.shape(), field.shape());
    assert_eq!(store.decompress_all(1).unwrap().data(), field.data());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_and_truncated_stores_are_rejected() {
    let field = grf_3d(&[8, 6, 4], 3);
    let opts = StoreWriteOptions::new(&[4, 3, 2]).workers(1);
    let (bytes, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();

    // Every truncation of the container fails to open.
    for frac in [0.1, 0.5, 0.9, 0.999] {
        let cut = (bytes.len() as f64 * frac) as usize;
        assert!(
            Store::from_bytes(bytes[..cut].to_vec()).is_err(),
            "truncated to {cut} bytes unexpectedly opened"
        );
    }

    // Corrupting the footer (manifest offset/length fields or the end
    // magic) must always fail to open. (Flips inside the manifest's stats
    // fields only change recorded stats; structural manifest corruption is
    // covered by the truncation sweep above and the manifest unit tests.)
    for i in [
        bytes.len() - 24, // manifest offset
        bytes.len() - 12, // manifest length
        bytes.len() - 4,  // footer magic
        0,                // head magic
    ] {
        let mut bad = bytes.clone();
        bad[i] ^= 0x5A;
        assert!(
            Store::from_bytes(bad).is_err(),
            "byte flip at {i} went unnoticed"
        );
    }

    // A payload flip is rejected by the per-chunk CRC with a precise
    // error, before any codec sees the bytes (ROADMAP checksum item).
    let mut bad = bytes.clone();
    bad[10] ^= 0xFF;
    let store = Store::from_bytes(bad).unwrap();
    let err = store.decompress_all(1).unwrap_err();
    assert!(
        format!("{err:#}").contains("CRC-32"),
        "payload corruption not attributed to checksums: {err:#}"
    );
}

/// Acceptance criterion: streaming and in-memory writers produce archives
/// that decode identically — in fact byte-identically, manifest and
/// trailer included, because the streaming sink consumes chunks in index
/// order regardless of worker count.
#[test]
fn streaming_and_in_memory_writers_produce_identical_files() {
    let field = grf_3d(&[12, 10, 8], 42);
    let spec = ffcz_spec("sz-like");
    let opts = StoreWriteOptions::new(&[5, 4, 3]).workers(3);
    let dir = std::env::temp_dir().join("ffcz_stream_vs_mem_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let p_stream = dir.join("streamed.ffcz");
    let p_mem = dir.join("in_memory.ffcz");

    let r_stream = write_store(&field, &spec, &opts, &p_stream).unwrap();
    let r_mem = write_store_in_memory(&field, &spec, &opts, &p_mem).unwrap();
    assert!(r_stream.streamed, "write_store streams by default");
    assert!(!r_mem.streamed);
    assert_eq!(r_stream.total_bytes, r_mem.total_bytes);

    let a = std::fs::read(&p_stream).unwrap();
    let b = std::fs::read(&p_mem).unwrap();
    assert_eq!(a, b, "streamed and in-memory files diverge");

    // Both decode through the ordinary reader path (CRCs verified).
    let fa = Store::open(&p_stream).unwrap().decompress_all(2).unwrap();
    let fb = Store::from_bytes(b).unwrap().decompress_all(2).unwrap();
    assert_eq!(fa.data(), fb.data());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance criterion: streaming a field ≥ 8× the chunk size never
/// holds more than (workers + queue_depth) chunk payloads at once —
/// asserted via the writer's payload-bytes-in-flight gauge — and the
/// archive decodes fully through the existing reader with per-chunk CRC
/// verification.
#[test]
fn streaming_write_bounds_payload_memory_and_roundtrips() {
    // 4 × 2 × 1 = 8 chunks; 2 workers + queue 2 → in-flight window of 4.
    let field = grf_3d(&[16, 8, 8], 47);
    let opts = StoreWriteOptions::new(&[4, 4, 8]).workers(2).queue_depth(2);
    assert_eq!(opts.window(), 4);

    let mut bytes = Vec::new();
    let (manifest, report) =
        stream_store_to(&field, &CodecChainSpec::lossless(), &opts, &mut bytes).unwrap();
    assert_eq!(manifest.chunks.len(), 8);
    assert!(report.streamed);

    let max_chunk = manifest.chunks.iter().map(|c| c.length).max().unwrap() as usize;
    assert!(
        report.peak_payload_bytes <= opts.window() * max_chunk,
        "peak {} exceeds window {} × max chunk {}",
        report.peak_payload_bytes,
        opts.window(),
        max_chunk
    );
    assert!(
        report.peak_payload_bytes < report.payload_bytes,
        "streaming held the entire payload ({} of {} bytes)",
        report.peak_payload_bytes,
        report.payload_bytes
    );

    // Full decode through the existing reader path, CRCs checked.
    assert!(manifest.chunks.iter().all(|c| c.crc32.is_some()));
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(store.decompress_all(2).unwrap().data(), field.data());
}

/// Acceptance criterion: cutting a streamed archive mid-chunk or
/// mid-manifest fails open/decode with the precise truncation error (the
/// trailer never made it to disk), via both the in-memory and the file
/// open paths.
#[test]
fn truncated_archives_fail_with_a_precise_error() {
    let field = grf_3d(&[8, 6, 4], 3);
    let opts = StoreWriteOptions::new(&[4, 3, 2]).workers(2);
    let dir = std::env::temp_dir().join("ffcz_truncation_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("full.ffcz");
    write_store(&field, &CodecChainSpec::lossless(), &opts, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let manifest = Store::open(&path).unwrap().manifest().clone();

    let footer_at = bytes.len() - 24;
    let manifest_offset =
        u64::from_le_bytes(bytes[footer_at..footer_at + 8].try_into().unwrap()) as usize;
    let mid_chunk = (manifest.chunks[0].offset + manifest.chunks[0].length / 2) as usize;
    let mid_manifest = manifest_offset + 5;
    let mid_trailer = bytes.len() - 10;
    for cut in [mid_chunk, mid_manifest, mid_trailer] {
        assert!(cut > 8 && cut < bytes.len(), "cut {cut} out of range");
        let err = Store::from_bytes(bytes[..cut].to_vec())
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("truncated or partially-written"),
            "cut at {cut}: unspecific error: {err}"
        );
        let trunc = dir.join(format!("cut_{cut}.ffcz"));
        std::fs::write(&trunc, &bytes[..cut]).unwrap();
        let err = format!("{:#}", Store::open(&trunc).unwrap_err());
        assert!(
            err.contains("truncated or partially-written"),
            "file cut at {cut}: unspecific error: {err}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_preserves_precision_tag() {
    let data: Vec<f64> = (0..24).map(|i| (i as f64) * 0.5).collect();
    let field = Field::new(&[4, 6], data, Precision::Single);
    let opts = StoreWriteOptions::new(&[2, 3]).workers(1);
    let (bytes, manifest, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();
    assert_eq!(manifest.precision, Precision::Single);
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(
        store.read_region(&[1, 2], &[2, 2], 1).unwrap().precision(),
        Precision::Single
    );
}
