//! Property-based tests (proptest is unavailable offline; cases are drawn
//! from the crate's seeded PRNG — deterministic, reproducible, and broad).
//!
//! Invariants under test:
//! 1. POCS output always lies in the s-cube ∩ f-cube (when converged);
//! 2. edits exactly reconstruct the correction (ε' = ε₀ + s + IFFT(f));
//! 3. the edit codec round-trips bit-exactly and its dequantization error
//!    is ≤ half a step;
//! 4. Huffman/bit-I/O/varint round-trip arbitrary data;
//! 5. every base compressor obeys its pointwise bound on adversarial
//!    random fields;
//! 6. FFT–IFFT identity on random shapes.

use ffcz::compressors::{paper_compressors, ErrorBound};
use ffcz::correction::{
    alternating_projection, check_dual_bounds, Bounds, PocsParams, QuantizedEdits,
};
use ffcz::data::{Field, Precision};
use ffcz::encoding::{huffman_decode, huffman_encode};
use ffcz::fourier::{fftn, ifftn, Complex};
use ffcz::util::XorShift;

const CASES: usize = 25;

fn random_shape(rng: &mut XorShift) -> Vec<usize> {
    match rng.below(3) {
        0 => vec![8 + rng.below(120)],
        1 => vec![4 + rng.below(12), 4 + rng.below(12)],
        _ => vec![3 + rng.below(5), 3 + rng.below(5), 3 + rng.below(5)],
    }
}

#[test]
fn prop_pocs_always_lands_in_intersection() {
    let mut rng = XorShift::new(0xB0C5);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        let e = rng.uniform(1e-4, 1.0);
        // Δ scaled to the expected |δ| magnitude so all regimes appear.
        let d = rng.uniform(0.05, 3.0) * e * (n as f64).sqrt();
        let eps0: Vec<f64> = (0..n).map(|_| rng.uniform(-e, e)).collect();
        let params = PocsParams {
            spatial: Bounds::Global(e),
            frequency: Bounds::Global(d),
            max_iters: 2000,
        };
        let r = alternating_projection(&eps0, &shape, &params);
        assert!(r.converged, "case {case} shape {shape:?} did not converge");
        let (s_ok, f_ok, ms, mf) =
            check_dual_bounds(&r.corrected_eps, &shape, &params.spatial, &params.frequency);
        assert!(
            s_ok && f_ok,
            "case {case} shape {shape:?}: max_s {ms} max_f {mf}"
        );
    }
}

#[test]
fn prop_edits_reconstruct_correction() {
    let mut rng = XorShift::new(77);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        let e = 0.1;
        let d = rng.uniform(0.1, 1.0) * e * (n as f64).sqrt();
        let eps0: Vec<f64> = (0..n).map(|_| rng.uniform(-e, e)).collect();
        let params = PocsParams {
            spatial: Bounds::Global(e),
            frequency: Bounds::Global(d),
            max_iters: 2000,
        };
        let r = alternating_projection(&eps0, &shape, &params);
        let mut freq = r.freq_edits.clone();
        ffcz::fourier::ifftn_inplace(&mut freq, &shape);
        for i in 0..n {
            let rebuilt = eps0[i] + r.spat_edits[i] + freq[i].re;
            assert!(
                (rebuilt - r.corrected_eps[i]).abs() < 1e-9,
                "case {case} idx {i}"
            );
        }
    }
}

#[test]
fn prop_edit_codec_roundtrip() {
    let mut rng = XorShift::new(1234);
    for _ in 0..CASES {
        let n = 100 + rng.below(5000);
        let density = rng.uniform(0.0, 0.3);
        let amp = 10f64.powf(rng.uniform(-6.0, 3.0));
        let edits: Vec<f64> = (0..n)
            .map(|_| {
                if rng.next_f64() < density {
                    rng.uniform(-amp, amp)
                } else {
                    0.0
                }
            })
            .collect();
        let q = QuantizedEdits::quantize(&edits);
        let bytes = q.to_bytes();
        let mut pos = 0;
        let q2 = QuantizedEdits::from_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(pos, bytes.len());
        assert_eq!(q, q2);
        let deq = q.dequantize();
        for (a, b) in edits.iter().zip(&deq) {
            assert!((a - b).abs() <= q.step / 2.0 + 1e-30);
        }
    }
}

#[test]
fn prop_huffman_roundtrip_arbitrary_symbols() {
    let mut rng = XorShift::new(555);
    for _ in 0..CASES {
        let n = rng.below(3000);
        let alphabet = 1 + rng.below(300) as u16;
        let syms: Vec<u16> = (0..n).map(|_| (rng.next_u64() as u16) % alphabet).collect();
        let enc = huffman_encode(&syms);
        let dec = huffman_decode(&enc, syms.len()).unwrap();
        assert_eq!(syms, dec);
    }
}

#[test]
fn prop_base_compressors_respect_bounds() {
    let mut rng = XorShift::new(9001);
    for case in 0..12 {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        // Adversarial: mixture of smooth + spikes + flat zero runs.
        let data: Vec<f64> = (0..n)
            .map(|i| {
                let smooth = (i as f64 * 0.1).sin() * 5.0;
                let spike = if rng.next_f64() < 0.01 {
                    rng.uniform(-100.0, 100.0)
                } else {
                    0.0
                };
                let zero_run = if (i / 37) % 3 == 0 { 0.0 } else { 1.0 };
                (smooth + spike) * zero_run
            })
            .collect();
        let field = Field::new(&shape, data, Precision::Double);
        let eb_rel = 10f64.powf(rng.uniform(-4.0, -2.0));
        let bound = ErrorBound::Relative(eb_rel);
        let eb = bound.absolute_for(&field);
        for base in paper_compressors() {
            let payload = base.compress(&field, bound).unwrap();
            let recon = base.decompress(&payload).unwrap();
            let max_err = field
                .data()
                .iter()
                .zip(recon.data())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(
                max_err <= eb * (1.0 + 1e-12),
                "case {case} {}: {max_err} > {eb}",
                base.name()
            );
        }
    }
}

#[test]
fn prop_fft_roundtrip_random_shapes() {
    let mut rng = XorShift::new(31337);
    for _ in 0..CASES {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        let x: Vec<Complex> = (0..n)
            .map(|_| Complex::new(rng.normal(), rng.normal()))
            .collect();
        let y = ifftn(&fftn(&x, &shape), &shape);
        let scale = x.iter().map(|c| c.abs()).fold(1.0f64, f64::max);
        for (a, b) in x.iter().zip(&y) {
            assert!((*a - *b).abs() < 1e-10 * scale);
        }
    }
}
