//! Property-based tests (proptest is unavailable offline; cases are drawn
//! from the crate's seeded PRNG — deterministic, reproducible, and broad).
//!
//! Invariants under test:
//! 1. POCS output always lies in the s-cube ∩ f-cube (when converged);
//! 2. edits exactly reconstruct the correction (ε' = ε₀ + s + IFFT(f));
//! 3. the edit codec round-trips bit-exactly and its dequantization error
//!    is ≤ half a step;
//! 4. Huffman/bit-I/O/varint round-trip arbitrary data;
//! 5. every base compressor obeys its pointwise bound on adversarial
//!    random fields;
//! 6. FFT–IFFT identity on random shapes;
//! 7. `rfftn`/`irfftn` match the complex `fftn` on random real inputs
//!    across pow2/odd/mixed N-D shapes (and round-trip);
//! 8. the half-spectrum POCS fast path reproduces
//!    `alternating_projection_reference` within 1e-10, with dual bounds
//!    verified by `check_dual_bounds` on every corrected output;
//! 9. a `CorrectionScratch` reused across chunks of different shapes and
//!    bound modes produces byte-identical archives to fresh-state
//!    encoding, and stops allocating once warmed on every shape.

use ffcz::compressors::{paper_compressors, ErrorBound};
use ffcz::correction::{
    alternating_projection, alternating_projection_reference, check_dual_bounds, Bounds,
    PocsParams, QuantizedEdits,
};
use ffcz::data::{Field, Precision};
use ffcz::encoding::{huffman_decode, huffman_encode};
use ffcz::fourier::{fftn, ifftn, irfftn, rfftn, Complex};
use ffcz::util::XorShift;

const CASES: usize = 25;

fn random_shape(rng: &mut XorShift) -> Vec<usize> {
    match rng.below(3) {
        0 => vec![8 + rng.below(120)],
        1 => vec![4 + rng.below(12), 4 + rng.below(12)],
        _ => vec![3 + rng.below(5), 3 + rng.below(5), 3 + rng.below(5)],
    }
}

#[test]
fn prop_pocs_always_lands_in_intersection() {
    let mut rng = XorShift::new(0xB0C5);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        let e = rng.uniform(1e-4, 1.0);
        // Δ scaled to the expected |δ| magnitude so all regimes appear.
        let d = rng.uniform(0.05, 3.0) * e * (n as f64).sqrt();
        let eps0: Vec<f64> = (0..n).map(|_| rng.uniform(-e, e)).collect();
        let params = PocsParams {
            spatial: Bounds::Global(e),
            frequency: Bounds::Global(d),
            max_iters: 2000,
            threads: 1,
        };
        let r = alternating_projection(&eps0, &shape, &params);
        assert!(r.converged, "case {case} shape {shape:?} did not converge");
        let (s_ok, f_ok, ms, mf) =
            check_dual_bounds(&r.corrected_eps, &shape, &params.spatial, &params.frequency);
        assert!(
            s_ok && f_ok,
            "case {case} shape {shape:?}: max_s {ms} max_f {mf}"
        );
    }
}

#[test]
fn prop_edits_reconstruct_correction() {
    let mut rng = XorShift::new(77);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        let e = 0.1;
        let d = rng.uniform(0.1, 1.0) * e * (n as f64).sqrt();
        let eps0: Vec<f64> = (0..n).map(|_| rng.uniform(-e, e)).collect();
        let params = PocsParams {
            spatial: Bounds::Global(e),
            frequency: Bounds::Global(d),
            max_iters: 2000,
            threads: 1,
        };
        let r = alternating_projection(&eps0, &shape, &params);
        let mut freq = r.freq_edits.expand();
        ffcz::fourier::ifftn_inplace(&mut freq, &shape);
        for i in 0..n {
            let rebuilt = eps0[i] + r.spat_edits[i] + freq[i].re;
            assert!(
                (rebuilt - r.corrected_eps[i]).abs() < 1e-9,
                "case {case} idx {i}"
            );
        }
    }
}

#[test]
fn prop_edit_codec_roundtrip() {
    let mut rng = XorShift::new(1234);
    for _ in 0..CASES {
        let n = 100 + rng.below(5000);
        let density = rng.uniform(0.0, 0.3);
        let amp = 10f64.powf(rng.uniform(-6.0, 3.0));
        let edits: Vec<f64> = (0..n)
            .map(|_| {
                if rng.next_f64() < density {
                    rng.uniform(-amp, amp)
                } else {
                    0.0
                }
            })
            .collect();
        let q = QuantizedEdits::quantize(&edits);
        let bytes = q.to_bytes();
        let mut pos = 0;
        let q2 = QuantizedEdits::from_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(pos, bytes.len());
        assert_eq!(q, q2);
        let deq = q.dequantize();
        for (a, b) in edits.iter().zip(&deq) {
            assert!((a - b).abs() <= q.step / 2.0 + 1e-30);
        }
    }
}

#[test]
fn prop_huffman_roundtrip_arbitrary_symbols() {
    let mut rng = XorShift::new(555);
    for _ in 0..CASES {
        let n = rng.below(3000);
        let alphabet = 1 + rng.below(300) as u16;
        let syms: Vec<u16> = (0..n).map(|_| (rng.next_u64() as u16) % alphabet).collect();
        let enc = huffman_encode(&syms);
        let dec = huffman_decode(&enc, syms.len()).unwrap();
        assert_eq!(syms, dec);
    }
}

#[test]
fn prop_base_compressors_respect_bounds() {
    let mut rng = XorShift::new(9001);
    for case in 0..12 {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        // Adversarial: mixture of smooth + spikes + flat zero runs.
        let data: Vec<f64> = (0..n)
            .map(|i| {
                let smooth = (i as f64 * 0.1).sin() * 5.0;
                let spike = if rng.next_f64() < 0.01 {
                    rng.uniform(-100.0, 100.0)
                } else {
                    0.0
                };
                let zero_run = if (i / 37) % 3 == 0 { 0.0 } else { 1.0 };
                (smooth + spike) * zero_run
            })
            .collect();
        let field = Field::new(&shape, data, Precision::Double);
        let eb_rel = 10f64.powf(rng.uniform(-4.0, -2.0));
        let bound = ErrorBound::Relative(eb_rel);
        let eb = bound.absolute_for(&field);
        for base in paper_compressors() {
            let payload = base.compress(&field, bound).unwrap();
            let recon = base.decompress(&payload).unwrap();
            let max_err = field
                .data()
                .iter()
                .zip(recon.data())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(
                max_err <= eb * (1.0 + 1e-12),
                "case {case} {}: {max_err} > {eb}",
                base.name()
            );
        }
    }
}

#[test]
fn prop_fft_roundtrip_random_shapes() {
    let mut rng = XorShift::new(31337);
    for _ in 0..CASES {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        let x: Vec<Complex> = (0..n)
            .map(|_| Complex::new(rng.normal(), rng.normal()))
            .collect();
        let y = ifftn(&fftn(&x, &shape), &shape);
        let scale = x.iter().map(|c| c.abs()).fold(1.0f64, f64::max);
        for (a, b) in x.iter().zip(&y) {
            assert!((*a - *b).abs() < 1e-10 * scale);
        }
    }
}

#[test]
fn prop_rfftn_matches_complex_fftn() {
    // The expanded half spectrum of a random real field equals the full
    // complex transform, and irfftn inverts rfftn — across pow2, odd
    // (Bluestein), and mixed N-D shapes.
    let mut rng = XorShift::new(0x5EC7);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let half = rfftn(&x, &shape);
        let expanded = half.expand();
        let buf: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
        let full = fftn(&buf, &shape);
        let scale = full.iter().map(|c| c.abs()).fold(1.0f64, f64::max);
        for (k, (a, b)) in expanded.iter().zip(&full).enumerate() {
            assert!(
                (*a - *b).abs() < 1e-9 * scale,
                "case {case} shape {shape:?} bin {k}: {a:?} vs {b:?}"
            );
        }
        let back = irfftn(&half);
        let xscale = x.iter().fold(1.0f64, |a, &v| a.max(v.abs()));
        for (i, (a, b)) in x.iter().zip(&back).enumerate() {
            assert!(
                (a - b).abs() < 1e-10 * xscale,
                "case {case} shape {shape:?} idx {i}"
            );
        }
    }
}

#[test]
fn prop_pocs_fast_path_matches_reference() {
    // The half-spectrum loop is the production path; the full-complex loop
    // is the oracle. Corrections, spatial edits, and expanded frequency
    // edits must agree to 1e-10, and the fast output must pass the
    // dual-bound verifier in its own right.
    let mut rng = XorShift::new(0xFA57);
    for case in 0..15 {
        let shape = random_shape(&mut rng);
        let n: usize = shape.iter().product();
        let e = rng.uniform(0.01, 0.5);
        let d = rng.uniform(0.1, 1.0) * e * (n as f64).sqrt();
        let eps0: Vec<f64> = (0..n).map(|_| rng.uniform(-e, e)).collect();
        let params = PocsParams {
            spatial: Bounds::Global(e),
            frequency: Bounds::Global(d),
            max_iters: 2000,
            threads: 1,
        };
        let fast = alternating_projection(&eps0, &shape, &params);
        let reference = alternating_projection_reference(&eps0, &shape, &params);
        // FFT-rounding differences can fire the convergence check one
        // iteration apart; the corrections still agree to 1e-10 below.
        assert!(
            fast.iterations.abs_diff(reference.iterations) <= 1,
            "case {case} shape {shape:?}: iterations {} vs {}",
            fast.iterations,
            reference.iterations
        );
        assert_eq!(fast.converged, reference.converged, "case {case}");
        // 1e-9, scaled by the bound magnitudes: covers FFT rounding plus
        // the sub-tolerance clips of a rounding-level extra iteration.
        let scale = 1e-9 * (1.0 + d);
        for i in 0..n {
            assert!(
                (fast.corrected_eps[i] - reference.corrected_eps[i]).abs() < scale,
                "case {case} shape {shape:?} corrected idx {i}"
            );
            assert!(
                (fast.spat_edits[i] - reference.spat_edits[i]).abs() < scale,
                "case {case} shape {shape:?} spat idx {i}"
            );
        }
        let ff = fast.freq_edits.expand();
        let rf = reference.freq_edits.expand();
        let fscale = 1e-9 * (d + e * (n as f64).sqrt());
        for k in 0..n {
            assert!(
                (ff[k] - rf[k]).abs() < fscale,
                "case {case} shape {shape:?} freq bin {k}"
            );
        }
        if fast.converged {
            let (s_ok, f_ok, ms, mf) =
                check_dual_bounds(&fast.corrected_eps, &shape, &params.spatial, &params.frequency);
            assert!(
                s_ok && f_ok,
                "case {case} shape {shape:?}: max_s {ms} max_f {mf}"
            );
        }
    }
}

/// 9. One `CorrectionScratch` driven across a sequence of chunks with
///    *different* shapes and bound modes produces archives byte-identical
///    to fresh-state encoding, and the scratch is workspace-stable: after
///    the first pass over all shapes, a second pass performs zero
///    allocation events.
#[test]
fn prop_scratch_reuse_bit_identical_across_shapes_and_bound_modes() {
    use ffcz::codec::{CodecChain, CodecChainSpec};
    use ffcz::compressors::{szlike::SzLike, Compressor};
    use ffcz::correction::{
        correct_reconstruction, correct_reconstruction_with_scratch, BoundSpec,
        CorrectionScratch, FfczConfig,
    };
    use ffcz::data::synth::{eeg::EegBuilder, grf::GrfBuilder};

    let base = SzLike::default();
    // (field, config): mixed dimensionalities and all three bound modes.
    let abs_field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(31).build();
    let abs_e = abs_field.value_span() * 1e-3;
    let cases: Vec<(Field, FfczConfig)> = vec![
        (
            GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(29).build(),
            FfczConfig::relative(1e-3, 1e-3),
        ),
        (
            GrfBuilder::new(&[8, 8, 8]).lognormal(1.0).seed(30).build(),
            FfczConfig::relative(1e-3, 1e-3),
        ),
        (
            EegBuilder::new(512).seed(32).build(),
            FfczConfig::relative(1e-3, 5e-4),
        ),
        (abs_field, FfczConfig::absolute(abs_e, abs_e)),
        (
            GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(33).build(),
            FfczConfig::power_spectrum(1e-2, 1e-3),
        ),
    ];

    let mut scratch = CorrectionScratch::new();
    let mut warm_events = 0u64;
    for pass in 0..2 {
        for (ci, (field, cfg)) in cases.iter().enumerate() {
            let bound = match cfg.spatial {
                BoundSpec::Absolute(v) => ErrorBound::Absolute(v),
                BoundSpec::Relative(r) => ErrorBound::Relative(r),
            };
            let payload = base.compress(field, bound).unwrap();
            let recon0 = base.decompress(&payload).unwrap();
            let fresh =
                correct_reconstruction(field, &recon0, "sz-like", payload.clone(), cfg).unwrap();
            let reused = correct_reconstruction_with_scratch(
                field,
                &recon0,
                "sz-like",
                payload.clone(),
                cfg,
                &mut scratch,
            )
            .unwrap();
            assert_eq!(
                fresh.to_bytes(),
                reused.to_bytes(),
                "pass {pass} case {ci}: scratch-reused archive differs from fresh"
            );
        }
        if pass == 0 {
            warm_events = scratch.allocation_events();
            assert!(warm_events > 0, "warm-up recorded no allocation events");
        }
    }
    assert_eq!(
        scratch.allocation_events(),
        warm_events,
        "scratch grew after warming on every shape"
    );

    // Codec-chain level: the store's per-worker entry point must be
    // byte-identical to the fresh-state one (covers the verify transform
    // and archive framing too).
    let chunk = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(34).build();
    let chain = CodecChain::from_spec(&CodecChainSpec::ffcz(
        "sz-like",
        &FfczConfig::relative(1e-3, 1e-3),
    ))
    .unwrap();
    let mut scratch = CorrectionScratch::new();
    let fresh = chain.encode_chunk(&chunk).unwrap();
    let reused = chain.encode_chunk_with_scratch(&chunk, &mut scratch).unwrap();
    assert_eq!(fresh.bytes, reused.bytes);
    assert_eq!(fresh.stats.spatial_ok, reused.stats.spatial_ok);
    assert_eq!(fresh.stats.frequency_ok, reused.stats.frequency_ok);
    // And a second encode through the warmed scratch allocates nothing.
    let warmed = scratch.allocation_events();
    let again = chain.encode_chunk_with_scratch(&chunk, &mut scratch).unwrap();
    assert_eq!(again.bytes, fresh.bytes);
    assert_eq!(
        scratch.allocation_events(),
        warmed,
        "steady-state chunk encode allocated scratch"
    );
}
