//! Integration: the PJRT runtime executes AOT artifacts and matches the
//! native Rust POCS engine. Skips (passes trivially) when `artifacts/` has
//! not been built — run `make artifacts` first for full coverage.

use std::path::Path;

use ffcz::correction::{alternating_projection, check_dual_bounds, Bounds, PocsParams};
use ffcz::runtime::PjrtEngine;
use ffcz::util::XorShift;

fn artifact_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

fn random_eps(n: usize, e: f64, seed: u64) -> Vec<f64> {
    let mut rng = XorShift::new(seed);
    (0..n).map(|_| rng.uniform(-e, e)).collect()
}

#[test]
fn pjrt_engine_loads_and_corrects_1d() {
    let Some(dir) = artifact_dir() else { return };
    let mut engine = PjrtEngine::new(dir).expect("engine");
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
    let shape = [4096usize];
    if !engine.supports_shape(&shape) {
        eprintln!("skipping: no 1d_4096 variant");
        return;
    }
    let (e, d) = (0.05, 1.0);
    let eps0 = random_eps(4096, e, 1);
    let result = engine.correct(&eps0, &shape, e, d).expect("correct");
    assert!(result.converged, "PJRT loop converged");
    // Dual bounds hold (f32 artifact ⇒ relaxed tolerance on the check).
    let (s_ok, f_ok, ms, mf) = check_dual_bounds(
        &result.corrected_eps,
        &shape,
        &Bounds::Global(e * (1.0 + 1e-3)),
        &Bounds::Global(d * (1.0 + 1e-3)),
    );
    assert!(s_ok && f_ok, "max_s {ms} max_f {mf}");
}

#[test]
fn pjrt_matches_native_engine() {
    let Some(dir) = artifact_dir() else { return };
    let mut engine = PjrtEngine::new(dir).expect("engine");
    let shape = [4096usize];
    if !engine.supports_shape(&shape) {
        return;
    }
    let (e, d) = (0.05, 1.2);
    let eps0 = random_eps(4096, e, 7);
    let pjrt = engine.correct(&eps0, &shape, e, d).expect("pjrt");
    let native = alternating_projection(
        &eps0,
        &shape,
        &PocsParams {
            spatial: Bounds::Global(e),
            frequency: Bounds::Global(d),
            max_iters: 64,
            threads: 1,
        },
    );
    assert_eq!(pjrt.converged, native.converged);
    // f32 vs f64 engines: compare within f32 tolerance.
    let mut max_d = 0.0f64;
    for (a, b) in pjrt.corrected_eps.iter().zip(&native.corrected_eps) {
        max_d = max_d.max((a - b).abs());
    }
    assert!(max_d < 5e-4, "engines diverge by {max_d}");
    // Iteration counts differ near the convergence boundary (f32 artifact
    // stops at 1e-4 relative tolerance, native f64 polishes to 1e-10), but
    // must stay in the same regime.
    let (pi, ni) = (pjrt.iterations as i64, native.iterations as i64);
    assert!(
        pi <= ni * 3 + 3 && ni <= pi * 3 + 3,
        "iterations {pi} vs {ni} — different regime"
    );
}

#[test]
fn pjrt_3d_variant_works() {
    let Some(dir) = artifact_dir() else { return };
    let mut engine = PjrtEngine::new(dir).expect("engine");
    let shape = [16usize, 16, 16];
    if !engine.supports_shape(&shape) {
        return;
    }
    let (e, d) = (0.1, 2.0);
    let eps0 = random_eps(4096, e, 3);
    let result = engine.correct(&eps0, &shape, e, d).expect("correct 3d");
    assert!(result.converged);
    let (s_ok, f_ok, ..) = check_dual_bounds(
        &result.corrected_eps,
        &shape,
        &Bounds::Global(e * (1.0 + 1e-3)),
        &Bounds::Global(d * (1.0 + 1e-3)),
    );
    assert!(s_ok && f_ok);
}

#[test]
fn unknown_shape_is_rejected() {
    let Some(dir) = artifact_dir() else { return };
    let mut engine = PjrtEngine::new(dir).expect("engine");
    let eps0 = vec![0.0; 12];
    assert!(engine.correct(&eps0, &[12], 0.1, 0.1).is_err());
}
