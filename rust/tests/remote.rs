//! Chaos tests for the remote HTTP-range backend and its resilience
//! layer, plus the degraded-mode archive server.
//!
//! The fixture here is a deliberately hostile HTTP range server: on a
//! deterministic, request-counter-driven schedule it injects slow
//! headers, truncated bodies, `429`/`503` bursts, connection resets, and
//! wrong-length ranges. Because the schedule is a pure function of the
//! global request index and every test drives reads sequentially, each
//! run is exactly replayable — the tests assert bit-identical bytes
//! against in-memory ground truth *and* exact deltas on the
//! `store.remote.*` counters (reruns of the same schedule must produce
//! the same deltas).
//!
//! Global telemetry counters are process-wide, so every test in this
//! binary serializes through [`guard`].
//!
//! `FFCZ_REMOTE_SWEEP=quick` shrinks the sweep for CI smoke runs.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use ffcz::codec::CodecChainSpec;
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::server::{protocol, status_of, ArchiveServer, Client, ServeOptions};
use ffcz::store::{
    breaker_open_of, encode_store, extract_subarray, read_exact_at, BreakerConfig, HedgeConfig,
    HttpRangeServer, HttpStorage, ResilienceOptions, ResilientStorage, RetryPolicy,
    StoreWriteOptions,
};
use ffcz::telemetry;

/// Serialize tests that assert on process-global telemetry counters.
fn guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn counter(name: &str) -> u64 {
    telemetry::counter(name).get()
}

/// Number of sweep reads; `FFCZ_REMOTE_SWEEP=quick` is the CI smoke
/// setting.
fn sweep_reads() -> usize {
    match std::env::var("FFCZ_REMOTE_SWEEP").as_deref() {
        Ok("quick") => 36,
        _ => 180,
    }
}

fn fixture_bytes(n: usize) -> Vec<u8> {
    (0..n).map(|i| ((i * 131 + 17) % 251) as u8).collect()
}

// ---------------------------------------------------- hostile fixture --

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fault {
    /// Serve correctly.
    None,
    /// Serve correctly, but only after a long pause before the headers.
    SlowHeaders,
    /// Correct headers, half the body, then close the connection.
    Truncate,
    /// Close the connection before writing anything.
    Reset,
    Http429,
    Http503,
    /// `Content-Length` seven bytes longer than the requested range.
    WrongLength,
}

impl Fault {
    /// Whether the client experiences this as a failed request (slow
    /// headers succeed — they just hurt).
    fn is_failure(self) -> bool {
        !matches!(self, Fault::None | Fault::SlowHeaders)
    }
}

/// Deterministic fault schedule: every `period`-th request (1-based
/// global request index) faults, cycling through `kinds` in order.
/// `period >= 2` guarantees faults are never adjacent, so a retry
/// budget of one always heals.
#[derive(Clone)]
struct FaultSchedule {
    period: u64,
    kinds: Vec<Fault>,
}

impl FaultSchedule {
    fn fault_for(&self, req: u64) -> Fault {
        if self.period == 0 || req % self.period != 0 {
            return Fault::None;
        }
        self.kinds[((req / self.period - 1) as usize) % self.kinds.len()]
    }
}

/// Pause injected by [`Fault::SlowHeaders`].
const SLOW_HEADERS: Duration = Duration::from_millis(300);

/// An HTTP/1.1 range server that misbehaves on a deterministic
/// schedule. Protocol-correct otherwise: single-range `GET`s answer
/// `206` with `Content-Range`/`Content-Length`.
struct FlakyServer {
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    /// Global request counter — the schedule's clock.
    requests: Arc<AtomicU64>,
}

impl FlakyServer {
    fn start(bytes: Vec<u8>, schedule: FaultSchedule) -> (Self, String) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let requests = Arc::new(AtomicU64::new(0));
        let bytes = Arc::new(bytes);
        let (loop_stop, loop_reqs) = (Arc::clone(&stop), Arc::clone(&requests));
        let accept = std::thread::spawn(move || {
            let mut handlers = Vec::new();
            while !loop_stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((conn, _)) => {
                        let (b, s, st, rq) = (
                            Arc::clone(&bytes),
                            schedule.clone(),
                            Arc::clone(&loop_stop),
                            Arc::clone(&loop_reqs),
                        );
                        handlers.push(std::thread::spawn(move || {
                            serve_flaky_connection(conn, &b, &s, &st, &rq)
                        }));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(5)),
                }
            }
            for h in handlers {
                let _ = h.join();
            }
        });
        let url = format!("http://{addr}/data");
        (
            Self {
                stop,
                accept: Some(accept),
                requests,
            },
            url,
        )
    }

    fn request_count(&self) -> u64 {
        self.requests.load(Ordering::SeqCst)
    }

    fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FlakyServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Read one request head; `Ok(None)` = idle timeout, `Err` = peer gone.
fn read_request_head(conn: &mut TcpStream) -> std::io::Result<Option<Vec<u8>>> {
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        match conn.read(&mut byte) {
            Ok(0) => return Err(std::io::ErrorKind::UnexpectedEof.into()),
            Ok(_) => head.push(byte[0]),
            Err(e)
                if head.is_empty()
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
            {
                return Ok(None)
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Some(head))
}

/// Extract `Range: bytes=F-L` from a request head.
fn parse_range(head: &[u8]) -> Option<(u64, u64)> {
    let text = std::str::from_utf8(head).ok()?;
    for line in text.split("\r\n") {
        let (name, value) = match line.split_once(':') {
            Some(pair) => pair,
            None => continue,
        };
        if name.eq_ignore_ascii_case("range") {
            let spec = value.trim().strip_prefix("bytes=")?;
            let (first, last) = spec.split_once('-')?;
            return Some((first.trim().parse().ok()?, last.trim().parse().ok()?));
        }
    }
    None
}

fn serve_flaky_connection(
    mut conn: TcpStream,
    bytes: &[u8],
    schedule: &FaultSchedule,
    stop: &AtomicBool,
    requests: &AtomicU64,
) {
    let _ = conn.set_read_timeout(Some(Duration::from_millis(20)));
    let _ = conn.set_nodelay(true);
    let total = bytes.len() as u64;
    while !stop.load(Ordering::SeqCst) {
        let head = match read_request_head(&mut conn) {
            Ok(Some(head)) => head,
            Ok(None) => continue,
            Err(_) => return,
        };
        let Some((first, last)) = parse_range(&head) else {
            return;
        };
        let req = requests.fetch_add(1, Ordering::SeqCst) + 1;
        let fault = schedule.fault_for(req);
        if fault == Fault::Reset {
            return;
        }
        if fault == Fault::SlowHeaders {
            std::thread::sleep(SLOW_HEADERS);
        }
        let status_only = |conn: &mut TcpStream, line: &str| {
            conn.write_all(format!("HTTP/1.1 {line}\r\nContent-Length: 0\r\n\r\n").as_bytes())
        };
        match fault {
            Fault::Http429 => {
                if status_only(&mut conn, "429 Too Many Requests").is_err() {
                    return;
                }
                continue;
            }
            Fault::Http503 => {
                if status_only(&mut conn, "503 Service Unavailable").is_err() {
                    return;
                }
                continue;
            }
            _ => {}
        }
        if first >= total {
            let head = format!(
                "HTTP/1.1 416 Range Not Satisfiable\r\nContent-Range: bytes */{total}\r\nContent-Length: 0\r\n\r\n"
            );
            if conn.write_all(head.as_bytes()).is_err() {
                return;
            }
            continue;
        }
        let last = last.min(total - 1);
        let body = &bytes[first as usize..=last as usize];
        let announced = match fault {
            Fault::WrongLength => body.len() as u64 + 7,
            _ => body.len() as u64,
        };
        let head = format!(
            "HTTP/1.1 206 Partial Content\r\nContent-Range: bytes {first}-{last}/{total}\r\nContent-Length: {announced}\r\n\r\n"
        );
        if conn.write_all(head.as_bytes()).is_err() {
            return;
        }
        match fault {
            Fault::WrongLength => continue, // client bails on the header
            Fault::Truncate => {
                let _ = conn.write_all(&body[..body.len() / 2]);
                return; // close mid-body
            }
            _ => {
                if conn.write_all(body).is_err() {
                    return;
                }
            }
        }
    }
}

// ------------------------------------------------------------- sweeps --

fn xorshift(mut x: u64) -> u64 {
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    x
}

/// Deterministic (offset, length) list for the sweep.
fn sweep_plan(object_len: usize, reads: usize, seed: u64) -> Vec<(u64, usize)> {
    let mut state = seed;
    (0..reads)
        .map(|_| {
            state = xorshift(state);
            let len = 1 + (state % 1500) as usize;
            state = xorshift(state);
            let offset = state % (object_len - len) as u64;
            (offset, len)
        })
        .collect()
}

/// Requests the client will issue for `plan` under `schedule`, starting
/// after `consumed` requests: (failed requests == expected retries,
/// final request counter). Mirrors the client exactly: each failed
/// request is retried once more until a request succeeds; faults are
/// never adjacent (period >= 2), so one retry always heals.
fn simulate(schedule: &FaultSchedule, consumed: u64, reads: usize) -> (u64, u64) {
    let mut req = consumed;
    let mut failures = 0u64;
    for _ in 0..reads {
        loop {
            req += 1;
            if schedule.fault_for(req).is_failure() {
                failures += 1;
            } else {
                break;
            }
        }
    }
    (failures, req)
}

/// One full sweep against a fresh hostile server: returns every read's
/// bytes, the `store.remote.{requests,retries,hedges}` deltas, and the
/// server-observed request count.
fn run_sweep(
    bytes: &[u8],
    schedule: &FaultSchedule,
    plan: &[(u64, usize)],
) -> (Vec<Vec<u8>>, [u64; 3], u64) {
    let (server, url) = FlakyServer::start(bytes.to_vec(), schedule.clone());
    let http = HttpStorage::open_with_timeout(&url, Duration::from_secs(10)).unwrap();
    let resilient = ResilientStorage::new(
        Arc::new(http),
        ResilienceOptions {
            retry: RetryPolicy::transient(4, Duration::from_micros(200)),
            deadline: None,
            breaker: BreakerConfig {
                failure_threshold: 0, // breaker exercised by its own test
                cooldown: Duration::ZERO,
            },
            hedge: HedgeConfig::default(),
        },
    );
    let before = [
        counter("store.remote.requests"),
        counter("store.remote.retries"),
        counter("store.remote.hedges"),
    ];
    let mut outputs = Vec::with_capacity(plan.len());
    for &(offset, len) in plan {
        let mut buf = vec![0u8; len];
        read_exact_at(&resilient, offset, &mut buf).unwrap();
        outputs.push(buf);
    }
    let deltas = [
        counter("store.remote.requests") - before[0],
        counter("store.remote.retries") - before[1],
        counter("store.remote.hedges") - before[2],
    ];
    let served = server.request_count();
    server.shutdown();
    (outputs, deltas, served)
}

/// The tentpole chaos sweep: every injected fault class on a
/// deterministic schedule; every read must come back bit-identical to
/// ground truth; the retry counter delta must match the schedule
/// *exactly*; and a replay of the same schedule must reproduce both.
#[test]
fn chaos_sweep_is_bit_exact_with_exact_and_replayable_counter_deltas() {
    let _guard = guard();
    let bytes = fixture_bytes(32 * 1024);
    let schedule = FaultSchedule {
        period: 3,
        kinds: vec![
            Fault::Http503,
            Fault::Truncate,
            Fault::Reset,
            Fault::WrongLength,
            Fault::Http429,
            Fault::SlowHeaders,
        ],
    };
    let plan = sweep_plan(bytes.len(), sweep_reads(), 0x00C0FFEE);
    // Request #1 is the size probe `HttpStorage::open` issues (the
    // schedule leaves it clean; `open` does not retry).
    let (expected_retries, expected_requests) = simulate(&schedule, 1, plan.len());

    let (outputs, deltas, served) = run_sweep(&bytes, &schedule, &plan);
    for (i, &(offset, len)) in plan.iter().enumerate() {
        assert_eq!(
            outputs[i],
            &bytes[offset as usize..offset as usize + len],
            "read #{i} (offset {offset}, len {len}) diverged from ground truth"
        );
    }
    assert_eq!(
        deltas[0],
        plan.len() as u64,
        "store.remote.requests must count one per read_at"
    );
    assert_eq!(
        deltas[1], expected_retries,
        "store.remote.retries must match the fault schedule exactly"
    );
    assert_eq!(deltas[2], 0, "hedging is disabled in this sweep");
    assert_eq!(
        served, expected_requests,
        "server-observed request count must match the simulation"
    );

    // Deterministic replay: a fresh server, the same schedule and plan.
    let (outputs2, deltas2, served2) = run_sweep(&bytes, &schedule, &plan);
    assert_eq!(outputs, outputs2, "replay produced different bytes");
    assert_eq!(deltas, deltas2, "replay produced different counter deltas");
    assert_eq!(served, served2, "replay produced different request counts");
}

/// Endpoint outage: the breaker trips after exactly `failure_threshold`
/// consecutive failures, fails fast with a typed [`BreakerOpen`] while
/// open, then half-opens and recovers once the endpoint is back on the
/// same address — with exact transition counter deltas.
#[test]
fn breaker_trips_fails_fast_and_recovers_when_the_endpoint_returns() {
    let _guard = guard();
    let bytes = fixture_bytes(8 * 1024);
    let (server, url) = HttpRangeServer::single(bytes.clone()).unwrap();
    let addr = url
        .strip_prefix("http://")
        .and_then(|rest| rest.split('/').next())
        .unwrap()
        .to_string();
    let http = HttpStorage::open_with_timeout(&url, Duration::from_secs(10)).unwrap();
    let resilient = ResilientStorage::new(
        Arc::new(http),
        ResilienceOptions {
            retry: RetryPolicy::none(),
            deadline: None,
            breaker: BreakerConfig {
                failure_threshold: 2,
                cooldown: Duration::from_millis(100),
            },
            hedge: HedgeConfig::default(),
        },
    );
    let mut buf = vec![0u8; 256];
    read_exact_at(&resilient, 100, &mut buf).unwrap();
    assert_eq!(&buf[..], &bytes[100..356]);

    let before = [
        counter("store.remote.breaker.opens"),
        counter("store.remote.breaker.half_opens"),
        counter("store.remote.breaker.closes"),
        counter("store.remote.breaker.rejections"),
        counter("store.remote.retries"),
    ];
    server.shutdown();
    // Two consecutive failures (a stale pooled connection, then a
    // refused dial) trip the threshold-2 breaker.
    for _ in 0..2 {
        let err = resilient.read_at(100, &mut buf).unwrap_err();
        assert!(
            breaker_open_of(&err).is_none(),
            "pre-trip failures must come from the endpoint, not the breaker"
        );
    }
    assert_eq!(resilient.breaker().state_name(), "open");

    // While open: typed fail-fast, nothing on the wire.
    let err = resilient.read_at(100, &mut buf).unwrap_err();
    let open = breaker_open_of(&err).expect("expected a typed BreakerOpen");
    assert_eq!(open.endpoint, format!("http://{addr}/data"));

    // The endpoint comes back on the same address; after the cooldown a
    // half-open probe succeeds, closes the breaker, and the read is
    // bit-exact again.
    let revived = HttpRangeServer::start_on(&addr, vec![("data".to_string(), bytes.clone())])
        .expect("rebinding the endpoint's address");
    std::thread::sleep(Duration::from_millis(150));
    read_exact_at(&resilient, 100, &mut buf).unwrap();
    assert_eq!(&buf[..], &bytes[100..356]);
    assert_eq!(resilient.breaker().state_name(), "closed");

    let deltas = [
        counter("store.remote.breaker.opens") - before[0],
        counter("store.remote.breaker.half_opens") - before[1],
        counter("store.remote.breaker.closes") - before[2],
        counter("store.remote.breaker.rejections") - before[3],
        counter("store.remote.retries") - before[4],
    ];
    assert_eq!(
        deltas,
        [1, 1, 1, 1, 0],
        "breaker transition counters [opens, half_opens, closes, rejections, retries]"
    );
    revived.shutdown();
}

/// A hedged read rescues a read whose primary request hits the
/// slow-headers fault: the hedge fires after the fixed trigger, wins,
/// and the counters record exactly one hedge and one hedge win.
#[test]
fn hedged_read_rescues_a_slow_primary_with_exact_counter_deltas() {
    let _guard = guard();
    let bytes = fixture_bytes(8 * 1024);
    // Requests 2, 4, 6, … stall before their headers; request 1 is the
    // clean size probe. The single sweep read's primary is request 2
    // (slow) and its hedge is request 3 (fast).
    let schedule = FaultSchedule {
        period: 2,
        kinds: vec![Fault::SlowHeaders],
    };
    let (server, url) = FlakyServer::start(bytes.clone(), schedule);
    let http = HttpStorage::open_with_timeout(&url, Duration::from_secs(10)).unwrap();
    let resilient = ResilientStorage::new(
        Arc::new(http),
        ResilienceOptions {
            retry: RetryPolicy::none(),
            deadline: None,
            breaker: BreakerConfig {
                failure_threshold: 0,
                cooldown: Duration::ZERO,
            },
            hedge: HedgeConfig {
                enabled: true,
                after: Some(Duration::from_millis(30)),
                ..HedgeConfig::default()
            },
        },
    );
    let before = [
        counter("store.remote.hedges"),
        counter("store.remote.hedge_wins"),
        counter("store.remote.retries"),
    ];
    let mut buf = vec![0u8; 512];
    let started = Instant::now();
    read_exact_at(&resilient, 1000, &mut buf).unwrap();
    assert!(
        started.elapsed() < SLOW_HEADERS,
        "hedge did not rescue the slow primary ({:?})",
        started.elapsed()
    );
    assert_eq!(&buf[..], &bytes[1000..1512]);
    let deltas = [
        counter("store.remote.hedges") - before[0],
        counter("store.remote.hedge_wins") - before[1],
        counter("store.remote.retries") - before[2],
    ];
    assert_eq!(deltas, [1, 1, 0], "[hedges, hedge_wins, retries]");
    server.shutdown();
}

// ----------------------------------------------- degraded-mode server --

/// The acceptance scenario: `ffcz serve` on a remote root survives its
/// endpoint dying mid-stream. Cached regions keep answering `ST_OK`
/// bit-exact, uncached regions answer `ST_DEGRADED`, the connection and
/// ping stay alive, and once the endpoint returns the shared breaker
/// half-opens, recovers, and full reads are bit-exact again.
#[test]
fn serve_survives_a_remote_endpoint_kill_and_recovers() {
    let _guard = guard();
    let field = GrfBuilder::new(&[12, 10])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(31)
        .build();
    let opts = StoreWriteOptions::new(&[5, 4]).workers(1);
    let (archive, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();

    let endpoint = HttpRangeServer::start(vec![("field.ffcz".to_string(), archive.clone())]).unwrap();
    let endpoint_addr = endpoint
        .root_url()
        .strip_prefix("http://")
        .unwrap()
        .to_string();
    let server = ArchiveServer::start(ServeOptions {
        remote_root: Some(endpoint.root_url()),
        degraded: true,
        resilience: ResilienceOptions {
            retry: RetryPolicy::none(),
            deadline: None,
            breaker: BreakerConfig {
                failure_threshold: 2,
                cooldown: Duration::from_millis(150),
            },
            hedge: HedgeConfig::default(),
        },
        ..ServeOptions::default()
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();

    // Warm the cache: the window covering exactly chunk (0, 0).
    let warm = client.read_region("field", &[0, 0], &[5, 4]).unwrap();
    let want_warm = extract_subarray(field.data(), field.shape(), &[0, 0], &[5, 4]);
    assert_eq!(warm.data(), &want_warm[..]);

    let before = [
        counter("store.remote.breaker.opens"),
        counter("store.remote.breaker.half_opens"),
        counter("store.remote.breaker.closes"),
        counter("server.requests.degraded"),
    ];

    // Kill the endpoint mid-stream.
    endpoint.shutdown();

    // Fully cached region: still ST_OK, still bit-exact.
    let cached = client.read_region("field", &[0, 0], &[5, 4]).unwrap();
    assert_eq!(cached.data(), &want_warm[..]);

    // A region needing uncached chunks: a typed ST_DEGRADED error frame.
    let err = client
        .read_region("field", &[0, 0], &[12, 10])
        .expect_err("uncached region must degrade while the endpoint is down");
    assert_eq!(
        status_of(&err),
        Some(protocol::ST_DEGRADED),
        "expected ST_DEGRADED, got: {err:#}"
    );

    // The server itself stays healthy.
    client.ping().unwrap();

    // Endpoint returns on the same address; after the breaker cooldown
    // the half-open probe succeeds and full reads are bit-exact again.
    let revived =
        HttpRangeServer::start_on(&endpoint_addr, vec![("field.ffcz".to_string(), archive)])
            .expect("rebinding the endpoint's address");
    std::thread::sleep(Duration::from_millis(300));
    let full = client.read_region("field", &[0, 0], &[12, 10]).unwrap();
    assert_eq!(full.data(), field.data(), "post-recovery read diverged");

    let deltas = [
        counter("store.remote.breaker.opens") - before[0],
        counter("store.remote.breaker.half_opens") - before[1],
        counter("store.remote.breaker.closes") - before[2],
        counter("server.requests.degraded") - before[3],
    ];
    assert_eq!(
        deltas,
        [1, 1, 1, 1],
        "[breaker.opens, breaker.half_opens, breaker.closes, server degraded answers]"
    );

    client.shutdown_server().unwrap();
    server.join();
    revived.shutdown();
}
