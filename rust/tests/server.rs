//! Integration tests for the archive read server, driven by a minimal
//! client **derived from `docs/SERVER.md`** rather than from
//! `ffcz::server::protocol`.
//!
//! The wire spec in `docs/SERVER.md` is normative; this file keeps it
//! honest. At run time the test re-parses the spec's constants table and
//! (a) cross-checks every value against the implementation's constants,
//! then (b) hand-builds raw frames from the *documented* values only and
//! drives a real file-backed server with them. If someone edits an
//! opcode, status, or cap in the code without updating the document —
//! or vice versa — these tests fail.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;

use ffcz::codec::CodecChainSpec;
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::Field;
use ffcz::server::{protocol, ArchiveServer, ServeOptions};
use ffcz::store::{encode_store, extract_subarray, StoreWriteOptions};

/// Parse the constants table of `docs/SERVER.md`: every row shaped
/// `| \`NAME\` | \`VALUE\` |` with a hex (`0x..`) or decimal value.
fn doc_constants() -> HashMap<String, u64> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/SERVER.md");
    let text = std::fs::read_to_string(path).expect("docs/SERVER.md must exist");
    let mut out = HashMap::new();
    for line in text.lines() {
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        // A table row splits as ["", name, value, ""].
        if cells.len() != 4 || cells[0] != "" || cells[3] != "" {
            continue;
        }
        let (name, value) = (cells[1], cells[2]);
        let (Some(name), Some(value)) = (
            name.strip_prefix('`').and_then(|s| s.strip_suffix('`')),
            value.strip_prefix('`').and_then(|s| s.strip_suffix('`')),
        ) else {
            continue;
        };
        let parsed = match value.strip_prefix("0x") {
            Some(hex) => u64::from_str_radix(hex, 16),
            None => value.parse(),
        };
        if let Ok(v) = parsed {
            out.insert(name.to_string(), v);
        }
    }
    out
}

/// Every documented constant must match the implementation, and every
/// implementation constant must be documented — drift in either
/// direction fails.
#[test]
fn documented_constants_match_the_implementation() {
    let doc = doc_constants();
    let code: [(&str, u64); 17] = [
        ("OP_PING", protocol::OP_PING as u64),
        ("OP_STAT", protocol::OP_STAT as u64),
        ("OP_READ_REGION", protocol::OP_READ_REGION as u64),
        ("OP_SHUTDOWN", protocol::OP_SHUTDOWN as u64),
        ("ST_OK", protocol::ST_OK as u64),
        ("ST_BAD_REQUEST", protocol::ST_BAD_REQUEST as u64),
        ("ST_UNKNOWN_ARCHIVE", protocol::ST_UNKNOWN_ARCHIVE as u64),
        ("ST_BAD_REGION", protocol::ST_BAD_REGION as u64),
        ("ST_IO", protocol::ST_IO as u64),
        ("ST_INTERNAL", protocol::ST_INTERNAL as u64),
        ("ST_TOO_LARGE", protocol::ST_TOO_LARGE as u64),
        ("ST_BUSY", protocol::ST_BUSY as u64),
        ("ST_DEGRADED", protocol::ST_DEGRADED as u64),
        ("PREC_F64", protocol::PREC_F64 as u64),
        ("PREC_F32", protocol::PREC_F32 as u64),
        ("MAX_REQUEST_FRAME", protocol::MAX_REQUEST_FRAME as u64),
        ("MAX_RESPONSE_FRAME", protocol::DEFAULT_MAX_RESPONSE_FRAME as u64),
    ];
    for (name, want) in code {
        assert_eq!(
            doc.get(name).copied(),
            Some(want),
            "docs/SERVER.md constant `{name}` disagrees with the code \
             (documented {:?}, implemented {want})",
            doc.get(name)
        );
    }
    assert_eq!(
        doc.len(),
        code.len(),
        "docs/SERVER.md documents constants the code does not define: {:?}",
        doc.keys()
            .filter(|k| !code.iter().any(|(n, _)| n == k))
            .collect::<Vec<_>>()
    );
}

/// Minimal wire client implemented from the document alone: raw
/// `TcpStream`, hand-rolled little-endian framing, constants taken from
/// the parsed table (never from `ffcz::server::protocol`).
struct DocClient {
    stream: TcpStream,
    c: HashMap<String, u64>,
}

impl DocClient {
    fn connect(addr: &str) -> Self {
        Self {
            stream: TcpStream::connect(addr).unwrap(),
            c: doc_constants(),
        }
    }

    fn op(&self, name: &str) -> u8 {
        self.c[name] as u8
    }

    fn send(&mut self, body: &[u8]) {
        let mut frame = (body.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(body);
        self.stream.write_all(&frame).unwrap();
    }

    fn recv(&mut self) -> Vec<u8> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        self.stream.read_exact(&mut body).unwrap();
        body
    }

    fn name_bytes(name: &str) -> Vec<u8> {
        let mut out = (name.len() as u16).to_le_bytes().to_vec();
        out.extend_from_slice(name.as_bytes());
        out
    }

    fn ping(&mut self) -> Vec<u8> {
        self.send(&[self.op("OP_PING")]);
        self.recv()
    }

    fn stat(&mut self, name: &str) -> Vec<u8> {
        let mut body = vec![self.op("OP_STAT")];
        body.extend_from_slice(&Self::name_bytes(name));
        self.send(&body);
        self.recv()
    }

    fn read_region(&mut self, name: &str, origin: &[u64], shape: &[u64]) -> Vec<u8> {
        let mut body = vec![self.op("OP_READ_REGION")];
        body.extend_from_slice(&Self::name_bytes(name));
        body.push(origin.len() as u8);
        for &v in origin {
            body.extend_from_slice(&v.to_le_bytes());
        }
        for &v in shape {
            body.extend_from_slice(&v.to_le_bytes());
        }
        self.send(&body);
        self.recv()
    }

    fn shutdown(&mut self) -> Vec<u8> {
        self.send(&[self.op("OP_SHUTDOWN")]);
        self.recv()
    }
}

fn u64_at(body: &[u8], pos: &mut usize) -> u64 {
    let v = u64::from_le_bytes(body[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    v
}

fn fixture(dir: &PathBuf) -> Field {
    let field = GrfBuilder::new(&[12, 10])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(31)
        .build();
    let opts = StoreWriteOptions::new(&[5, 4]).workers(1);
    let (bytes, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("field.ffcz"), bytes).unwrap();
    field
}

/// Full doc-derived round trip against a file-backed server: ping, stat
/// (with `.ffcz` name resolution), a bit-exact region read, the
/// documented error statuses, and shutdown — all framed by hand from
/// the documented constants.
#[test]
fn doc_derived_client_round_trips_against_a_file_backed_server() {
    let root = std::env::temp_dir().join(format!("ffcz_server_doc_{}", std::process::id()));
    let field = fixture(&root);
    let server = ArchiveServer::start(ServeOptions {
        root: Some(root.clone()),
        ..ServeOptions::default()
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut client = DocClient::connect(&addr);
    let st_ok = client.c["ST_OK"] as u8;

    assert_eq!(client.ping(), vec![st_ok]);

    // STAT by bare name: the server must resolve `root/field.ffcz`.
    let stat = client.stat("field");
    assert_eq!(stat[0], st_ok);
    assert_eq!(stat[1], 2, "rank");
    let mut pos = 2;
    assert_eq!([u64_at(&stat, &mut pos), u64_at(&stat, &mut pos)], [12, 10]);
    assert_eq!([u64_at(&stat, &mut pos), u64_at(&stat, &mut pos)], [5, 4]);
    assert_eq!(u64_at(&stat, &mut pos), 9, "3×3 chunk grid");
    let payload_bytes = u64_at(&stat, &mut pos);
    assert!(payload_bytes > 0);
    assert_eq!(stat[pos] as u64, client.c["PREC_F64"]);
    assert_eq!(pos + 1, stat.len(), "STAT payload longer than documented");

    // READ_REGION, decoded per the documented layout, bit-identical to
    // the ground-truth slice of the source field (lossless chain).
    let (origin, shape) = ([3u64, 2], [6u64, 7]);
    let body = client.read_region("field", &origin, &shape);
    assert_eq!(body[0], st_ok);
    assert_eq!(body[1], 2, "rank");
    let mut pos = 2;
    assert_eq!([u64_at(&body, &mut pos), u64_at(&body, &mut pos)], [6, 7]);
    assert_eq!(body[pos] as u64, client.c["PREC_F64"]);
    pos += 1;
    let mut samples = Vec::with_capacity(42);
    for _ in 0..42 {
        samples.push(f64::from_bits(u64_at(&body, &mut pos)));
    }
    assert_eq!(pos, body.len(), "READ_REGION payload longer than documented");
    let want = extract_subarray(field.data(), field.shape(), &[3, 2], &[6, 7]);
    let want_bits: Vec<u64> = want.iter().map(|v| v.to_bits()).collect();
    let got_bits: Vec<u64> = samples.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got_bits, want_bits, "region diverged from ground truth");

    // Documented error statuses, each with a UTF-8 message tail and a
    // connection that keeps serving afterwards. (Clone the constants so
    // the closure does not hold a borrow across the client calls.)
    let consts = client.c.clone();
    let check_error = move |body: &[u8], status_name: &str| {
        assert_eq!(body[0] as u64, consts[status_name], "{status_name}");
        let msg_len = u16::from_le_bytes(body[1..3].try_into().unwrap()) as usize;
        assert_eq!(body.len(), 3 + msg_len, "{status_name} message framing");
        assert!(
            std::str::from_utf8(&body[3..]).is_ok(),
            "{status_name} message must be UTF-8"
        );
    };
    let unknown = client.stat("missing");
    check_error(&unknown, "ST_UNKNOWN_ARCHIVE");
    let traversal = client.stat("../escape");
    check_error(&traversal, "ST_BAD_REQUEST");
    let bad_region = client.read_region("field", &[10, 0], &[6, 4]);
    check_error(&bad_region, "ST_BAD_REGION");
    let bad_rank = client.read_region("field", &[0], &[4]);
    check_error(&bad_rank, "ST_BAD_REGION");
    let unknown_op = {
        client.send(&[0x7E]);
        client.recv()
    };
    check_error(&unknown_op, "ST_BAD_REQUEST");
    assert_eq!(client.ping(), vec![st_ok], "connection must survive errors");

    assert_eq!(client.shutdown(), vec![st_ok]);
    server.join();
    let _ = std::fs::remove_dir_all(&root);
}

/// An oversized request frame is a protocol violation: the documented
/// behaviour is that the server drops the connection (no response).
#[test]
fn oversized_request_frames_drop_the_connection() {
    let server = ArchiveServer::start(ServeOptions::default()).unwrap();
    let addr = server.local_addr().to_string();
    let doc = doc_constants();
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    // Announce a body one byte over the documented cap; the server must
    // reject it from the header alone, so no body needs to be sent.
    let len = (doc["MAX_REQUEST_FRAME"] + 1) as u32;
    stream.write_all(&len.to_le_bytes()).unwrap();
    stream.flush().unwrap();
    let mut buf = [0u8; 16];
    // The only acceptable outcome is EOF (or a reset) — never a frame.
    match stream.read(&mut buf) {
        Ok(0) => {}
        Ok(n) => panic!("server answered {n} bytes instead of dropping the connection"),
        Err(e)
            if e.kind() == std::io::ErrorKind::TimedOut
                || e.kind() == std::io::ErrorKind::WouldBlock =>
        {
            panic!("server neither answered nor dropped the connection")
        }
        Err(_) => {} // reset is fine too
    }
    server.shutdown();
}
