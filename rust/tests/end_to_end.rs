//! End-to-end integration: every base compressor × every synthetic dataset
//! × several bound configurations must compress, round-trip through bytes,
//! decompress, and satisfy the dual-domain guarantee.

use ffcz::compressors::paper_compressors;
use ffcz::correction::{compress, decompress, verify, FfczArchive, FfczConfig};
use ffcz::data::synth;
use ffcz::metrics::QualityReport;

#[test]
fn full_matrix_dual_bounds() {
    let suite = synth::benchmark_suite(16);
    for (name, field) in &suite {
        for base in paper_compressors() {
            let cfg = FfczConfig::relative(1e-3, 1e-3);
            let archive = compress(field, base.as_ref(), &cfg)
                .unwrap_or_else(|e| panic!("{name}/{}: compress failed: {e:#}", base.name()));
            // Byte round-trip.
            let bytes = archive.to_bytes();
            let back = FfczArchive::from_bytes(&bytes)
                .unwrap_or_else(|e| panic!("{name}/{}: parse failed: {e:#}", base.name()));
            let recon = decompress(&back)
                .unwrap_or_else(|e| panic!("{name}/{}: decompress failed: {e:#}", base.name()));
            assert_eq!(recon.shape(), field.shape());
            let rep = verify(field, &recon, &cfg);
            assert!(
                rep.spatial_ok && rep.frequency_ok,
                "{name}/{}: dual bound violated ({rep:?})",
                base.name()
            );
        }
    }
}

#[test]
fn tighter_frequency_bounds_still_hold() {
    let field = synth::grf::GrfBuilder::new(&[24, 24])
        .lognormal(2.0)
        .seed(77)
        .build();
    for base in paper_compressors() {
        for db in [1e-3, 1e-4, 1e-5] {
            let cfg = FfczConfig::relative(1e-3, db);
            let archive = compress(&field, base.as_ref(), &cfg).unwrap();
            let recon = decompress(&archive).unwrap();
            let rep = verify(&field, &recon, &cfg);
            assert!(
                rep.spatial_ok && rep.frequency_ok,
                "{} @ db={db}: {rep:?}",
                base.name()
            );
        }
    }
}

#[test]
fn quality_never_worse_than_base_alone() {
    let field = synth::turbulence::TurbulenceBuilder::new(&[20, 20, 20])
        .seed(5)
        .build();
    for base in paper_compressors() {
        let payload = base
            .compress(&field, ffcz::compressors::ErrorBound::Relative(1e-3))
            .unwrap();
        let recon_base = base.decompress(&payload).unwrap();
        let q_base = QualityReport::compute(&field, &recon_base);

        let cfg = FfczConfig::relative(1e-3, 1e-4);
        let archive = compress(&field, base.as_ref(), &cfg).unwrap();
        let recon = decompress(&archive).unwrap();
        let q = QualityReport::compute(&field, &recon);
        assert!(
            q.max_rfe <= q_base.max_rfe * 1.01,
            "{}: RFE {} vs base {}",
            base.name(),
            q.max_rfe,
            q_base.max_rfe
        );
        assert!(
            q.psnr_db >= q_base.psnr_db - 0.2,
            "{}: PSNR {} vs base {}",
            base.name(),
            q.psnr_db,
            q_base.psnr_db
        );
    }
}

#[test]
fn one_dimensional_and_odd_shapes() {
    // Non-power-of-two and 1D shapes exercise Bluestein + all paths.
    for shape in [vec![1000usize], vec![17, 31], vec![7, 9, 11]] {
        let n: usize = shape.iter().product();
        let data: Vec<f64> = (0..n)
            .map(|i| (i as f64 * 0.17).sin() * 3.0 + (i as f64 * 0.031).cos())
            .collect();
        let field = ffcz::data::Field::new(&shape, data, ffcz::data::Precision::Double);
        let base = ffcz::compressors::szlike::SzLike::default();
        let cfg = FfczConfig::relative(1e-3, 1e-3);
        let archive = compress(&field, &base, &cfg).unwrap();
        let recon = decompress(&archive).unwrap();
        let rep = verify(&field, &recon, &cfg);
        assert!(rep.spatial_ok && rep.frequency_ok, "shape {shape:?}: {rep:?}");
    }
}

#[test]
fn corrupted_archives_error_cleanly() {
    let field = synth::eeg::EegBuilder::new(1024).seed(1).build();
    let base = ffcz::compressors::szlike::SzLike::default();
    let cfg = FfczConfig::relative(1e-3, 1e-3);
    let bytes = compress(&field, &base, &cfg).unwrap().to_bytes();
    // Truncations at various points must error, never panic.
    for cut in [0, 4, 10, bytes.len() / 2, bytes.len() - 1] {
        let r = FfczArchive::from_bytes(&bytes[..cut]);
        assert!(r.is_err(), "truncation at {cut} accepted");
    }
    // A bit flip must error or produce a parseable-but-different archive —
    // never panic.
    let mut flipped = bytes.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0xFF;
    let _ = FfczArchive::from_bytes(&flipped); // no panic = pass
}

#[test]
fn power_spectrum_mode_across_compressors() {
    let field = synth::grf::GrfBuilder::new(&[24, 24])
        .lognormal(1.5)
        .seed(9)
        .build();
    for base in paper_compressors() {
        let cfg = FfczConfig::power_spectrum(1e-3, 1e-3);
        let archive = compress(&field, base.as_ref(), &cfg).unwrap();
        let recon = decompress(&archive).unwrap();
        let ps0 = ffcz::fourier::power_spectrum(&field);
        let ps1 = ffcz::fourier::power_spectrum(&recon);
        assert!(
            ps1.max_relative_error(&ps0) <= 1.1e-3,
            "{}: spectrum ribbon violated",
            base.name()
        );
    }
}
