//! `docs/FORMAT.md` conformance tests.
//!
//! The format document is normative: a third party must be able to write
//! an independent parser (or writer) from it alone. These tests keep it
//! honest in three ways:
//!
//! 1. the constants quoted in the doc's § 1.2 table are machine-checked
//!    against the implementation;
//! 2. a fresh manifest-v2 archive is walked byte by byte with a parser
//!    implemented **from the document's tables only** (its own varint,
//!    CRC-32, and bit-flag readers — nothing from `store::manifest`);
//! 3. a manifest-v1 container is **written** following the document alone
//!    and must open and decode bit-exactly through the real reader;
//! 4. the recovery-journal sidecar left behind by an interrupted write is
//!    walked record by record following § 8.1 and cross-checked against
//!    the manifest of the committed archive.

use std::collections::HashMap;

use ffcz::codec::CodecChainSpec;
use ffcz::correction::FfczConfig;
use ffcz::data::synth::grf::GrfBuilder;
use ffcz::data::Precision;
use ffcz::encoding::lossless_compress;
use ffcz::store::{
    encode_store, extract_subarray, resume_store_write, staging_paths, write_store_faulted,
    FaultPlan, Store, StoreWriteOptions,
};

fn format_doc() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/FORMAT.md");
    std::fs::read_to_string(path).expect("docs/FORMAT.md is part of the repository")
}

/// Extract the § 1.2 constants table through the `xtask` parser — the
/// same code the `format-constants` lint reads the document with, so
/// this test and the lint can never disagree about what the table says.
fn doc_constants(doc: &str) -> HashMap<String, String> {
    xtask::docparse::format_constants(doc)
        .into_iter()
        .map(|c| (c.name, c.value))
        .collect()
}

/// Unsigned LEB128 as specified in § 1.1 (independent of
/// `ffcz::encoding::varint`).
fn doc_varint(buf: &[u8], pos: &mut usize) -> u64 {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = buf[*pos];
        *pos += 1;
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return v;
        }
        shift += 7;
    }
}

fn doc_varint_write(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// CRC-32 as specified in § 1.1: reflected polynomial `0xEDB88320`, init
/// and final XOR `0xFFFFFFFF` (bitwise, independent of
/// `ffcz::encoding::crc32`).
fn doc_crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
        }
    }
    crc ^ 0xFFFF_FFFF
}

fn doc_read_f64(buf: &[u8], pos: &mut usize) -> f64 {
    let v = f64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    v
}

#[test]
fn doc_constants_match_the_implementation() {
    let c = doc_constants(&format_doc());
    assert_eq!(
        c.get("STORE_MAGIC").map(String::as_bytes),
        Some(&ffcz::store::manifest::STORE_MAGIC[..])
    );
    assert_eq!(
        c.get("FOOTER_MAGIC").map(String::as_bytes),
        Some(&ffcz::store::manifest::FOOTER_MAGIC[..])
    );
    assert_eq!(
        c["FOOTER_LEN"].parse::<usize>().unwrap(),
        ffcz::store::manifest::FOOTER_LEN
    );
    assert_eq!(
        c["MANIFEST_VERSION"].parse::<u64>().unwrap(),
        ffcz::store::manifest::MANIFEST_VERSION
    );
    assert_eq!(
        c["MIN_MANIFEST_VERSION"].parse::<u64>().unwrap(),
        ffcz::store::manifest::MIN_MANIFEST_VERSION
    );
    assert_eq!(
        c["CHAIN_SPEC_VERSION"].parse::<u8>().unwrap(),
        ffcz::codec::CHAIN_SPEC_VERSION
    );
    assert_eq!(
        c.get("JOURNAL_MAGIC").map(String::as_bytes),
        Some(&ffcz::store::manifest::JOURNAL_MAGIC[..])
    );
    // Lossless-frame codec bytes: documented, implemented, and the
    // reserved real-libzstd byte is refused with the documented
    // "rebuild with real zstd" direction (never decoded).
    assert_eq!(
        c["LOSSLESS_CODEC_RAW"].parse::<u8>().unwrap(),
        ffcz::encoding::LOSSLESS_CODEC_RAW
    );
    assert_eq!(
        c["LOSSLESS_CODEC_ZSTD"].parse::<u8>().unwrap(),
        ffcz::encoding::LOSSLESS_CODEC_ZSTD
    );
    assert_eq!(
        c["LOSSLESS_CODEC_LIBZSTD"].parse::<u8>().unwrap(),
        ffcz::encoding::LOSSLESS_CODEC_LIBZSTD
    );
    let payload = b"spectrum-preserving".repeat(64);
    let frame = ffcz::encoding::lossless_compress(&payload);
    assert!(
        frame[0] == ffcz::encoding::LOSSLESS_CODEC_RAW
            || frame[0] == ffcz::encoding::LOSSLESS_CODEC_ZSTD,
        "writers emit only the documented raw/zstd codec bytes"
    );
    assert_eq!(ffcz::encoding::lossless_decompress(&frame).unwrap(), payload);
    let mut libzstd_frame = frame.clone();
    libzstd_frame[0] = ffcz::encoding::LOSSLESS_CODEC_LIBZSTD;
    let err = ffcz::encoding::lossless_decompress(&libzstd_frame)
        .unwrap_err()
        .to_string();
    assert!(err.contains("rebuild with real zstd"), "got: {err}");

    // The documented CRC-32 parameters produce the documented check value
    // — and both agree with the implementation.
    let check = u32::from_str_radix(c["CRC32_CHECK"].trim_start_matches("0x"), 16).unwrap();
    assert_eq!(doc_crc32(b"123456789"), check);
    assert_eq!(ffcz::encoding::crc32(b"123456789"), check);
    assert_eq!(ffcz::encoding::CRC32_CHECK, check);
    // Varint example quoted in § 1.1: 300 → AC 02.
    let mut buf = Vec::new();
    doc_varint_write(&mut buf, 300);
    assert_eq!(buf, [0xAC, 0x02]);
}

/// Walk a freshly written v2 archive following §§ 2–5 and 7 of the doc,
/// using only the independent readers above, and cross-check the result
/// against the real reader.
#[test]
fn v2_archive_walks_by_the_documented_layout() {
    let field = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(17).build();
    let ffcz_chain = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
    // 2 × 2 grid with a lossless override: two chain-table entries.
    let opts = StoreWriteOptions::new(&[4, 4])
        .workers(2)
        .override_chunk("c/0/0", CodecChainSpec::lossless());
    let (bytes, manifest, report) = encode_store(&field, &ffcz_chain, &opts).unwrap();
    assert!(report.all_chunks_ok);

    // § 2 container framing, § 3 trailer.
    assert_eq!(&bytes[..8], b"FFCZSTR1");
    let n = bytes.len();
    assert_eq!(&bytes[n - 8..], b"FFCZEND1");
    let manifest_offset =
        u64::from_le_bytes(bytes[n - 24..n - 16].try_into().unwrap()) as usize;
    let manifest_len = u64::from_le_bytes(bytes[n - 16..n - 8].try_into().unwrap()) as usize;
    assert!(manifest_offset >= 8);
    assert_eq!(manifest_offset + manifest_len, n - 24);

    // § 4 manifest, field by field.
    let m = &bytes[manifest_offset..manifest_offset + manifest_len];
    let mut p = 0usize;
    assert_eq!(doc_varint(m, &mut p), 2, "manifest version");
    assert_eq!(m[p], 1, "precision tag: double");
    p += 1;
    let ndim = doc_varint(m, &mut p) as usize;
    assert_eq!(ndim, 2);
    let shape: Vec<u64> = (0..ndim).map(|_| doc_varint(m, &mut p)).collect();
    assert_eq!(shape, [8, 8]);
    let chunk_shape: Vec<u64> = (0..ndim).map(|_| doc_varint(m, &mut p)).collect();
    assert_eq!(chunk_shape, [4, 4]);

    // § 4 field 7 chain table, entries per § 7.
    let n_chains = doc_varint(m, &mut p) as usize;
    assert_eq!(n_chains, 2);
    let mut base_names = Vec::new();
    for _ in 0..n_chains {
        let len = doc_varint(m, &mut p) as usize;
        let spec = &m[p..p + len];
        p += len;
        let mut q = 0usize;
        assert_eq!(spec[q], 1, "chain spec version");
        q += 1;
        let array_tag = spec[q];
        q += 1;
        match array_tag {
            0 => {} // raw-f64: no further array-stage fields
            1 => {
                let name_len = doc_varint(spec, &mut q) as usize;
                base_names
                    .push(String::from_utf8(spec[q..q + name_len].to_vec()).unwrap());
                q += name_len;
                assert!(spec[q] <= 1, "bound spec tag");
                q += 1 + 8; // tag + f64 LE
            }
            t => panic!("undocumented array-stage tag {t}"),
        }
        let correction = spec[q];
        q += 1;
        match correction {
            0 => {}
            1 => {
                assert_ne!(array_tag, 0, "correction over raw-f64 is invalid per § 7");
                assert!(spec[q] <= 2, "frequency spec tag");
                q += 1 + 8; // tag + f64 LE
                doc_varint(spec, &mut q); // max iterations
                doc_varint(spec, &mut q); // max quant retries
            }
            t => panic!("undocumented correction flag {t}"),
        }
        let n_stages = doc_varint(spec, &mut q) as usize;
        for _ in 0..n_stages {
            let l = doc_varint(spec, &mut q) as usize;
            assert!(std::str::from_utf8(&spec[q..q + l]).is_ok());
            q += l;
        }
        assert_eq!(q, len, "chain spec consumed exactly its length prefix");
    }
    assert_eq!(base_names, ["sz-like"], "chain 0 is the store default");

    // § 4 fields 8–12: chunk table. Grid per § 5: ceil(8/4)² = 4 chunks.
    let count = doc_varint(m, &mut p) as usize;
    assert_eq!(count, 4);
    let table_flags = m[p];
    p += 1;
    assert_eq!(table_flags, 0x01, "TABLE_FLAG_CRC32 and nothing else");
    let flag_bytes = count.div_ceil(8);
    let s_ok = &m[p..p + flag_bytes];
    p += flag_bytes;
    let f_ok = &m[p..p + flag_bytes];
    p += flag_bytes;
    let mut cursor = 8u64; // this implementation writes payloads contiguously
    for i in 0..count {
        let chain = doc_varint(m, &mut p) as usize;
        assert!(chain < n_chains, "chain index in table range");
        let offset = doc_varint(m, &mut p);
        let length = doc_varint(m, &mut p);
        assert_eq!(offset, cursor, "contiguous row-major payloads");
        assert!(offset + length <= manifest_offset as u64, "payload region");
        let crc = u32::from_le_bytes(m[p..p + 4].try_into().unwrap());
        p += 4;
        let payload = &bytes[offset as usize..(offset + length) as usize];
        assert_eq!(crc, doc_crc32(payload), "chunk {i} CRC-32 per § 1.1");
        let spatial_ratio = doc_read_f64(m, &mut p);
        let frequency_ratio = doc_read_f64(m, &mut p);
        assert!(spatial_ratio <= 1.0 + 1e-9 && frequency_ratio <= 1.0 + 1e-9);
        doc_varint(m, &mut p); // POCS iterations
        // Bit-packed flags, MSB-first per § 1.1.
        assert_ne!(s_ok[i / 8] & (0x80 >> (i % 8)), 0, "chunk {i} spatial_ok");
        assert_ne!(f_ok[i / 8] & (0x80 >> (i % 8)), 0, "chunk {i} frequency_ok");
        cursor = offset + length;
    }
    assert_eq!(p, m.len(), "no trailing manifest bytes");
    assert_eq!(cursor as usize, manifest_offset, "payloads tile the region");

    // Cross-check against the real reader: same structure, decodable.
    assert_eq!(manifest.chunks.len(), count);
    let store = Store::from_bytes(bytes).unwrap();
    assert_eq!(store.shape(), &[8, 8]);
    assert!(store.decompress_all(2).is_ok());
}

/// Write a manifest-v1 container following only §§ 2, 3, 5, and 6 of the
/// doc (chunk payload content is opaque to the container, § 7.1, so the
/// crate's lossless coder supplies it) and require the real reader to
/// open and decode it bit-exactly through the documented v1 shim.
#[test]
fn v1_archive_written_from_the_doc_alone_is_readable() {
    let field = GrfBuilder::new(&[6, 5]).lognormal(1.0).seed(4).build();
    assert_eq!(field.precision(), Precision::Double);
    // Chunk shape [3, 5]: a 2 × 1 grid per § 5.
    let chunk_shape = [3usize, 5];
    let origins = [[0usize, 0], [3, 0]];
    let extents = [[3usize, 5], [3, 5]];

    let mut out = Vec::new();
    out.extend_from_slice(b"FFCZSTR1"); // § 2 head magic
    let mut entries: Vec<(u64, u64)> = Vec::new();
    for (origin, extent) in origins.iter().zip(&extents) {
        let sub = extract_subarray(field.data(), field.shape(), origin, extent);
        let mut raw = Vec::with_capacity(sub.len() * 8);
        for v in sub {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let payload = lossless_compress(&raw);
        entries.push((out.len() as u64, payload.len() as u64));
        out.extend_from_slice(&payload);
    }

    // § 6 manifest version 1.
    let mut m = Vec::new();
    doc_varint_write(&mut m, 1); // version
    m.push(1u8); // precision: double
    doc_varint_write(&mut m, 2); // ndim
    doc_varint_write(&mut m, 6); // array shape
    doc_varint_write(&mut m, 5);
    doc_varint_write(&mut m, 3); // chunk shape
    doc_varint_write(&mut m, 5);
    m.push(0u8); // legacy codec spec tag 0: lossless
    doc_varint_write(&mut m, 2); // chunk count
    m.push(0b1100_0000); // spatial_ok: both chunks, MSB-first
    m.push(0b1100_0000); // frequency_ok
    for &(offset, length) in &entries {
        doc_varint_write(&mut m, offset);
        doc_varint_write(&mut m, length);
        m.extend_from_slice(&0.0f64.to_le_bytes()); // max spatial ratio
        m.extend_from_slice(&0.0f64.to_le_bytes()); // max frequency ratio
        doc_varint_write(&mut m, 0); // POCS iterations
    }

    // § 3 trailer.
    let manifest_offset = out.len() as u64;
    out.extend_from_slice(&m);
    out.extend_from_slice(&manifest_offset.to_le_bytes());
    out.extend_from_slice(&(m.len() as u64).to_le_bytes());
    out.extend_from_slice(b"FFCZEND1");

    let store = Store::from_bytes(out).unwrap();
    let manifest = store.manifest();
    assert_eq!(manifest.shape, field.shape());
    assert_eq!(manifest.chains, vec![CodecChainSpec::lossless()]);
    assert!(manifest.chunks.iter().all(|c| c.crc32.is_none()));
    assert_eq!(
        store.decompress_all(1).unwrap().data(),
        field.data(),
        "doc-built v1 archive decodes bit-exactly"
    );
}

/// Walk the recovery-journal sidecar of an interrupted write following
/// § 8.1 of the doc — its own varint and CRC-32 readers only — and
/// cross-check every record against the manifest the committed archive
/// ends up with.
#[test]
fn recovery_journal_walks_by_the_documented_layout() {
    let field = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(17).build();
    let chain = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
    let opts = StoreWriteOptions::new(&[4, 4])
        .workers(1)
        .override_chunk("c/0/0", CodecChainSpec::lossless());
    let (want, manifest, _) = encode_store(&field, &chain, &opts).unwrap();

    let path = std::env::temp_dir().join(format!("ffcz_fmt_jrn_{}.ffcz", std::process::id()));
    let (tmp, jrn) = staging_paths(&path);
    for p in [&path, &tmp, &jrn] {
        let _ = std::fs::remove_file(p);
    }

    // Probe run: a fault-free plan through the injector learns the op
    // count of this exact write sequence (the schedule is deterministic).
    let (_, counts) = write_store_faulted(&field, &chain, &opts, &path, FaultPlan::none())
        .expect("fault-free probe write commits");
    std::fs::remove_file(&path).expect("removing the probe archive");

    // Interrupt at the manifest write: every payload is staged and every
    // journal record is durable, but no commit record exists.
    let plan = FaultPlan {
        fail_ops: vec![counts.ops - 1],
        ..FaultPlan::none()
    };
    write_store_faulted(&field, &chain, &opts, &path, plan)
        .expect_err("the injected manifest-write failure surfaces");
    assert!(!path.exists(), "no partial archive under the final name");

    // § 8.1: head magic, then one framed record per staged payload.
    let jrn_bytes = std::fs::read(&jrn).expect("the journal sidecar survives the crash");
    let tmp_bytes = std::fs::read(&tmp).expect("the staging file survives the crash");
    assert_eq!(&jrn_bytes[..8], b"FFCZJRN1", "JOURNAL_MAGIC per § 1.2");
    let mut pos = 8usize;
    let mut index = 0usize;
    while pos < jrn_bytes.len() {
        let body_len = doc_varint(&jrn_bytes, &mut pos) as usize;
        let body = &jrn_bytes[pos..pos + body_len];
        let crc =
            u32::from_le_bytes(jrn_bytes[pos + body_len..pos + body_len + 4].try_into().unwrap());
        assert_eq!(crc, doc_crc32(body), "record {index} framing CRC per § 1.1");
        pos += body_len + 4;

        let mut b = 0usize;
        assert_eq!(doc_varint(body, &mut b) as usize, index, "contiguous chunk indices");
        let chunk_chain = doc_varint(body, &mut b) as usize;
        let offset = doc_varint(body, &mut b);
        let length = doc_varint(body, &mut b);
        let payload_crc = u32::from_le_bytes(body[b..b + 4].try_into().unwrap());
        b += 4;
        let flags = body[b];
        b += 1;
        assert_eq!(flags & !0b11, 0, "only bits 0 and 1 are defined");
        let spatial_ratio = doc_read_f64(body, &mut b);
        let frequency_ratio = doc_read_f64(body, &mut b);
        let pocs_iterations = doc_varint(body, &mut b);
        assert_eq!(b, body.len(), "record body consumed exactly its length prefix");

        // A trusted record's payload range lies in the staging file and
        // checksums to the recorded payload CRC-32.
        let payload = &tmp_bytes[offset as usize..(offset + length) as usize];
        assert_eq!(payload_crc, doc_crc32(payload), "chunk {index} payload CRC-32");

        // Cross-check: the journal record carries exactly what the
        // committed manifest's chunk-table row will say.
        let entry = &manifest.chunks[index];
        assert_eq!(chunk_chain, entry.chain);
        assert_eq!(offset, entry.offset);
        assert_eq!(length, entry.length);
        assert_eq!(Some(payload_crc), entry.crc32);
        assert_eq!(flags & 1 != 0, entry.stats.spatial_ok);
        assert_eq!(flags & 2 != 0, entry.stats.frequency_ok);
        assert_eq!(spatial_ratio.to_bits(), entry.stats.max_spatial_ratio.to_bits());
        assert_eq!(frequency_ratio.to_bits(), entry.stats.max_frequency_ratio.to_bits());
        assert_eq!(pocs_iterations, u64::from(entry.stats.pocs_iterations));
        index += 1;
    }
    assert_eq!(index, manifest.chunks.len(), "one journal record per chunk");

    // Resuming from this crash point salvages everything and commits an
    // archive byte-identical to an uninterrupted write.
    let report = resume_store_write(&field, &chain, &opts, &path).expect("resume commits");
    assert_eq!(report.salvaged_chunks, manifest.chunks.len());
    assert_eq!(report.reencoded_chunks, 0);
    assert_eq!(std::fs::read(&path).unwrap(), want, "byte-identical per § 8.1");
    assert!(!tmp.exists() && !jrn.exists(), "commit removes the staging pair");
    std::fs::remove_file(&path).expect("removing the test archive");
}
