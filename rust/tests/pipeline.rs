//! Coordinator integration: pipelined vs sequential equivalence, sharding
//! round-trips under correction, and backpressure behaviour.

use ffcz::compressors::szlike::SzLike;
use ffcz::coordinator::{run_pipeline, shard_field, unshard_field, ExecMode, PipelineConfig};
use ffcz::correction::{decompress, verify, FfczConfig};
use ffcz::data::synth;

#[test]
fn sharded_correction_roundtrip() {
    // A large 3D snapshot sharded into slabs, each independently corrected,
    // then reassembled: every shard (and thus the whole) within bounds.
    let field = synth::grf::GrfBuilder::new(&[24, 16, 16])
        .lognormal(1.5)
        .seed(3)
        .build();
    let shards = shard_field(&field, 3);
    assert_eq!(shards.len(), 3);
    let base = SzLike::default();
    let cfg = FfczConfig::relative(1e-3, 1e-3);
    let mut recon_shards = Vec::new();
    for shard in &shards {
        let archive = ffcz::correction::compress(shard, &base, &cfg).unwrap();
        let recon = decompress(&archive).unwrap();
        let rep = verify(shard, &recon, &cfg);
        assert!(rep.spatial_ok && rep.frequency_ok);
        recon_shards.push(recon);
    }
    let whole = unshard_field(&recon_shards).unwrap();
    assert_eq!(whole.shape(), field.shape());
    // Per-shard spatial bounds imply the global spatial bound.
    let e = ffcz::compressors::ErrorBound::Relative(1e-3).absolute_for(&field);
    for (a, b) in field.data().iter().zip(whole.data()) {
        // Shard-relative bounds may differ slightly from the global span;
        // allow 4× slack (shards see a sub-span of the full range).
        assert!((a - b).abs() <= 4.0 * e, "{a} vs {b}");
    }
}

#[test]
fn deep_queue_and_single_instance() {
    let base = SzLike::default();
    let mut cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-3));
    cfg.queue_depth = 16;
    // Single instance: pipeline degenerates gracefully.
    let one = vec![(
        "only".to_string(),
        synth::eeg::EegBuilder::new(2048).seed(1).build(),
    )];
    let report = run_pipeline(one, &base, &cfg).unwrap();
    assert_eq!(report.archives.len(), 1);
    assert!(report.makespan >= report.timings[0].edit_end - report.timings[0].compress_start);
}

#[test]
fn empty_instance_list_is_ok() {
    let base = SzLike::default();
    let cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-3));
    let report = run_pipeline(Vec::new(), &base, &cfg).unwrap();
    assert!(report.archives.is_empty());
    assert!(report.timings.is_empty());
}

#[test]
fn pipelined_hides_editing_time() {
    // With editing cheaper than compression (the paper's Obs. 3 setting),
    // pipelined makespan must be well under the sequential one for a
    // multi-instance stream.
    let instances: Vec<_> = (0..6)
        .map(|i| {
            (
                format!("i{i}"),
                synth::grf::GrfBuilder::new(&[16, 16, 16])
                    .lognormal(1.5)
                    .seed(10 + i as u64)
                    .build(),
            )
        })
        .collect();
    let base = SzLike::default();
    let mut cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-3));
    let piped = run_pipeline(instances.clone(), &base, &cfg).unwrap();
    cfg.mode = ExecMode::Sequential;
    let seq = run_pipeline(instances, &base, &cfg).unwrap();
    // Makespan must not exceed sequential (with generous noise margin).
    assert!(
        piped.makespan.as_secs_f64() <= seq.makespan.as_secs_f64() * 1.15,
        "pipelined {:?} vs sequential {:?}",
        piped.makespan,
        seq.makespan
    );
}

#[test]
fn per_instance_results_identical_to_direct_call() {
    let field = synth::turbulence::TurbulenceBuilder::new(&[16, 16, 16])
        .seed(2)
        .build();
    let base = SzLike::default();
    let cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-3));
    let report = run_pipeline(vec![("x".into(), field.clone())], &base, &cfg).unwrap();
    let direct = ffcz::correction::compress(&field, &base, &cfg.ffcz).unwrap();
    assert_eq!(report.archives[0].1.to_bytes(), direct.to_bytes());
}
