//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the `ffcz` crate uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!`, `bail!`,
//! and `ensure!` macros. Context frames accumulate outermost-first and the
//! alternate display format (`{:#}`) renders the full chain
//! (`outer: inner: root`), matching upstream behaviour.
//!
//! Not implemented (unused by this repo): downcasting, backtraces,
//! `std::error::Error` for [`Error`] itself.

use std::fmt;

/// An error type that can wrap any `std::error::Error` plus a stack of
/// human-readable context frames.
pub struct Error {
    /// Outermost context first; the last element is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Push a new outermost context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`, exactly like
// upstream anyhow — that keeps this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to `Result` and `Option` values.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn context_chain_renders_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn option_context() {
        let e = None::<u8>.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<()> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fell through with {x}"))
        }
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(1).unwrap_err()), "fell through with 1");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "root cause");
    }
}
