//! Offline drop-in subset of the `zstd` crate API.
//!
//! The real `zstd` crate binds the C libzstd, which is unavailable in this
//! offline build environment. This shim keeps the two entry points the
//! `ffcz` crate uses — [`encode_all`] and [`decode_all`] — with the same
//! signatures, backed by a self-consistent greedy LZ77 coder (4-byte
//! minimum match, unbounded window, varint token lengths). It is **not**
//! the zstd wire format: archives written with this shim must be read by a
//! shim build, and vice versa — `ffcz::encoding::lossless` tags both with
//! the same codec byte, so a build linked against real libzstd would fail
//! to decode shim frames (with this module's `ZSHM` magic in the error
//! path, not silent corruption). If real zstd ever lands, bump the frame
//! codec byte in `encoding::lossless` so the two formats stay
//! distinguishable (tracked in ROADMAP "Store subsystem follow-ups").
//!
//! Ratios are worse than real zstd (no entropy stage), but long runs and
//! repeated structure — the shape of quantized-edit and flag payloads —
//! still collapse well, and `lossless_compress` falls back to a raw frame
//! whenever this coder would expand the data.

use std::io::{Error, ErrorKind, Read, Result};

const MAGIC: &[u8; 4] = b"ZSHM";
const TOKEN_LITERALS: u8 = 0;
const TOKEN_MATCH: u8 = 1;
const MIN_MATCH: usize = 4;
const HASH_BITS: u32 = 16;

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&byte) = buf.get(*pos) else {
            return Err(Error::new(ErrorKind::UnexpectedEof, "truncated varint"));
        };
        *pos += 1;
        if shift >= 64 {
            return Err(Error::new(ErrorKind::InvalidData, "varint overflow"));
        }
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes(data[i..i + 4].try_into().unwrap());
    (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

fn emit_literals(out: &mut Vec<u8>, lits: &[u8]) {
    if lits.is_empty() {
        return;
    }
    out.push(TOKEN_LITERALS);
    write_varint(out, lits.len() as u64);
    out.extend_from_slice(lits);
}

/// Compress everything readable from `source`. `level` is accepted for API
/// compatibility and ignored (the shim has a single effort level).
pub fn encode_all<R: Read>(mut source: R, level: i32) -> Result<Vec<u8>> {
    let _ = level;
    let mut data = Vec::new();
    source.read_to_end(&mut data)?;

    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    out.extend_from_slice(MAGIC);
    write_varint(&mut out, data.len() as u64);

    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i + MIN_MATCH <= data.len() {
        let h = hash4(&data, i);
        let cand = table[h];
        table[h] = i;
        if cand != usize::MAX && data[cand..cand + MIN_MATCH] == data[i..i + MIN_MATCH] {
            let mut len = MIN_MATCH;
            while i + len < data.len() && data[cand + len] == data[i + len] {
                len += 1;
            }
            emit_literals(&mut out, &data[lit_start..i]);
            out.push(TOKEN_MATCH);
            write_varint(&mut out, (i - cand) as u64);
            write_varint(&mut out, len as u64);
            i += len;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    emit_literals(&mut out, &data[lit_start..]);
    Ok(out)
}

/// Decompress everything readable from `source`.
pub fn decode_all<R: Read>(mut source: R) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    source.read_to_end(&mut buf)?;
    if buf.len() < MAGIC.len() || &buf[..MAGIC.len()] != MAGIC {
        return Err(Error::new(ErrorKind::InvalidData, "bad shim-zstd magic"));
    }
    let mut pos = MAGIC.len();
    let n = read_varint(&buf, &mut pos)? as usize;
    let mut out = Vec::with_capacity(n);
    while pos < buf.len() {
        let token = buf[pos];
        pos += 1;
        match token {
            TOKEN_LITERALS => {
                let len = read_varint(&buf, &mut pos)? as usize;
                if pos + len > buf.len() {
                    return Err(Error::new(ErrorKind::UnexpectedEof, "truncated literals"));
                }
                out.extend_from_slice(&buf[pos..pos + len]);
                pos += len;
            }
            TOKEN_MATCH => {
                let dist = read_varint(&buf, &mut pos)? as usize;
                let len = read_varint(&buf, &mut pos)? as usize;
                if dist == 0 || dist > out.len() {
                    return Err(Error::new(ErrorKind::InvalidData, "bad match distance"));
                }
                // Overlapping copies are the LZ77 run-extension case: copy
                // byte by byte.
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
            x => {
                return Err(Error::new(
                    ErrorKind::InvalidData,
                    format!("unknown token {x}"),
                ));
            }
        }
    }
    if out.len() != n {
        return Err(Error::new(
            ErrorKind::InvalidData,
            format!("decoded {} bytes, header promised {n}", out.len()),
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) {
        let c = encode_all(data, 3).unwrap();
        assert_eq!(decode_all(&c[..]).unwrap(), data);
    }

    #[test]
    fn roundtrip_cases() {
        roundtrip(b"");
        roundtrip(b"abc");
        roundtrip(&[7u8; 100_000]);
        roundtrip(b"abcdabcdabcdabcdxyz");
        // Pseudo-random (incompressible) bytes.
        let mut x = 0x2545F4914F6CDD1Du64;
        let data: Vec<u8> = (0..10_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                x as u8
            })
            .collect();
        roundtrip(&data);
    }

    #[test]
    fn long_runs_collapse() {
        let c = encode_all(&[7u8; 100_000][..], 3).unwrap();
        assert!(c.len() < 100, "run-length case should be tiny, got {}", c.len());
    }

    #[test]
    fn garbage_rejected() {
        assert!(decode_all(&[0xFFu8, 0xFF][..]).is_err());
        assert!(decode_all(&b"ZSHM"[..]).is_err()); // truncated length
        let mut c = encode_all(&b"hello world hello world"[..], 3).unwrap();
        c.truncate(c.len() - 3);
        assert!(decode_all(&c[..]).is_err());
    }
}
