//! Streaming compression–editing coordinator (Layer 3).
//!
//! FFCz is a data-pipeline system: simulation instances (snapshots, time
//! steps, parameter sweeps) stream through base compression and FFCz
//! editing. The paper's Fig. 7(d) observation — *editing instance `i`
//! overlaps with compressing instance `i+1`, so the pipeline's makespan
//! equals the compression-only makespan* — is exactly what
//! [`pipeline::run_pipeline`] implements: a two-stage pipeline over OS
//! threads with a bounded hand-off queue (backpressure).
//!
//! [`sharding`] splits oversized fields into independently-corrected
//! shards so memory stays bounded and shards parallelize.

pub mod pipeline;
pub mod sharding;

pub use pipeline::{run_pipeline, ExecMode, InstanceTiming, PipelineConfig, PipelineReport};
pub use pipeline::{run_pipeline_to_store, StorePipelineReport, StoreSink};
pub use sharding::{shard_field, unshard_field};
