//! Two-stage pipelined compression–editing executor (paper Fig. 7(d)).
//!
//! Stage 1 (worker thread): base-compress instance `i+1`.
//! Stage 2 (caller thread): FFCz-edit instance `i`.
//! A bounded hand-off channel provides backpressure: compression stalls
//! when editing falls behind, keeping at most `queue_depth` decompressed
//! instances in flight.
//!
//! [`ExecMode::Sequential`] runs the same work without overlap, so
//! experiments can measure exactly how much the pipeline hides (the
//! paper's claim: total runtime ≈ compression-only runtime).

use std::path::PathBuf;
use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::codec::CodecChainSpec;
use crate::compressors::Compressor;
use crate::correction::{
    correct_reconstruction_with_scratch, CorrectionScratch, FfczArchive, FfczConfig,
};
use crate::data::Field;
use crate::store::{encode_store, write_store, StoreWriteOptions, StoreWriteReport};
use crate::telemetry;

/// Pipeline execution mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Compress instance i+1 while editing instance i (two threads).
    Pipelined,
    /// Strictly alternate compress → edit on one thread (baseline).
    Sequential,
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub mode: ExecMode,
    /// Bounded hand-off depth between the stages (backpressure window).
    pub queue_depth: usize,
    /// FFCz bounds applied to every instance.
    pub ffcz: FfczConfig,
}

impl PipelineConfig {
    pub fn new(ffcz: FfczConfig) -> Self {
        Self {
            mode: ExecMode::Pipelined,
            queue_depth: 2,
            ffcz,
        }
    }
}

/// Stage timestamps of one instance, as offsets from pipeline start
/// (drives the Fig. 7(d) timeline).
#[derive(Debug, Clone)]
pub struct InstanceTiming {
    pub name: String,
    pub compress_start: Duration,
    pub compress_end: Duration,
    pub edit_start: Duration,
    pub edit_end: Duration,
}

/// Outcome of a pipeline run.
#[derive(Debug)]
pub struct PipelineReport {
    pub archives: Vec<(String, FfczArchive)>,
    pub timings: Vec<InstanceTiming>,
    /// Wall-clock of the whole run.
    pub makespan: Duration,
    /// Σ compression stage time.
    pub compress_total: Duration,
    /// Σ editing stage time.
    pub edit_total: Duration,
}

impl PipelineReport {
    /// Render the Fig. 7(d)-style timeline as aligned text rows.
    pub fn timeline_text(&self) -> String {
        let mut out = String::new();
        out.push_str("instance            compress[ms]          edit[ms]\n");
        for t in &self.timings {
            out.push_str(&format!(
                "{:<16} {:>8.1} – {:>8.1}  {:>8.1} – {:>8.1}\n",
                t.name,
                t.compress_start.as_secs_f64() * 1e3,
                t.compress_end.as_secs_f64() * 1e3,
                t.edit_start.as_secs_f64() * 1e3,
                t.edit_end.as_secs_f64() * 1e3,
            ));
        }
        out.push_str(&format!(
            "makespan {:.1} ms  (compress Σ {:.1} ms, edit Σ {:.1} ms)\n",
            self.makespan.as_secs_f64() * 1e3,
            self.compress_total.as_secs_f64() * 1e3,
            self.edit_total.as_secs_f64() * 1e3,
        ));
        out
    }
}

struct StageOutput {
    name: String,
    field: Field,
    recon: Field,
    payload: Vec<u8>,
    compress_start: Duration,
    compress_end: Duration,
}

/// Run instances through the compression–editing pipeline.
pub fn run_pipeline(
    instances: Vec<(String, Field)>,
    base: &dyn Compressor,
    cfg: &PipelineConfig,
) -> Result<PipelineReport> {
    match cfg.mode {
        ExecMode::Pipelined => run_pipelined(instances, base, cfg),
        ExecMode::Sequential => run_sequential(instances, base, cfg),
    }
}

fn compress_stage(
    base: &dyn Compressor,
    cfg: &PipelineConfig,
    t0: Instant,
    name: String,
    field: Field,
) -> Result<StageOutput> {
    let compress_start = t0.elapsed();
    let bound = match cfg.ffcz.spatial {
        crate::correction::BoundSpec::Absolute(v) => crate::compressors::ErrorBound::Absolute(v),
        crate::correction::BoundSpec::Relative(r) => crate::compressors::ErrorBound::Relative(r),
    };
    let payload = base.compress(&field, bound)?;
    let recon = base.decompress(&payload)?;
    let compress_end = t0.elapsed();
    Ok(StageOutput {
        name,
        field,
        recon,
        payload,
        compress_start,
        compress_end,
    })
}

/// Edit one instance. `scratch` lives on the editing thread across
/// instances, so same-shape snapshots after the first reuse every plan
/// handle and transform buffer (instance sequences are the common case —
/// same grid every step).
fn edit_stage(
    base_name: &str,
    cfg: &PipelineConfig,
    t0: Instant,
    s: StageOutput,
    scratch: &mut CorrectionScratch,
) -> Result<((String, FfczArchive), InstanceTiming)> {
    let edit_start = t0.elapsed();
    let archive = correct_reconstruction_with_scratch(
        &s.field, &s.recon, base_name, s.payload, &cfg.ffcz, scratch,
    )?;
    let edit_end = t0.elapsed();
    Ok((
        (s.name.clone(), archive),
        InstanceTiming {
            name: s.name,
            compress_start: s.compress_start,
            compress_end: s.compress_end,
            edit_start,
            edit_end,
        },
    ))
}

fn run_pipelined(
    instances: Vec<(String, Field)>,
    base: &dyn Compressor,
    cfg: &PipelineConfig,
) -> Result<PipelineReport> {
    let t0 = Instant::now();
    let base_name = base.name();
    let run_span = telemetry::span("pipeline.run").arg("instances", instances.len() as u64);
    let run_span_id = run_span.id();
    let (tx, rx) = sync_channel::<Result<StageOutput>>(cfg.queue_depth.max(1));

    let mut archives = Vec::new();
    let mut timings = Vec::new();
    let mut scratch = CorrectionScratch::new();
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        std::thread::scope(|scope| -> Result<()> {
            // Stage 1: compression worker.
            scope.spawn(move || {
                for (name, field) in instances {
                    let stage_span =
                        telemetry::span_with_parent("pipeline.compress", run_span_id);
                    let out = compress_stage(base, cfg, t0, name, field);
                    drop(stage_span);
                    if tx.send(out).is_err() {
                        break; // consumer hung up
                    }
                }
                drop(tx);
            });
            // Stage 2: editing on this thread. `rx` is moved in so an early
            // error return drops it, which unblocks a producer stalled on a
            // full queue (its send fails and the worker exits).
            for out in rx {
                let stage_span = telemetry::span("pipeline.edit");
                let (arch, timing) = edit_stage(base_name, cfg, t0, out?, &mut scratch)?;
                drop(stage_span);
                archives.push(arch);
                timings.push(timing);
            }
            Ok(())
        })
    }))
    .map_err(|_| anyhow::anyhow!("pipeline worker panicked"))??;

    Ok(finish_report(archives, timings, t0))
}

fn run_sequential(
    instances: Vec<(String, Field)>,
    base: &dyn Compressor,
    cfg: &PipelineConfig,
) -> Result<PipelineReport> {
    let t0 = Instant::now();
    let base_name = base.name();
    let _run_span = telemetry::span("pipeline.run").arg("instances", instances.len() as u64);
    let mut archives = Vec::new();
    let mut timings = Vec::new();
    let mut scratch = CorrectionScratch::new();
    for (name, field) in instances {
        let stage_span = telemetry::span("pipeline.compress");
        let out = compress_stage(base, cfg, t0, name, field)?;
        drop(stage_span);
        let stage_span = telemetry::span("pipeline.edit");
        let (arch, timing) = edit_stage(base_name, cfg, t0, out, &mut scratch)?;
        drop(stage_span);
        archives.push(arch);
        timings.push(timing);
    }
    Ok(finish_report(archives, timings, t0))
}

fn finish_report(
    archives: Vec<(String, FfczArchive)>,
    timings: Vec<InstanceTiming>,
    t0: Instant,
) -> PipelineReport {
    let makespan = t0.elapsed();
    let compress_total = timings
        .iter()
        .map(|t| t.compress_end - t.compress_start)
        .sum();
    let edit_total = timings.iter().map(|t| t.edit_end - t.edit_start).sum();
    PipelineReport {
        archives,
        timings,
        makespan,
        compress_total,
        edit_total,
    }
}

/// Destination for streamed instances landing directly in chunked stores
/// (one `.ffcz` file per instance under `dir`).
#[derive(Debug, Clone)]
pub struct StoreSink {
    /// Output directory (created if missing).
    pub dir: PathBuf,
    /// Default per-chunk codec chain applied to every instance.
    pub spec: CodecChainSpec,
    /// Chunk shape; `None` picks the sharding-style default of
    /// [`StoreWriteOptions::default_for`]: axis-0 slabs, `max(workers, 2)`
    /// of them (the chunked analogue of [`super::sharding::shard_field`]).
    pub chunk_shape: Option<Vec<usize>>,
    /// Worker threads for per-chunk encoding.
    pub workers: usize,
    /// Per-chunk chain overrides (chunk key → chain), applied to every
    /// instance's grid; see [`StoreWriteOptions::overrides`].
    pub overrides: Vec<(String, CodecChainSpec)>,
    /// Assemble each instance's container fully in memory before writing
    /// (the pre-streaming behavior; peak memory is payload + container).
    /// Default `false`: chunk payloads stream to the file as they are
    /// encoded, holding at most `workers + queue_depth` payloads.
    pub in_memory: bool,
}

impl StoreSink {
    pub fn new(dir: PathBuf, spec: CodecChainSpec) -> Self {
        Self {
            dir,
            spec,
            chunk_shape: None,
            workers: 2,
            overrides: Vec::new(),
            in_memory: false,
        }
    }

    fn options_for(&self, field: &Field) -> Result<StoreWriteOptions> {
        let mut opts = match &self.chunk_shape {
            Some(c) => StoreWriteOptions::new(c).workers(self.workers),
            None => StoreWriteOptions::default_for(field.shape(), self.workers)?,
        };
        opts.overrides = self.overrides.clone();
        Ok(opts)
    }
}

/// Outcome of a [`run_pipeline_to_store`] run.
#[derive(Debug)]
pub struct StorePipelineReport {
    /// `(instance name, store path, write summary)` in input order.
    pub outputs: Vec<(String, PathBuf, StoreWriteReport)>,
    /// Wall-clock of the whole run.
    pub makespan: Duration,
    /// Σ chunked-encode stage time.
    pub encode_total: Duration,
    /// Σ file-write stage time.
    pub write_total: Duration,
}

impl StorePipelineReport {
    /// Did every chunk of every instance pass dual-domain verification?
    pub fn all_chunks_ok(&self) -> bool {
        self.outputs.iter().all(|(_, _, r)| r.all_chunks_ok)
    }
}

struct EncodedInstance {
    name: String,
    bytes: Vec<u8>,
    report: StoreWriteReport,
    encode_start: Duration,
    encode_end: Duration,
}

/// Stream instances straight into chunked `.ffcz` stores, one file per
/// instance.
///
/// Default (streaming) mode fuses encode and write per instance: the chunk
/// worker pool hands each finished payload to the writer thread, which
/// spills it to the instance's file immediately (see
/// [`crate::store::stream_store_to`]). Peak payload memory per instance is
/// O((workers + queue_depth) × chunk) instead of O(field) — the property
/// that lets multi-GB instances flow through without hitting the in-memory
/// scale wall. Instances run in sequence; parallelism comes from the
/// per-chunk workers, and the fused elapsed time is attributed to
/// [`StorePipelineReport::encode_total`].
///
/// With [`StoreSink::in_memory`] set, the original two-stage overlap runs
/// instead: stage 1 assembles instance `i+1`'s whole container in memory
/// (chunk-parallel) while stage 2 writes instance `i` to disk — the
/// Fig. 7(d) overlap applied to the archive path, at the cost of holding
/// payload + container for an instance at once.
pub fn run_pipeline_to_store(
    instances: Vec<(String, Field)>,
    sink: &StoreSink,
) -> Result<StorePipelineReport> {
    std::fs::create_dir_all(&sink.dir)
        .with_context(|| format!("creating {}", sink.dir.display()))?;
    if !sink.in_memory {
        return run_streaming_to_store(instances, sink);
    }
    let t0 = Instant::now();
    let run_span =
        telemetry::span("pipeline.store").arg("instances", instances.len() as u64);
    let run_span_id = run_span.id();
    let (tx, rx) = sync_channel::<Result<EncodedInstance>>(2);

    let mut outputs = Vec::new();
    let mut encode_total = Duration::ZERO;
    let mut write_total = Duration::ZERO;
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        std::thread::scope(|scope| -> Result<()> {
            scope.spawn(move || {
                for (name, field) in instances {
                    let _stage_span =
                        telemetry::span_with_parent("pipeline.encode", run_span_id);
                    let encode_start = t0.elapsed();
                    let out = sink.options_for(&field).and_then(|opts| {
                        encode_store(&field, &sink.spec, &opts).map(|(bytes, _, report)| {
                            EncodedInstance {
                                name,
                                bytes,
                                report,
                                encode_start,
                                encode_end: t0.elapsed(),
                            }
                        })
                    });
                    if tx.send(out).is_err() {
                        break; // consumer hung up
                    }
                }
                drop(tx);
            });
            for enc in rx {
                let enc = enc?;
                let _stage_span = telemetry::span("pipeline.write");
                let write_start = t0.elapsed();
                let path = sink.dir.join(format!("{}.ffcz", enc.name));
                std::fs::write(&path, &enc.bytes)
                    .with_context(|| format!("writing {}", path.display()))?;
                write_total += t0.elapsed() - write_start;
                encode_total += enc.encode_end - enc.encode_start;
                outputs.push((enc.name, path, enc.report));
            }
            Ok(())
        })
    }))
    .map_err(|_| anyhow::anyhow!("store pipeline worker panicked"))??;

    Ok(StorePipelineReport {
        outputs,
        makespan: t0.elapsed(),
        encode_total,
        write_total,
    })
}

/// Streaming store path: each instance's chunks spill to its file as they
/// are encoded. `write_total` stays zero — file writes happen inside the
/// fused encode stage, interleaved with chunk encoding.
fn run_streaming_to_store(
    instances: Vec<(String, Field)>,
    sink: &StoreSink,
) -> Result<StorePipelineReport> {
    let t0 = Instant::now();
    let _run_span =
        telemetry::span("pipeline.store").arg("instances", instances.len() as u64);
    let mut outputs = Vec::with_capacity(instances.len());
    let mut encode_total = Duration::ZERO;
    for (name, field) in instances {
        let _stage_span = telemetry::span("pipeline.encode");
        let opts = sink.options_for(&field)?;
        let path = sink.dir.join(format!("{name}.ffcz"));
        let report = write_store(&field, &sink.spec, &opts, &path)
            .with_context(|| format!("streaming instance '{name}' to {}", path.display()))?;
        encode_total += report.elapsed;
        outputs.push((name, path, report));
    }
    Ok(StorePipelineReport {
        outputs,
        makespan: t0.elapsed(),
        encode_total,
        write_total: Duration::ZERO,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::szlike::SzLike;
    use crate::correction::{decompress, verify};
    use crate::data::synth;

    fn instances(n: usize) -> Vec<(String, Field)> {
        (0..n)
            .map(|i| {
                (
                    format!("inst{i}"),
                    synth::grf::GrfBuilder::new(&[16, 16, 16])
                        .lognormal(1.0)
                        .seed(100 + i as u64)
                        .build(),
                )
            })
            .collect()
    }

    #[test]
    fn pipelined_outputs_satisfy_bounds() {
        let cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-3));
        let base = SzLike::default();
        let insts = instances(4);
        let originals: Vec<Field> = insts.iter().map(|(_, f)| f.clone()).collect();
        let report = run_pipeline(insts, &base, &cfg).unwrap();
        assert_eq!(report.archives.len(), 4);
        assert_eq!(report.timings.len(), 4);
        for ((_, arch), orig) in report.archives.iter().zip(&originals) {
            let recon = decompress(arch).unwrap();
            let rep = verify(orig, &recon, &cfg.ffcz);
            assert!(rep.spatial_ok && rep.frequency_ok);
        }
    }

    #[test]
    fn sequential_and_pipelined_agree_on_archives() {
        let base = SzLike::default();
        let mut cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-3));
        let a = run_pipeline(instances(3), &base, &cfg).unwrap();
        cfg.mode = ExecMode::Sequential;
        let b = run_pipeline(instances(3), &base, &cfg).unwrap();
        // Order may differ only if the pipeline reorders — it must not.
        for ((na, aa), (nb, ab)) in a.archives.iter().zip(&b.archives) {
            assert_eq!(na, nb);
            assert_eq!(aa.to_bytes(), ab.to_bytes());
        }
    }

    #[test]
    fn pipeline_overlaps_stages() {
        // Overlap evidence that is robust to very fast stages: either some
        // compress(i+1) starts before edit(i) ends, or the makespan is
        // visibly below the serial sum of all stage times.
        let cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-3));
        let base = SzLike::default();
        let report = run_pipeline(instances(6), &base, &cfg).unwrap();
        let overlap = report
            .timings
            .windows(2)
            .any(|w| w[1].compress_start < w[0].edit_end);
        let serial = report.compress_total + report.edit_total;
        let hidden = report.makespan.as_secs_f64() < 0.98 * serial.as_secs_f64();
        assert!(
            overlap || hidden,
            "no overlap evidence; timeline: {}",
            report.timeline_text()
        );
    }

    #[test]
    fn store_sink_writes_decodable_stores() {
        let dir = std::env::temp_dir().join("ffcz_store_pipeline_test");
        let _ = std::fs::remove_dir_all(&dir);
        let insts = instances(3);
        let originals: Vec<(String, Field)> = insts.clone();
        let sink = StoreSink::new(
            dir.clone(),
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)),
        );
        let report = run_pipeline_to_store(insts, &sink).unwrap();
        assert_eq!(report.outputs.len(), 3);
        assert!(report.all_chunks_ok());
        for ((name, path, _), (orig_name, orig)) in report.outputs.iter().zip(&originals) {
            assert_eq!(name, orig_name);
            let store = crate::store::Store::open(path).unwrap();
            assert_eq!(store.shape(), orig.shape());
            // Per-chunk relative bounds: check a coarse whole-field error
            // envelope (each chunk's span ≤ the field's span would not hold
            // in general, so verify pointwise against the max chunk bound).
            let recon = store.decompress_all(2).unwrap();
            assert_eq!(recon.shape(), orig.shape());
            assert!(store.manifest().all_chunks_ok());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn streaming_and_in_memory_sinks_produce_identical_archives() {
        let root = std::env::temp_dir().join("ffcz_sink_equivalence_test");
        let _ = std::fs::remove_dir_all(&root);
        let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        let mut streaming = StoreSink::new(root.join("streaming"), spec.clone());
        streaming.workers = 3;
        let mut in_memory = StoreSink::new(root.join("in_memory"), spec);
        in_memory.workers = 3;
        in_memory.in_memory = true;

        let a = run_pipeline_to_store(instances(2), &streaming).unwrap();
        let b = run_pipeline_to_store(instances(2), &in_memory).unwrap();
        assert_eq!(a.outputs.len(), b.outputs.len());
        for ((name_a, path_a, rep_a), (name_b, path_b, rep_b)) in
            a.outputs.iter().zip(&b.outputs)
        {
            assert_eq!(name_a, name_b);
            assert!(rep_a.streamed && !rep_b.streamed);
            assert_eq!(
                std::fs::read(path_a).unwrap(),
                std::fs::read(path_b).unwrap(),
                "streamed and in-memory archives diverge for '{name_a}'"
            );
            // The streamed write never held the whole payload at once.
            assert!(rep_a.peak_payload_bytes <= rep_b.peak_payload_bytes);
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn timeline_text_renders() {
        let cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-3));
        let base = SzLike::default();
        let report = run_pipeline(instances(2), &base, &cfg).unwrap();
        let text = report.timeline_text();
        assert!(text.contains("makespan"));
        assert!(text.contains("inst0"));
    }
}
