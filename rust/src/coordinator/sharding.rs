//! Sharding of oversized fields into independently-corrected instances.
//!
//! Shards split along axis 0 (the slowest-varying axis of the row-major
//! layout, so shards are contiguous memory). Each shard is corrected
//! independently — dual-domain bounds then hold *per shard*, the natural
//! granularity for streaming workloads where instances arrive one at a
//! time (paper Fig. 7(d)).

use anyhow::{bail, Result};

use crate::data::Field;

/// Split a field into up to `n_shards` contiguous chunks along axis 0.
/// Every shard keeps the remaining axes intact; axis-0 extents differ by
/// at most one. Returns fewer shards if axis 0 is too small.
pub fn shard_field(field: &Field, n_shards: usize) -> Vec<Field> {
    let d0 = field.shape()[0];
    let k = n_shards.clamp(1, d0);
    let inner: usize = field.shape()[1..].iter().product();
    let base = d0 / k;
    let extra = d0 % k;
    let mut out = Vec::with_capacity(k);
    let mut row = 0usize;
    for i in 0..k {
        let rows = base + usize::from(i < extra);
        let start = row * inner;
        let end = (row + rows) * inner;
        let mut shape = field.shape().to_vec();
        shape[0] = rows;
        out.push(Field::new(
            &shape,
            field.data()[start..end].to_vec(),
            field.precision(),
        ));
        row += rows;
    }
    out
}

/// Reassemble shards produced by [`shard_field`] (same order).
pub fn unshard_field(shards: &[Field]) -> Result<Field> {
    if shards.is_empty() {
        bail!("no shards");
    }
    let tail = &shards[0].shape()[1..];
    let precision = shards[0].precision();
    let mut d0 = 0usize;
    let mut data = Vec::new();
    for s in shards {
        if &s.shape()[1..] != tail {
            bail!("inconsistent shard shapes");
        }
        d0 += s.shape()[0];
        data.extend_from_slice(s.data());
    }
    let mut shape = vec![d0];
    shape.extend_from_slice(tail);
    Ok(Field::new(&shape, data, precision))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Precision;

    fn field_3d() -> Field {
        let data: Vec<f64> = (0..5 * 4 * 3).map(|i| i as f64).collect();
        Field::new(&[5, 4, 3], data, Precision::Single)
    }

    #[test]
    fn roundtrip_even_and_uneven() {
        let f = field_3d();
        for k in [1usize, 2, 3, 5, 10] {
            let shards = shard_field(&f, k);
            assert!(shards.len() <= 5);
            let g = unshard_field(&shards).unwrap();
            assert_eq!(f, g);
        }
    }

    #[test]
    fn shard_extents_balanced() {
        let f = field_3d();
        let shards = shard_field(&f, 2);
        assert_eq!(shards[0].shape()[0], 3);
        assert_eq!(shards[1].shape()[0], 2);
    }

    #[test]
    fn mismatched_shards_rejected() {
        let a = Field::zeros(&[2, 3], Precision::Double);
        let b = Field::zeros(&[2, 4], Precision::Double);
        assert!(unshard_field(&[a, b]).is_err());
    }

    #[test]
    fn empty_shard_list_rejected() {
        assert!(unshard_field(&[]).is_err());
    }
}
