//! Concurrent archive read server: serve `read_region` requests from
//! many `.ffcz` archives over a small length-prefixed TCP protocol.
//!
//! The store layer ([`crate::store`]) already decodes arbitrary
//! rectangular windows of a chunked archive through any
//! [`crate::store::ReadableStorage`] backend; this subsystem puts a
//! daemon in front of it so many clients share one set of open
//! archives — and, through them, one decoded-chunk LRU, one resolved
//! codec-chain table, and one FFT plan cache per archive — instead of
//! each re-opening and re-decoding on their own.
//!
//! * [`protocol`] — the wire format (framing, opcodes, statuses,
//!   request/response layouts), specified normatively in
//!   `docs/SERVER.md` and implemented here as pure bytes-in/bytes-out
//!   helpers shared by both sides;
//! * [`service`] — [`ArchiveServer`]: accept loop, per-connection
//!   threads, lazy archive resolution from a root directory (or
//!   [`ArchiveServer::register`]ed in-memory stores), pooled
//!   [`crate::correction::CorrectionScratch`] buffers, transient-fault
//!   retries, a max-concurrent-connections cap (`ST_BUSY` to excess
//!   accepts), per-connection request deadlines, and `server.*`
//!   telemetry;
//! * [`client`] — the blocking [`Client`] used by `ffcz get`, the
//!   stress tests, and the benchmarks; with a
//!   [`crate::store::RetryPolicy`] attached it reconnects and reissues
//!   idempotent requests across transient faults, giving up with the
//!   typed [`RetriesExhausted`] error.
//!
//! The CLI front ends are `ffcz serve` (run a daemon) and `ffcz get`
//! (ping / stat / fetch a region / request shutdown).

pub mod client;
pub mod protocol;
pub mod service;

pub use client::{retries_exhausted_of, status_of, Client, RetriesExhausted, ServerError};
pub use protocol::{ArchiveStat, Request, Response};
pub use service::{ArchiveServer, ServeOptions};
