//! Blocking TCP client for the archive read server.
//!
//! One [`Client`] wraps one connection; requests are strictly
//! sequential per connection (the protocol has no request IDs —
//! pipelining means opening more connections, which is exactly what the
//! server's thread-per-connection model expects). Server-reported
//! failures surface as [`ServerError`] values inside the `anyhow` chain,
//! so callers can branch on the wire status via [`status_of`].

use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::data::Field;

use super::protocol::{
    self, encode_request, ArchiveStat, FrameRead, Request, Response, DEFAULT_MAX_RESPONSE_FRAME,
    OP_PING, OP_READ_REGION, OP_SHUTDOWN, OP_STAT,
};

/// A failure reported by the server, carrying the wire status byte
/// (`ST_*` in [`super::protocol`]) and the server's message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerError {
    pub status: u8,
    pub message: String,
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "server error (status {:#04x}): {}",
            self.status, self.message
        )
    }
}

impl std::error::Error for ServerError {}

/// The wire status inside an error returned by a [`Client`] call, if
/// the failure was server-reported (`None` for transport errors).
pub fn status_of(err: &anyhow::Error) -> Option<u8> {
    err.chain()
        .find_map(|c| c.downcast_ref::<ServerError>())
        .map(|se| se.status)
}

/// One blocking connection to an archive read server.
pub struct Client {
    stream: TcpStream,
    /// Cap on response bodies this client will accept.
    max_response_bytes: usize,
}

impl Client {
    /// Connect to `addr` (e.g. `127.0.0.1:7070`).
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to archive server at {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Self {
            stream,
            max_response_bytes: DEFAULT_MAX_RESPONSE_FRAME,
        })
    }

    /// Raise or lower the response-size cap (default 256 MiB).
    pub fn with_max_response_bytes(mut self, bytes: usize) -> Self {
        self.max_response_bytes = bytes;
        self
    }

    fn round_trip(&mut self, req: &Request, op: u8) -> Result<Response> {
        protocol::write_frame(&mut self.stream, &encode_request(req))
            .context("sending request frame")?;
        let body = loop {
            match protocol::read_frame(&mut self.stream, self.max_response_bytes)
                .context("reading response frame")?
            {
                FrameRead::Frame(body) => break body,
                FrameRead::Idle => continue,
                FrameRead::Eof => bail!("server closed the connection mid-request"),
            }
        };
        match protocol::parse_response(op, &body).context("parsing response frame")? {
            Response::Error { status, message } => {
                Err(anyhow::Error::new(ServerError { status, message }))
            }
            resp => Ok(resp),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        match self.round_trip(&Request::Ping, OP_PING)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected ping response {other:?}"),
        }
    }

    /// Archive metadata: shape, chunk grid, payload size, precision.
    pub fn stat(&mut self, name: &str) -> Result<ArchiveStat> {
        let req = Request::Stat {
            name: name.to_string(),
        };
        match self.round_trip(&req, OP_STAT)? {
            Response::Stat(stat) => Ok(stat),
            other => bail!("unexpected stat response {other:?}"),
        }
    }

    /// Decode a rectangular region of archive `name` into a [`Field`].
    pub fn read_region(&mut self, name: &str, origin: &[usize], shape: &[usize]) -> Result<Field> {
        let req = Request::ReadRegion {
            name: name.to_string(),
            origin: origin.iter().map(|&v| v as u64).collect(),
            shape: shape.iter().map(|&v| v as u64).collect(),
        };
        match self.round_trip(&req, OP_READ_REGION)? {
            Response::Region {
                shape: got_shape,
                precision,
                data,
            } => {
                let shape_usize: Vec<usize> = got_shape
                    .iter()
                    .map(|&v| usize::try_from(v).context("region extent overflows usize"))
                    .collect::<Result<_>>()?;
                let n: usize = shape_usize.iter().product();
                if n != data.len() {
                    bail!(
                        "region shape {shape_usize:?} disagrees with {} samples",
                        data.len()
                    );
                }
                Ok(Field::new(&shape_usize, data, precision))
            }
            other => bail!("unexpected read_region response {other:?}"),
        }
    }

    /// Ask the server to shut down (honored unless started with
    /// shutdown disabled).
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.round_trip(&Request::Shutdown, OP_SHUTDOWN)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected shutdown response {other:?}"),
        }
    }
}
