//! Blocking TCP client for the archive read server.
//!
//! One [`Client`] wraps one connection; requests are strictly
//! sequential per connection (the protocol has no request IDs —
//! pipelining means opening more connections, which is exactly what the
//! server's thread-per-connection model expects). Server-reported
//! failures surface as [`ServerError`] values inside the `anyhow` chain,
//! so callers can branch on the wire status via [`status_of`].
//!
//! ## Retries
//!
//! A client carries a [`RetryPolicy`] (default: off). With retries
//! enabled, the idempotent operations — [`Client::ping`],
//! [`Client::stat`], [`Client::read_region`] — transparently survive
//! transient failures: connection-level faults (refused, reset, timed
//! out, a server that hung up mid-request) and `ST_BUSY` rejections
//! from a server at its connection cap. Each retry reconnects and
//! reissues the request on a fresh connection, pacing attempts with the
//! policy's [`RetrySchedule`] — linear by default, exponential backoff
//! and seeded jitter when the policy opts in, and an optional total
//! deadline bounding the whole loop. When the budget (or deadline) runs
//! out the caller gets the typed give-up error [`RetriesExhausted`],
//! recoverable from the `anyhow` chain via [`retries_exhausted_of`].
//! [`Client::shutdown_server`] is *not* retried: it is not idempotent
//! from the fleet's point of view, and a lost response is
//! indistinguishable from a successful shutdown.

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::data::Field;
use crate::store::{RetryPolicy, RetrySchedule};

use super::protocol::{
    self, encode_request, ArchiveStat, FrameRead, Request, Response, DEFAULT_MAX_RESPONSE_FRAME,
    OP_PING, OP_READ_REGION, OP_SHUTDOWN, OP_STAT, ST_BUSY,
};

/// A failure reported by the server, carrying the wire status byte
/// (`ST_*` in [`super::protocol`]) and the server's message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerError {
    pub status: u8,
    pub message: String,
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "server error (status {:#04x}): {}",
            self.status, self.message
        )
    }
}

impl std::error::Error for ServerError {}

/// The wire status inside an error returned by a [`Client`] call, if
/// the failure was server-reported (`None` for transport errors).
pub fn status_of(err: &anyhow::Error) -> Option<u8> {
    err.chain()
        .find_map(|c| c.downcast_ref::<ServerError>())
        .map(|se| se.status)
}

/// The typed give-up error a retrying [`Client`] returns once its
/// [`RetryPolicy`] budget is spent: every attempt failed with a fault
/// the client classifies as transient. Non-transient failures (a bad
/// region, an unknown archive) are returned as-is on the first attempt
/// and never wrapped.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetriesExhausted {
    /// Total attempts made, the initial try included.
    pub attempts: u32,
    /// Rendering of the error the final attempt failed with.
    pub last_error: String,
}

impl std::fmt::Display for RetriesExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "gave up after {} attempts; last error: {}",
            self.attempts, self.last_error
        )
    }
}

impl std::error::Error for RetriesExhausted {}

/// The give-up record inside an error returned by a retrying [`Client`]
/// call, if the failure was a spent retry budget (`None` otherwise) —
/// the retry-side analogue of [`status_of`].
pub fn retries_exhausted_of(err: &anyhow::Error) -> Option<&RetriesExhausted> {
    err.chain().find_map(|c| c.downcast_ref::<RetriesExhausted>())
}

/// Whether a failed attempt is worth reissuing on a fresh connection:
/// `ST_BUSY` from a server at its cap, or any connection-level I/O
/// fault in the chain. Server verdicts about the request itself
/// (bad region, unknown archive, too large) are not transient.
fn is_retryable(err: &anyhow::Error) -> bool {
    if let Some(server) = err.chain().find_map(|c| c.downcast_ref::<ServerError>()) {
        return server.status == ST_BUSY;
    }
    err.chain().any(|c| {
        c.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::Interrupted
                    | std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::ConnectionRefused
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::NotConnected
                    | std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::UnexpectedEof
            )
        })
    })
}

/// Pace the next reissue through the policy's [`RetrySchedule`] (linear
/// or exponential, jittered or not). Returns `false` when the policy's
/// total deadline leaves no room for another attempt — the caller must
/// give up instead of sleeping.
fn sleep_before_retry(schedule: &mut RetrySchedule, policy: &RetryPolicy) -> bool {
    let delay = schedule.next_delay();
    if let Some(budget) = policy.deadline {
        if schedule.elapsed() + delay >= budget {
            return false;
        }
    }
    if !delay.is_zero() {
        std::thread::sleep(delay);
    }
    true
}

/// One blocking connection to an archive read server.
pub struct Client {
    /// The address reconnects re-dial.
    addr: String,
    stream: TcpStream,
    /// Cap on response bodies this client will accept.
    max_response_bytes: usize,
    /// Transient-fault budget for idempotent operations.
    retry: RetryPolicy,
}

impl Client {
    /// Connect to `addr` (e.g. `127.0.0.1:7070`). Retries are off;
    /// opt in with [`Client::with_retry_policy`] or
    /// [`Client::connect_with_retry`].
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to archive server at {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Self {
            addr: addr.to_string(),
            stream,
            max_response_bytes: DEFAULT_MAX_RESPONSE_FRAME,
            retry: RetryPolicy::none(),
        })
    }

    /// Connect to `addr`, retrying refused/reset connects under
    /// `policy`; the returned client keeps the same policy for its
    /// requests.
    pub fn connect_with_retry(addr: &str, policy: RetryPolicy) -> Result<Self> {
        let budget = policy.max_attempts.max(1);
        let mut schedule = RetrySchedule::new(policy);
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            let err = match Self::connect(addr) {
                Ok(client) => return Ok(client.with_retry_policy(policy)),
                Err(err) => err,
            };
            if !is_retryable(&err) {
                return Err(err);
            }
            if attempts >= budget {
                if budget == 1 {
                    return Err(err);
                }
                return Err(anyhow::Error::new(RetriesExhausted {
                    attempts,
                    last_error: format!("{err:#}"),
                }));
            }
            if !sleep_before_retry(&mut schedule, &policy) {
                return Err(anyhow::Error::new(RetriesExhausted {
                    attempts,
                    last_error: format!("{err:#}"),
                }));
            }
        }
    }

    /// Raise or lower the response-size cap (default 256 MiB).
    pub fn with_max_response_bytes(mut self, bytes: usize) -> Self {
        self.max_response_bytes = bytes;
        self
    }

    /// Enable transparent reconnect-and-reissue for idempotent
    /// operations under `policy` (default: [`RetryPolicy::none`]).
    pub fn with_retry_policy(mut self, policy: RetryPolicy) -> Self {
        self.retry = policy;
        self
    }

    /// Drop the (possibly half-dead) connection and dial the server
    /// again.
    fn reconnect(&mut self) -> Result<()> {
        let stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("reconnecting to archive server at {}", self.addr))?;
        let _ = stream.set_nodelay(true);
        self.stream = stream;
        Ok(())
    }

    /// Run an idempotent operation under the retry policy: transient
    /// failures reconnect (the old connection may be half-dead after a
    /// deadline close or server restart) and reissue, pacing attempts
    /// through the policy's [`RetrySchedule`]; a spent budget or
    /// deadline surfaces as [`RetriesExhausted`].
    fn retrying<T>(&mut self, mut attempt: impl FnMut(&mut Self) -> Result<T>) -> Result<T> {
        let policy = self.retry;
        let budget = policy.max_attempts.max(1);
        let mut schedule = RetrySchedule::new(policy);
        let mut attempts = 0u32;
        let mut reissue = false;
        loop {
            attempts += 1;
            let result = if reissue {
                self.reconnect().and_then(|()| attempt(self))
            } else {
                attempt(self)
            };
            let err = match result {
                Ok(value) => return Ok(value),
                Err(err) => err,
            };
            if !is_retryable(&err) {
                return Err(err);
            }
            if attempts >= budget {
                if budget == 1 {
                    return Err(err);
                }
                return Err(anyhow::Error::new(RetriesExhausted {
                    attempts,
                    last_error: format!("{err:#}"),
                }));
            }
            reissue = true;
            if !sleep_before_retry(&mut schedule, &policy) {
                return Err(anyhow::Error::new(RetriesExhausted {
                    attempts,
                    last_error: format!("{err:#}"),
                }));
            }
        }
    }

    fn round_trip(&mut self, req: &Request, op: u8) -> Result<Response> {
        protocol::write_frame(&mut self.stream, &encode_request(req))
            .context("sending request frame")?;
        let body = loop {
            match protocol::read_frame(&mut self.stream, self.max_response_bytes)
                .context("reading response frame")?
            {
                FrameRead::Frame(body) => break body,
                FrameRead::Idle => continue,
                // Typed as an I/O error so the retry classifier treats
                // a mid-request hangup (deadline close, restart) the
                // same as every other connection-level fault.
                FrameRead::Eof => {
                    return Err(anyhow::Error::new(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "server closed the connection mid-request",
                    )))
                }
            }
        };
        match protocol::parse_response(op, &body).context("parsing response frame")? {
            Response::Error { status, message } => {
                Err(anyhow::Error::new(ServerError { status, message }))
            }
            resp => Ok(resp),
        }
    }

    /// Liveness probe. Idempotent: retried under the client's policy.
    pub fn ping(&mut self) -> Result<()> {
        self.retrying(|c| c.ping_once())
    }

    fn ping_once(&mut self) -> Result<()> {
        match self.round_trip(&Request::Ping, OP_PING)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected ping response {other:?}"),
        }
    }

    /// Archive metadata: shape, chunk grid, payload size, precision.
    /// Idempotent: retried under the client's policy.
    pub fn stat(&mut self, name: &str) -> Result<ArchiveStat> {
        self.retrying(|c| c.stat_once(name))
    }

    fn stat_once(&mut self, name: &str) -> Result<ArchiveStat> {
        let req = Request::Stat {
            name: name.to_string(),
        };
        match self.round_trip(&req, OP_STAT)? {
            Response::Stat(stat) => Ok(stat),
            other => bail!("unexpected stat response {other:?}"),
        }
    }

    /// Decode a rectangular region of archive `name` into a [`Field`].
    /// Idempotent: retried under the client's policy.
    pub fn read_region(&mut self, name: &str, origin: &[usize], shape: &[usize]) -> Result<Field> {
        self.retrying(|c| c.read_region_once(name, origin, shape))
    }

    fn read_region_once(&mut self, name: &str, origin: &[usize], shape: &[usize]) -> Result<Field> {
        let req = Request::ReadRegion {
            name: name.to_string(),
            origin: origin.iter().map(|&v| v as u64).collect(),
            shape: shape.iter().map(|&v| v as u64).collect(),
        };
        match self.round_trip(&req, OP_READ_REGION)? {
            Response::Region {
                shape: got_shape,
                precision,
                data,
            } => {
                let shape_usize: Vec<usize> = got_shape
                    .iter()
                    .map(|&v| usize::try_from(v).context("region extent overflows usize"))
                    .collect::<Result<_>>()?;
                let n: usize = shape_usize.iter().product();
                if n != data.len() {
                    bail!(
                        "region shape {shape_usize:?} disagrees with {} samples",
                        data.len()
                    );
                }
                Ok(Field::new(&shape_usize, data, precision))
            }
            other => bail!("unexpected read_region response {other:?}"),
        }
    }

    /// Ask the server to shut down (honored unless started with
    /// shutdown disabled). Never retried: a lost response is
    /// indistinguishable from a successful shutdown.
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.round_trip(&Request::Shutdown, OP_SHUTDOWN)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected shutdown response {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spent_retry_budget_surfaces_as_a_typed_error() {
        // Port 1 has no listener; every connect is refused, which the
        // classifier treats as transient.
        let policy = RetryPolicy::transient(3, Duration::ZERO);
        let err = Client::connect_with_retry("127.0.0.1:1", policy).unwrap_err();
        let give_up = retries_exhausted_of(&err).expect("typed give-up error in the chain");
        assert_eq!(give_up.attempts, 3);
        assert!(give_up.last_error.contains("127.0.0.1:1"));
        assert!(status_of(&err).is_none());

        // With retries off the raw connect error comes back unwrapped.
        let raw = Client::connect_with_retry("127.0.0.1:1", RetryPolicy::none()).unwrap_err();
        assert!(retries_exhausted_of(&raw).is_none());
    }

    #[test]
    fn retry_deadline_bounds_reconnect_attempts() {
        // Refused connects are near-instant; with a 100-attempt budget
        // but a 30 ms deadline and 20 ms backoff, the schedule must give
        // up on the deadline long before the attempt budget.
        let policy = RetryPolicy::transient(100, Duration::from_millis(20))
            .with_deadline(Duration::from_millis(30));
        let started = std::time::Instant::now();
        let err = Client::connect_with_retry("127.0.0.1:1", policy).unwrap_err();
        let give_up = retries_exhausted_of(&err).expect("typed give-up error in the chain");
        assert!(give_up.attempts < 100, "deadline never fired");
        assert!(
            started.elapsed() < Duration::from_secs(2),
            "deadline did not bound the loop"
        );
    }

    #[test]
    fn request_verdicts_are_never_classified_as_transient() {
        let busy = anyhow::Error::new(ServerError {
            status: ST_BUSY,
            message: "at cap".to_string(),
        });
        assert!(is_retryable(&busy));
        let bad_region = anyhow::Error::new(ServerError {
            status: super::super::protocol::ST_BAD_REGION,
            message: "rank mismatch".to_string(),
        });
        assert!(!is_retryable(&bad_region));
        let hangup = anyhow::Error::new(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "server closed the connection mid-request",
        ));
        assert!(is_retryable(&hangup));
        let not_transient = anyhow::Error::msg("some application error");
        assert!(!is_retryable(&not_transient));
    }
}
