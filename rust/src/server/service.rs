//! The archive read server: a daemon that opens many `.ffcz` archives
//! and serves concurrent `read_region` requests over the length-prefixed
//! TCP protocol in [`super::protocol`].
//!
//! Architecture: one nonblocking accept loop on its own thread, one
//! thread per connection. All connections share the server state behind
//! an `Arc` —
//!
//! * an archive table (`name → Arc<Store>`): archives are opened lazily
//!   from the configured root directory on first reference and kept open
//!   (the open [`Store`] carries the parsed manifest, the resolved codec
//!   chains, and the decoded-chunk LRU, so every subsequent request on
//!   any connection hits the same caches);
//! * a pool of [`CorrectionScratch`] buffers: each connection checks one
//!   out for its lifetime and returns it on close, so decode transform
//!   state (FFT plans, spectrum buffers) warms once per chunk shape per
//!   connection rather than once per request;
//! * payload reads run under the server's [`RetryPolicy`] (default:
//!   transient faults retried with linear backoff), so a flaky storage
//!   backend degrades to latency instead of request failures;
//! * remote archives: with [`ServeOptions::remote_root`] set, names that
//!   miss the local root resolve against an HTTP endpoint —
//!   [`crate::store::HttpStorage`] wrapped in
//!   [`crate::store::ResilientStorage`] (retries, deadlines, hedging,
//!   and a circuit breaker shared per endpoint so every archive on one
//!   host trips and recovers together);
//! * degraded mode ([`ServeOptions::degraded`]): when the backend is
//!   unreachable, regions wholly in the decoded-chunk cache still answer
//!   `ST_OK` bit-exact, and regions needing unfetchable chunks answer
//!   `ST_DEGRADED` (counted in `server.requests.degraded`) instead of
//!   `ST_IO` — the contract is documented in `docs/STORAGE.md`;
//! * overload and stall protection: accepts beyond
//!   [`ServeOptions::max_connections`] are answered with a single
//!   `ST_BUSY` error frame and closed (counted in
//!   `server.requests.rejected`), and a connection that completes no
//!   request within [`ServeOptions::request_deadline`] is closed so
//!   abandoned peers release their connection slot.
//!
//! Every request is traced (`server.request` span) and counted
//! (`server.requests.*`, `server.inflight`, `server.request_ns` — see
//! `docs/TELEMETRY.md`). Failures are mapped to precise wire statuses
//! ([`super::protocol`]) and never tear down the server; a request for a
//! chunk whose payload fails CRC-32 verification answers `ST_IO` and the
//! connection keeps serving.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::correction::CorrectionScratch;
use crate::store::{
    Breaker, HttpStorage, ResilienceOptions, ResilientStorage, RetryPolicy, Store,
};
use crate::telemetry::{self, diag};
use crate::util::sync::{lock, read, write};

use super::protocol::{
    self, error_body, ok_body, region_body, stat_body, ArchiveStat, FrameRead, Request,
    DEFAULT_MAX_RESPONSE_FRAME, MAX_REQUEST_FRAME, ST_BAD_REGION, ST_BAD_REQUEST, ST_BUSY,
    ST_DEGRADED, ST_INTERNAL, ST_IO, ST_OK, ST_TOO_LARGE, ST_UNKNOWN_ARCHIVE,
};

/// How often idle connection threads and the accept loop re-check the
/// shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(20);

/// Server configuration. `Default` binds an ephemeral loopback port with
/// no archive root (only [`ArchiveServer::register`]ed archives are
/// servable), a 64 MiB decoded-chunk cache per archive, and transient
/// retries on.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address, e.g. `127.0.0.1:7070` (`:0` picks a free port).
    pub addr: String,
    /// Directory archives are resolved in: request name `n` opens
    /// `root/n`, then `root/n.ffcz`. `None` disables path resolution.
    pub root: Option<PathBuf>,
    /// HTTP base URL archives are resolved against when the local root
    /// misses: request name `n` opens `remote_root/n`, then
    /// `remote_root/n.ffcz`, each as an [`HttpStorage`] wrapped in
    /// [`ResilientStorage`] (per-endpoint breaker shared server-wide;
    /// the resilience layer owns retries, so the store-level policy is
    /// [`RetryPolicy::none`]). `None` disables remote resolution.
    pub remote_root: Option<String>,
    /// Resilience configuration applied to remote archives.
    pub resilience: ResilienceOptions,
    /// Serve degraded reads: when a region's backend fetch fails,
    /// answer `ST_OK` bit-exact if every needed chunk is cached, and
    /// `ST_DEGRADED` instead of `ST_IO` otherwise. Data-integrity
    /// failures (CRC, decode) still answer `ST_IO`/`ST_INTERNAL`.
    pub degraded: bool,
    /// Decoded-chunk LRU budget applied to each archive the server
    /// opens (bytes of decoded samples; 0 disables caching).
    pub cache_bytes: usize,
    /// Cap on response frame bodies; regions that would exceed it are
    /// refused with `ST_TOO_LARGE` before any decode work.
    pub max_response_bytes: usize,
    /// Retry policy applied to payload reads of archives the server
    /// opens.
    pub retry: RetryPolicy,
    /// Whether `SHUTDOWN` requests are honored (tests and the CLI say
    /// yes; long-running daemons may refuse them with `--no-shutdown`).
    pub allow_shutdown: bool,
    /// Per-connection request deadline: a connection that completes no
    /// request frame for this long is closed, so stalled or abandoned
    /// peers cannot pin a connection slot forever. Zero disables it.
    pub request_deadline: Duration,
    /// Cap on concurrently served connections. Excess accepts are
    /// answered with a single `ST_BUSY` error frame and closed (counted
    /// in `server.requests.rejected`). Zero means unlimited.
    pub max_connections: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            root: None,
            remote_root: None,
            resilience: ResilienceOptions::default(),
            degraded: false,
            cache_bytes: 64 << 20,
            max_response_bytes: DEFAULT_MAX_RESPONSE_FRAME,
            retry: RetryPolicy::transient(4, Duration::from_millis(2)),
            allow_shutdown: true,
            request_deadline: Duration::from_secs(30),
            max_connections: 64,
        }
    }
}

/// Registered-metric handles for the request path, fetched once.
struct ServerMetrics {
    requests: telemetry::Counter,
    errors: telemetry::Counter,
    ping: telemetry::Counter,
    stat: telemetry::Counter,
    read_region: telemetry::Counter,
    connections: telemetry::Counter,
    rejected: telemetry::Counter,
    bytes_out: telemetry::Counter,
    /// `READ_REGION` requests answered `ST_DEGRADED`.
    degraded: telemetry::Counter,
    inflight: telemetry::Gauge,
    request_ns: telemetry::Histogram,
}

fn server_metrics() -> &'static ServerMetrics {
    static METRICS: OnceLock<ServerMetrics> = OnceLock::new();
    METRICS.get_or_init(|| ServerMetrics {
        requests: telemetry::counter("server.requests.total"),
        errors: telemetry::counter("server.requests.errors"),
        ping: telemetry::counter("server.requests.ping"),
        stat: telemetry::counter("server.requests.stat"),
        read_region: telemetry::counter("server.requests.read_region"),
        connections: telemetry::counter("server.connections"),
        rejected: telemetry::counter("server.requests.rejected"),
        bytes_out: telemetry::counter("server.bytes_out"),
        degraded: telemetry::counter("server.requests.degraded"),
        inflight: telemetry::gauge("server.inflight"),
        request_ns: telemetry::histogram("server.request_ns"),
    })
}

struct ServerInner {
    opts: ServeOptions,
    stores: RwLock<HashMap<String, Arc<Store>>>,
    /// One circuit breaker per remote endpoint (authority), shared by
    /// every resilient store the server opens against it.
    breakers: Mutex<HashMap<String, Arc<Breaker>>>,
    scratch_pool: Mutex<Vec<CorrectionScratch>>,
    shutdown: AtomicBool,
    inflight: AtomicU64,
    active: AtomicU64,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

/// A running archive read server. Dropping the handle shuts the server
/// down and joins its threads.
///
/// ```
/// use ffcz::codec::CodecChainSpec;
/// use ffcz::data::synth::grf::GrfBuilder;
/// use ffcz::server::{ArchiveServer, Client, ServeOptions};
/// use ffcz::store::{encode_store, Store, StoreWriteOptions};
/// use std::sync::Arc;
///
/// let field = GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(5).build();
/// let opts = StoreWriteOptions::new(&[8, 8]);
/// let (bytes, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();
///
/// let server = ArchiveServer::start(ServeOptions::default()).unwrap();
/// server.register("f", Arc::new(Store::from_bytes(bytes).unwrap()));
///
/// let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
/// let region = client.read_region("f", &[4, 4], &[8, 8]).unwrap();
/// assert_eq!(region.shape(), &[8, 8]);
/// server.shutdown();
/// ```
pub struct ArchiveServer {
    inner: Arc<ServerInner>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl ArchiveServer {
    /// Bind `opts.addr` and start accepting connections.
    pub fn start(opts: ServeOptions) -> Result<Self> {
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding archive server to {}", opts.addr))?;
        listener
            .set_nonblocking(true)
            .context("setting the server listener nonblocking")?;
        let addr = listener
            .local_addr()
            .context("reading the bound server address")?;
        let inner = Arc::new(ServerInner {
            opts,
            stores: RwLock::new(HashMap::new()),
            breakers: Mutex::new(HashMap::new()),
            scratch_pool: Mutex::new(Vec::new()),
            shutdown: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            active: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
        });
        let accept_inner = Arc::clone(&inner);
        let accept = std::thread::Builder::new()
            .name("ffcz-accept".to_string())
            .spawn(move || accept_loop(listener, accept_inner))
            .context("spawning the server accept thread")?;
        diag::verbose(&format!("archive server listening on {addr}"));
        Ok(Self {
            inner,
            addr,
            accept: Some(accept),
        })
    }

    /// The address the server is listening on (resolves `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Make an already-open store servable under `name`, bypassing root
    /// resolution — the way tests serve in-memory or fault-injected
    /// archives. The store is used as configured by the caller (cache
    /// budget and retry policy are not overridden).
    pub fn register(&self, name: &str, store: Arc<Store>) {
        write(&self.inner.stores).insert(name.to_string(), store);
    }

    /// Signal shutdown and wait for the accept loop and every
    /// connection thread to exit. In-flight requests complete.
    pub fn shutdown(mut self) {
        self.shutdown_and_join();
    }

    /// Block until the server shuts down (via a `SHUTDOWN` request).
    pub fn join(mut self) {
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }

    fn shutdown_and_join(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for ArchiveServer {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<ServerInner>) {
    while !inner.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let cap = inner.opts.max_connections as u64;
                if cap > 0 && inner.active.load(Ordering::SeqCst) >= cap {
                    server_metrics().rejected.incr();
                    reject_connection(stream, cap);
                    continue;
                }
                server_metrics().connections.incr();
                inner.active.fetch_add(1, Ordering::SeqCst);
                let conn_inner = Arc::clone(&inner);
                match std::thread::Builder::new()
                    .name("ffcz-conn".to_string())
                    .spawn(move || serve_connection(stream, conn_inner))
                {
                    Ok(handle) => lock(&inner.conns).push(handle),
                    Err(e) => {
                        inner.active.fetch_sub(1, Ordering::SeqCst);
                        diag::warn(&format!("could not spawn connection thread: {e}"));
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL_INTERVAL);
            }
            Err(e) => {
                diag::warn(&format!("accept failed: {e}"));
                std::thread::sleep(POLL_INTERVAL);
            }
        }
    }
    let handles = std::mem::take(&mut *lock(&inner.conns));
    for handle in handles {
        let _ = handle.join();
    }
}

/// Answer an over-cap accept with a single `ST_BUSY` error frame and
/// close the socket. Best-effort: a peer that already went away just
/// misses the courtesy notice, and a client whose request write races
/// the close sees a connection error — which its retry loop treats the
/// same way as `ST_BUSY`.
fn reject_connection(mut stream: TcpStream, cap: u64) {
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_write_timeout(Some(POLL_INTERVAL));
    let body = error_body(
        ST_BUSY,
        &format!("server is at its {cap}-connection cap; retry later"),
    );
    let _ = protocol::write_frame(&mut stream, &body);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

fn serve_connection(stream: TcpStream, inner: Arc<ServerInner>) {
    serve_connection_loop(stream, &inner);
    inner.active.fetch_sub(1, Ordering::SeqCst);
}

fn serve_connection_loop(mut stream: TcpStream, inner: &Arc<ServerInner>) {
    // The listener is nonblocking; accepted sockets must not inherit
    // that. A short read timeout keeps idle connections responsive to
    // shutdown without busy-waiting.
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
    let _ = stream.set_nodelay(true);
    let metrics = server_metrics();
    let deadline = inner.opts.request_deadline;
    let mut last_request = Instant::now();
    let mut scratch = lock(&inner.scratch_pool)
        .pop()
        .unwrap_or_else(CorrectionScratch::new);
    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let body = match protocol::read_frame(&mut stream, MAX_REQUEST_FRAME) {
            Ok(FrameRead::Idle) => {
                if !deadline.is_zero() && last_request.elapsed() >= deadline {
                    diag::verbose("closing connection: request deadline exceeded");
                    break;
                }
                continue;
            }
            Ok(FrameRead::Eof) => break,
            Ok(FrameRead::Frame(body)) => body,
            Err(e) => {
                diag::verbose(&format!("dropping connection: {e}"));
                break;
            }
        };
        last_request = Instant::now();
        let started = Instant::now();
        let span = telemetry::span("server.request").arg("bytes_in", body.len() as u64);
        metrics.requests.incr();
        metrics
            .inflight
            .set(inner.inflight.fetch_add(1, Ordering::SeqCst) + 1);
        let (reply, stop) = handle_request(inner, &body, &mut scratch);
        metrics
            .inflight
            .set(inner.inflight.fetch_sub(1, Ordering::SeqCst).saturating_sub(1));
        metrics.request_ns.record_duration(started.elapsed());
        drop(span);
        if reply.first() != Some(&ST_OK) {
            metrics.errors.incr();
        }
        if protocol::write_frame(&mut stream, &reply).is_err() {
            break;
        }
        metrics.bytes_out.add(reply.len() as u64 + 4);
        if stop {
            inner.shutdown.store(true, Ordering::SeqCst);
            break;
        }
    }
    lock(&inner.scratch_pool).push(scratch);
}

/// Handle one parsed frame; returns the response body and whether the
/// server should shut down afterwards.
fn handle_request(
    inner: &ServerInner,
    body: &[u8],
    scratch: &mut CorrectionScratch,
) -> (Vec<u8>, bool) {
    let metrics = server_metrics();
    let req = match protocol::parse_request(body) {
        Ok(req) => req,
        Err(e) => return (error_body(ST_BAD_REQUEST, &format!("{e:#}")), false),
    };
    match req {
        Request::Ping => {
            metrics.ping.incr();
            (ok_body(), false)
        }
        Request::Shutdown => {
            if inner.opts.allow_shutdown {
                (ok_body(), true)
            } else {
                (
                    error_body(ST_BAD_REQUEST, "shutdown is disabled on this server"),
                    false,
                )
            }
        }
        Request::Stat { name } => {
            metrics.stat.incr();
            match lookup_store(inner, &name) {
                Ok(store) => {
                    let m = store.manifest();
                    (
                        stat_body(&ArchiveStat {
                            shape: m.shape.iter().map(|&v| v as u64).collect(),
                            chunk_shape: m.chunk_shape.iter().map(|&v| v as u64).collect(),
                            chunks: m.chunks.len() as u64,
                            payload_bytes: m.payload_bytes(),
                            precision: m.precision,
                        }),
                        false,
                    )
                }
                Err((status, msg)) => (error_body(status, &msg), false),
            }
        }
        Request::ReadRegion {
            name,
            origin,
            shape,
        } => {
            metrics.read_region.incr();
            let reply = match lookup_store(inner, &name) {
                Ok(store) => read_region_reply(inner, &store, &origin, &shape, scratch),
                Err((status, msg)) => error_body(status, &msg),
            };
            (reply, false)
        }
    }
}

/// Resolve an archive name to an open store: the shared table first,
/// then lazily from the root directory (`name`, then `name.ffcz`), then
/// the remote root (same two candidates against the HTTP endpoint).
fn lookup_store(inner: &ServerInner, name: &str) -> Result<Arc<Store>, (u8, String)> {
    if let Some(store) = read(&inner.stores).get(name) {
        return Ok(Arc::clone(store));
    }
    if name.is_empty()
        || name.starts_with(['/', '\\'])
        || name.contains('\\')
        || name.split('/').any(|c| c.is_empty() || c == "." || c == "..")
    {
        return Err((
            ST_BAD_REQUEST,
            format!("invalid archive name '{name}' (relative paths only, no '..')"),
        ));
    }
    let store = open_by_name(inner, name)?;
    let store = Arc::new(store);
    let mut stores = write(&inner.stores);
    // Two connections may race to open the same archive; first insert
    // wins so every request shares one decoded-chunk cache.
    let entry = stores
        .entry(name.to_string())
        .or_insert_with(|| Arc::clone(&store));
    Ok(Arc::clone(entry))
}

/// Open archive `name` from the local root if it resolves there, the
/// remote root otherwise.
fn open_by_name(inner: &ServerInner, name: &str) -> Result<Store, (u8, String)> {
    if let Some(root) = &inner.opts.root {
        let direct = root.join(name);
        let with_ext = root.join(format!("{name}.ffcz"));
        let path = if direct.is_file() {
            Some(direct)
        } else if with_ext.is_file() {
            Some(with_ext)
        } else {
            None
        };
        if let Some(path) = path {
            return match Store::open(&path) {
                Ok(store) => Ok(store
                    .with_retry_policy(inner.opts.retry)
                    .with_cache_budget(inner.opts.cache_bytes)),
                Err(e) => Err((ST_IO, format!("{e:#}"))),
            };
        }
        if inner.opts.remote_root.is_none() {
            return Err((
                ST_UNKNOWN_ARCHIVE,
                format!("no archive '{name}' under {}", root.display()),
            ));
        }
    }
    let Some(remote_root) = &inner.opts.remote_root else {
        return Err((
            ST_UNKNOWN_ARCHIVE,
            format!("archive '{name}' is not registered and no --root or --remote-root is configured"),
        ));
    };
    open_remote(inner, name, remote_root)
}

/// Open archive `name` against the remote root: `base/name`, then
/// `base/name.ffcz`, each as a resilient HTTP-range store. The
/// store-level retry policy is `none` — the resilience layer owns
/// retries, so faults are never retried twice over.
fn open_remote(inner: &ServerInner, name: &str, remote_root: &str) -> Result<Store, (u8, String)> {
    let base = remote_root.trim_end_matches('/');
    let mut last: Option<String> = None;
    for url in [format!("{base}/{name}"), format!("{base}/{name}.ffcz")] {
        let http = match HttpStorage::open(&url) {
            Ok(http) => http,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                last = Some(format!("{url}: {e}"));
                continue;
            }
            Err(e) => return Err((ST_IO, format!("opening {url}: {e}"))),
        };
        let breaker = breaker_for(inner, http.endpoint());
        let resilient =
            ResilientStorage::with_breaker(Arc::new(http), inner.opts.resilience, breaker);
        return match Store::open_storage(Arc::new(resilient)) {
            Ok(store) => Ok(store
                .with_retry_policy(RetryPolicy::none())
                .with_cache_budget(inner.opts.cache_bytes)),
            Err(e) => Err((ST_IO, format!("opening {url}: {e:#}"))),
        };
    }
    Err((
        ST_UNKNOWN_ARCHIVE,
        format!(
            "no archive '{name}' under {base} ({})",
            last.unwrap_or_else(|| "no candidates tried".to_string())
        ),
    ))
}

/// The server-wide circuit breaker for `endpoint`, created on first use.
fn breaker_for(inner: &ServerInner, endpoint: &str) -> Arc<Breaker> {
    let mut breakers = lock(&inner.breakers);
    if let Some(b) = breakers.get(endpoint) {
        return Arc::clone(b);
    }
    let b = Arc::new(Breaker::new(endpoint, inner.opts.resilience.breaker));
    breakers.insert(endpoint.to_string(), Arc::clone(&b));
    b
}

fn read_region_reply(
    inner: &ServerInner,
    store: &Store,
    origin: &[u64],
    shape: &[u64],
    scratch: &mut CorrectionScratch,
) -> Vec<u8> {
    let array = store.manifest().shape.clone();
    if origin.len() != array.len() || shape.len() != array.len() {
        return error_body(
            ST_BAD_REGION,
            &format!(
                "region rank {} does not match array rank {}",
                shape.len(),
                array.len()
            ),
        );
    }
    let mut o = Vec::with_capacity(array.len());
    let mut s = Vec::with_capacity(array.len());
    for d in 0..array.len() {
        let (Ok(ov), Ok(sv)) = (usize::try_from(origin[d]), usize::try_from(shape[d])) else {
            return error_body(ST_BAD_REGION, "region coordinates overflow");
        };
        if sv == 0 {
            return error_body(ST_BAD_REGION, &format!("zero-sized region axis {d}"));
        }
        match ov.checked_add(sv) {
            Some(end) if end <= array[d] => {}
            _ => {
                return error_body(
                    ST_BAD_REGION,
                    &format!(
                        "axis {d}: origin {ov} + shape {sv} exceeds array extent {}",
                        array[d]
                    ),
                )
            }
        }
        o.push(ov);
        s.push(sv);
    }
    let Some(n) = s.iter().try_fold(1usize, |a, &v| a.checked_mul(v)) else {
        return error_body(ST_TOO_LARGE, "region sample count overflows");
    };
    let resp_bytes = 3 + 8 * s.len() + 8 * n;
    if resp_bytes > inner.opts.max_response_bytes {
        return error_body(
            ST_TOO_LARGE,
            &format!(
                "a {n}-sample region needs a {resp_bytes}-byte response (cap {})",
                inner.opts.max_response_bytes
            ),
        );
    }
    if inner.opts.degraded {
        // Degraded serving: chunks the backend cannot produce fall back
        // to the decoded-chunk cache. A fully-served region answers
        // `ST_OK` bit-exact; a region needing unfetchable chunks
        // answers `ST_DEGRADED` (no partial data on the wire).
        // Data-integrity failures still propagate to the mapping below.
        return match store.read_region_degraded(&o, &s, scratch) {
            Ok(region) if region.is_complete() => region_body(
                region.field.shape(),
                store.manifest().precision,
                region.field.data(),
            ),
            Ok(region) => {
                server_metrics().degraded.incr();
                error_body(
                    ST_DEGRADED,
                    &format!(
                        "degraded: {} requested chunk(s) unavailable from the storage \
                         backend and not cached; retry after the backend recovers",
                        region.missing.len()
                    ),
                )
            }
            Err(e) => region_error_body(&e),
        };
    }
    match store.read_region_with_scratch(&o, &s, scratch) {
        Ok(field) => region_body(field.shape(), store.manifest().precision, field.data()),
        Err(e) => region_error_body(&e),
    }
}

/// Map a failed region read to a wire status: storage-level failures
/// (I/O errors anywhere in the chain, CRC-32 mismatches) answer `ST_IO`,
/// everything else `ST_INTERNAL`.
fn region_error_body(e: &anyhow::Error) -> Vec<u8> {
    let msg = format!("{e:#}");
    let io_like = e
        .chain()
        .any(|c| c.downcast_ref::<std::io::Error>().is_some())
        || msg.contains("CRC-32");
    error_body(if io_like { ST_IO } else { ST_INTERNAL }, &msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::CodecChainSpec;
    use crate::data::synth::grf::GrfBuilder;
    use crate::server::Client;
    use crate::store::{encode_store, StoreWriteOptions};

    fn fixture_bytes(seed: u64) -> Vec<u8> {
        let field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(seed).build();
        let opts = StoreWriteOptions::new(&[5, 4]).workers(1);
        let (bytes, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();
        bytes
    }

    #[test]
    fn serves_registered_in_memory_archives() {
        let bytes = fixture_bytes(11);
        let store = Arc::new(Store::from_bytes(bytes.clone()).unwrap());
        let server = ArchiveServer::start(ServeOptions::default()).unwrap();
        server.register("mem", Arc::clone(&store));
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
        client.ping().unwrap();

        let stat = client.stat("mem").unwrap();
        assert_eq!(stat.shape, vec![12, 10]);
        assert_eq!(stat.chunk_shape, vec![5, 4]);
        assert_eq!(stat.chunks, 9);
        assert_eq!(stat.precision, crate::data::Precision::Double);

        let truth = Store::from_bytes(bytes).unwrap();
        let want = truth.read_region(&[3, 2], &[6, 7], 1).unwrap();
        let got = client.read_region("mem", &[3, 2], &[6, 7]).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got.data(), want.data());
        server.shutdown();
    }

    #[test]
    fn error_statuses_are_precise_and_nonfatal() {
        let store = Arc::new(Store::from_bytes(fixture_bytes(12)).unwrap());
        let server = ArchiveServer::start(ServeOptions::default()).unwrap();
        server.register("f", store);
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();

        let unknown = client.stat("missing").unwrap_err();
        assert_eq!(super::super::client::status_of(&unknown), Some(ST_UNKNOWN_ARCHIVE));

        let bad_rank = client.read_region("f", &[0], &[4]).unwrap_err();
        assert_eq!(super::super::client::status_of(&bad_rank), Some(ST_BAD_REGION));

        let oob = client.read_region("f", &[10, 0], &[6, 4]).unwrap_err();
        assert_eq!(super::super::client::status_of(&oob), Some(ST_BAD_REGION));

        let traversal = client.stat("../escape").unwrap_err();
        assert_eq!(super::super::client::status_of(&traversal), Some(ST_BAD_REQUEST));

        // The connection survived all four errors.
        client.ping().unwrap();
        let got = client.read_region("f", &[0, 0], &[12, 10]).unwrap();
        assert_eq!(got.shape(), &[12, 10]);
        server.shutdown();
    }

    #[test]
    fn response_size_cap_refuses_before_decoding() {
        let store = Arc::new(Store::from_bytes(fixture_bytes(13)).unwrap());
        let opts = ServeOptions {
            max_response_bytes: 128,
            ..ServeOptions::default()
        };
        let server = ArchiveServer::start(opts).unwrap();
        server.register("f", Arc::clone(&store));
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
        let err = client.read_region("f", &[0, 0], &[12, 10]).unwrap_err();
        assert_eq!(super::super::client::status_of(&err), Some(ST_TOO_LARGE));
        assert_eq!(store.chunks_decoded(), 0, "cap must refuse before decode");
        server.shutdown();
    }

    #[test]
    fn shutdown_request_stops_the_server() {
        let server = ArchiveServer::start(ServeOptions::default()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client.shutdown_server().unwrap();
        server.join();
        // The listener is gone; a fresh connection must fail (possibly
        // after the OS drains the backlog, so poll briefly).
        let mut refused = false;
        for _ in 0..50 {
            match Client::connect(&addr) {
                Err(_) => {
                    refused = true;
                    break;
                }
                Ok(mut c) => {
                    if c.ping().is_err() {
                        refused = true;
                        break;
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(refused, "server kept serving after shutdown");
    }

    #[test]
    fn connection_cap_turns_away_excess_accepts() {
        let store = Arc::new(Store::from_bytes(fixture_bytes(14)).unwrap());
        let opts = ServeOptions {
            max_connections: 1,
            ..ServeOptions::default()
        };
        let server = ArchiveServer::start(opts).unwrap();
        server.register("f", store);
        let addr = server.local_addr().to_string();

        let mut first = Client::connect(&addr).unwrap();
        // A served request proves the accept loop has seen (and now
        // counts) the first connection.
        first.ping().unwrap();

        let rejected_before = telemetry::counter("server.requests.rejected").get();
        let mut second = Client::connect(&addr).unwrap();
        let err = second.ping().unwrap_err();
        // The courtesy ST_BUSY frame may race the close; a connection
        // error is the same verdict from the client's point of view.
        if let Some(status) = super::super::client::status_of(&err) {
            assert_eq!(status, ST_BUSY);
        }
        let mut counted = false;
        for _ in 0..100 {
            if telemetry::counter("server.requests.rejected").get() > rejected_before {
                counted = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(counted, "over-cap accept was not counted as rejected");

        // Closing the served connection frees the slot.
        drop(first);
        let mut reconnected = false;
        for _ in 0..100 {
            if let Ok(mut c) = Client::connect(&addr) {
                if c.ping().is_ok() {
                    reconnected = true;
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(reconnected, "slot was never released after disconnect");
        server.shutdown();
    }

    #[test]
    fn idle_connections_are_closed_at_the_request_deadline() {
        let opts = ServeOptions {
            request_deadline: Duration::from_millis(100),
            ..ServeOptions::default()
        };
        let server = ArchiveServer::start(opts).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client.ping().unwrap();
        std::thread::sleep(Duration::from_millis(400));
        // The server hung up on the stalled connection…
        assert!(client.ping().is_err(), "idle connection outlived the deadline");
        // …but fresh connections are still welcome.
        let mut fresh = Client::connect(&addr).unwrap();
        fresh.ping().unwrap();
        server.shutdown();
    }

    #[test]
    fn degraded_mode_serves_cached_regions_and_answers_st_degraded() {
        use crate::store::{FaultInjector, FaultPlan, MemStorage};

        let bytes = fixture_bytes(16);
        let truth = Store::from_bytes(bytes.clone()).unwrap();
        let injector = Arc::new(FaultInjector::new(MemStorage::new(bytes), FaultPlan::none()));
        let faults = injector.handle();
        let store = Arc::new(
            Store::open_storage(injector)
                .unwrap()
                .with_cache_budget(64 << 20),
        );
        let opts = ServeOptions {
            degraded: true,
            ..ServeOptions::default()
        };
        let server = ArchiveServer::start(opts).unwrap();
        server.register("f", Arc::clone(&store));
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();

        // Warm the cache with the top-left chunk, then kill the backend.
        let warm = client.read_region("f", &[0, 0], &[5, 4]).unwrap();
        faults.set_plan(FaultPlan {
            transient_every: 1,
            ..FaultPlan::none()
        });

        // Cached region: still ST_OK, bit-exact.
        let cached = client.read_region("f", &[0, 0], &[5, 4]).unwrap();
        assert_eq!(cached.data(), warm.data());
        assert_eq!(
            cached.data(),
            truth.read_region(&[0, 0], &[5, 4], 1).unwrap().data()
        );

        // Region needing unfetchable chunks: typed ST_DEGRADED, and the
        // connection keeps serving.
        let err = client.read_region("f", &[0, 0], &[12, 10]).unwrap_err();
        assert_eq!(super::super::client::status_of(&err), Some(ST_DEGRADED));
        client.ping().unwrap();

        // Backend recovers: full region served again.
        faults.set_plan(FaultPlan::none());
        let full = client.read_region("f", &[0, 0], &[12, 10]).unwrap();
        assert_eq!(
            full.data(),
            truth.read_region(&[0, 0], &[12, 10], 1).unwrap().data()
        );
        server.shutdown();
    }

    #[test]
    fn retrying_client_survives_a_deadline_close() {
        let store = Arc::new(Store::from_bytes(fixture_bytes(15)).unwrap());
        let opts = ServeOptions {
            request_deadline: Duration::from_millis(100),
            ..ServeOptions::default()
        };
        let server = ArchiveServer::start(opts).unwrap();
        server.register("f", store);
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr)
            .unwrap()
            .with_retry_policy(RetryPolicy::transient(4, Duration::from_millis(1)));
        let before = client.read_region("f", &[0, 0], &[12, 10]).unwrap();
        // Let the server close the idle connection, then reissue: the
        // client reconnects under the hood and the caller never sees
        // the hangup.
        std::thread::sleep(Duration::from_millis(400));
        let after = client.read_region("f", &[0, 0], &[12, 10]).unwrap();
        assert_eq!(before.data(), after.data());
        server.shutdown();
    }
}
