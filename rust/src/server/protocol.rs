//! Wire protocol of the archive read server.
//!
//! The protocol is specified normatively in `docs/SERVER.md` at the
//! repository root; this module is the reference implementation of both
//! sides (the server parses requests and builds responses, the client
//! does the reverse). Everything here is pure bytes-in/bytes-out so the
//! framing and layouts are unit-testable without sockets.
//!
//! In one paragraph: every message is a **frame** — a `u32`
//! little-endian body length followed by that many body bytes. A request
//! body starts with a one-byte opcode; a response body starts with a
//! one-byte status. All integers are little-endian, all sample payloads
//! are IEEE-754 `f64` little-endian in row-major order, and all names
//! are UTF-8. The layouts below mirror `docs/SERVER.md` table for table;
//! the doc-derived client in `rust/tests/server.rs` re-parses that
//! document and round-trips raw frames against this implementation, so
//! the two cannot drift silently.

use std::io::{self, Read, Write};

use anyhow::{bail, Context, Result};

use crate::data::Precision;
use crate::encoding::fixed;

// ------------------------------------------------------------ opcodes --

/// Liveness probe; empty payload, empty `OK` response.
pub const OP_PING: u8 = 0x01;
/// Archive metadata: shape, chunk grid, payload size, precision.
pub const OP_STAT: u8 = 0x02;
/// Decode and return a rectangular region of an archive.
pub const OP_READ_REGION: u8 = 0x03;
/// Ask the server to stop accepting connections and exit its loops.
pub const OP_SHUTDOWN: u8 = 0x0F;

// ----------------------------------------------------------- statuses --

/// Request succeeded; payload depends on the opcode.
pub const ST_OK: u8 = 0x00;
/// Malformed frame: unknown opcode, truncated payload, bad UTF-8.
pub const ST_BAD_REQUEST: u8 = 0x01;
/// The named archive is not registered and not found under the root.
pub const ST_UNKNOWN_ARCHIVE: u8 = 0x02;
/// Region outside the array, wrong rank, or zero-sized axis.
pub const ST_BAD_REGION: u8 = 0x03;
/// Storage-level failure: I/O error or CRC-32 payload mismatch.
pub const ST_IO: u8 = 0x04;
/// Decode failure not attributable to storage.
pub const ST_INTERNAL: u8 = 0x05;
/// The response would exceed the server's response-size cap.
pub const ST_TOO_LARGE: u8 = 0x06;
/// The server is at its concurrent-connection cap; retry later.
pub const ST_BUSY: u8 = 0x07;
/// Degraded mode: the archive's remote backend is unreachable and at
/// least one requested chunk is not in the decoded-chunk cache, so the
/// region cannot be served bit-exact. Cached-only regions still answer
/// `ST_OK`. Not retryable at the protocol level — the backend must
/// recover first (the server's circuit breaker re-probes on its own).
pub const ST_DEGRADED: u8 = 0x08;

// -------------------------------------------------- precision tags ----

/// Samples decoded from a double-precision archive.
pub const PREC_F64: u8 = 0;
/// Samples decoded from a single-precision archive (still shipped as
/// `f64` on the wire; the tag records the source representation).
pub const PREC_F32: u8 = 1;

// ------------------------------------------------------------- limits --

/// Hard cap on request frame bodies (1 MiB): requests are tiny (an
/// opcode, a name, two coordinate vectors), so anything larger is a
/// framing error, not a big request.
pub const MAX_REQUEST_FRAME: usize = 1 << 20;
/// Default cap on response frame bodies (256 MiB ≈ a 32M-sample region).
pub const DEFAULT_MAX_RESPONSE_FRAME: usize = 256 << 20;

fn precision_tag(p: Precision) -> u8 {
    match p {
        Precision::Double => PREC_F64,
        Precision::Single => PREC_F32,
    }
}

fn precision_from_tag(tag: u8) -> Result<Precision> {
    match tag {
        PREC_F64 => Ok(Precision::Double),
        PREC_F32 => Ok(Precision::Single),
        other => bail!("unknown precision tag {other:#04x}"),
    }
}

// ------------------------------------------------------------ framing --

/// Result of pulling one frame off a connection.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete frame body.
    Frame(Vec<u8>),
    /// Clean end-of-stream before any byte of a new frame.
    Eof,
    /// Read timeout before any byte of a new frame (only with a socket
    /// read timeout set) — the connection is idle, poll again.
    Idle,
}

/// Write one frame: `u32` LE body length, then the body.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Read one frame of at most `max` body bytes. EOF or a read timeout
/// *before the first byte* of a frame are reported as [`FrameRead::Eof`]
/// / [`FrameRead::Idle`]; either mid-frame is an error (the peer died or
/// stalled with a frame half-sent, and the stream offset is lost).
pub fn read_frame(r: &mut impl Read, max: usize) -> io::Result<FrameRead> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(FrameRead::Eof),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("connection closed mid-frame ({got} of 4 header bytes)"),
                ))
            }
            Ok(n) => got += n,
            Err(e)
                if got == 0
                    && matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                    ) =>
            {
                return Ok(FrameRead::Idle)
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > max {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame body of {len} bytes exceeds the {max}-byte cap"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(FrameRead::Frame(body))
}

// ----------------------------------------------------------- requests --

/// A parsed request body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    Ping,
    Stat { name: String },
    ReadRegion {
        name: String,
        origin: Vec<u64>,
        shape: Vec<u64>,
    },
    Shutdown,
}

fn push_name(out: &mut Vec<u8>, name: &str) {
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
}

fn read_name(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = u16::from_le_bytes(fixed::take::<2>(buf, pos, "name length")?) as usize;
    let Some(bytes) = buf.get(*pos..).and_then(|b| b.get(..len)) else {
        bail!("truncated archive name ({len} bytes declared)");
    };
    *pos += len;
    String::from_utf8(bytes.to_vec()).context("archive name is not UTF-8")
}

/// Serialize a request to a frame body (no length prefix).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut out = Vec::new();
    match req {
        Request::Ping => out.push(OP_PING),
        Request::Shutdown => out.push(OP_SHUTDOWN),
        Request::Stat { name } => {
            out.push(OP_STAT);
            push_name(&mut out, name);
        }
        Request::ReadRegion {
            name,
            origin,
            shape,
        } => {
            out.push(OP_READ_REGION);
            push_name(&mut out, name);
            out.push(origin.len() as u8);
            for &v in origin {
                out.extend_from_slice(&v.to_le_bytes());
            }
            for &v in shape {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    out
}

/// Parse a request frame body. Any error here maps to
/// [`ST_BAD_REQUEST`] on the server side.
pub fn parse_request(body: &[u8]) -> Result<Request> {
    let mut pos = 0usize;
    let op = fixed::take::<1>(body, &mut pos, "opcode")?[0];
    let req = match op {
        OP_PING => Request::Ping,
        OP_SHUTDOWN => Request::Shutdown,
        OP_STAT => Request::Stat {
            name: read_name(body, &mut pos)?,
        },
        OP_READ_REGION => {
            let name = read_name(body, &mut pos)?;
            let ndim = fixed::take::<1>(body, &mut pos, "rank")?[0] as usize;
            let mut origin = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                origin.push(fixed::read_u64_le(body, &mut pos, "origin component")?);
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(fixed::read_u64_le(body, &mut pos, "shape component")?);
            }
            Request::ReadRegion {
                name,
                origin,
                shape,
            }
        }
        other => bail!("unknown opcode {other:#04x}"),
    };
    if pos != body.len() {
        bail!("{} trailing bytes after request payload", body.len() - pos);
    }
    Ok(req)
}

// ---------------------------------------------------------- responses --

/// Archive metadata returned by a `STAT` request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArchiveStat {
    pub shape: Vec<u64>,
    pub chunk_shape: Vec<u64>,
    /// Number of chunks in the grid.
    pub chunks: u64,
    /// Total encoded payload bytes across all chunks.
    pub payload_bytes: u64,
    pub precision: Precision,
}

/// A parsed response body.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Empty `OK` (ping / shutdown acknowledgements).
    Ok,
    Stat(ArchiveStat),
    Region {
        shape: Vec<u64>,
        precision: Precision,
        data: Vec<f64>,
    },
    Error { status: u8, message: String },
}

/// Empty success body (ping / shutdown acknowledgement).
pub fn ok_body() -> Vec<u8> {
    vec![ST_OK]
}

/// Error body: status, `u16` LE message length, UTF-8 message.
pub fn error_body(status: u8, message: &str) -> Vec<u8> {
    let msg = message.as_bytes();
    let take = msg.len().min(u16::MAX as usize);
    let mut out = Vec::with_capacity(3 + take);
    out.push(status);
    out.extend_from_slice(&(take as u16).to_le_bytes());
    out.extend_from_slice(&msg[..take]);
    out
}

/// `STAT` success body.
pub fn stat_body(stat: &ArchiveStat) -> Vec<u8> {
    let mut out = vec![ST_OK, stat.shape.len() as u8];
    for &v in &stat.shape {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for &v in &stat.chunk_shape {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&stat.chunks.to_le_bytes());
    out.extend_from_slice(&stat.payload_bytes.to_le_bytes());
    out.push(precision_tag(stat.precision));
    out
}

/// `READ_REGION` success body: rank, region shape, precision tag, then
/// the samples as `f64` LE in row-major order.
pub fn region_body(shape: &[usize], precision: Precision, data: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(2 + 8 * shape.len() + 1 + 8 * data.len());
    out.push(ST_OK);
    out.push(shape.len() as u8);
    for &v in shape {
        out.extend_from_slice(&(v as u64).to_le_bytes());
    }
    out.push(precision_tag(precision));
    for &v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn parse_error_tail(status: u8, body: &[u8], pos: &mut usize) -> Result<Response> {
    let len = u16::from_le_bytes(fixed::take::<2>(body, pos, "error message length")?) as usize;
    let Some(bytes) = body.get(*pos..).and_then(|b| b.get(..len)) else {
        bail!("truncated error message ({len} bytes declared)");
    };
    *pos += len;
    Ok(Response::Error {
        status,
        message: String::from_utf8_lossy(bytes).into_owned(),
    })
}

/// Parse a response frame body. `op` is the opcode of the request this
/// response answers — `OK` payloads are op-specific.
pub fn parse_response(op: u8, body: &[u8]) -> Result<Response> {
    let mut pos = 0usize;
    let status = fixed::take::<1>(body, &mut pos, "status")?[0];
    if status != ST_OK {
        let resp = parse_error_tail(status, body, &mut pos)?;
        if pos != body.len() {
            bail!("{} trailing bytes after error response", body.len() - pos);
        }
        return Ok(resp);
    }
    let resp = match op {
        OP_PING | OP_SHUTDOWN => Response::Ok,
        OP_STAT => {
            let ndim = fixed::take::<1>(body, &mut pos, "rank")?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(fixed::read_u64_le(body, &mut pos, "shape component")?);
            }
            let mut chunk_shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                chunk_shape.push(fixed::read_u64_le(body, &mut pos, "chunk-shape component")?);
            }
            let chunks = fixed::read_u64_le(body, &mut pos, "chunk count")?;
            let payload_bytes = fixed::read_u64_le(body, &mut pos, "payload bytes")?;
            let precision =
                precision_from_tag(fixed::take::<1>(body, &mut pos, "precision tag")?[0])?;
            Response::Stat(ArchiveStat {
                shape,
                chunk_shape,
                chunks,
                payload_bytes,
                precision,
            })
        }
        OP_READ_REGION => {
            let ndim = fixed::take::<1>(body, &mut pos, "rank")?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(fixed::read_u64_le(body, &mut pos, "shape component")?);
            }
            let precision =
                precision_from_tag(fixed::take::<1>(body, &mut pos, "precision tag")?[0])?;
            let n = shape
                .iter()
                .try_fold(1u64, |a, &s| a.checked_mul(s))
                .and_then(|n| usize::try_from(n).ok())
                .context("region sample count overflows")?;
            let mut data = Vec::with_capacity(n);
            for _ in 0..n {
                data.push(fixed::read_f64_le(body, &mut pos, "sample")?);
            }
            Response::Region {
                shape,
                precision,
                data,
            }
        }
        other => bail!("cannot parse a response for unknown opcode {other:#04x}"),
    };
    if pos != body.len() {
        bail!("{} trailing bytes after response payload", body.len() - pos);
    }
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_round_trip() {
        let reqs = [
            Request::Ping,
            Request::Shutdown,
            Request::Stat {
                name: "nyx/baryon.ffcz".to_string(),
            },
            Request::ReadRegion {
                name: "f".to_string(),
                origin: vec![0, 4, 9],
                shape: vec![8, 2, 1],
            },
        ];
        for req in &reqs {
            let body = encode_request(req);
            assert_eq!(&parse_request(&body).unwrap(), req, "{req:?}");
        }
    }

    #[test]
    fn malformed_requests_error_without_panicking() {
        // Empty body, unknown opcode, truncated name, trailing garbage,
        // short coordinate vectors — all must be Err, never a panic.
        assert!(parse_request(&[]).is_err());
        assert!(parse_request(&[0x7E]).is_err());
        assert!(parse_request(&[OP_STAT, 10, 0, b'x']).is_err());
        let mut ok = encode_request(&Request::Ping);
        ok.push(0);
        assert!(parse_request(&ok).is_err());
        let mut rr = encode_request(&Request::ReadRegion {
            name: "a".to_string(),
            origin: vec![1, 2],
            shape: vec![3, 4],
        });
        rr.truncate(rr.len() - 5);
        assert!(parse_request(&rr).is_err());
    }

    #[test]
    fn responses_round_trip() {
        let stat = ArchiveStat {
            shape: vec![64, 64],
            chunk_shape: vec![16, 16],
            chunks: 16,
            payload_bytes: 12345,
            precision: Precision::Double,
        };
        match parse_response(OP_STAT, &stat_body(&stat)).unwrap() {
            Response::Stat(s) => assert_eq!(s, stat),
            other => panic!("wrong variant: {other:?}"),
        }

        let data = vec![1.5, -2.25, f64::MIN_POSITIVE, 0.0];
        match parse_response(OP_READ_REGION, &region_body(&[2, 2], Precision::Single, &data))
            .unwrap()
        {
            Response::Region {
                shape,
                precision,
                data: got,
            } => {
                assert_eq!(shape, vec![2, 2]);
                assert_eq!(precision, Precision::Single);
                let bits: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
                let want: Vec<u64> = data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, want);
            }
            other => panic!("wrong variant: {other:?}"),
        }

        match parse_response(OP_PING, &ok_body()).unwrap() {
            Response::Ok => {}
            other => panic!("wrong variant: {other:?}"),
        }

        match parse_response(OP_READ_REGION, &error_body(ST_BAD_REGION, "nope")).unwrap() {
            Response::Error { status, message } => {
                assert_eq!(status, ST_BAD_REGION);
                assert_eq!(message, "nope");
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn frames_round_trip_and_enforce_the_cap() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = std::io::Cursor::new(buf);
        match read_frame(&mut r, 64).unwrap() {
            FrameRead::Frame(b) => assert_eq!(b, b"hello"),
            other => panic!("wrong read: {other:?}"),
        }
        match read_frame(&mut r, 64).unwrap() {
            FrameRead::Frame(b) => assert!(b.is_empty()),
            other => panic!("wrong read: {other:?}"),
        }
        assert!(matches!(read_frame(&mut r, 64).unwrap(), FrameRead::Eof));

        // Over-cap length prefix is rejected before allocating the body.
        let huge = (u32::MAX).to_le_bytes().to_vec();
        let mut r = std::io::Cursor::new(huge);
        assert!(read_frame(&mut r, 1 << 20).is_err());

        // Truncation mid-header and mid-body are errors, not EOFs.
        let mut partial = Vec::new();
        write_frame(&mut partial, b"abcdef").unwrap();
        let mut r = std::io::Cursor::new(partial[..2].to_vec());
        assert!(read_frame(&mut r, 64).is_err());
        let mut r = std::io::Cursor::new(partial[..7].to_vec());
        assert!(read_frame(&mut r, 64).is_err());
    }
}
