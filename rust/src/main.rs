//! `ffcz` — command-line interface to the FFCz dual-domain compression
//! system.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!
//! ```text
//! ffcz compress   --input f.ffld --output f.fz [--base sz-like]
//!                 [--eb 1e-3] [--db 1e-3 | --power-spectrum 1e-3]
//! ffcz decompress --input f.fz --output f.ffld
//! ffcz verify     --original f.ffld --archive f.fz [--eb ..] [--db ..]
//! ffcz synth      --dataset nyx-baryon --scale 32 --output f.ffld
//! ffcz experiment <fig1|table2|...|all> [--scale 32] [--out results]
//! ffcz pipeline   --instances 4 --scale 32 [--sequential]
//! ffcz info       --archive f.fz
//! ```

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use ffcz::compressors::by_name;
use ffcz::coordinator::{run_pipeline, ExecMode, PipelineConfig};
use ffcz::correction::{self, BoundSpec, FfczArchive, FfczConfig, FrequencyBound};
use ffcz::data::{io, synth};
use ffcz::experiments::{self, ExpOptions};
use ffcz::metrics::QualityReport;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (positional, flags) = parse_flags(&args[1..]);
    match cmd.as_str() {
        "compress" => cmd_compress(&flags),
        "decompress" => cmd_decompress(&flags),
        "verify" => cmd_verify(&flags),
        "synth" => cmd_synth(&flags),
        "experiment" => cmd_experiment(&positional, &flags),
        "pipeline" => cmd_pipeline(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `ffcz help`)"),
    }
}

fn print_usage() {
    println!(
        "ffcz — spectrum-preserving lossy compression (FFCz reproduction)\n\
         \n\
         usage: ffcz <command> [flags]\n\
         \n\
         commands:\n\
         \x20 compress    --input F --output F [--base sz-like|zfp-like|sperr-like]\n\
         \x20             [--eb REL] [--db REL | --power-spectrum REL]\n\
         \x20 decompress  --input F --output F\n\
         \x20 verify      --original F --archive F [--eb REL] [--db REL]\n\
         \x20 synth       --dataset NAME --scale N --output F   (nyx-baryon, nyx-dm,\n\
         \x20             s3d-co2, hedm, eeg)\n\
         \x20 experiment  <id|all> [--scale N] [--out DIR] [--artifacts DIR]\n\
         \x20 pipeline    [--instances N] [--scale N] [--sequential]\n\
         \x20 info        --archive F"
    );
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            // boolean flags take no value; detect by next token
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    (positional, flags)
}

fn get<'a>(flags: &'a HashMap<String, String>, key: &str) -> Result<&'a str> {
    flags
        .get(key)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
}

fn parse_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse::<f64>()
            .with_context(|| format!("--{key} expects a number, got '{v}'")),
    }
}

fn build_config(flags: &HashMap<String, String>) -> Result<FfczConfig> {
    let eb = parse_f64(flags, "eb", 1e-3)?;
    let cfg = if let Some(ps) = flags.get("power-spectrum") {
        let p: f64 = ps.parse().context("--power-spectrum expects a number")?;
        FfczConfig::power_spectrum(eb, p)
    } else {
        let db = parse_f64(flags, "db", 1e-3)?;
        FfczConfig {
            spatial: BoundSpec::Relative(eb),
            frequency: FrequencyBound::Uniform(BoundSpec::Relative(db)),
            max_iters: 200,
            max_quant_retries: 3,
        }
    };
    Ok(cfg)
}

fn cmd_compress(flags: &HashMap<String, String>) -> Result<()> {
    let input = PathBuf::from(get(flags, "input")?);
    let output = PathBuf::from(get(flags, "output")?);
    let base_name = flags.get("base").map(|s| s.as_str()).unwrap_or("sz-like");
    let base = by_name(base_name).ok_or_else(|| anyhow::anyhow!("unknown base {base_name}"))?;
    let cfg = build_config(flags)?;

    let field = io::load(&input)?;
    let archive = correction::compress(&field, base.as_ref(), &cfg)?;
    let bytes = archive.to_bytes();
    std::fs::write(&output, &bytes)?;
    println!(
        "compressed {} ({} samples) -> {} ({}, ratio {:.1}, base {}, edits {})",
        input.display(),
        field.len(),
        output.display(),
        ffcz::util::human_bytes(bytes.len()),
        field.original_bytes() as f64 / bytes.len() as f64,
        ffcz::util::human_bytes(archive.base_bytes()),
        ffcz::util::human_bytes(archive.edit_bytes()),
    );
    println!(
        "POCS: {} iterations, {} spatial + {} frequency active edits{}",
        archive.stats.iterations,
        archive.stats.active_spat,
        archive.stats.active_freq,
        if archive.stats.used_raw_fallback {
            " (raw-edit fallback)"
        } else {
            ""
        }
    );
    Ok(())
}

fn cmd_decompress(flags: &HashMap<String, String>) -> Result<()> {
    let input = PathBuf::from(get(flags, "input")?);
    let output = PathBuf::from(get(flags, "output")?);
    let archive = FfczArchive::from_bytes(&std::fs::read(&input)?)?;
    let field = correction::decompress(&archive)?;
    io::save(&field, &output)?;
    println!(
        "decompressed {} -> {} (shape {:?})",
        input.display(),
        output.display(),
        field.shape()
    );
    Ok(())
}

fn cmd_verify(flags: &HashMap<String, String>) -> Result<()> {
    let original = io::load(&PathBuf::from(get(flags, "original")?))?;
    let archive =
        FfczArchive::from_bytes(&std::fs::read(PathBuf::from(get(flags, "archive")?))?)?;
    let recon = correction::decompress(&archive)?;
    let cfg = build_config(flags)?;
    let report = correction::verify(&original, &recon, &cfg);
    let quality = QualityReport::compute(&original, &recon);
    println!(
        "spatial:   {} (max ratio {:.4})",
        if report.spatial_ok { "OK" } else { "VIOLATED" },
        report.max_spatial_ratio
    );
    println!(
        "frequency: {} (max ratio {:.4})",
        if report.frequency_ok { "OK" } else { "VIOLATED" },
        report.max_frequency_ratio
    );
    println!(
        "PSNR {:.2} dB, SSNR {:.2} dB, max |ε| {:.3e}, max RFE {:.3e}",
        quality.psnr_db, quality.ssnr_db, quality.max_abs_err, quality.max_rfe
    );
    if !(report.spatial_ok && report.frequency_ok) {
        bail!("dual-domain verification failed");
    }
    Ok(())
}

fn cmd_synth(flags: &HashMap<String, String>) -> Result<()> {
    let dataset = get(flags, "dataset")?;
    let scale: usize = parse_f64(flags, "scale", 32.0)? as usize;
    let output = PathBuf::from(get(flags, "output")?);
    let suite = synth::benchmark_suite(scale);
    let field = suite
        .into_iter()
        .find(|(n, _)| n == dataset)
        .map(|(_, f)| f)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    io::save(&field, &output)?;
    println!(
        "wrote {} (shape {:?}, {})",
        output.display(),
        field.shape(),
        ffcz::util::human_bytes(field.original_bytes())
    );
    Ok(())
}

fn cmd_experiment(positional: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let Some(id) = positional.first() else {
        bail!("experiment id required: {:?} or 'all'", experiments::ALL);
    };
    let mut opts = ExpOptions::default();
    opts.scale = parse_f64(flags, "scale", opts.scale as f64)? as usize;
    if let Some(out) = flags.get("out") {
        opts.out_dir = out.into();
    }
    if let Some(dir) = flags.get("artifacts") {
        opts.artifact_dir = dir.into();
    }
    experiments::run(id, &opts)
}

fn cmd_pipeline(flags: &HashMap<String, String>) -> Result<()> {
    let n: usize = parse_f64(flags, "instances", 4.0)? as usize;
    let scale: usize = parse_f64(flags, "scale", 32.0)? as usize;
    let base_name = flags.get("base").map(|s| s.as_str()).unwrap_or("sz-like");
    let base = by_name(base_name).ok_or_else(|| anyhow::anyhow!("unknown base {base_name}"))?;
    let mut cfg = PipelineConfig::new(build_config(flags)?);
    if flags.contains_key("sequential") {
        cfg.mode = ExecMode::Sequential;
    }
    let instances: Vec<_> = (0..n)
        .map(|i| {
            (
                format!("snap{i}"),
                synth::grf::GrfBuilder::new(&[scale, scale, scale])
                    .lognormal(1.2)
                    .seed(300 + i as u64)
                    .build(),
            )
        })
        .collect();
    let report = run_pipeline(instances, base.as_ref(), &cfg)?;
    print!("{}", report.timeline_text());
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    let archive =
        FfczArchive::from_bytes(&std::fs::read(PathBuf::from(get(flags, "archive")?))?)?;
    println!("base compressor : {}", archive.base_name);
    println!(
        "base payload    : {}",
        ffcz::util::human_bytes(archive.base_bytes())
    );
    println!(
        "edit payload    : {}",
        ffcz::util::human_bytes(archive.edit_bytes())
    );
    println!("iterations      : {}", archive.stats.iterations);
    println!("active spatial  : {}", archive.stats.active_spat);
    println!("active frequency: {}", archive.stats.active_freq);
    println!("raw fallback    : {}", archive.stats.used_raw_fallback);
    Ok(())
}
