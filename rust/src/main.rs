//! `ffcz` — command-line interface to the FFCz dual-domain compression
//! system.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!
//! ```text
//! ffcz compress   --input f.ffld --output f.fz [--base sz-like]
//!                 [--eb 1e-3 | --abs-eb 2e-4]
//!                 [--db 1e-3 | --abs-db 2e-4 | --power-spectrum 1e-3]
//! ffcz decompress --input f.fz --output f.ffld
//! ffcz verify     --original f.ffld --archive f.fz [--eb ..] [--db ..]
//! ffcz synth      --dataset nyx-baryon --scale 32 --output f.ffld
//! ffcz experiment <fig1|table2|...|all> [--scale 32] [--out results]
//! ffcz pipeline   --instances 4 --scale 32 [--sequential] [--store dir]
//!                 [--in-memory]
//! ffcz archive    create|extract|inspect|read-region|verify|repair …
//!                 (chunked .ffcz store, streamed writes by default with
//!                 --in-memory escape hatch, per-chunk codec chains via
//!                 --chunk-codec — grammar in docs/FORMAT.md; verify re-checks
//!                 every chunk, repair salvages an interrupted create)
//! ffcz serve      --root archives/ and/or --remote-root http://host/prefix
//!                 [--addr 127.0.0.1:7070] [--cache-mb 64]
//!                 [--port-file p.txt] [--no-shutdown] [--max-conns 64]
//!                 [--deadline-ms 30000] [--degraded]
//!                 (remote archives are read over resilient HTTP ranges —
//!                 retries, deadlines, circuit breaker; see docs/STORAGE.md)
//! ffcz get        --addr 127.0.0.1:7070 --archive f --origin 0,0 --shape 8,8
//!                 --output w.ffld   (also --ping | --stat | --shutdown;
//!                 [--retries N] [--backoff-ms N] retry transient faults;
//!                 wire protocol in docs/SERVER.md)
//! ffcz info       --archive f.fz
//! ```

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use ffcz::codec::{require_compressor, CodecChainSpec};
use ffcz::coordinator::{run_pipeline, run_pipeline_to_store, ExecMode, PipelineConfig, StoreSink};
use ffcz::correction::{self, BoundSpec, FfczArchive, FfczConfig, FrequencyBound};
use ffcz::data::{io, synth};
use ffcz::experiments::{self, ExpOptions};
use ffcz::metrics::QualityReport;
use ffcz::server::{ArchiveServer, Client, ServeOptions};
use ffcz::store::{
    resume_store_write, staging_paths, write_store, write_store_in_memory, RetryPolicy, Store,
    StoreWriteOptions,
};
use ffcz::telemetry::{self, diag};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            diag::error(&format!("{e:#}"));
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (positional, flags) = parse_flags(&args[1..]);
    // Global diagnostic flags, honored uniformly by every subcommand.
    diag::apply_flags(flags.contains_key("verbose"), flags.contains_key("quiet"));
    let trace_out = flags.get("trace-out").map(PathBuf::from);
    if trace_out.is_some() {
        telemetry::trace::enable();
    }
    let result = match cmd.as_str() {
        "compress" => cmd_compress(&flags),
        "decompress" => cmd_decompress(&flags),
        "verify" => cmd_verify(&flags),
        "synth" => cmd_synth(&flags),
        "experiment" => cmd_experiment(&positional, &flags),
        "pipeline" => cmd_pipeline(&flags),
        "archive" => cmd_archive(&positional, &flags),
        "serve" => cmd_serve(&flags),
        "get" => cmd_get(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `ffcz help`)"),
    };
    if let Some(path) = &trace_out {
        telemetry::trace::disable();
        match telemetry::trace::write_chrome_json(path) {
            Ok(n) => diag::info(&format!(
                "wrote {n} trace events to {} (open in Perfetto or chrome://tracing)",
                path.display()
            )),
            Err(e) => diag::warn(&format!("could not write trace: {e:#}")),
        }
    }
    result
}

fn print_usage() {
    println!(
        "ffcz — spectrum-preserving lossy compression (FFCz reproduction)\n\
         \n\
         usage: ffcz <command> [flags]\n\
         \n\
         commands:\n\
         \x20 compress    --input F --output F [--base sz-like|zfp-like|sperr-like]\n\
         \x20             [--eb REL | --abs-eb ABS]\n\
         \x20             [--db REL | --abs-db ABS | --power-spectrum REL]\n\
         \x20             [--threads N]  POCS transform threads (default auto:\n\
         \x20             archive writes budget cores/workers per chunk;\n\
         \x20             output is identical for every N)\n\
         \x20 decompress  --input F --output F\n\
         \x20 verify      --original F --archive F [--eb REL] [--db REL]\n\
         \x20 synth       --dataset NAME --scale N --output F   (nyx-baryon, nyx-dm,\n\
         \x20             s3d-co2, hedm, eeg)\n\
         \x20 experiment  <id|all> [--scale N] [--out DIR] [--artifacts DIR]\n\
         \x20 pipeline    [--instances N] [--scale N] [--sequential]\n\
         \x20             [--store DIR] [--chunk A,B,C] [--workers N] [--in-memory]\n\
         \x20             store sink streams chunk payloads to each file by\n\
         \x20             default (--in-memory assembles containers first) and\n\
         \x20             also takes the archive-create codec flags\n\
         \x20             (--lossless, --base-only, bound flags, --chunk-codec)\n\
         \x20 archive     create --input F --output F [--chunk A,B,C]\n\
         \x20             [--base NAME | --lossless] [--base-only]\n\
         \x20             [--eb REL | --abs-eb ABS]\n\
         \x20             [--db REL | --abs-db ABS | --power-spectrum REL]\n\
         \x20             [--max-iters N] [--quant-retries N] [--threads N]\n\
         \x20             [--chunk-codec 'KEY=SPEC[;KEY=SPEC…]']\n\
         \x20             [--workers N] [--queue-depth N] [--in-memory]\n\
         \x20             streams chunk payloads to the file as they are\n\
         \x20             encoded (peak payload memory ≈ (workers + queue)\n\
         \x20             chunks); --in-memory restores full assembly first.\n\
         \x20             chunk-codec mini-language (EBNF in docs/FORMAT.md):\n\
         \x20               overrides = entry {';' entry}\n\
         \x20               entry     = KEY '=' SPEC        KEY: 'c/0/1' …\n\
         \x20               SPEC      = 'lossless' | BASE [':' opt {',' opt}]\n\
         \x20               opt       = 'eb=R' | 'abs-eb=A' | 'db=R' | 'abs-db=A'\n\
         \x20                         | 'ps=R' | 'iters=N' | 'quant-retries=N'\n\
         \x20                         | 'threads=N' | 'base-only'\n\
         \x20 serve       --root DIR and/or --remote-root URL [--addr H:P]\n\
         \x20             [--cache-mb N] [--port-file F] [--no-shutdown]\n\
         \x20             [--max-conns N] [--deadline-ms N] [--degraded]\n\
         \x20             archive read server (protocol in docs/SERVER.md);\n\
         \x20             --addr default 127.0.0.1:7070, port 0 picks a free\n\
         \x20             port (resolved address goes to --port-file); accepts\n\
         \x20             beyond --max-conns (default 64, 0 = unlimited) are\n\
         \x20             turned away with ST_BUSY; connections idle past\n\
         \x20             --deadline-ms (default 30000, 0 = off) are closed;\n\
         \x20             --remote-root http://host/prefix resolves archives\n\
         \x20             over resilient HTTP ranges (docs/STORAGE.md) and\n\
         \x20             turns on degraded serving: when the endpoint is\n\
         \x20             down, cached regions answer normally and uncached\n\
         \x20             ones answer ST_DEGRADED (--degraded forces this\n\
         \x20             mode for local roots too)\n\
         \x20 get         --addr H:P (--ping | --shutdown |\n\
         \x20             --archive NAME --stat |\n\
         \x20             --archive NAME --origin A,B,C --shape A,B,C --output F)\n\
         \x20             [--retries N] [--backoff-ms N]  retry transient\n\
         \x20             connect/read faults (default 3 attempts; 1 = off)\n\
         \x20 archive     extract --input F --output F [--workers N]\n\
         \x20 archive     inspect --input F-or-URL [--chunks] [--stats]\n\
         \x20             (extract/inspect/read-region/verify also accept\n\
         \x20             --input http://host/file.ffcz: remote HTTP-range\n\
         \x20             reads through the resilience layer)\n\
         \x20 archive     read-region --input F --origin A,B,C --shape A,B,C\n\
         \x20             --output F [--workers N]\n\
         \x20 archive     verify --input F [--workers N] [--json]\n\
         \x20             re-check every chunk (CRC-32, decode, dual-domain\n\
         \x20             bounds); nonzero exit if any fails, report as JSON\n\
         \x20 archive     repair --from F --output F [create flags]\n\
         \x20             finish an interrupted create from its .tmp/.tmp.jrn\n\
         \x20             staging files: salvage intact chunks, re-encode the\n\
         \x20             rest from --from, commit atomically (byte-identical\n\
         \x20             to an uninterrupted write; repeat the create flags)\n\
         \x20 info        --archive F\n\
         \n\
         global flags (any command):\n\
         \x20 --verbose       show per-stage detail lines\n\
         \x20 --quiet         suppress progress/summary lines (errors still print)\n\
         \x20 --trace-out F   record span traces and write Chrome trace_event\n\
         \x20                 JSON to F on exit (load in https://ui.perfetto.dev\n\
         \x20                 or chrome://tracing; see docs/TELEMETRY.md)\n\
         \x20 --stats         (archive create/inspect) per-chunk encode table\n\
         \x20                 plus a telemetry registry snapshot as JSON"
    );
}

/// Parse a comma- (or `x`-) separated axis list (`16,16,16`).
fn parse_axes(s: &str, what: &str) -> Result<Vec<usize>> {
    s.split([',', 'x'])
        .map(|p| {
            p.trim()
                .parse::<usize>()
                .with_context(|| format!("bad {what} component '{p}' in '{s}'"))
        })
        .collect()
}

fn parse_workers(flags: &HashMap<String, String>) -> Result<usize> {
    Ok(parse_f64(flags, "workers", 2.0)?.max(1.0) as usize)
}

/// Build the default per-chunk codec chain from `--lossless` / `--base` /
/// `--base-only` and the bound flags (`--eb`/`--abs-eb`,
/// `--db`/`--abs-db`/`--power-spectrum`).
fn build_chain_spec(flags: &HashMap<String, String>) -> Result<CodecChainSpec> {
    if flags.contains_key("lossless") {
        return Ok(CodecChainSpec::lossless());
    }
    let base = flags.get("base").map(|s| s.as_str()).unwrap_or("sz-like");
    require_compressor(base)?;
    if flags.contains_key("base-only") {
        Ok(CodecChainSpec::base_only(base, spatial_bound_flag(flags)?))
    } else {
        Ok(CodecChainSpec::ffcz(base, &build_config(flags)?))
    }
}

/// Spatial bound E from `--abs-eb` (absolute) or `--eb` (relative,
/// default 1e-3).
fn spatial_bound_flag(flags: &HashMap<String, String>) -> Result<BoundSpec> {
    match flags.get("abs-eb") {
        Some(v) => Ok(BoundSpec::Absolute(
            v.parse().context("--abs-eb expects a number")?,
        )),
        None => Ok(BoundSpec::Relative(parse_f64(flags, "eb", 1e-3)?)),
    }
}

/// Frequency bound Δ from `--power-spectrum`, `--abs-db`, or `--db`
/// (relative, default 1e-3).
fn frequency_bound_flag(flags: &HashMap<String, String>) -> Result<FrequencyBound> {
    if let Some(ps) = flags.get("power-spectrum") {
        let p: f64 = ps.parse().context("--power-spectrum expects a number")?;
        return Ok(FrequencyBound::PowerSpectrumRelative(p));
    }
    match flags.get("abs-db") {
        Some(v) => Ok(FrequencyBound::Uniform(BoundSpec::Absolute(
            v.parse().context("--abs-db expects a number")?,
        ))),
        None => Ok(FrequencyBound::Uniform(BoundSpec::Relative(parse_f64(
            flags, "db", 1e-3,
        )?))),
    }
}

/// Parse one `--chunk-codec` chain mini-spec: `lossless`, or
/// `BASE[:key=val,…]` with keys `eb` / `abs-eb` / `db` / `abs-db` / `ps`
/// (power-spectrum relative) / `iters` (POCS iteration cap) /
/// `quant-retries` (quantization bound-shrink retries) / `threads` (POCS
/// transform threads, execution-only) / `base-only`.
/// The full grammar (EBNF) is in `docs/FORMAT.md`.
fn parse_chain_mini(s: &str) -> Result<CodecChainSpec> {
    let s = s.trim();
    if s == "lossless" {
        return Ok(CodecChainSpec::lossless());
    }
    let (base, params) = match s.split_once(':') {
        Some((b, p)) => (b.trim(), p),
        None => (s, ""),
    };
    require_compressor(base)?;
    let mut spatial = BoundSpec::Relative(1e-3);
    let mut frequency: Option<FrequencyBound> = None;
    let mut max_iters = 200usize;
    let mut max_quant_retries = 3usize;
    // 0 = auto (cooperatively budgeted by the store writer); the
    // `threads=` key sets an explicit count.
    let mut threads = 0usize;
    let mut correction_knobs = false;
    let mut base_only = false;
    for part in params.split(',').filter(|p| !p.trim().is_empty()) {
        let (key, val) = match part.split_once('=') {
            Some((k, v)) => (k.trim(), v.trim()),
            None => (part.trim(), ""),
        };
        let num = || {
            val.parse::<f64>()
                .with_context(|| format!("chunk-codec key '{key}' expects a number, got '{val}'"))
        };
        let int = || {
            val.parse::<usize>().with_context(|| {
                format!("chunk-codec key '{key}' expects a non-negative integer, got '{val}'")
            })
        };
        match key {
            "eb" => spatial = BoundSpec::Relative(num()?),
            "abs-eb" => spatial = BoundSpec::Absolute(num()?),
            "db" => frequency = Some(FrequencyBound::Uniform(BoundSpec::Relative(num()?))),
            "abs-db" => frequency = Some(FrequencyBound::Uniform(BoundSpec::Absolute(num()?))),
            "ps" => frequency = Some(FrequencyBound::PowerSpectrumRelative(num()?)),
            "iters" => {
                max_iters = int()?;
                if max_iters == 0 {
                    bail!("chunk-codec key 'iters' must be ≥ 1 in '{s}' (0 would skip POCS \
                           and the chunk could never meet its frequency bound)");
                }
                correction_knobs = true;
            }
            "quant-retries" => {
                max_quant_retries = int()?;
                correction_knobs = true;
            }
            "threads" => {
                threads = int()?;
                if threads == 0 {
                    bail!("chunk-codec key 'threads' must be ≥ 1 in '{s}'");
                }
                correction_knobs = true;
            }
            "base-only" => base_only = true,
            other => bail!("unknown chunk-codec key '{other}' in '{s}'"),
        }
    }
    if base_only && (frequency.is_some() || correction_knobs) {
        bail!(
            "chunk-codec spec '{s}' combines base-only with a correction key \
             (db / abs-db / ps / iters / quant-retries / threads) — pick one"
        );
    }
    Ok(if base_only {
        CodecChainSpec::base_only(base, spatial)
    } else {
        CodecChainSpec::ffcz(
            base,
            &FfczConfig {
                spatial,
                frequency: frequency
                    .unwrap_or(FrequencyBound::Uniform(BoundSpec::Relative(1e-3))),
                max_iters,
                max_quant_retries,
                threads,
            },
        )
    })
}

/// Parse `--chunk-codec 'KEY=SPEC[;KEY=SPEC…]'` into per-chunk overrides.
fn parse_chunk_codec_overrides(
    flags: &HashMap<String, String>,
) -> Result<Vec<(String, CodecChainSpec)>> {
    let Some(value) = flags.get("chunk-codec") else {
        return Ok(Vec::new());
    };
    let mut overrides = Vec::new();
    for item in value.split(';').filter(|p| !p.trim().is_empty()) {
        let Some((key, spec)) = item.split_once('=') else {
            bail!("--chunk-codec expects KEY=SPEC[;KEY=SPEC…], got '{item}'");
        };
        overrides.push((key.trim().to_string(), parse_chain_mini(spec)?));
    }
    if overrides.is_empty() {
        bail!("--chunk-codec given but no KEY=SPEC entries parsed");
    }
    Ok(overrides)
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            // boolean flags take no value; detect by next token
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    (positional, flags)
}

fn get<'a>(flags: &'a HashMap<String, String>, key: &str) -> Result<&'a str> {
    flags
        .get(key)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
}

fn parse_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse::<f64>()
            .with_context(|| format!("--{key} expects a number, got '{v}'")),
    }
}

fn build_config(flags: &HashMap<String, String>) -> Result<FfczConfig> {
    Ok(FfczConfig {
        spatial: spatial_bound_flag(flags)?,
        frequency: frequency_bound_flag(flags)?,
        max_iters: parse_f64(flags, "max-iters", 200.0)?.max(1.0) as usize,
        max_quant_retries: parse_f64(flags, "quant-retries", 3.0)?.max(0.0) as usize,
        // Default 0 = auto: the store writer budgets
        // available_parallelism()/workers per chunk; whole-field paths run
        // single-threaded. An explicit --threads N (≥ 1) always wins.
        threads: parse_f64(flags, "threads", 0.0)?.max(0.0) as usize,
    })
}

fn cmd_compress(flags: &HashMap<String, String>) -> Result<()> {
    let input = PathBuf::from(get(flags, "input")?);
    let output = PathBuf::from(get(flags, "output")?);
    let base_name = flags.get("base").map(|s| s.as_str()).unwrap_or("sz-like");
    let base = require_compressor(base_name)?;
    let cfg = build_config(flags)?;

    let field = io::load(&input)?;
    let archive = correction::compress(&field, base.as_ref(), &cfg)?;
    let bytes = archive.to_bytes();
    std::fs::write(&output, &bytes)?;
    diag::info(&format!(
        "compressed {} ({} samples) -> {} ({}, ratio {:.1}, base {}, edits {})",
        input.display(),
        field.len(),
        output.display(),
        ffcz::util::human_bytes(bytes.len()),
        field.original_bytes() as f64 / bytes.len() as f64,
        ffcz::util::human_bytes(archive.base_bytes()),
        ffcz::util::human_bytes(archive.edit_bytes()),
    ));
    diag::info(&format!(
        "POCS: {} iterations, {} spatial + {} frequency active edits{}",
        archive.stats.iterations,
        archive.stats.active_spat,
        archive.stats.active_freq,
        if archive.stats.used_raw_fallback {
            " (raw-edit fallback)"
        } else {
            ""
        }
    ));
    Ok(())
}

fn cmd_decompress(flags: &HashMap<String, String>) -> Result<()> {
    let input = PathBuf::from(get(flags, "input")?);
    let output = PathBuf::from(get(flags, "output")?);
    let archive = FfczArchive::from_bytes(&std::fs::read(&input)?)?;
    let field = correction::decompress(&archive)?;
    io::save(&field, &output)?;
    diag::info(&format!(
        "decompressed {} -> {} (shape {:?})",
        input.display(),
        output.display(),
        field.shape()
    ));
    Ok(())
}

fn cmd_verify(flags: &HashMap<String, String>) -> Result<()> {
    let original = io::load(&PathBuf::from(get(flags, "original")?))?;
    let archive =
        FfczArchive::from_bytes(&std::fs::read(PathBuf::from(get(flags, "archive")?))?)?;
    let recon = correction::decompress(&archive)?;
    let cfg = build_config(flags)?;
    let report = correction::verify(&original, &recon, &cfg);
    let quality = QualityReport::compute(&original, &recon);
    println!(
        "spatial:   {} (max ratio {:.4})",
        if report.spatial_ok { "OK" } else { "VIOLATED" },
        report.max_spatial_ratio
    );
    println!(
        "frequency: {} (max ratio {:.4})",
        if report.frequency_ok { "OK" } else { "VIOLATED" },
        report.max_frequency_ratio
    );
    println!(
        "PSNR {:.2} dB, SSNR {:.2} dB, max |ε| {:.3e}, max RFE {:.3e}",
        quality.psnr_db, quality.ssnr_db, quality.max_abs_err, quality.max_rfe
    );
    if !(report.spatial_ok && report.frequency_ok) {
        bail!("dual-domain verification failed");
    }
    Ok(())
}

fn cmd_synth(flags: &HashMap<String, String>) -> Result<()> {
    let dataset = get(flags, "dataset")?;
    let scale: usize = parse_f64(flags, "scale", 32.0)? as usize;
    let output = PathBuf::from(get(flags, "output")?);
    let suite = synth::benchmark_suite(scale);
    let field = suite
        .into_iter()
        .find(|(n, _)| n == dataset)
        .map(|(_, f)| f)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    io::save(&field, &output)?;
    diag::info(&format!(
        "wrote {} (shape {:?}, {})",
        output.display(),
        field.shape(),
        ffcz::util::human_bytes(field.original_bytes())
    ));
    Ok(())
}

fn cmd_experiment(positional: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let Some(id) = positional.first() else {
        bail!("experiment id required: {:?} or 'all'", experiments::ALL);
    };
    let mut opts = ExpOptions::default();
    opts.scale = parse_f64(flags, "scale", opts.scale as f64)? as usize;
    if let Some(out) = flags.get("out") {
        opts.out_dir = out.into();
    }
    if let Some(dir) = flags.get("artifacts") {
        opts.artifact_dir = dir.into();
    }
    experiments::run(id, &opts)
}

fn cmd_pipeline(flags: &HashMap<String, String>) -> Result<()> {
    let n: usize = parse_f64(flags, "instances", 4.0)? as usize;
    let scale: usize = parse_f64(flags, "scale", 32.0)? as usize;
    let base_name = flags.get("base").map(|s| s.as_str()).unwrap_or("sz-like");
    let base = require_compressor(base_name)?;
    let mut cfg = PipelineConfig::new(build_config(flags)?);
    if flags.contains_key("sequential") {
        cfg.mode = ExecMode::Sequential;
    }
    let instances: Vec<_> = (0..n)
        .map(|i| {
            (
                format!("snap{i}"),
                synth::grf::GrfBuilder::new(&[scale, scale, scale])
                    .lognormal(1.2)
                    .seed(300 + i as u64)
                    .build(),
            )
        })
        .collect();
    if let Some(dir) = flags.get("store") {
        // Streamed instances land directly in chunked .ffcz stores.
        let mut sink = StoreSink::new(PathBuf::from(dir), build_chain_spec(flags)?);
        sink.workers = parse_workers(flags)?;
        sink.overrides = parse_chunk_codec_overrides(flags)?;
        sink.in_memory = flags.contains_key("in-memory");
        if let Some(chunk) = flags.get("chunk") {
            sink.chunk_shape = Some(parse_axes(chunk, "chunk")?);
        }
        let report = run_pipeline_to_store(instances, &sink)?;
        for (name, path, w) in &report.outputs {
            diag::info(&format!(
                "{name}: {} ({} chunks, {}, all chunks {})",
                path.display(),
                w.chunk_count,
                ffcz::util::human_bytes(w.total_bytes),
                if w.all_chunks_ok { "OK" } else { "VIOLATED" },
            ));
            if flags.contains_key("stats") {
                print!("{}", w.render_chunk_table());
            }
        }
        diag::info(&format!(
            "makespan {} (encode Σ {}, write Σ {})",
            ffcz::util::human_duration(report.makespan),
            ffcz::util::human_duration(report.encode_total),
            ffcz::util::human_duration(report.write_total),
        ));
        if !report.all_chunks_ok() {
            bail!("dual-domain verification failed for at least one chunk");
        }
        return Ok(());
    }
    let report = run_pipeline(instances, base.as_ref(), &cfg)?;
    print!("{}", report.timeline_text());
    Ok(())
}

fn cmd_archive(positional: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let Some(sub) = positional.first() else {
        bail!("archive subcommand required: create | extract | inspect | read-region | verify | repair");
    };
    match sub.as_str() {
        "create" => cmd_archive_create(flags),
        "extract" => cmd_archive_extract(flags),
        "inspect" => cmd_archive_inspect(flags),
        "read-region" => cmd_archive_read_region(flags),
        "verify" => cmd_archive_verify(flags),
        "repair" => cmd_archive_repair(flags),
        other => bail!("unknown archive subcommand '{other}'"),
    }
}

fn cmd_archive_create(flags: &HashMap<String, String>) -> Result<()> {
    let input = PathBuf::from(get(flags, "input")?);
    let output = PathBuf::from(get(flags, "output")?);
    let field = io::load(&input)?;
    let spec = build_chain_spec(flags)?;
    let workers = parse_workers(flags)?;
    let mut opts = match flags.get("chunk") {
        Some(c) => StoreWriteOptions::new(&parse_axes(c, "chunk")?).workers(workers),
        None => StoreWriteOptions::default_for(field.shape(), workers)?,
    };
    opts.queue_depth = parse_f64(flags, "queue-depth", opts.queue_depth as f64)? as usize;
    opts.overrides = parse_chunk_codec_overrides(flags)?;
    let chunk_shape = opts.chunk_shape.clone();
    let report = if flags.contains_key("in-memory") {
        write_store_in_memory(&field, &spec, &opts, &output)?
    } else {
        write_store(&field, &spec, &opts, &output)?
    };
    diag::info(&format!(
        "archived {} (shape {:?}) -> {} ({}, ratio {:.1})",
        input.display(),
        field.shape(),
        output.display(),
        ffcz::util::human_bytes(report.total_bytes),
        field.original_bytes() as f64 / report.total_bytes as f64,
    ));
    diag::info(&format!(
        "{} chunks of {:?} ({} payload + {} manifest), {} workers, {} — chunks {}",
        report.chunk_count,
        chunk_shape,
        ffcz::util::human_bytes(report.payload_bytes),
        ffcz::util::human_bytes(report.manifest_bytes),
        workers,
        ffcz::util::human_duration(report.elapsed),
        if report.all_chunks_ok { "OK" } else { "VIOLATED" },
    ));
    diag::verbose(&format!(
        "{}: peak {} of chunk payloads in memory, {} scratch warm-up allocations",
        if report.streamed {
            "streamed"
        } else {
            "in-memory assembly"
        },
        ffcz::util::human_bytes(report.peak_payload_bytes),
        report.scratch_alloc_events,
    ));
    if flags.contains_key("stats") {
        // Requested data, not a diagnostic: always printed.
        print!("{}", report.render_chunk_table());
        println!("{}", telemetry::snapshot().to_json());
    }
    if !report.all_chunks_ok {
        bail!("dual-domain verification failed for at least one chunk");
    }
    Ok(())
}

/// Open `--input` as a local archive path or, when it starts with
/// `http://`, as a remote archive read over resilient HTTP range
/// requests (retries, deadlines, circuit breaker — see docs/STORAGE.md).
fn open_store_flag(input: &str) -> Result<Store> {
    if input.starts_with("http://") {
        let http = ffcz::store::HttpStorage::open(input)
            .with_context(|| format!("opening remote archive {input}"))?;
        let resilient = ffcz::store::ResilientStorage::new(
            std::sync::Arc::new(http),
            ffcz::store::ResilienceOptions::default(),
        );
        Store::open_storage(std::sync::Arc::new(resilient))
    } else {
        Store::open(&PathBuf::from(input))
    }
}

fn cmd_archive_extract(flags: &HashMap<String, String>) -> Result<()> {
    let input = get(flags, "input")?;
    let output = PathBuf::from(get(flags, "output")?);
    let store = open_store_flag(input)?;
    let field = store.decompress_all(parse_workers(flags)?)?;
    io::save(&field, &output)?;
    diag::info(&format!(
        "extracted {input} -> {} (shape {:?}, {} chunks decoded)",
        output.display(),
        field.shape(),
        store.chunks_decoded(),
    ));
    Ok(())
}

fn cmd_archive_inspect(flags: &HashMap<String, String>) -> Result<()> {
    let input = get(flags, "input")?;
    let store = open_store_flag(input)?;
    let m = store.manifest();
    println!("array shape  : {:?} ({})", m.shape, m.precision.name());
    println!(
        "chunk grid   : {:?} chunks of {:?}",
        store.grid().grid_shape(),
        m.chunk_shape
    );
    for (i, chain) in m.chains.iter().enumerate() {
        println!(
            "codec chain  : #{i} {}{}",
            chain.describe(),
            if i == 0 { " (default)" } else { "" }
        );
    }
    println!(
        "payload      : {} in {} chunks",
        ffcz::util::human_bytes(m.payload_bytes() as usize),
        m.chunks.len()
    );
    println!(
        "checksums    : {}",
        if m.chunks.iter().all(|c| c.crc32.is_some()) {
            "CRC-32 per chunk"
        } else {
            "none (manifest v1 archive)"
        }
    );
    println!(
        "dual bounds  : {}",
        if m.all_chunks_ok() {
            "OK (every chunk)"
        } else {
            "VIOLATED (at least one chunk)"
        }
    );
    if flags.contains_key("chunks") || flags.contains_key("stats") {
        println!(
            "chunk        offset      bytes  chain       crc32  s-ok f-ok  s-ratio  f-ratio  iters"
        );
        for (i, c) in m.chunks.iter().enumerate() {
            println!(
                "{:<10} {:>8} {:>10}  {:>5} {:>10}  {:>4} {:>4}  {:>7.3} {:>8.3} {:>6}",
                store.grid().chunk_key(i),
                c.offset,
                c.length,
                format!("#{}", c.chain),
                c.crc32
                    .map(|v| format!("{v:08x}"))
                    .unwrap_or_else(|| "-".to_string()),
                if c.stats.spatial_ok { "yes" } else { "NO" },
                if c.stats.frequency_ok { "yes" } else { "NO" },
                c.stats.max_spatial_ratio,
                c.stats.max_frequency_ratio,
                c.stats.pocs_iterations,
            );
        }
    }
    if flags.contains_key("stats") {
        println!("{}", telemetry::snapshot().to_json());
    }
    Ok(())
}

fn cmd_archive_read_region(flags: &HashMap<String, String>) -> Result<()> {
    let input = get(flags, "input")?;
    let output = PathBuf::from(get(flags, "output")?);
    let origin = parse_axes(get(flags, "origin")?, "origin")?;
    let shape = parse_axes(get(flags, "shape")?, "shape")?;
    let store = open_store_flag(input)?;
    let region = store.read_region(&origin, &shape, parse_workers(flags)?)?;
    io::save(&region, &output)?;
    diag::info(&format!(
        "read region origin {:?} shape {:?} from {input} ({} of {} chunks decoded) -> {}",
        origin,
        shape,
        store.chunks_decoded(),
        store.grid().chunk_count(),
        output.display(),
    ));
    Ok(())
}

/// `ffcz archive verify --input F [--workers N] [--json]`: re-check
/// every chunk of an archive — payload CRC-32, full decode, and the
/// recorded dual-domain bounds — and exit nonzero if any chunk fails.
fn cmd_archive_verify(flags: &HashMap<String, String>) -> Result<()> {
    let input = get(flags, "input")?;
    let store = open_store_flag(input)?;
    let report = store.verify(parse_workers(flags)?)?;
    if flags.contains_key("json") {
        // Requested data, not a diagnostic: always printed.
        println!("{}", report.to_json());
    } else {
        diag::info(&format!(
            "verified {input}: {}/{} chunks OK in {}",
            report.chunks.len() - report.failed(),
            report.chunks.len(),
            ffcz::util::human_duration(report.elapsed),
        ));
        for chunk in report.chunks.iter().filter(|c| !c.ok()) {
            diag::error(&format!(
                "chunk {} ({}): {}",
                chunk.index,
                chunk.key,
                chunk.error.as_deref().unwrap_or("failed"),
            ));
        }
    }
    if !report.ok() {
        bail!(
            "{} of {} chunks failed verification",
            report.failed(),
            report.chunks.len()
        );
    }
    Ok(())
}

/// `ffcz archive repair --from F --output F [create flags]`: finish an
/// interrupted `archive create`. Salvages the CRC-valid chunk prefix
/// from the staging files `<output>.tmp` / `<output>.tmp.jrn`,
/// re-encodes only the missing chunks from the source field `--from`,
/// and commits atomically — byte-identical to an uninterrupted write.
/// The codec flags must repeat the original invocation's.
fn cmd_archive_repair(flags: &HashMap<String, String>) -> Result<()> {
    let from = PathBuf::from(get(flags, "from")?);
    let output = PathBuf::from(get(flags, "output")?);
    let (tmp, _jrn) = staging_paths(&output);
    if output.is_file() && !tmp.exists() {
        diag::info(&format!(
            "{} is committed and has no staging leftovers — nothing to repair",
            output.display()
        ));
        return Ok(());
    }
    let field = io::load(&from)?;
    let spec = build_chain_spec(flags)?;
    let workers = parse_workers(flags)?;
    let mut opts = match flags.get("chunk") {
        Some(c) => StoreWriteOptions::new(&parse_axes(c, "chunk")?).workers(workers),
        None => StoreWriteOptions::default_for(field.shape(), workers)?,
    };
    opts.queue_depth = parse_f64(flags, "queue-depth", opts.queue_depth as f64)? as usize;
    opts.overrides = parse_chunk_codec_overrides(flags)?;
    let report = resume_store_write(&field, &spec, &opts, &output)?;
    diag::info(&format!(
        "repaired {}: {} chunks salvaged, {} re-encoded ({} total, chunks {})",
        output.display(),
        report.salvaged_chunks,
        report.reencoded_chunks,
        ffcz::util::human_bytes(report.write.total_bytes),
        if report.write.all_chunks_ok {
            "OK"
        } else {
            "VIOLATED"
        },
    ));
    if !report.write.all_chunks_ok {
        bail!("dual-domain verification failed for at least one chunk");
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let root = flags.get("root").map(PathBuf::from);
    if let Some(root) = &root {
        if !root.is_dir() {
            bail!("--root {} is not a directory", root.display());
        }
    }
    let remote_root = flags.get("remote-root").cloned();
    if let Some(url) = &remote_root {
        if !url.starts_with("http://") {
            bail!("--remote-root expects an http:// base URL, got '{url}'");
        }
    }
    if root.is_none() && remote_root.is_none() {
        bail!("serve needs --root DIR and/or --remote-root URL");
    }
    // Remote endpoints can die mid-stream; degraded serving (cached
    // regions answer normally, uncached ones ST_DEGRADED) is on whenever
    // a remote root is configured, and opt-in via --degraded otherwise.
    let degraded = flags.contains_key("degraded") || remote_root.is_some();
    let sources = [
        root.as_ref().map(|r| r.display().to_string()),
        remote_root.clone(),
    ]
    .into_iter()
    .flatten()
    .collect::<Vec<_>>()
    .join(" and ");
    let opts = ServeOptions {
        addr: flags
            .get("addr")
            .cloned()
            .unwrap_or_else(|| "127.0.0.1:7070".to_string()),
        root,
        remote_root,
        degraded,
        cache_bytes: (parse_f64(flags, "cache-mb", 64.0)?.max(0.0) * (1 << 20) as f64) as usize,
        allow_shutdown: !flags.contains_key("no-shutdown"),
        max_connections: parse_f64(flags, "max-conns", 64.0)?.max(0.0) as usize,
        request_deadline: Duration::from_millis(
            parse_f64(flags, "deadline-ms", 30_000.0)?.max(0.0) as u64,
        ),
        ..ServeOptions::default()
    };
    let server = ArchiveServer::start(opts)?;
    let addr = server.local_addr();
    if let Some(port_file) = flags.get("port-file") {
        std::fs::write(port_file, addr.to_string())
            .with_context(|| format!("writing --port-file {port_file}"))?;
    }
    diag::info(&format!(
        "serving archives from {sources} on {addr} (stop with `ffcz get --addr {addr} --shutdown`)"
    ));
    server.join();
    diag::info("server stopped");
    Ok(())
}

fn cmd_get(flags: &HashMap<String, String>) -> Result<()> {
    let addr = get(flags, "addr")?;
    // Transient connect/read faults (including ST_BUSY from a server at
    // its connection cap) are retried with linear backoff; --retries 1
    // turns retrying off. Shutdown requests are never retried.
    let retries = (parse_f64(flags, "retries", 3.0)?.max(1.0) as u32).max(1);
    let backoff = Duration::from_millis(parse_f64(flags, "backoff-ms", 25.0)?.max(0.0) as u64);
    let mut client = Client::connect_with_retry(addr, RetryPolicy::transient(retries, backoff))?;
    if flags.contains_key("ping") {
        client.ping()?;
        println!("ok");
        return Ok(());
    }
    if flags.contains_key("shutdown") {
        client.shutdown_server()?;
        diag::info("server acknowledged shutdown");
        return Ok(());
    }
    let name = get(flags, "archive")?;
    if flags.contains_key("stat") {
        let stat = client.stat(name)?;
        println!("archive      : {name}");
        println!("array shape  : {:?} ({})", stat.shape, stat.precision.name());
        println!(
            "chunk grid   : {} chunks of {:?}",
            stat.chunks, stat.chunk_shape
        );
        println!(
            "payload      : {}",
            ffcz::util::human_bytes(stat.payload_bytes as usize)
        );
        return Ok(());
    }
    let origin = parse_axes(get(flags, "origin")?, "origin")?;
    let shape = parse_axes(get(flags, "shape")?, "shape")?;
    let output = PathBuf::from(get(flags, "output")?);
    let field = client.read_region(name, &origin, &shape)?;
    io::save(&field, &output)?;
    diag::info(&format!(
        "fetched region origin {:?} shape {:?} of '{name}' from {addr} -> {}",
        origin,
        shape,
        output.display(),
    ));
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    let archive =
        FfczArchive::from_bytes(&std::fs::read(PathBuf::from(get(flags, "archive")?))?)?;
    println!("base compressor : {}", archive.base_name);
    println!(
        "base payload    : {}",
        ffcz::util::human_bytes(archive.base_bytes())
    );
    println!(
        "edit payload    : {}",
        ffcz::util::human_bytes(archive.edit_bytes())
    );
    println!("iterations      : {}", archive.stats.iterations);
    println!("active spatial  : {}", archive.stats.active_spat);
    println!("active frequency: {}", archive.stats.active_freq);
    println!("raw fallback    : {}", archive.stats.used_raw_fallback);
    Ok(())
}
