//! Resilience layer for remote (or any) read backends.
//!
//! [`ResilientStorage`] wraps a [`ReadableStorage`] and makes its
//! `read_at` production-worthy against the failure modes a network can
//! produce. Four mechanisms, all specified normatively in
//! `docs/STORAGE.md` and all observable through the `store.remote.*`
//! metrics glossed in `docs/TELEMETRY.md`:
//!
//! * **Retries** — transient faults retried under a [`RetryPolicy`]
//!   through the shared [`RetrySchedule`] (exponential backoff and
//!   seeded deterministic jitter compose here); counted in
//!   `store.remote.retries`.
//! * **Deadlines** — an absolute per-`read_at` budget across *all*
//!   attempts and sleeps. Exceeding it surfaces a typed
//!   [`DeadlineExceeded`] (see [`deadline_exceeded_of`]) and counts in
//!   `store.remote.deadline_exceeded`.
//! * **Circuit breaker** — a per-endpoint closed → open → half-open
//!   state machine ([`Breaker`], shareable across wrappers via `Arc` so
//!   every store talking to one endpoint trips together). While open,
//!   reads fail fast with a typed [`BreakerOpen`] (see
//!   [`breaker_open_of`]) instead of burning the retry budget against a
//!   dead endpoint; transitions and rejections count in
//!   `store.remote.breaker.{opens,half_opens,closes,rejections}`.
//! * **Hedged reads** — when an attempt is slower than a latency
//!   percentile of recent reads (or a fixed trigger), a second identical
//!   request fires and the first success wins; the loser's result is
//!   discarded when it lands. Counted in `store.remote.hedges` /
//!   `store.remote.hedge_wins`.
//!
//! Degraded-mode reads — serving what the decoded-chunk LRU still holds
//! when the backend is gone — live one layer up, in
//! [`crate::store::Store::read_region_degraded`] and the archive
//! server's `ST_DEGRADED` answers.

use std::io;
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::telemetry;
use crate::util::sync::lock;

use super::storage::{ReadableStorage, RetryPolicy, RetrySchedule};

/// Registered-metric handles for the resilience layer, fetched once.
struct RemoteMetrics {
    requests: telemetry::Counter,
    retries: telemetry::Counter,
    hedges: telemetry::Counter,
    hedge_wins: telemetry::Counter,
    deadline_exceeded: telemetry::Counter,
    breaker_opens: telemetry::Counter,
    breaker_half_opens: telemetry::Counter,
    breaker_closes: telemetry::Counter,
    breaker_rejections: telemetry::Counter,
}

fn remote_metrics() -> &'static RemoteMetrics {
    static METRICS: OnceLock<RemoteMetrics> = OnceLock::new();
    METRICS.get_or_init(|| RemoteMetrics {
        requests: telemetry::counter("store.remote.requests"),
        retries: telemetry::counter("store.remote.retries"),
        hedges: telemetry::counter("store.remote.hedges"),
        hedge_wins: telemetry::counter("store.remote.hedge_wins"),
        deadline_exceeded: telemetry::counter("store.remote.deadline_exceeded"),
        breaker_opens: telemetry::counter("store.remote.breaker.opens"),
        breaker_half_opens: telemetry::counter("store.remote.breaker.half_opens"),
        breaker_closes: telemetry::counter("store.remote.breaker.closes"),
        breaker_rejections: telemetry::counter("store.remote.breaker.rejections"),
    })
}

// ------------------------------------------------------- typed errors --

/// The circuit breaker refused the read without touching the endpoint.
/// Rides inside an [`io::Error`]; recover it with [`breaker_open_of`].
#[derive(Debug, Clone)]
pub struct BreakerOpen {
    /// The endpoint whose breaker is open.
    pub endpoint: String,
    /// Time until the breaker half-opens and probes again.
    pub retry_in: Duration,
}

impl std::fmt::Display for BreakerOpen {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "circuit breaker for {} is open (half-opens in {:.0?})",
            self.endpoint, self.retry_in
        )
    }
}

impl std::error::Error for BreakerOpen {}

/// The absolute per-read deadline was exceeded across attempts. Rides
/// inside an [`io::Error`]; recover it with [`deadline_exceeded_of`].
#[derive(Debug, Clone)]
pub struct DeadlineExceeded {
    /// The configured budget.
    pub budget: Duration,
    /// Time actually spent when the read gave up.
    pub elapsed: Duration,
}

impl std::fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "read deadline exceeded: {:.0?} spent of a {:.0?} budget",
            self.elapsed, self.budget
        )
    }
}

impl std::error::Error for DeadlineExceeded {}

/// Downcast an [`io::Error`] to the [`BreakerOpen`] it carries, if any.
pub fn breaker_open_of(e: &io::Error) -> Option<&BreakerOpen> {
    e.get_ref()?.downcast_ref()
}

/// Downcast an [`io::Error`] to the [`DeadlineExceeded`] it carries.
pub fn deadline_exceeded_of(e: &io::Error) -> Option<&DeadlineExceeded> {
    e.get_ref()?.downcast_ref()
}

/// Find a [`BreakerOpen`] anywhere in an `anyhow` error chain (store
/// read errors arrive context-wrapped).
pub fn breaker_open_in_chain(err: &anyhow::Error) -> Option<&BreakerOpen> {
    err.chain()
        .find_map(|c| c.downcast_ref::<io::Error>().and_then(breaker_open_of))
}

/// Find a [`DeadlineExceeded`] anywhere in an `anyhow` error chain.
pub fn deadline_exceeded_in_chain(err: &anyhow::Error) -> Option<&DeadlineExceeded> {
    err.chain()
        .find_map(|c| c.downcast_ref::<io::Error>().and_then(deadline_exceeded_of))
}

// ----------------------------------------------------- circuit breaker --

/// Circuit-breaker tuning. `failure_threshold` consecutive failures
/// open the breaker; after `cooldown` it half-opens and admits probes —
/// one success closes it, one failure re-opens it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerConfig {
    /// Consecutive failures that trip the breaker; 0 disables it.
    pub failure_threshold: u32,
    /// How long the breaker stays open before half-opening.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            failure_threshold: 5,
            cooldown: Duration::from_millis(500),
        }
    }
}

enum BreakerState {
    Closed { failures: u32 },
    Open { until: Instant },
    HalfOpen,
}

/// A per-endpoint circuit breaker. Share one `Arc<Breaker>` across every
/// [`ResilientStorage`] that talks to the same endpoint so they trip —
/// and recover — together.
pub struct Breaker {
    endpoint: String,
    cfg: BreakerConfig,
    state: Mutex<BreakerState>,
}

impl Breaker {
    pub fn new(endpoint: &str, cfg: BreakerConfig) -> Self {
        Self {
            endpoint: endpoint.to_string(),
            cfg,
            state: Mutex::new(BreakerState::Closed { failures: 0 }),
        }
    }

    /// The endpoint this breaker guards.
    pub fn endpoint(&self) -> &str {
        &self.endpoint
    }

    /// Current state as a diagnostic label.
    pub fn state_name(&self) -> &'static str {
        match *lock(&self.state) {
            BreakerState::Closed { .. } => "closed",
            BreakerState::Open { until } if Instant::now() < until => "open",
            // Cooldown elapsed: the next admit() will half-open.
            BreakerState::Open { .. } | BreakerState::HalfOpen => "half-open",
        }
    }

    /// Gate one attempt: `Ok` admits it (possibly as a half-open probe),
    /// `Err` is a typed [`BreakerOpen`] fail-fast.
    fn admit(&self) -> io::Result<()> {
        if self.cfg.failure_threshold == 0 {
            return Ok(());
        }
        let mut state = lock(&self.state);
        match *state {
            BreakerState::Closed { .. } | BreakerState::HalfOpen => Ok(()),
            BreakerState::Open { until } => {
                let now = Instant::now();
                if now >= until {
                    *state = BreakerState::HalfOpen;
                    remote_metrics().breaker_half_opens.incr();
                    Ok(())
                } else {
                    remote_metrics().breaker_rejections.incr();
                    Err(io::Error::other(BreakerOpen {
                        endpoint: self.endpoint.clone(),
                        retry_in: until - now,
                    }))
                }
            }
        }
    }

    fn on_success(&self) {
        if self.cfg.failure_threshold == 0 {
            return;
        }
        let mut state = lock(&self.state);
        match *state {
            BreakerState::Closed { failures: 0 } => {}
            BreakerState::HalfOpen => {
                remote_metrics().breaker_closes.incr();
                *state = BreakerState::Closed { failures: 0 };
            }
            _ => *state = BreakerState::Closed { failures: 0 },
        }
    }

    fn on_failure(&self) {
        if self.cfg.failure_threshold == 0 {
            return;
        }
        let mut state = lock(&self.state);
        match *state {
            BreakerState::Closed { failures } => {
                let failures = failures + 1;
                if failures >= self.cfg.failure_threshold {
                    remote_metrics().breaker_opens.incr();
                    *state = BreakerState::Open {
                        until: Instant::now() + self.cfg.cooldown,
                    };
                } else {
                    *state = BreakerState::Closed { failures };
                }
            }
            // A failed half-open probe re-opens for another cooldown.
            BreakerState::HalfOpen => {
                remote_metrics().breaker_opens.incr();
                *state = BreakerState::Open {
                    until: Instant::now() + self.cfg.cooldown,
                };
            }
            BreakerState::Open { .. } => {}
        }
    }
}

// -------------------------------------------------------- hedged reads --

/// Hedged-read tuning. Disabled by default: hedging spawns a worker
/// thread per read, which is the right trade only when the backend's
/// tail latency dwarfs a thread spawn (networks, not local files).
#[derive(Debug, Clone, Copy)]
pub struct HedgeConfig {
    /// Master switch.
    pub enabled: bool,
    /// Fixed hedge trigger, overriding the percentile estimate
    /// (deterministic tests pin this).
    pub after: Option<Duration>,
    /// Latency quantile (0–1) of recent successful reads beyond which
    /// the hedge fires.
    pub percentile: f64,
    /// Successful reads observed before the percentile is trusted;
    /// until then (and with no fixed trigger) reads never hedge.
    pub min_samples: usize,
    /// Lower bound on the percentile-derived trigger, so a burst of
    /// fast reads cannot arm hair-trigger hedging.
    pub floor: Duration,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            after: None,
            percentile: 0.95,
            min_samples: 16,
            floor: Duration::from_millis(10),
        }
    }
}

/// Sliding window of recent successful read latencies.
const LATENCY_WINDOW: usize = 64;

struct LatencyRing {
    samples: Vec<Duration>,
    next: usize,
}

impl LatencyRing {
    fn new() -> Self {
        Self {
            samples: Vec::with_capacity(LATENCY_WINDOW),
            next: 0,
        }
    }

    fn record(&mut self, d: Duration) {
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(d);
        } else {
            self.samples[self.next] = d;
        }
        self.next = (self.next + 1) % LATENCY_WINDOW;
    }

    fn percentile(&self, p: f64, min_samples: usize) -> Option<Duration> {
        if self.samples.len() < min_samples.max(1) {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort();
        let idx = ((sorted.len() as f64 - 1.0) * p.clamp(0.0, 1.0)).round() as usize;
        sorted.get(idx).copied()
    }
}

// ---------------------------------------------------------- the wrapper --

/// Everything [`ResilientStorage`] is allowed to do around one read.
#[derive(Debug, Clone, Copy)]
pub struct ResilienceOptions {
    /// Transient-fault retry policy (exponential backoff + seeded
    /// jitter by default; see [`RetryPolicy`]).
    pub retry: RetryPolicy,
    /// Absolute per-`read_at` budget across all attempts and sleeps;
    /// `None` disables. (A `retry.deadline` is honored too; this field
    /// takes precedence when both are set.)
    pub deadline: Option<Duration>,
    pub breaker: BreakerConfig,
    pub hedge: HedgeConfig,
}

impl Default for ResilienceOptions {
    fn default() -> Self {
        Self {
            retry: RetryPolicy::transient(4, Duration::from_millis(5))
                .exponential()
                .with_jitter(0x5EED),
            deadline: None,
            breaker: BreakerConfig::default(),
            hedge: HedgeConfig::default(),
        }
    }
}

/// [`ReadableStorage`] wrapper adding retries, deadlines, a circuit
/// breaker, and hedged reads around any backend. See the module docs
/// for the semantics and `docs/STORAGE.md` for the normative contract.
pub struct ResilientStorage {
    inner: Arc<dyn ReadableStorage>,
    opts: ResilienceOptions,
    breaker: Arc<Breaker>,
    latencies: Mutex<LatencyRing>,
}

impl ResilientStorage {
    /// Wrap `inner` with a private breaker keyed by its description.
    pub fn new(inner: Arc<dyn ReadableStorage>, opts: ResilienceOptions) -> Self {
        let endpoint = inner.describe();
        let breaker = Arc::new(Breaker::new(&endpoint, opts.breaker));
        Self::with_breaker(inner, opts, breaker)
    }

    /// Wrap `inner` sharing an existing per-endpoint `breaker` (every
    /// store on one endpoint trips and recovers together).
    pub fn with_breaker(
        inner: Arc<dyn ReadableStorage>,
        opts: ResilienceOptions,
        breaker: Arc<Breaker>,
    ) -> Self {
        Self {
            inner,
            opts,
            breaker,
            latencies: Mutex::new(LatencyRing::new()),
        }
    }

    /// The shared circuit breaker.
    pub fn breaker(&self) -> &Arc<Breaker> {
        &self.breaker
    }

    fn hedge_trigger(&self) -> Option<Duration> {
        let cfg = self.opts.hedge;
        if !cfg.enabled {
            return None;
        }
        if let Some(after) = cfg.after {
            return Some(after);
        }
        lock(&self.latencies)
            .percentile(cfg.percentile, cfg.min_samples)
            .map(|d| d.max(cfg.floor))
    }

    /// One (possibly hedged) attempt. First success wins; the loser's
    /// result is discarded when it lands (its worker finds the channel
    /// closed) and only counted.
    fn attempt(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        let Some(trigger) = self.hedge_trigger() else {
            return self.inner.read_at(offset, buf);
        };
        let metrics = remote_metrics();
        let (tx, rx) = mpsc::channel::<(u8, io::Result<Vec<u8>>)>();
        if !spawn_read(&self.inner, offset, buf.len(), 0, tx.clone()) {
            drop(tx);
            return self.inner.read_at(offset, buf);
        }
        let winner = match rx.recv_timeout(trigger) {
            Ok(first) => {
                drop(tx);
                first
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                metrics.hedges.incr();
                let hedged = spawn_read(&self.inner, offset, buf.len(), 1, tx.clone());
                drop(tx);
                let first = rx
                    .recv()
                    .map_err(|_| io::Error::other("hedged read workers disappeared"))?;
                match (hedged, first) {
                    // The first finisher failed but the race is still
                    // on: the straggler may yet succeed.
                    (true, (id, Err(e))) => match rx.recv() {
                        Ok((id2, Ok(bytes))) => (id2, Ok(bytes)),
                        _ => (id, Err(e)),
                    },
                    (_, first) => first,
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(io::Error::other("hedged read worker disappeared"))
            }
        };
        match winner {
            (id, Ok(bytes)) => {
                if id == 1 {
                    metrics.hedge_wins.incr();
                }
                let n = bytes.len().min(buf.len());
                buf[..n].copy_from_slice(&bytes[..n]);
                Ok(n)
            }
            (_, Err(e)) => Err(e),
        }
    }
}

/// Spawn one hedge worker reading into its own buffer; returns whether
/// the spawn succeeded (callers fall back to inline reads when it
/// doesn't).
fn spawn_read(
    inner: &Arc<dyn ReadableStorage>,
    offset: u64,
    len: usize,
    id: u8,
    tx: mpsc::Sender<(u8, io::Result<Vec<u8>>)>,
) -> bool {
    let inner = Arc::clone(inner);
    std::thread::Builder::new()
        .name("ffcz-hedge".to_string())
        .spawn(move || {
            let mut local = vec![0u8; len];
            let res = inner.read_at(offset, &mut local).map(|n| {
                local.truncate(n);
                local
            });
            let _ = tx.send((id, res));
        })
        .is_ok()
}

impl ReadableStorage for ResilientStorage {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        let metrics = remote_metrics();
        metrics.requests.incr();
        let started = Instant::now();
        let deadline = self.opts.deadline.or(self.opts.retry.deadline);
        // The schedule handles attempts and backoff; the deadline is
        // enforced here so it can surface as a typed error.
        let mut policy = self.opts.retry;
        policy.deadline = None;
        let mut schedule = RetrySchedule::new(policy);
        loop {
            self.breaker.admit()?;
            if let Some(budget) = deadline {
                if started.elapsed() >= budget {
                    metrics.deadline_exceeded.incr();
                    return Err(io::Error::other(DeadlineExceeded {
                        budget,
                        elapsed: started.elapsed(),
                    }));
                }
            }
            let attempt_started = Instant::now();
            match self.attempt(offset, buf) {
                Ok(n) => {
                    self.breaker.on_success();
                    lock(&self.latencies).record(attempt_started.elapsed());
                    return Ok(n);
                }
                Err(e) => {
                    self.breaker.on_failure();
                    match schedule.backoff_for(e.kind()) {
                        Some(delay) => {
                            if let Some(budget) = deadline {
                                if started.elapsed() + delay >= budget {
                                    metrics.deadline_exceeded.incr();
                                    return Err(io::Error::other(DeadlineExceeded {
                                        budget,
                                        elapsed: started.elapsed(),
                                    }));
                                }
                            }
                            metrics.retries.incr();
                            if !delay.is_zero() {
                                std::thread::sleep(delay);
                            }
                        }
                        None => return Err(e),
                    }
                }
            }
        }
    }

    fn size(&self) -> io::Result<u64> {
        self.inner.size()
    }

    fn describe(&self) -> String {
        format!("resilient {}", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::storage::{read_exact_at, FaultInjector, FaultPlan, MemStorage};
    use std::sync::atomic::{AtomicU64, Ordering};

    fn mem(n: usize) -> MemStorage {
        MemStorage::new((0..n).map(|i| (i % 251) as u8).collect())
    }

    /// Test double: fails every read while `broken`, optionally sleeping
    /// per call according to a schedule.
    struct Flaky {
        inner: MemStorage,
        broken: std::sync::atomic::AtomicBool,
        calls: AtomicU64,
        /// Sleep applied to calls whose 1-based index is in this list.
        slow_calls: Vec<u64>,
        slow_by: Duration,
    }

    impl Flaky {
        fn new(n: usize) -> Self {
            Self {
                inner: mem(n),
                broken: std::sync::atomic::AtomicBool::new(false),
                calls: AtomicU64::new(0),
                slow_calls: Vec::new(),
                slow_by: Duration::ZERO,
            }
        }
    }

    impl ReadableStorage for Flaky {
        fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
            let call = self.calls.fetch_add(1, Ordering::SeqCst) + 1;
            if self.broken.load(Ordering::SeqCst) {
                return Err(io::Error::new(
                    io::ErrorKind::ConnectionRefused,
                    "endpoint is down",
                ));
            }
            if self.slow_calls.contains(&call) {
                std::thread::sleep(self.slow_by);
            }
            self.inner.read_at(offset, buf)
        }
        fn size(&self) -> io::Result<u64> {
            self.inner.size()
        }
        fn describe(&self) -> String {
            "flaky://test".to_string()
        }
    }

    fn no_hedge_opts() -> ResilienceOptions {
        ResilienceOptions {
            retry: RetryPolicy::transient(3, Duration::ZERO),
            deadline: None,
            breaker: BreakerConfig {
                failure_threshold: 3,
                cooldown: Duration::from_millis(40),
            },
            hedge: HedgeConfig::default(),
        }
    }

    #[test]
    fn passthrough_matches_inner_backend() {
        let resilient = ResilientStorage::new(Arc::new(mem(4096)), ResilienceOptions::default());
        let mut got = vec![0u8; 777];
        read_exact_at(&resilient, 123, &mut got).unwrap();
        let mut want = vec![0u8; 777];
        read_exact_at(&mem(4096), 123, &mut want).unwrap();
        assert_eq!(got, want);
        assert_eq!(resilient.size().unwrap(), 4096);
    }

    #[test]
    fn transient_faults_heal_under_the_schedule() {
        let inj = FaultInjector::new(
            mem(1024),
            FaultPlan {
                transient_every: 2,
                ..FaultPlan::none()
            },
        );
        let resilient = ResilientStorage::new(
            Arc::new(inj),
            ResilienceOptions {
                retry: RetryPolicy::transient(3, Duration::ZERO),
                ..ResilienceOptions::default()
            },
        );
        let mut buf = [0u8; 32];
        for i in 0..10u64 {
            read_exact_at(&resilient, i * 16, &mut buf).unwrap();
        }
    }

    #[test]
    fn breaker_opens_fails_fast_half_opens_and_recovers() {
        let flaky = Arc::new(Flaky::new(512));
        let resilient = ResilientStorage::new(
            Arc::clone(&flaky) as Arc<dyn ReadableStorage>,
            no_hedge_opts(),
        );
        assert_eq!(resilient.breaker().state_name(), "closed");

        let mut buf = [0u8; 16];
        flaky.broken.store(true, Ordering::SeqCst);
        // Hard (non-transient) failures: no retries, each counts once.
        for _ in 0..3 {
            let err = resilient.read_at(0, &mut buf).unwrap_err();
            assert!(breaker_open_of(&err).is_none());
        }
        assert_eq!(resilient.breaker().state_name(), "open");
        let calls_when_open = flaky.calls.load(Ordering::SeqCst);

        // While open: typed fail-fast, endpoint untouched.
        let err = resilient.read_at(0, &mut buf).unwrap_err();
        let open = breaker_open_of(&err).expect("expected a typed BreakerOpen");
        assert_eq!(open.endpoint, "flaky://test");
        assert_eq!(flaky.calls.load(Ordering::SeqCst), calls_when_open);

        // Cooldown elapses; the endpoint recovers; a half-open probe
        // succeeds and closes the breaker.
        std::thread::sleep(Duration::from_millis(60));
        flaky.broken.store(false, Ordering::SeqCst);
        read_exact_at(&resilient, 0, &mut buf).unwrap();
        assert_eq!(resilient.breaker().state_name(), "closed");
    }

    #[test]
    fn failed_half_open_probe_reopens() {
        let flaky = Arc::new(Flaky::new(512));
        let resilient = ResilientStorage::new(
            Arc::clone(&flaky) as Arc<dyn ReadableStorage>,
            no_hedge_opts(),
        );
        let mut buf = [0u8; 16];
        flaky.broken.store(true, Ordering::SeqCst);
        for _ in 0..3 {
            let _ = resilient.read_at(0, &mut buf);
        }
        assert_eq!(resilient.breaker().state_name(), "open");
        std::thread::sleep(Duration::from_millis(60));
        // Probe admitted, still failing: back to open.
        let err = resilient.read_at(0, &mut buf).unwrap_err();
        assert!(breaker_open_of(&err).is_none(), "probe must reach the endpoint");
        assert_eq!(resilient.breaker().state_name(), "open");
    }

    #[test]
    fn deadline_surfaces_as_a_typed_error() {
        let inj = FaultInjector::new(
            mem(512),
            FaultPlan {
                transient_every: 1, // every attempt faults
                ..FaultPlan::none()
            },
        );
        let resilient = ResilientStorage::new(
            Arc::new(inj),
            ResilienceOptions {
                retry: RetryPolicy::transient(100, Duration::from_millis(20)),
                deadline: Some(Duration::from_millis(50)),
                breaker: BreakerConfig {
                    failure_threshold: 0,
                    cooldown: Duration::ZERO,
                },
                hedge: HedgeConfig::default(),
            },
        );
        let mut buf = [0u8; 16];
        let started = Instant::now();
        let err = resilient.read_at(0, &mut buf).unwrap_err();
        let deadline = deadline_exceeded_of(&err).expect("expected a typed DeadlineExceeded");
        assert_eq!(deadline.budget, Duration::from_millis(50));
        assert!(started.elapsed() < Duration::from_secs(2), "budget not enforced");
    }

    #[test]
    fn hedge_fires_on_a_slow_primary_and_the_fast_hedge_wins() {
        let flaky = Arc::new(Flaky {
            inner: mem(1024),
            broken: std::sync::atomic::AtomicBool::new(false),
            calls: AtomicU64::new(0),
            slow_calls: vec![1], // only the primary's first call stalls
            slow_by: Duration::from_millis(300),
        });
        let resilient = ResilientStorage::new(
            Arc::clone(&flaky) as Arc<dyn ReadableStorage>,
            ResilienceOptions {
                retry: RetryPolicy::none(),
                deadline: None,
                breaker: BreakerConfig {
                    failure_threshold: 0,
                    cooldown: Duration::ZERO,
                },
                hedge: HedgeConfig {
                    enabled: true,
                    after: Some(Duration::from_millis(25)),
                    ..HedgeConfig::default()
                },
            },
        );
        let mut got = vec![0u8; 256];
        let started = Instant::now();
        read_exact_at(&resilient, 100, &mut got).unwrap();
        assert!(
            started.elapsed() < Duration::from_millis(250),
            "hedge did not rescue the slow primary ({:?})",
            started.elapsed()
        );
        let mut want = vec![0u8; 256];
        read_exact_at(&mem(1024), 100, &mut want).unwrap();
        assert_eq!(got, want);
        assert!(flaky.calls.load(Ordering::SeqCst) >= 2, "no hedge was fired");
    }

    #[test]
    fn disabled_hedging_never_spawns_a_second_read() {
        let flaky = Arc::new(Flaky::new(1024));
        let resilient = ResilientStorage::new(
            Arc::clone(&flaky) as Arc<dyn ReadableStorage>,
            ResilienceOptions::default(),
        );
        let mut buf = vec![0u8; 64];
        for i in 0..8u64 {
            resilient.read_at(i * 64, &mut buf).map(|_| ()).unwrap();
        }
        assert_eq!(flaky.calls.load(Ordering::SeqCst), 8);
    }
}
