//! Store reader: manifest-only open, random-access chunk decode (CRC-32
//! verified, per-chunk codec chains), and partial `read_region` that
//! touches only intersecting chunks. All byte I/O goes through the
//! [`ReadableStorage`] abstraction in [`super::storage`], so a store can
//! read from a local file, a memory buffer, or any custom backend (the
//! fault-injecting wrapper in tests, object stores later) — with transient
//! storage faults retried under a configurable [`RetryPolicy`].

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::codec::CodecChain;
use crate::correction::CorrectionScratch;
use crate::data::Field;
use crate::encoding::{crc32, fixed};
use crate::telemetry;
use crate::util::sync::lock;

use super::grid::{extract_subarray, insert_subarray, ChunkGrid};
use super::manifest::{Manifest, FOOTER_LEN, FOOTER_MAGIC, STORE_MAGIC};
use super::parallel::par_try_map_with;
use super::storage::{
    read_exact_at_retry, FileStorage, MemStorage, ReadableStorage, RetryPolicy,
};

/// The precise error for archives whose streaming write never completed:
/// valid head magic, missing or displaced trailer.
fn truncated_store_error() -> anyhow::Error {
    anyhow::anyhow!(
        "truncated or partially-written .ffcz store: the file starts with a valid \
         \"FFCZSTR1\" header but does not end with the 24-byte \"FFCZEND1\" trailer \
         (the write was interrupted before finish, or the tail was cut off)"
    )
}

/// An opened `.ffcz` chunked store.
///
/// Opening parses only the trailer (footer) and manifest; chunk payloads
/// are fetched and decoded on demand, so a [`Store::read_region`] over a
/// small window of a large array does a small fraction of the full decode
/// work. Every chain in the manifest's chain table is resolved against the
/// codec registries at open time, and chunk payloads are CRC-32-verified
/// before decode (manifest v2 archives; v1 archives predate checksums).
/// The number of chunk decodes is observable via [`Store::chunks_decoded`]
/// (used by tests to assert partial-decode behaviour). A container whose
/// streaming write was interrupted — valid header, no trailer — is
/// rejected at open with a precise "truncated or partially-written" error.
///
/// ```
/// use ffcz::codec::CodecChainSpec;
/// use ffcz::data::synth::grf::GrfBuilder;
/// use ffcz::store::{encode_store, Store, StoreWriteOptions};
///
/// let field = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(2).build();
/// let opts = StoreWriteOptions::new(&[4, 4]);
/// let (bytes, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();
///
/// let store = Store::from_bytes(bytes).unwrap();
/// assert_eq!(store.shape(), &[8, 8]);
/// assert_eq!(store.grid().chunk_count(), 4);
/// assert_eq!(store.decompress_all(1).unwrap().data(), field.data());
/// ```
pub struct Store {
    storage: Arc<dyn ReadableStorage>,
    /// Transient-fault retry policy for payload reads (default: none).
    retry: RetryPolicy,
    manifest: Manifest,
    grid: ChunkGrid,
    /// One executable chain per manifest chain-table entry.
    codecs: Vec<CodecChain>,
    /// Start of the manifest region — chunk payloads must end before it.
    manifest_offset: u64,
    /// Per-handle decode/hit/miss tallies ride on unregistered
    /// [`telemetry::Counter`] handles (tests assert exact per-store
    /// counts); the process-wide `store.read.*` registry metrics
    /// aggregate the same events across every store.
    chunks_decoded: telemetry::Counter,
    /// Transient storage-fault retries performed by this handle.
    retries: telemetry::Counter,
    /// Decoded-chunk LRU (disabled until [`Store::set_cache_budget`]).
    cache: Mutex<ChunkCache>,
    cache_hits: telemetry::Counter,
    cache_misses: telemetry::Counter,
}

/// Registered-metric handles for the read path, fetched once.
struct ReadMetrics {
    lru_hits: telemetry::Counter,
    lru_misses: telemetry::Counter,
    /// High-water mark of decoded bytes held by any one store's LRU.
    lru_bytes: telemetry::Gauge,
    /// Transient storage-fault retries across all stores.
    retries: telemetry::Counter,
    /// Chunks a degraded-mode read could not serve (backend down, chunk
    /// not in the LRU).
    degraded: telemetry::Counter,
}

fn read_metrics() -> &'static ReadMetrics {
    static METRICS: OnceLock<ReadMetrics> = OnceLock::new();
    METRICS.get_or_init(|| ReadMetrics {
        lru_hits: telemetry::counter("store.read.lru_hits"),
        lru_misses: telemetry::counter("store.read.lru_misses"),
        lru_bytes: telemetry::gauge("store.read.lru_bytes"),
        retries: telemetry::counter("store.read.retries"),
        degraded: telemetry::counter("store.read.degraded"),
    })
}

/// Decoded-chunk LRU keyed by chunk index, bounded by a byte budget
/// (decoded `f64` samples). Overlapping `read_region` windows re-touch the
/// same chunks; caching the decoded fields skips the payload fetch,
/// CRC check, and codec decode on every re-touch.
struct ChunkCache {
    /// Byte budget; 0 disables caching entirely (the default).
    budget: usize,
    /// Decoded bytes currently held.
    bytes: usize,
    /// Monotonic access clock for LRU ordering.
    clock: u64,
    entries: HashMap<usize, CacheEntry>,
    /// Stamp-ordered eviction index: `stamp → chunk index`, mirroring
    /// `entries` exactly (each entry's current stamp appears once; stamps
    /// are unique because the clock only ticks under the cache lock).
    /// Eviction pops the smallest stamp — O(log n) per evicted chunk —
    /// instead of min-scanning the entry map, which made mass evictions
    /// (budget shrink, hot sweeps over 10⁵+ cached chunks) quadratic.
    order: BTreeMap<u64, usize>,
}

struct CacheEntry {
    stamp: u64,
    field: Arc<Field>,
}

impl ChunkCache {
    fn disabled() -> Self {
        Self {
            budget: 0,
            bytes: 0,
            clock: 0,
            entries: HashMap::new(),
            order: BTreeMap::new(),
        }
    }

    /// Re-stamp `index` as most recently used and hand back its decoded
    /// field; `None` if the chunk is not cached. One map lookup — this is
    /// the whole hit path under the cache lock.
    fn touch(&mut self, index: usize) -> Option<Arc<Field>> {
        self.clock += 1;
        let stamp = self.clock;
        let entry = self.entries.get_mut(&index)?;
        self.order.remove(&entry.stamp);
        entry.stamp = stamp;
        self.order.insert(stamp, index);
        Some(entry.field.clone())
    }

    /// Evict least-recently-used entries until within budget.
    fn evict_to_budget(&mut self) {
        while self.bytes > self.budget {
            let Some((_, oldest)) = self.order.pop_first() else {
                break;
            };
            if let Some(e) = self.entries.remove(&oldest) {
                self.bytes -= e.field.len() * 8;
            }
        }
    }

    /// Drop every entry (budget set to 0 / cache disabled).
    fn clear(&mut self) {
        self.entries.clear();
        self.order.clear();
        self.bytes = 0;
    }
}

impl Store {
    /// Open a store file, reading only footer + manifest.
    pub fn open(path: &Path) -> Result<Self> {
        let storage = FileStorage::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::open_storage(Arc::new(storage))
    }

    /// Open a store held fully in memory.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self> {
        Self::open_storage(Arc::new(MemStorage::new(bytes)))
    }

    /// Open a store from any [`ReadableStorage`] backend, reading only
    /// head magic, footer, and manifest. The open path itself does not
    /// retry transient faults (openers want failures surfaced
    /// immediately); set a payload-read policy with
    /// [`Store::with_retry_policy`] afterwards.
    pub fn open_storage(storage: Arc<dyn ReadableStorage>) -> Result<Self> {
        let total_len = storage
            .size()
            .with_context(|| format!("stat {}", storage.describe()))?;
        if total_len < STORE_MAGIC.len() as u64 {
            bail!("not a .ffcz store (file too short)");
        }
        let mut head = [0u8; 8];
        super::storage::read_exact_at(storage.as_ref(), 0, &mut head)
            .with_context(|| format!("reading header of {}", storage.describe()))?;
        if &head != STORE_MAGIC {
            bail!("not a .ffcz store (bad head magic)");
        }
        if total_len < (STORE_MAGIC.len() + FOOTER_LEN) as u64 {
            bail!(truncated_store_error());
        }
        let mut footer = [0u8; FOOTER_LEN];
        super::storage::read_exact_at(
            storage.as_ref(),
            total_len - FOOTER_LEN as u64,
            &mut footer,
        )
        .with_context(|| format!("reading trailer of {}", storage.describe()))?;
        let (manifest_offset, manifest_len) = Self::parse_footer(&footer, total_len)?;
        let mut manifest_buf = vec![0u8; manifest_len as usize];
        super::storage::read_exact_at(storage.as_ref(), manifest_offset, &mut manifest_buf)
            .context("reading manifest")?;
        let manifest = Manifest::from_bytes(&manifest_buf)?;
        Self::build(storage, manifest, manifest_offset)
    }

    fn parse_footer(footer: &[u8], total_len: u64) -> Result<(u64, u64)> {
        debug_assert_eq!(footer.len(), FOOTER_LEN);
        if &footer[16..24] != FOOTER_MAGIC {
            // A valid header without the trailer is the signature of a
            // write interrupted mid-payload or mid-manifest: streaming
            // writers emit the trailer last, precisely so this case is
            // distinguishable from "not our file at all".
            bail!(truncated_store_error());
        }
        let mut pos = 0usize;
        let manifest_offset = fixed::read_u64_le(footer, &mut pos, "footer manifest offset")?;
        let manifest_len = fixed::read_u64_le(footer, &mut pos, "footer manifest length")?;
        let payload_start = STORE_MAGIC.len() as u64;
        let footer_start = total_len - FOOTER_LEN as u64;
        if manifest_offset < payload_start
            || manifest_offset.checked_add(manifest_len) != Some(footer_start)
        {
            bail!(
                "corrupt footer: manifest [{manifest_offset}, +{manifest_len}) \
                 does not fit the {total_len}-byte container"
            );
        }
        Ok((manifest_offset, manifest_len))
    }

    fn build(
        storage: Arc<dyn ReadableStorage>,
        manifest: Manifest,
        manifest_offset: u64,
    ) -> Result<Self> {
        let grid = manifest.grid()?;
        let codecs = manifest
            .chains
            .iter()
            .map(CodecChain::from_spec)
            .collect::<Result<Vec<_>>>()?;
        // Chunk ranges must lie inside the payload region.
        for (i, c) in manifest.chunks.iter().enumerate() {
            let end = c.offset.checked_add(c.length);
            let in_payload = c.offset >= STORE_MAGIC.len() as u64
                && matches!(end, Some(end) if end <= manifest_offset);
            if !in_payload {
                bail!(
                    "chunk {} byte range [{}, +{}) escapes the payload region",
                    grid.chunk_key(i),
                    c.offset,
                    c.length
                );
            }
        }
        Ok(Self {
            storage,
            retry: RetryPolicy::none(),
            manifest,
            grid,
            codecs,
            manifest_offset,
            chunks_decoded: telemetry::Counter::new(),
            retries: telemetry::Counter::new(),
            cache: Mutex::new(ChunkCache::disabled()),
            cache_hits: telemetry::Counter::new(),
            cache_misses: telemetry::Counter::new(),
        })
    }

    /// Retry transient storage faults (interrupted syscalls, timeouts) on
    /// payload reads under `policy`. Hard faults — CRC mismatches,
    /// premature EOF, permission errors — are never retried.
    pub fn with_retry_policy(mut self, policy: RetryPolicy) -> Self {
        self.retry = policy;
        self
    }

    /// See [`Store::with_retry_policy`].
    pub fn set_retry_policy(&mut self, policy: RetryPolicy) {
        self.retry = policy;
    }

    /// Transient-fault retries performed by this handle so far.
    pub fn retries(&self) -> u64 {
        self.retries.get()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn grid(&self) -> &ChunkGrid {
        &self.grid
    }

    /// Array shape of the stored field.
    pub fn shape(&self) -> &[usize] {
        &self.manifest.shape
    }

    /// Number of chunk decodes performed by this handle so far (cache hits
    /// do not decode, so they do not count).
    pub fn chunks_decoded(&self) -> usize {
        self.chunks_decoded.get() as usize
    }

    /// Enable (or resize) the decoded-chunk LRU cache: decoded chunks are
    /// kept up to `bytes` of decoded samples and served to overlapping
    /// [`Store::read_region`] windows without re-fetching or re-decoding.
    /// A budget of 0 disables caching and drops held chunks (the default
    /// state). Shrinking evicts least-recently-used entries immediately.
    pub fn set_cache_budget(&self, bytes: usize) {
        let mut cache = lock(&self.cache);
        cache.budget = bytes;
        if bytes == 0 {
            cache.clear();
        } else {
            cache.evict_to_budget();
        }
    }

    /// Builder-style [`Store::set_cache_budget`].
    pub fn with_cache_budget(self, bytes: usize) -> Self {
        self.set_cache_budget(bytes);
        self
    }

    /// Cache hits served so far (0 while the cache is disabled).
    pub fn cache_hits(&self) -> usize {
        self.cache_hits.get() as usize
    }

    /// Cache misses (decodes performed with the cache enabled).
    pub fn cache_misses(&self) -> usize {
        self.cache_misses.get() as usize
    }

    /// Decoded bytes currently held by the cache.
    pub fn cache_bytes(&self) -> usize {
        lock(&self.cache).bytes
    }

    /// Decode chunk `index` through the LRU cache (a plain
    /// [`Store::decode_chunk`] when caching is disabled). The chunk decode
    /// itself runs outside the cache lock, so concurrent
    /// [`Store::read_region`] workers never serialize on a decode; two
    /// racing misses on the same chunk decode twice and the second insert
    /// wins.
    pub fn decode_chunk_cached(&self, index: usize) -> Result<Arc<Field>> {
        self.decode_chunk_cached_with_scratch(index, &mut CorrectionScratch::new())
    }

    /// [`Store::decode_chunk_cached`] with caller-owned correction scratch
    /// (cache hits never touch it). Batch readers — `read_region` workers,
    /// server request handlers — hold one scratch per worker so decode
    /// transform state warms once per chunk shape.
    pub fn decode_chunk_cached_with_scratch(
        &self,
        index: usize,
        scratch: &mut CorrectionScratch,
    ) -> Result<Arc<Field>> {
        {
            let mut cache = lock(&self.cache);
            if cache.budget == 0 {
                drop(cache);
                return Ok(Arc::new(self.decode_chunk_with_scratch(index, scratch)?));
            }
            if let Some(field) = cache.touch(index) {
                drop(cache);
                self.cache_hits.incr();
                read_metrics().lru_hits.incr();
                return Ok(field);
            }
        }
        let field = Arc::new(self.decode_chunk_with_scratch(index, scratch)?);
        self.cache_misses.incr();
        read_metrics().lru_misses.incr();
        let mut cache = lock(&self.cache);
        if cache.budget == 0 {
            // Disabled while we were decoding.
            return Ok(field);
        }
        let field_bytes = field.len() * 8;
        if field_bytes <= cache.budget {
            cache.clock += 1;
            let stamp = cache.clock;
            if let Some(old) = cache.entries.insert(
                index,
                CacheEntry {
                    stamp,
                    field: field.clone(),
                },
            ) {
                // Racing miss on the same chunk: replace the loser's entry
                // and retire its stamp from the eviction index.
                cache.bytes -= old.field.len() * 8;
                cache.order.remove(&old.stamp);
            }
            cache.order.insert(stamp, index);
            cache.bytes += field_bytes;
            cache.evict_to_budget();
            read_metrics().lru_bytes.max(cache.bytes as u64);
        }
        Ok(field)
    }

    /// Raw payload bytes of chunk `index`, fetched through the storage
    /// backend (transient faults retried under the store's policy).
    fn chunk_bytes(&self, index: usize) -> Result<Vec<u8>> {
        let entry = &self.manifest.chunks[index];
        let mut buf = vec![0u8; entry.length as usize];
        let retries =
            read_exact_at_retry(self.storage.as_ref(), entry.offset, &mut buf, &self.retry)
                .with_context(|| format!("reading chunk {}", self.grid.chunk_key(index)))?;
        if retries > 0 {
            self.retries.add(retries as u64);
            read_metrics().retries.add(retries as u64);
        }
        // Verify the payload against the manifest checksum before it
        // reaches any codec: corruption in the payload region surfaces as
        // a precise error here, not as a downstream parse failure.
        if let Some(expect) = entry.crc32 {
            let got = crc32(&buf);
            if got != expect {
                bail!(
                    "chunk {} payload corrupt: CRC-32 {got:#010x} does not match \
                     manifest {expect:#010x}",
                    self.grid.chunk_key(index)
                );
            }
        }
        Ok(buf)
    }

    /// Decode chunk `index` (its edge-clipped extent as a standalone field).
    pub fn decode_chunk(&self, index: usize) -> Result<Field> {
        self.decode_chunk_with_scratch(index, &mut CorrectionScratch::new())
    }

    /// [`Store::decode_chunk`] with caller-owned correction scratch;
    /// bit-identical output, but transform plans and workspace buffers
    /// warm once per chunk shape instead of once per chunk.
    pub fn decode_chunk_with_scratch(
        &self,
        index: usize,
        scratch: &mut CorrectionScratch,
    ) -> Result<Field> {
        if index >= self.manifest.chunks.len() {
            bail!(
                "chunk index {index} out of range ({} chunks)",
                self.manifest.chunks.len()
            );
        }
        let coords = self.grid.chunk_coords(index);
        let extent = self.grid.chunk_extent(&coords);
        let bytes = self.chunk_bytes(index)?;
        self.chunks_decoded.incr();
        self.codecs[self.manifest.chunks[index].chain]
            .decode_chunk_with_scratch(&bytes, &extent, self.manifest.precision, scratch)
            .with_context(|| format!("decoding chunk {}", self.grid.chunk_key(index)))
    }

    /// Decode the subarray `[origin, origin + shape)`, touching only the
    /// chunks that intersect it. Chunk decodes run on up to `workers`
    /// threads.
    ///
    /// ```
    /// use ffcz::codec::CodecChainSpec;
    /// use ffcz::data::synth::grf::GrfBuilder;
    /// use ffcz::store::{encode_store, extract_subarray, Store, StoreWriteOptions};
    ///
    /// let field = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(3).build();
    /// let opts = StoreWriteOptions::new(&[4, 4]);
    /// let (bytes, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();
    /// let store = Store::from_bytes(bytes).unwrap();
    ///
    /// // A 3 × 2 window inside chunk c/0/0: one chunk decoded, bit-exact.
    /// let region = store.read_region(&[1, 1], &[3, 2], 1).unwrap();
    /// assert_eq!(store.chunks_decoded(), 1);
    /// let expect = extract_subarray(field.data(), field.shape(), &[1, 1], &[3, 2]);
    /// assert_eq!(region.data(), &expect[..]);
    /// ```
    pub fn read_region(&self, origin: &[usize], shape: &[usize], workers: usize) -> Result<Field> {
        let ids = self.grid.chunks_intersecting(origin, shape)?;
        let read_span = telemetry::span("store.read_region").arg("chunks", ids.len() as u64);
        let read_span_id = read_span.id();
        let n: usize = shape.iter().product();
        let mut out = vec![0.0f64; n];
        // One correction scratch per worker: decode transform state (plan
        // handles, FFT workspace, spectrum buffers) warms once per chunk
        // shape per worker and is reused across all its chunks.
        let pieces = par_try_map_with(ids.len(), workers, CorrectionScratch::new, |j, scratch| {
            self.read_chunk_piece(ids[j], origin, shape, read_span_id, scratch)
        })?;
        for (region_local, sub_shape, sub) in pieces {
            insert_subarray(&mut out, shape, &region_local, &sub, &sub_shape);
        }
        Ok(Field::new(shape, out, self.manifest.precision))
    }

    /// [`Store::read_region`] decoded sequentially through caller-owned
    /// scratch — the entry point for request handlers (the archive read
    /// server) that pool one scratch per connection across many requests.
    pub fn read_region_with_scratch(
        &self,
        origin: &[usize],
        shape: &[usize],
        scratch: &mut CorrectionScratch,
    ) -> Result<Field> {
        let ids = self.grid.chunks_intersecting(origin, shape)?;
        let read_span = telemetry::span("store.read_region").arg("chunks", ids.len() as u64);
        let read_span_id = read_span.id();
        let n: usize = shape.iter().product();
        let mut out = vec![0.0f64; n];
        for &index in &ids {
            let (region_local, sub_shape, sub) =
                self.read_chunk_piece(index, origin, shape, read_span_id, scratch)?;
            insert_subarray(&mut out, shape, &region_local, &sub, &sub_shape);
        }
        Ok(Field::new(shape, out, self.manifest.precision))
    }

    /// [`Store::read_region`] that survives a dead or flapping storage
    /// backend: chunks still present in the decoded-chunk LRU (or
    /// fetchable) are served normally, while chunks whose *payload
    /// fetch* fails — connection refused, deadline exceeded, breaker
    /// open — are NaN-filled in the output and reported in
    /// [`RegionRead::missing`] instead of erroring the whole region.
    /// Data-integrity failures (CRC mismatch, codec decode errors) are
    /// never masked: those still propagate, because they mean the bytes
    /// arrived and are wrong. The archive server's degraded mode and its
    /// `ST_DEGRADED` answers build on this; the contract is documented
    /// in `docs/STORAGE.md`.
    pub fn read_region_degraded(
        &self,
        origin: &[usize],
        shape: &[usize],
        scratch: &mut CorrectionScratch,
    ) -> Result<RegionRead> {
        let ids = self.grid.chunks_intersecting(origin, shape)?;
        let read_span = telemetry::span("store.read_region").arg("chunks", ids.len() as u64);
        let read_span_id = read_span.id();
        let n: usize = shape.iter().product();
        let mut out = vec![0.0f64; n];
        let mut missing = Vec::new();
        for &index in &ids {
            match self.read_chunk_piece(index, origin, shape, read_span_id, scratch) {
                Ok((region_local, sub_shape, sub)) => {
                    insert_subarray(&mut out, shape, &region_local, &sub, &sub_shape);
                }
                Err(e) if is_storage_error(&e) => {
                    let (region_local, sub_shape) = self.piece_geometry(index, origin, shape);
                    let nans = vec![f64::NAN; sub_shape.iter().product()];
                    insert_subarray(&mut out, shape, &region_local, &nans, &sub_shape);
                    missing.push(index);
                }
                Err(e) => return Err(e),
            }
        }
        if !missing.is_empty() {
            read_metrics().degraded.add(missing.len() as u64);
        }
        Ok(RegionRead {
            field: Field::new(shape, out, self.manifest.precision),
            missing,
        })
    }

    /// Intersection of chunk `index` with the requested region:
    /// `(region-local origin, piece shape)` — the geometry half of
    /// [`Store::read_chunk_piece`], used to NaN-fill unservable chunks.
    fn piece_geometry(
        &self,
        index: usize,
        origin: &[usize],
        shape: &[usize],
    ) -> (Vec<usize>, Vec<usize>) {
        let coords = self.grid.chunk_coords(index);
        let c_origin = self.grid.chunk_origin(&coords);
        let c_extent = self.grid.chunk_extent(&coords);
        let lo: Vec<usize> = (0..shape.len())
            .map(|d| origin[d].max(c_origin[d]))
            .collect();
        let hi: Vec<usize> = (0..shape.len())
            .map(|d| (origin[d] + shape[d]).min(c_origin[d] + c_extent[d]))
            .collect();
        let sub_shape: Vec<usize> = (0..shape.len()).map(|d| hi[d] - lo[d]).collect();
        let region_local: Vec<usize> = (0..shape.len()).map(|d| lo[d] - origin[d]).collect();
        (region_local, sub_shape)
    }

    /// Decode one chunk (through the LRU) and extract its intersection
    /// with the requested region: `(region-local origin, piece shape,
    /// piece samples)`.
    fn read_chunk_piece(
        &self,
        index: usize,
        origin: &[usize],
        shape: &[usize],
        parent_span: u64,
        scratch: &mut CorrectionScratch,
    ) -> Result<(Vec<usize>, Vec<usize>, Vec<f64>)> {
        let _chunk_span = telemetry::span_with_parent("store.chunk.read", parent_span)
            .arg("chunk", index as u64);
        let chunk = self.decode_chunk_cached_with_scratch(index, scratch)?;
        let coords = self.grid.chunk_coords(index);
        let c_origin = self.grid.chunk_origin(&coords);
        let c_extent = self.grid.chunk_extent(&coords);
        // Intersection of the chunk box with the requested region.
        let lo: Vec<usize> = (0..shape.len())
            .map(|d| origin[d].max(c_origin[d]))
            .collect();
        let hi: Vec<usize> = (0..shape.len())
            .map(|d| (origin[d] + shape[d]).min(c_origin[d] + c_extent[d]))
            .collect();
        let sub_shape: Vec<usize> = (0..shape.len()).map(|d| hi[d] - lo[d]).collect();
        let chunk_local: Vec<usize> = (0..shape.len()).map(|d| lo[d] - c_origin[d]).collect();
        let sub = extract_subarray(chunk.data(), &c_extent, &chunk_local, &sub_shape);
        let region_local: Vec<usize> = (0..shape.len()).map(|d| lo[d] - origin[d]).collect();
        Ok((region_local, sub_shape, sub))
    }

    /// Decode the whole array (all chunks, in parallel).
    pub fn decompress_all(&self, workers: usize) -> Result<Field> {
        let origin = vec![0usize; self.manifest.shape.len()];
        let shape = self.manifest.shape.clone();
        self.read_region(&origin, &shape, workers)
    }

    /// Integrity verification of every chunk, on up to `workers` threads:
    /// payload fetch + CRC-32 against the manifest, a full decode through
    /// the chunk's codec chain, and a re-check that the recorded
    /// dual-domain verification stats hold and are self-consistent (the
    /// `spatial_ok`/`frequency_ok` flags agree with the stored worst-case
    /// ratios, and both bounds are satisfied). Verification never stops
    /// early — the report covers all chunks, failing ones annotated. The
    /// operator entry point is `ffcz archive verify`.
    pub fn verify(&self, workers: usize) -> Result<VerifyReport> {
        let t0 = std::time::Instant::now();
        let _span =
            telemetry::span("store.verify").arg("chunks", self.manifest.chunks.len() as u64);
        let chunks = par_try_map_with(
            self.manifest.chunks.len(),
            workers,
            CorrectionScratch::new,
            |index, scratch| Ok(self.verify_chunk(index, scratch)),
        )?;
        Ok(VerifyReport {
            chunks,
            elapsed: t0.elapsed(),
        })
    }

    fn verify_chunk(&self, index: usize, scratch: &mut CorrectionScratch) -> ChunkVerifyReport {
        let entry = &self.manifest.chunks[index];
        let mut report = ChunkVerifyReport {
            index,
            key: self.grid.chunk_key(index),
            crc_ok: false,
            decode_ok: false,
            bounds_ok: false,
            error: None,
        };
        // Payload fetch + CRC (chunk_bytes checks the manifest checksum
        // before handing bytes onward).
        let bytes = match self.chunk_bytes(index) {
            Ok(bytes) => bytes,
            Err(e) => {
                report.error = Some(format!("{e:#}"));
                return report;
            }
        };
        report.crc_ok = true;
        let coords = self.grid.chunk_coords(index);
        let extent = self.grid.chunk_extent(&coords);
        match self.codecs[entry.chain].decode_chunk_with_scratch(
            &bytes,
            &extent,
            self.manifest.precision,
            scratch,
        ) {
            Ok(_) => report.decode_ok = true,
            Err(e) => {
                report.error = Some(format!("{e:#}"));
                return report;
            }
        }
        // Dual-domain bound re-check against the manifest record: both
        // flags set, and each flag consistent with its stored worst-case
        // ratio (≤ 1 is in-bound).
        let stats = &entry.stats;
        report.bounds_ok = stats.spatial_ok
            && stats.frequency_ok
            && stats.max_spatial_ratio <= 1.0
            && stats.max_frequency_ratio <= 1.0;
        if !report.bounds_ok {
            report.error = Some(format!(
                "dual-domain bounds not satisfied: spatial_ok={} (max ratio {:.6}), \
                 frequency_ok={} (max ratio {:.6})",
                stats.spatial_ok,
                stats.max_spatial_ratio,
                stats.frequency_ok,
                stats.max_frequency_ratio
            ));
        }
        report
    }
}

/// True when `err` carries an [`std::io::Error`] anywhere in its chain —
/// the payload fetch failed (backend down, deadline, breaker), as
/// opposed to a data-integrity failure (CRC mismatch, decode error)
/// whose bytes arrived and are wrong.
fn is_storage_error(err: &anyhow::Error) -> bool {
    err.chain()
        .any(|c| c.downcast_ref::<std::io::Error>().is_some())
}

/// Outcome of [`Store::read_region_degraded`]: the decoded window plus
/// the chunks it could not serve.
#[derive(Debug, Clone)]
pub struct RegionRead {
    /// The requested window; regions of chunks listed in `missing` are
    /// NaN-filled.
    pub field: Field,
    /// Row-major indices of chunks whose payload fetch failed, in
    /// ascending order. Empty means the read is complete and bit-exact.
    pub missing: Vec<usize>,
}

impl RegionRead {
    /// True iff every intersecting chunk was served.
    pub fn is_complete(&self) -> bool {
        self.missing.is_empty()
    }
}

/// Per-chunk outcome of [`Store::verify`].
#[derive(Debug, Clone)]
pub struct ChunkVerifyReport {
    /// Row-major chunk index.
    pub index: usize,
    /// Zarr-style chunk key (`"c/1/0"`).
    pub key: String,
    /// Payload read back and matched the manifest CRC-32.
    pub crc_ok: bool,
    /// Payload decoded cleanly through its codec chain.
    pub decode_ok: bool,
    /// Recorded dual-domain verification stats hold and are
    /// self-consistent.
    pub bounds_ok: bool,
    /// Detail for the first failing check, if any.
    pub error: Option<String>,
}

impl ChunkVerifyReport {
    /// True iff every check passed for this chunk.
    pub fn ok(&self) -> bool {
        self.crc_ok && self.decode_ok && self.bounds_ok
    }
}

/// Outcome of [`Store::verify`] over every chunk.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    /// One entry per chunk, in index order.
    pub chunks: Vec<ChunkVerifyReport>,
    pub elapsed: std::time::Duration,
}

impl VerifyReport {
    /// True iff every chunk passed every check.
    pub fn ok(&self) -> bool {
        self.chunks.iter().all(ChunkVerifyReport::ok)
    }

    /// Number of failing chunks.
    pub fn failed(&self) -> usize {
        self.chunks.iter().filter(|c| !c.ok()).count()
    }

    /// Stable JSON rendering for `ffcz archive verify`: the summary plus
    /// one row per failing chunk.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"chunks\": {},\n", self.chunks.len()));
        out.push_str(&format!("  \"failed\": {},\n", self.failed()));
        out.push_str(&format!("  \"ok\": {},\n", self.ok()));
        out.push_str(&format!(
            "  \"elapsed_s\": {:.6},\n",
            self.elapsed.as_secs_f64()
        ));
        out.push_str("  \"failures\": [");
        let mut first = true;
        for c in self.chunks.iter().filter(|c| !c.ok()) {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n    {{\"chunk\": \"{}\", \"crc_ok\": {}, \"decode_ok\": {}, \
                 \"bounds_ok\": {}, \"error\": \"{}\"}}",
                json_escape(&c.key),
                c.crc_ok,
                c.decode_ok,
                c.bounds_ok,
                json_escape(c.error.as_deref().unwrap_or(""))
            ));
        }
        if !first {
            out.push_str("\n  ");
        }
        out.push_str("]\n}");
        out
    }
}

/// Minimal JSON string escaping for the verify report.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::CodecChainSpec;
    use crate::data::synth::grf::GrfBuilder;
    use crate::store::writer::{encode_store, StoreWriteOptions};

    fn store_bytes() -> (Field, Vec<u8>) {
        let field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(9).build();
        let opts = StoreWriteOptions::new(&[5, 4]).workers(2);
        let (bytes, _, _) = encode_store(&field, &CodecChainSpec::lossless(), &opts).unwrap();
        (field, bytes)
    }

    #[test]
    fn full_decode_matches_source() {
        let (field, bytes) = store_bytes();
        let store = Store::from_bytes(bytes).unwrap();
        let out = store.decompress_all(3).unwrap();
        assert_eq!(out.shape(), field.shape());
        assert_eq!(out.data(), field.data());
        assert_eq!(out.precision(), field.precision());
        assert_eq!(store.chunks_decoded(), store.grid().chunk_count());
    }

    #[test]
    fn read_region_touches_only_intersecting_chunks() {
        let (field, bytes) = store_bytes();
        let store = Store::from_bytes(bytes).unwrap();
        // A window inside chunk (0, 0) only.
        let region = store.read_region(&[1, 1], &[3, 2], 1).unwrap();
        assert_eq!(store.chunks_decoded(), 1);
        let expect = extract_subarray(field.data(), field.shape(), &[1, 1], &[3, 2]);
        assert_eq!(region.data(), &expect[..]);
    }

    #[test]
    fn corrupt_containers_rejected() {
        let (_, bytes) = store_bytes();
        // Bad head magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(Store::from_bytes(bad).is_err());
        // Bad footer magic.
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 1] ^= 0xFF;
        assert!(Store::from_bytes(bad).is_err());
        // Truncated tail.
        let bad = bytes[..bytes.len() - 10].to_vec();
        assert!(Store::from_bytes(bad).is_err());
        // Too short entirely.
        assert!(Store::from_bytes(b"FFCZSTR1".to_vec()).is_err());
    }

    #[test]
    fn payload_corruption_caught_by_crc() {
        let (_, bytes) = store_bytes();
        let mut bad = bytes.clone();
        bad[10] ^= 0xFF; // inside chunk 0's payload
        let store = Store::from_bytes(bad).unwrap();
        let err = store.decode_chunk(0).unwrap_err();
        assert!(format!("{err:#}").contains("CRC-32"), "{err:#}");
        assert!(store.decompress_all(1).is_err());
    }

    #[test]
    fn lru_cache_serves_overlapping_regions_without_redecoding() {
        let (field, bytes) = store_bytes();
        let store = Store::from_bytes(bytes).unwrap();
        store.set_cache_budget(field.len() * 8); // room for every chunk
        let a = store.read_region(&[0, 0], &[10, 8], 2).unwrap();
        let decoded_cold = store.chunks_decoded();
        assert!(decoded_cold >= 4);
        assert_eq!(store.cache_misses(), decoded_cold);
        assert_eq!(store.cache_hits(), 0);
        // Same window again: all chunks come from the cache.
        let b = store.read_region(&[0, 0], &[10, 8], 2).unwrap();
        assert_eq!(store.chunks_decoded(), decoded_cold, "re-decoded");
        assert_eq!(store.cache_hits(), decoded_cold);
        assert_eq!(a.data(), b.data());
        // Overlapping window: only the newly-touched chunks decode.
        let expect = extract_subarray(field.data(), field.shape(), &[2, 2], &[6, 5]);
        let c = store.read_region(&[2, 2], &[6, 5], 1).unwrap();
        assert_eq!(c.data(), &expect[..]);
        assert_eq!(store.chunks_decoded(), decoded_cold, "window inside cached chunks");
    }

    #[test]
    fn lru_cache_respects_byte_budget() {
        let (_, bytes) = store_bytes();
        let store = Store::from_bytes(bytes).unwrap();
        // Room for roughly two 5×4 chunks of f64s.
        let budget = 2 * 5 * 4 * 8;
        store.set_cache_budget(budget);
        store.decompress_all(1).unwrap();
        assert!(
            store.cache_bytes() <= budget,
            "cache {} bytes exceeds budget {budget}",
            store.cache_bytes()
        );
        assert!(store.cache_bytes() > 0);
        // Disabling drops everything and stops counting.
        store.set_cache_budget(0);
        assert_eq!(store.cache_bytes(), 0);
        let (hits, misses) = (store.cache_hits(), store.cache_misses());
        store.decompress_all(1).unwrap();
        assert_eq!((store.cache_hits(), store.cache_misses()), (hits, misses));
    }

    #[test]
    fn lru_stamp_index_stays_consistent_under_churn_and_mass_eviction() {
        let (field, bytes) = store_bytes();
        let store = Store::from_bytes(bytes).unwrap();
        // Budget for roughly two full 5×4 chunks: constant churn.
        let budget = 2 * 5 * 4 * 8;
        store.set_cache_budget(budget);
        // Sweep overlapping windows in a non-monotonic order so hits,
        // misses, evictions, and re-inserts interleave.
        let windows = [
            ([0usize, 0usize], [6usize, 6usize]),
            ([4, 2], [8, 8]),
            ([0, 0], [6, 6]),
            ([6, 4], [6, 6]),
            ([2, 0], [4, 10]),
            ([0, 0], [12, 10]),
            ([4, 2], [8, 8]),
        ];
        for (origin, shape) in windows {
            let got = store.read_region(&origin, &shape, 2).unwrap();
            let want = extract_subarray(field.data(), field.shape(), &origin, &shape);
            assert_eq!(got.data(), &want[..], "window {origin:?}+{shape:?}");
            assert!(
                store.cache_bytes() <= budget,
                "cache {} exceeds budget {budget}",
                store.cache_bytes()
            );
        }
        assert!(store.cache_hits() > 0, "sweep produced no cache hits");
        assert!(store.cache_misses() > 0);
        // Mass eviction via budget shrink: one chunk's worth left.
        store.set_cache_budget(5 * 4 * 8);
        assert!(store.cache_bytes() <= 5 * 4 * 8);
        // The cache still serves correct data afterwards.
        let got = store.read_region(&[0, 0], &[12, 10], 1).unwrap();
        assert_eq!(got.data(), field.data());
        // Disable: everything dropped, index emptied with it.
        store.set_cache_budget(0);
        assert_eq!(store.cache_bytes(), 0);
    }

    #[test]
    fn verify_walks_every_chunk_and_flags_corruption() {
        let (_, bytes) = store_bytes();
        let store = Store::from_bytes(bytes.clone()).unwrap();
        let report = store.verify(2).unwrap();
        assert!(report.ok());
        assert_eq!(report.chunks.len(), store.grid().chunk_count());
        assert_eq!(report.failed(), 0);
        for (i, c) in report.chunks.iter().enumerate() {
            assert_eq!(c.index, i);
            assert!(c.crc_ok && c.decode_ok && c.bounds_ok);
            assert!(c.error.is_none());
        }
        assert!(report.to_json().contains("\"failed\": 0"));

        // Corrupt one payload byte: exactly that chunk fails, at the CRC
        // check, and the JSON report names it.
        let mut bad = bytes;
        bad[10] ^= 0xFF;
        let store = Store::from_bytes(bad).unwrap();
        let report = store.verify(1).unwrap();
        assert!(!report.ok());
        assert_eq!(report.failed(), 1);
        assert!(!report.chunks[0].crc_ok);
        assert!(report.chunks[1..].iter().all(ChunkVerifyReport::ok));
        let json = report.to_json();
        assert!(json.contains("c/0/0") && json.contains("CRC-32"), "{json}");
    }

    #[test]
    fn degraded_read_serves_cached_chunks_and_nan_fills_the_rest() {
        use crate::store::storage::{FaultInjector, FaultPlan, MemStorage};

        let (field, bytes) = store_bytes();
        let injector = Arc::new(FaultInjector::new(MemStorage::new(bytes), FaultPlan::none()));
        let faults = injector.handle();
        let store = Store::open_storage(injector).unwrap();
        store.set_cache_budget(field.len() * 8);

        // Warm the cache for the top-left window only.
        let warm = store.read_region(&[0, 0], &[5, 4], 1).unwrap();
        assert_eq!(store.chunks_decoded(), 1);

        // Kill the backend: every subsequent payload read faults (retry
        // policy is none, so the fault surfaces immediately).
        faults.set_plan(FaultPlan {
            transient_every: 1,
            ..FaultPlan::none()
        });

        // The cached chunk is still served bit-exact.
        let mut scratch = CorrectionScratch::new();
        let cached = store
            .read_region_degraded(&[0, 0], &[5, 4], &mut scratch)
            .unwrap();
        assert!(cached.is_complete());
        assert_eq!(cached.field.data(), warm.data());

        // A window spanning cached + uncached chunks: the cached piece is
        // exact, the unservable chunks are reported and NaN-filled.
        let got = store
            .read_region_degraded(&[0, 0], &[12, 10], &mut scratch)
            .unwrap();
        assert!(!got.is_complete());
        assert_eq!(
            got.missing.len(),
            store.grid().chunk_count() - 1,
            "only the warmed chunk should be servable"
        );
        assert!(!got.missing.contains(&0));
        let expect = extract_subarray(field.data(), field.shape(), &[0, 0], &[5, 4]);
        let head = extract_subarray(got.field.data(), &[12, 10], &[0, 0], &[5, 4]);
        assert_eq!(head, expect);
        assert!(got.field.data().iter().any(|v| v.is_nan()));

        // Data-integrity failures are never masked: with the backend
        // healthy again but a payload byte corrupted, the CRC error
        // propagates instead of degrading.
        faults.set_plan(FaultPlan::none());
        let (_, bytes2) = store_bytes();
        let mut bad = bytes2;
        bad[10] ^= 0xFF;
        let store2 = Store::from_bytes(bad).unwrap();
        let err = store2
            .read_region_degraded(&[0, 0], &[5, 4], &mut scratch)
            .unwrap_err();
        assert!(format!("{err:#}").contains("CRC-32"), "{err:#}");
    }

    #[test]
    fn out_of_bounds_region_rejected() {
        let (_, bytes) = store_bytes();
        let store = Store::from_bytes(bytes).unwrap();
        assert!(store.read_region(&[10, 8], &[4, 4], 1).is_err());
        assert!(store.read_region(&[0], &[4], 1).is_err());
    }
}
