//! Work-stealing-free worker pool for per-chunk codec work.
//!
//! Chunks are independent (the dual-domain guarantee is per chunk, see
//! [`crate::codec`]), so compress/decompress parallelizes with a plain
//! `std::thread` scope and an atomic work index — no dependencies, no
//! channels, deterministic output order. This is the chunk-level analogue
//! of how [`crate::coordinator::sharding`] parallelizes over shards.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

/// Apply `f` to every index in `0..n` using up to `workers` OS threads and
/// collect the results in index order. Returns the first error (by index)
/// if any task fails; remaining tasks may still have run.
pub fn par_try_map<T, F>(n: usize, workers: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if workers == 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("every index claimed by exactly one worker")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::bail;

    #[test]
    fn preserves_order_across_worker_counts() {
        for workers in [1usize, 2, 4, 9] {
            let out = par_try_map(17, workers, |i| Ok(i * i)).unwrap();
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<usize> = par_try_map(0, 4, |i| Ok(i)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn first_error_by_index_wins() {
        let err = par_try_map(10, 3, |i| {
            if i >= 4 {
                bail!("task {i} failed");
            }
            Ok(i)
        })
        .unwrap_err();
        assert_eq!(format!("{err}"), "task 4 failed");
    }
}
