//! Work-stealing-free worker pool for per-chunk codec work.
//!
//! Chunks are independent (the dual-domain guarantee is per chunk, see
//! [`crate::codec`]), so compress/decompress parallelizes with a plain
//! `std::thread` scope and an atomic work index — no dependencies, no
//! channels, deterministic output order. This is the chunk-level analogue
//! of how [`crate::coordinator::sharding`] parallelizes over shards.
//!
//! Entry points:
//!
//! * [`par_try_map`] collects every result into a `Vec` (decode paths,
//!   where the caller needs all pieces anyway);
//! * [`par_try_map_ordered_sink`] hands results to a single-threaded sink
//!   **in index order** through a bounded window, so at most
//!   `window` results exist at once — the streaming store writer uses this
//!   to spill chunk payloads to disk with O(window × chunk) peak memory
//!   instead of O(field);
//! * the `*_with` variants ([`par_try_map_with`],
//!   [`par_try_map_ordered_sink_with`]) additionally give each worker
//!   thread its own state built by an `init` closure — how the store
//!   encoder hands every worker one
//!   [`crate::correction::CorrectionScratch`] that lives across all the
//!   chunks that worker encodes.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use anyhow::{bail, Result};

use crate::util::sync::lock;

/// Apply `f` to every index in `0..n` using up to `workers` OS threads and
/// collect the results in index order. Returns the first error (by index)
/// if any task fails; remaining tasks may still have run.
pub fn par_try_map<T, F>(n: usize, workers: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    par_try_map_with(n, workers, || (), |i, _: &mut ()| f(i))
}

/// [`par_try_map`] with per-worker state: every worker thread builds one
/// `S` with `init` at start-up and threads it through each `f(index,
/// &mut state)` call it executes. State is worker-private (no `Sync`
/// bound, never crosses threads), so grow-only scratch warms once per
/// worker and is reused for every further index that worker claims.
pub fn par_try_map_with<T, S, I, F>(n: usize, workers: usize, init: I, f: F) -> Result<Vec<T>>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> Result<T> + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if workers == 1 || n <= 1 {
        let _worker_span = crate::telemetry::span("store.worker");
        let mut state = init();
        return (0..n).map(|i| f(i, &mut state)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let _worker_span = crate::telemetry::span("store.worker");
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i, &mut state);
                    *lock(&slots[i]) = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, m)| {
            let slot = m.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner());
            match slot {
                Some(r) => r,
                // Unreachable by construction (every index is claimed by
                // exactly one worker), but a library path must not panic.
                None => bail!("worker pool bug: index {i} never produced a result"),
            }
        })
        .collect()
}

/// Producer-side gate of the ordered sink: `written` is the next index the
/// sink expects, `abort` wakes producers blocked on a full window when the
/// consumer bails out early.
struct WindowGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    written: usize,
    abort: bool,
}

/// Apply `f` to every index in `0..n` on up to `workers` OS threads and
/// feed the results to `sink` **in index order** on the calling thread.
///
/// Backpressure: a worker does not start index `i` until
/// `i < written + window` (where `written` is the number of results the
/// sink has consumed), so at most `window` results are in flight —
/// produced but not yet sunk — at any moment. This is what bounds the
/// streaming store writer's peak payload memory to O(window × chunk).
///
/// Because the sink always observes index order, the byte stream it
/// produces is identical for every worker count (and identical to a
/// sequential run). Errors from `f` propagate at their index position
/// (first error by index wins, as in [`par_try_map`]); a sink error aborts
/// the remaining work.
pub fn par_try_map_ordered_sink<T, F, S>(
    n: usize,
    workers: usize,
    window: usize,
    f: F,
    sink: S,
) -> Result<()>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
    S: FnMut(usize, T) -> Result<()>,
{
    par_try_map_ordered_sink_with(n, workers, window, || (), |i, _: &mut ()| f(i), sink)
}

/// [`par_try_map_ordered_sink`] with per-worker state (see
/// [`par_try_map_with`]): each producer thread builds one `S` with `init`
/// and reuses it for every index it claims, while the sink still observes
/// strict index order — the combination behind the streaming store
/// writer's per-worker correction scratch.
pub fn par_try_map_ordered_sink_with<T, S, I, F, Snk>(
    n: usize,
    workers: usize,
    window: usize,
    init: I,
    f: F,
    mut sink: Snk,
) -> Result<()>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> Result<T> + Sync,
    Snk: FnMut(usize, T) -> Result<()>,
{
    let workers = workers.clamp(1, n.max(1));
    let window = window.max(workers);
    if workers == 1 || n <= 1 {
        let _worker_span = crate::telemetry::span("store.worker");
        let mut state = init();
        for i in 0..n {
            sink(i, f(i, &mut state)?)?;
        }
        return Ok(());
    }
    let next = AtomicUsize::new(0);
    let gate = WindowGate {
        state: Mutex::new(GateState {
            written: 0,
            abort: false,
        }),
        cv: Condvar::new(),
    };
    let (tx, rx) = std::sync::mpsc::sync_channel::<(usize, Result<T>)>(window);
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..workers {
            let tx = tx.clone();
            let (next, gate, f, init) = (&next, &gate, &f, &init);
            scope.spawn(move || {
                let _worker_span = crate::telemetry::span("store.worker");
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // Wait for index i to enter the write window.
                    {
                        let mut st = lock(&gate.state);
                        while !st.abort && i >= st.written + window {
                            st = gate
                                .cv
                                .wait(st)
                                .unwrap_or_else(|poisoned| poisoned.into_inner());
                        }
                        if st.abort {
                            break;
                        }
                    }
                    if tx.send((i, f(i, &mut state))).is_err() {
                        break; // consumer hung up (early error)
                    }
                }
            });
        }
        drop(tx);

        // Single consumer on this thread: reorder to index order. The
        // reorder buffer is bounded by the window (no worker may run ahead
        // of `written + window`). On any failure, raise `abort` so workers
        // blocked on the gate wake up; dropping `rx` on return unblocks
        // workers stalled on a full channel.
        let abort = |gate: &WindowGate| {
            let mut st = lock(&gate.state);
            st.abort = true;
            gate.cv.notify_all();
        };
        let mut pending: BTreeMap<usize, Result<T>> = BTreeMap::new();
        let mut expect = 0usize;
        for (i, r) in rx {
            pending.insert(i, r);
            while let Some(r) = pending.remove(&expect) {
                let value = match r {
                    Ok(v) => v,
                    Err(e) => {
                        abort(&gate);
                        return Err(e);
                    }
                };
                if let Err(e) = sink(expect, value) {
                    abort(&gate);
                    return Err(e);
                }
                expect += 1;
                let mut st = lock(&gate.state);
                st.written = expect;
                gate.cv.notify_all();
            }
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::bail;

    #[test]
    fn preserves_order_across_worker_counts() {
        for workers in [1usize, 2, 4, 9] {
            let out = par_try_map(17, workers, |i| Ok(i * i)).unwrap();
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<usize> = par_try_map(0, 4, |i| Ok(i)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn first_error_by_index_wins() {
        let err = par_try_map(10, 3, |i| {
            if i >= 4 {
                bail!("task {i} failed");
            }
            Ok(i)
        })
        .unwrap_err();
        assert_eq!(format!("{err}"), "task 4 failed");
    }

    #[test]
    fn ordered_sink_sees_index_order_for_every_worker_count() {
        for workers in [1usize, 2, 4, 9] {
            for window in [1usize, 2, 5] {
                let mut seen = Vec::new();
                par_try_map_ordered_sink(
                    17,
                    workers,
                    window,
                    |i| Ok(i * i),
                    |i, v| {
                        seen.push((i, v));
                        Ok(())
                    },
                )
                .unwrap();
                let expect: Vec<(usize, usize)> = (0..17).map(|i| (i, i * i)).collect();
                assert_eq!(seen, expect, "workers={workers} window={window}");
            }
        }
    }

    #[test]
    fn ordered_sink_handles_empty_input() {
        let mut calls = 0usize;
        par_try_map_ordered_sink(0, 4, 2, |i| Ok(i), |_, _: usize| {
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls, 0);
    }

    #[test]
    fn ordered_sink_propagates_first_error_by_index() {
        for workers in [1usize, 3] {
            let mut sunk = Vec::new();
            let err = par_try_map_ordered_sink(
                10,
                workers,
                3,
                |i| {
                    if i >= 4 {
                        bail!("task {i} failed");
                    }
                    Ok(i)
                },
                |i, v| {
                    sunk.push((i, v));
                    Ok(())
                },
            )
            .unwrap_err();
            assert_eq!(format!("{err}"), "task 4 failed", "workers={workers}");
            assert_eq!(sunk, vec![(0, 0), (1, 1), (2, 2), (3, 3)]);
        }
    }

    #[test]
    fn ordered_sink_aborts_on_sink_error() {
        for workers in [1usize, 4] {
            let err = par_try_map_ordered_sink(
                100,
                workers,
                2,
                |i| Ok(i),
                |i, _| {
                    if i == 5 {
                        bail!("sink full");
                    }
                    Ok(())
                },
            )
            .unwrap_err();
            assert_eq!(format!("{err}"), "sink full", "workers={workers}");
        }
    }

    #[test]
    fn per_worker_state_is_private_and_reused() {
        // Each worker's state counts how many indices it handled; the sum
        // of all per-state counts must equal n (every index touched one
        // state exactly once — states are never shared across threads).
        let total_handled = AtomicUsize::new(0);
        for workers in [1usize, 3] {
            total_handled.store(0, Ordering::SeqCst);
            let out = par_try_map_with(
                23,
                workers,
                || 0usize,
                |i, count| {
                    *count += 1;
                    // Report the running per-state count so the final sum
                    // over "last seen per state" equals n.
                    total_handled.fetch_add(1, Ordering::SeqCst);
                    Ok((i, *count))
                },
            )
            .unwrap();
            assert_eq!(total_handled.load(Ordering::SeqCst), 23);
            assert_eq!(out.len(), 23);
            // Indices arrive in order and every state was reused at least
            // once when there are fewer workers than items.
            for (j, (i, count)) in out.iter().enumerate() {
                assert_eq!(*i, j);
                assert!(*count >= 1);
            }
            let max_count = out.iter().map(|(_, c)| *c).max().unwrap();
            assert!(
                max_count >= 23 / workers.max(1) / 2,
                "workers={workers}: states not reused (max count {max_count})"
            );
        }

        // Ordered-sink variant: same invariant, sink still in order.
        let mut seen = Vec::new();
        par_try_map_ordered_sink_with(
            17,
            4,
            3,
            || 0usize,
            |i, count| {
                *count += 1;
                Ok(i)
            },
            |i, v| {
                seen.push((i, v));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen, (0..17).map(|i| (i, i)).collect::<Vec<_>>());
    }

    #[test]
    fn ordered_sink_window_bounds_lead_over_writer() {
        // With window w, no producer may start index i before i - w items
        // have been sunk: the max "lead" observed inside f is < w + sunk.
        let written = AtomicUsize::new(0);
        let max_lead = AtomicUsize::new(0);
        let window = 3usize;
        par_try_map_ordered_sink(
            40,
            4,
            window,
            |i| {
                let w = written.load(Ordering::SeqCst);
                let lead = i.saturating_sub(w);
                max_lead.fetch_max(lead, Ordering::SeqCst);
                Ok(i)
            },
            |_, _| {
                written.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
        )
        .unwrap();
        let observed = max_lead.load(Ordering::SeqCst);
        assert!(
            observed <= window + 1,
            "producer ran {observed} ahead of the sink (window {window})"
        );
    }
}
