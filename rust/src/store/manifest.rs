//! Versioned binary manifest of a `.ffcz` chunked store.
//!
//! The manifest is self-describing: array shape and source precision, a
//! **codec chain table** ([`CodecChainSpec`] entries), and a per-chunk
//! table of byte ranges, chain indices, CRC-32 payload checksums, and
//! dual-domain verification stats. It is serialized with the crate's
//! [`varint`] primitives; the per-chunk `spatial_ok` / `frequency_ok` bits
//! are bit-packed with [`crate::encoding::pack_flags`].
//!
//! ## Container layout (`.ffcz`)
//!
//! ```text
//! offset 0          "FFCZSTR1"                 8-byte head magic
//! offset 8          chunk payload 0 … k-1      concatenated codec output
//! manifest_offset   manifest bytes             (this module)
//! end - 24          manifest_offset  u64 LE ┐
//! end - 16          manifest_len     u64 LE │  24-byte footer (trailer)
//! end - 8           "FFCZEND1"               ┘
//! ```
//!
//! Readers locate the manifest through the trailer, which is why the
//! streaming writer ([`super::writer::StoreStreamWriter`]) can spill chunk
//! payloads to the file as they are encoded and append manifest + trailer
//! last: a write interrupted at any earlier point leaves no trailer, and
//! opening such a file fails with a precise "truncated or
//! partially-written" error. The normative, third-party-implementable
//! byte-level specification of this container lives in `docs/FORMAT.md` at
//! the repository root.
//!
//! ## Manifest layout (version 2)
//!
//! ```text
//! version            varint (= 2)
//! precision          u8 (0 = single, 1 = double)
//! ndim               varint, then ndim × shape varints
//!                    then ndim × chunk-shape varints
//! chain count        varint (≥ 1)
//! per chain          varint byte length · CodecChainSpec::to_bytes
//! chunk count        varint (must equal the grid's chunk count)
//! table flags        u8 (bit 0: per-chunk CRC-32 present)
//! spatial_ok bits    ceil(count / 8) bytes, MSB-first
//! frequency_ok bits  ceil(count / 8) bytes, MSB-first
//! per chunk          chain index varint · offset varint · length varint ·
//!                    [crc32 u32 LE, if table bit 0] ·
//!                    max_spatial_ratio f64 LE · max_frequency_ratio f64 LE ·
//!                    pocs_iterations varint
//! ```
//!
//! ## Version 1 compatibility
//!
//! Version 1 manifests (single store-wide legacy `CodecSpec`, no chunk
//! checksums) are still parsed: the legacy codec spec is lifted onto an
//! equivalent [`CodecChainSpec`] via
//! [`CodecChainSpec::from_legacy_v1_bytes`], every chunk references chain
//! 0, and [`ChunkEntry::crc32`] is `None` (nothing to verify). Writers
//! always emit version 2.

use anyhow::{bail, Result};

use crate::codec::{ChunkStats, CodecChainSpec};
use crate::data::Precision;
use crate::encoding::{fixed, pack_flags, unpack_flags, varint};

use super::grid::ChunkGrid;

/// Head magic of a `.ffcz` store file.
pub const STORE_MAGIC: &[u8; 8] = b"FFCZSTR1";
/// Trailing magic of the 24-byte footer.
pub const FOOTER_MAGIC: &[u8; 8] = b"FFCZEND1";
/// Head magic of the sidecar recovery journal the streaming file writer
/// keeps next to `<path>.tmp` (see `docs/FORMAT.md` § commit and
/// recovery semantics). The journal is out-of-band recovery state, never
/// part of a committed archive.
pub const JOURNAL_MAGIC: &[u8; 8] = b"FFCZJRN1";
/// Footer size in bytes.
pub const FOOTER_LEN: usize = 24;
/// Manifest version written by this crate.
pub const MANIFEST_VERSION: u64 = 2;
/// Oldest manifest version still readable.
pub const MIN_MANIFEST_VERSION: u64 = 1;

/// Table-flags bit: every chunk entry carries a CRC-32.
const TABLE_FLAG_CRC32: u8 = 0b0000_0001;

/// Byte range, codec chain, checksum, and stats of one chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkEntry {
    /// Payload offset from the start of the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub length: u64,
    /// Index into [`Manifest::chains`].
    pub chain: usize,
    /// CRC-32 (IEEE) of the encoded payload; `None` for manifest v1
    /// archives, which predate chunk checksums.
    pub crc32: Option<u32>,
    pub stats: ChunkStats,
}

/// The store manifest: everything needed to decode any chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub shape: Vec<usize>,
    pub precision: Precision,
    pub chunk_shape: Vec<usize>,
    /// Codec chain table; chunk entries index into it. Chain 0 is the
    /// store default.
    pub chains: Vec<CodecChainSpec>,
    /// One entry per chunk, in row-major grid order.
    pub chunks: Vec<ChunkEntry>,
}

impl Manifest {
    /// The chunk grid implied by the shapes.
    pub fn grid(&self) -> Result<ChunkGrid> {
        let grid = ChunkGrid::new(&self.shape, &self.chunk_shape)?;
        if grid.chunk_count() != self.chunks.len() {
            bail!(
                "manifest has {} chunk entries, grid implies {}",
                self.chunks.len(),
                grid.chunk_count()
            );
        }
        Ok(grid)
    }

    /// The chain spec governing chunk `index`.
    pub fn chain_of(&self, index: usize) -> &CodecChainSpec {
        &self.chains[self.chunks[index].chain]
    }

    /// Do all chunks satisfy both recorded bounds?
    pub fn all_chunks_ok(&self) -> bool {
        self.chunks
            .iter()
            .all(|c| c.stats.spatial_ok && c.stats.frequency_ok)
    }

    /// Total chunk payload bytes.
    pub fn payload_bytes(&self) -> u64 {
        self.chunks.iter().map(|c| c.length).sum()
    }

    /// Serialize as manifest version 2. Chunk CRCs are emitted only when
    /// every entry carries one (a v1-loaded manifest round-trips its
    /// checksum-less state instead of inventing checksums).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::write(&mut out, MANIFEST_VERSION);
        out.push(match self.precision {
            Precision::Single => 0u8,
            Precision::Double => 1u8,
        });
        varint::write(&mut out, self.shape.len() as u64);
        for &d in &self.shape {
            varint::write(&mut out, d as u64);
        }
        for &d in &self.chunk_shape {
            varint::write(&mut out, d as u64);
        }
        varint::write(&mut out, self.chains.len() as u64);
        for chain in &self.chains {
            let bytes = chain.to_bytes();
            varint::write(&mut out, bytes.len() as u64);
            out.extend_from_slice(&bytes);
        }
        varint::write(&mut out, self.chunks.len() as u64);
        let with_crc = self.chunks.iter().all(|c| c.crc32.is_some());
        out.push(if with_crc { TABLE_FLAG_CRC32 } else { 0u8 });
        let s_ok: Vec<bool> = self.chunks.iter().map(|c| c.stats.spatial_ok).collect();
        let f_ok: Vec<bool> = self.chunks.iter().map(|c| c.stats.frequency_ok).collect();
        out.extend_from_slice(&pack_flags(&s_ok));
        out.extend_from_slice(&pack_flags(&f_ok));
        for c in &self.chunks {
            varint::write(&mut out, c.chain as u64);
            varint::write(&mut out, c.offset);
            varint::write(&mut out, c.length);
            if let (true, Some(crc)) = (with_crc, c.crc32) {
                out.extend_from_slice(&crc.to_le_bytes());
            }
            out.extend_from_slice(&c.stats.max_spatial_ratio.to_le_bytes());
            out.extend_from_slice(&c.stats.max_frequency_ratio.to_le_bytes());
            varint::write(&mut out, c.stats.pocs_iterations as u64);
        }
        out
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let version = varint::read(buf, &mut pos)?;
        if !(MIN_MANIFEST_VERSION..=MANIFEST_VERSION).contains(&version) {
            bail!(
                "unsupported manifest version {version} (this build reads \
                 {MIN_MANIFEST_VERSION}..={MANIFEST_VERSION})"
            );
        }
        let precision = match buf.get(pos) {
            Some(0) => Precision::Single,
            Some(1) => Precision::Double,
            Some(x) => bail!("bad precision tag {x}"),
            None => bail!("truncated manifest"),
        };
        pos += 1;
        let ndim = varint::read(buf, &mut pos)? as usize;
        if ndim == 0 || ndim > 8 {
            bail!("unreasonable ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(varint::read(buf, &mut pos)? as usize);
        }
        let mut chunk_shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            chunk_shape.push(varint::read(buf, &mut pos)? as usize);
        }
        let (chains, with_crc) = if version == 1 {
            // v1 shim: one store-wide legacy codec spec, no checksums.
            (
                vec![CodecChainSpec::from_legacy_v1_bytes(buf, &mut pos)?],
                false,
            )
        } else {
            let n_chains = varint::read(buf, &mut pos)? as usize;
            // A serialized chain occupies ≥ 4 bytes; bound allocations by
            // the (untrusted) buffer.
            if n_chains == 0 || n_chains > buf.len() / 4 + 1 {
                bail!("implausible chain count {n_chains}");
            }
            let mut chains = Vec::with_capacity(n_chains);
            for _ in 0..n_chains {
                let len = varint::read(buf, &mut pos)? as usize;
                // `len` is untrusted and may be near u64::MAX: compare
                // against the remaining bytes, never compute `pos + len`.
                if len > buf.len() - pos {
                    bail!("truncated codec chain spec");
                }
                let mut spec_pos = 0usize;
                let spec = CodecChainSpec::from_bytes(&buf[pos..pos + len], &mut spec_pos)?;
                if spec_pos != len {
                    bail!(
                        "{} trailing bytes after codec chain spec",
                        len - spec_pos
                    );
                }
                pos += len;
                chains.push(spec);
            }
            (chains, true)
        };
        let count = varint::read(buf, &mut pos)? as usize;
        let with_crc = if version == 1 {
            with_crc
        } else {
            let flags = *buf
                .get(pos)
                .ok_or_else(|| anyhow::anyhow!("truncated manifest table flags"))?;
            pos += 1;
            if flags & !TABLE_FLAG_CRC32 != 0 {
                bail!("unknown manifest table flags {flags:#04x}");
            }
            flags & TABLE_FLAG_CRC32 != 0
        };
        // All of shape/count are untrusted: overflow must reject, never
        // panic, and allocations must be bounded by the buffer itself.
        let mut n = 1usize;
        for &d in &shape {
            n = n
                .checked_mul(d)
                .ok_or_else(|| anyhow::anyhow!("shape {shape:?} overflows"))?;
        }
        // A manifest cannot plausibly index more chunks than there are
        // samples, and each entry occupies ≥ 19 serialized bytes.
        if count == 0 || count > n.max(1) || count > buf.len() / 19 + 1 {
            bail!("implausible chunk count {count} for shape {shape:?}");
        }
        let flag_bytes = count.div_ceil(8);
        if pos + 2 * flag_bytes > buf.len() {
            bail!("truncated manifest flags");
        }
        let s_ok = unpack_flags(&buf[pos..pos + flag_bytes], count);
        pos += flag_bytes;
        let f_ok = unpack_flags(&buf[pos..pos + flag_bytes], count);
        pos += flag_bytes;
        let mut chunks = Vec::with_capacity(count);
        for i in 0..count {
            let chain = if version == 1 {
                0usize
            } else {
                varint::read(buf, &mut pos)? as usize
            };
            if chain >= chains.len() {
                bail!(
                    "chunk {i} references chain {chain}, but the table has {} entries",
                    chains.len()
                );
            }
            let offset = varint::read(buf, &mut pos)?;
            let length = varint::read(buf, &mut pos)?;
            let crc32 = if with_crc {
                Some(fixed::read_u32_le(buf, &mut pos, "chunk CRC")?)
            } else {
                None
            };
            let max_spatial_ratio = crate::codec::spec::read_f64(buf, &mut pos)?;
            let max_frequency_ratio = crate::codec::spec::read_f64(buf, &mut pos)?;
            let pocs_iterations = varint::read(buf, &mut pos)? as u32;
            chunks.push(ChunkEntry {
                offset,
                length,
                chain,
                crc32,
                stats: ChunkStats {
                    spatial_ok: s_ok[i],
                    frequency_ok: f_ok[i],
                    max_spatial_ratio,
                    max_frequency_ratio,
                    pocs_iterations,
                },
            });
        }
        if pos != buf.len() {
            bail!("{} trailing bytes after manifest", buf.len() - pos);
        }
        let manifest = Manifest {
            shape,
            precision,
            chunk_shape,
            chains,
            chunks,
        };
        manifest.grid()?; // validates shapes and the entry count
        Ok(manifest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::correction::FfczConfig;

    fn sample() -> Manifest {
        Manifest {
            shape: vec![10, 6],
            precision: Precision::Double,
            chunk_shape: vec![4, 4],
            chains: vec![
                CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)),
                CodecChainSpec::lossless(),
            ],
            chunks: (0..6)
                .map(|i| ChunkEntry {
                    offset: 8 + 100 * i,
                    length: 100,
                    chain: (i % 2) as usize,
                    crc32: Some(0xDEAD_0000 + i as u32),
                    stats: ChunkStats {
                        spatial_ok: true,
                        frequency_ok: i != 3,
                        max_spatial_ratio: 0.5,
                        max_frequency_ratio: 0.25 * i as f64,
                        pocs_iterations: i as u32,
                    },
                })
                .collect(),
        }
    }

    #[test]
    fn roundtrip_v2() {
        let m = sample();
        let bytes = m.to_bytes();
        let back = Manifest::from_bytes(&bytes).unwrap();
        assert_eq!(back, m);
        assert!(!back.all_chunks_ok()); // chunk 3 has frequency_ok = false
        assert_eq!(back.payload_bytes(), 600);
        assert_eq!(back.chain_of(1), &CodecChainSpec::lossless());
    }

    #[test]
    fn roundtrip_without_checksums() {
        // A v1-loaded manifest (crc32 = None) re-serializes faithfully
        // instead of inventing checksums.
        let mut m = sample();
        for c in &mut m.chunks {
            c.crc32 = None;
        }
        let back = Manifest::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(back, m);
    }

    /// Hand-built manifest v1 bytes (the frozen legacy layout: single
    /// store-wide codec spec, no chain table, no checksums).
    fn v1_bytes() -> Vec<u8> {
        let mut out = Vec::new();
        varint::write(&mut out, 1); // version
        out.push(1u8); // double precision
        varint::write(&mut out, 2); // ndim
        varint::write(&mut out, 10);
        varint::write(&mut out, 6);
        varint::write(&mut out, 4); // chunk shape
        varint::write(&mut out, 4);
        // Legacy CodecSpec::Ffcz { "sz-like", 1e-3, Some(1e-3) }.
        out.push(1u8);
        varint::write(&mut out, 7);
        out.extend_from_slice(b"sz-like");
        out.extend_from_slice(&1e-3f64.to_le_bytes());
        out.push(1u8);
        out.extend_from_slice(&1e-3f64.to_le_bytes());
        varint::write(&mut out, 6); // chunk count
        out.extend_from_slice(&pack_flags(&[true; 6]));
        out.extend_from_slice(&pack_flags(&[true; 6]));
        for i in 0..6u64 {
            varint::write(&mut out, 8 + 100 * i); // offset
            varint::write(&mut out, 100); // length
            out.extend_from_slice(&0.5f64.to_le_bytes());
            out.extend_from_slice(&0.25f64.to_le_bytes());
            varint::write(&mut out, i); // pocs iterations
        }
        out
    }

    #[test]
    fn v1_manifest_parses_through_the_shim() {
        let m = Manifest::from_bytes(&v1_bytes()).unwrap();
        assert_eq!(m.shape, vec![10, 6]);
        assert_eq!(m.chains.len(), 1);
        assert_eq!(
            m.chains[0],
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3))
        );
        assert!(m.chunks.iter().all(|c| c.chain == 0 && c.crc32.is_none()));
        assert!(m.all_chunks_ok());
        // And re-serializes as v2 without inventing checksums.
        let back = Manifest::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_truncation_at_every_prefix() {
        for bytes in [sample().to_bytes(), v1_bytes()] {
            for cut in 0..bytes.len() {
                assert!(
                    Manifest::from_bytes(&bytes[..cut]).is_err(),
                    "prefix of {cut} bytes unexpectedly parsed"
                );
            }
        }
    }

    #[test]
    fn rejects_trailing_garbage_and_bad_version() {
        let mut bytes = sample().to_bytes();
        bytes.push(0);
        assert!(Manifest::from_bytes(&bytes).is_err());
        let mut bad = Vec::new();
        varint::write(&mut bad, 99);
        assert!(Manifest::from_bytes(&bad).is_err());
    }

    #[test]
    fn rejects_entry_count_mismatch_and_bad_chain_index() {
        let mut m = sample();
        m.chunks.pop();
        assert!(Manifest::from_bytes(&m.to_bytes()).is_err());
        let mut m = sample();
        m.chunks[0].chain = 7;
        assert!(Manifest::from_bytes(&m.to_bytes()).is_err());
    }
}
