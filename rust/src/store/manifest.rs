//! Versioned binary manifest of a `.ffcz` chunked store.
//!
//! The manifest is self-describing: array shape and source precision, the
//! chunk grid, the codec chain, and a per-chunk table of byte ranges plus
//! dual-domain verification stats. It is serialized with the crate's
//! [`varint`] primitives; the per-chunk `spatial_ok` / `frequency_ok` bits
//! are bit-packed with [`crate::encoding::pack_flags`].
//!
//! ## Container layout (`.ffcz`)
//!
//! ```text
//! offset 0          "FFCZSTR1"                 8-byte head magic
//! offset 8          chunk payload 0 … k-1      concatenated codec output
//! manifest_offset   manifest bytes             (this module)
//! end - 24          manifest_offset  u64 LE ┐
//! end - 16          manifest_len     u64 LE │  24-byte footer
//! end - 8           "FFCZEND1"               ┘
//! ```
//!
//! Readers locate the manifest through the footer, so chunk payloads can be
//! streamed to the file as they are encoded and the manifest appended last.
//!
//! ## Manifest layout (version 1)
//!
//! ```text
//! version            varint (= 1)
//! precision          u8 (0 = single, 1 = double)
//! ndim               varint, then ndim × shape varints
//!                    then ndim × chunk-shape varints
//! codec spec         see CodecSpec::to_bytes
//! chunk count        varint (must equal the grid's chunk count)
//! spatial_ok bits    ceil(count / 8) bytes, MSB-first
//! frequency_ok bits  ceil(count / 8) bytes, MSB-first
//! per chunk          offset varint · length varint ·
//!                    max_spatial_ratio f64 LE · max_frequency_ratio f64 LE ·
//!                    pocs_iterations varint
//! ```

use anyhow::{bail, Result};

use crate::data::Precision;
use crate::encoding::{pack_flags, unpack_flags, varint};

use super::codec::{read_f64, CodecSpec};
use super::grid::ChunkGrid;

/// Head magic of a `.ffcz` store file.
pub const STORE_MAGIC: &[u8; 8] = b"FFCZSTR1";
/// Trailing magic of the 24-byte footer.
pub const FOOTER_MAGIC: &[u8; 8] = b"FFCZEND1";
/// Footer size in bytes.
pub const FOOTER_LEN: usize = 24;
/// Current manifest version.
pub const MANIFEST_VERSION: u64 = 1;

/// Dual-domain verification outcome of one chunk, recorded at encode time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkStats {
    pub spatial_ok: bool,
    pub frequency_ok: bool,
    /// max |ε_n| / E_n over the chunk (≤ 1 is in-bound).
    pub max_spatial_ratio: f64,
    /// max ‖δ_k‖∞ / Δ_k over the chunk (≤ 1 is in-bound).
    pub max_frequency_ratio: f64,
    /// POCS iterations spent correcting this chunk.
    pub pocs_iterations: u32,
}

impl ChunkStats {
    /// Stats of a bit-exact (lossless) chunk.
    pub fn exact() -> Self {
        Self {
            spatial_ok: true,
            frequency_ok: true,
            max_spatial_ratio: 0.0,
            max_frequency_ratio: 0.0,
            pocs_iterations: 0,
        }
    }
}

/// Byte range and stats of one chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkEntry {
    /// Payload offset from the start of the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub length: u64,
    pub stats: ChunkStats,
}

/// The store manifest: everything needed to decode any chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub shape: Vec<usize>,
    pub precision: Precision,
    pub chunk_shape: Vec<usize>,
    pub codec: CodecSpec,
    /// One entry per chunk, in row-major grid order.
    pub chunks: Vec<ChunkEntry>,
}

impl Manifest {
    /// The chunk grid implied by the shapes.
    pub fn grid(&self) -> Result<ChunkGrid> {
        let grid = ChunkGrid::new(&self.shape, &self.chunk_shape)?;
        if grid.chunk_count() != self.chunks.len() {
            bail!(
                "manifest has {} chunk entries, grid implies {}",
                self.chunks.len(),
                grid.chunk_count()
            );
        }
        Ok(grid)
    }

    /// Do all chunks satisfy both recorded bounds?
    pub fn all_chunks_ok(&self) -> bool {
        self.chunks
            .iter()
            .all(|c| c.stats.spatial_ok && c.stats.frequency_ok)
    }

    /// Total chunk payload bytes.
    pub fn payload_bytes(&self) -> u64 {
        self.chunks.iter().map(|c| c.length).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::write(&mut out, MANIFEST_VERSION);
        out.push(match self.precision {
            Precision::Single => 0u8,
            Precision::Double => 1u8,
        });
        varint::write(&mut out, self.shape.len() as u64);
        for &d in &self.shape {
            varint::write(&mut out, d as u64);
        }
        for &d in &self.chunk_shape {
            varint::write(&mut out, d as u64);
        }
        out.extend_from_slice(&self.codec.to_bytes());
        varint::write(&mut out, self.chunks.len() as u64);
        let s_ok: Vec<bool> = self.chunks.iter().map(|c| c.stats.spatial_ok).collect();
        let f_ok: Vec<bool> = self.chunks.iter().map(|c| c.stats.frequency_ok).collect();
        out.extend_from_slice(&pack_flags(&s_ok));
        out.extend_from_slice(&pack_flags(&f_ok));
        for c in &self.chunks {
            varint::write(&mut out, c.offset);
            varint::write(&mut out, c.length);
            out.extend_from_slice(&c.stats.max_spatial_ratio.to_le_bytes());
            out.extend_from_slice(&c.stats.max_frequency_ratio.to_le_bytes());
            varint::write(&mut out, c.stats.pocs_iterations as u64);
        }
        out
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let version = varint::read(buf, &mut pos)?;
        if version != MANIFEST_VERSION {
            bail!("unsupported manifest version {version}");
        }
        let precision = match buf.get(pos) {
            Some(0) => Precision::Single,
            Some(1) => Precision::Double,
            Some(x) => bail!("bad precision tag {x}"),
            None => bail!("truncated manifest"),
        };
        pos += 1;
        let ndim = varint::read(buf, &mut pos)? as usize;
        if ndim == 0 || ndim > 8 {
            bail!("unreasonable ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(varint::read(buf, &mut pos)? as usize);
        }
        let mut chunk_shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            chunk_shape.push(varint::read(buf, &mut pos)? as usize);
        }
        let codec = CodecSpec::from_bytes(buf, &mut pos)?;
        let count = varint::read(buf, &mut pos)? as usize;
        // All of shape/count are untrusted: overflow must reject, never
        // panic, and allocations must be bounded by the buffer itself.
        let mut n = 1usize;
        for &d in &shape {
            n = n
                .checked_mul(d)
                .ok_or_else(|| anyhow::anyhow!("shape {shape:?} overflows"))?;
        }
        // A manifest cannot plausibly index more chunks than there are
        // samples, and each entry occupies ≥ 18 serialized bytes.
        if count == 0 || count > n.max(1) || count > buf.len() / 18 + 1 {
            bail!("implausible chunk count {count} for shape {shape:?}");
        }
        let flag_bytes = count.div_ceil(8);
        if pos + 2 * flag_bytes > buf.len() {
            bail!("truncated manifest flags");
        }
        let s_ok = unpack_flags(&buf[pos..pos + flag_bytes], count);
        pos += flag_bytes;
        let f_ok = unpack_flags(&buf[pos..pos + flag_bytes], count);
        pos += flag_bytes;
        let mut chunks = Vec::with_capacity(count);
        for i in 0..count {
            let offset = varint::read(buf, &mut pos)?;
            let length = varint::read(buf, &mut pos)?;
            let max_spatial_ratio = read_f64(buf, &mut pos)?;
            let max_frequency_ratio = read_f64(buf, &mut pos)?;
            let pocs_iterations = varint::read(buf, &mut pos)? as u32;
            chunks.push(ChunkEntry {
                offset,
                length,
                stats: ChunkStats {
                    spatial_ok: s_ok[i],
                    frequency_ok: f_ok[i],
                    max_spatial_ratio,
                    max_frequency_ratio,
                    pocs_iterations,
                },
            });
        }
        if pos != buf.len() {
            bail!("{} trailing bytes after manifest", buf.len() - pos);
        }
        let manifest = Manifest {
            shape,
            precision,
            chunk_shape,
            codec,
            chunks,
        };
        manifest.grid()?; // validates shapes and the entry count
        Ok(manifest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            shape: vec![10, 6],
            precision: Precision::Double,
            chunk_shape: vec![4, 4],
            codec: CodecSpec::Ffcz {
                base: "sz-like".into(),
                spatial_rel: 1e-3,
                frequency_rel: Some(1e-3),
            },
            chunks: (0..6)
                .map(|i| ChunkEntry {
                    offset: 8 + 100 * i,
                    length: 100,
                    stats: ChunkStats {
                        spatial_ok: true,
                        frequency_ok: i != 3,
                        max_spatial_ratio: 0.5,
                        max_frequency_ratio: 0.25 * i as f64,
                        pocs_iterations: i as u32,
                    },
                })
                .collect(),
        }
    }

    #[test]
    fn roundtrip() {
        let m = sample();
        let bytes = m.to_bytes();
        let back = Manifest::from_bytes(&bytes).unwrap();
        assert_eq!(back, m);
        assert!(!back.all_chunks_ok()); // chunk 3 has frequency_ok = false
        assert_eq!(back.payload_bytes(), 600);
    }

    #[test]
    fn rejects_truncation_at_every_prefix() {
        let bytes = sample().to_bytes();
        for cut in 0..bytes.len() {
            assert!(
                Manifest::from_bytes(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes unexpectedly parsed"
            );
        }
    }

    #[test]
    fn rejects_trailing_garbage_and_bad_version() {
        let mut bytes = sample().to_bytes();
        bytes.push(0);
        assert!(Manifest::from_bytes(&bytes).is_err());
        let mut bad = Vec::new();
        varint::write(&mut bad, 99);
        assert!(Manifest::from_bytes(&bad).is_err());
    }

    #[test]
    fn rejects_entry_count_mismatch() {
        let mut m = sample();
        m.chunks.pop();
        assert!(Manifest::from_bytes(&m.to_bytes()).is_err());
    }
}
