//! Storage-backend abstraction for reader I/O.
//!
//! [`crate::store::reader::Store`] historically read straight from a local
//! `File`. Production archives live behind many kinds of byte sources —
//! local files, memory-resident containers, object stores, test harnesses —
//! so all reader I/O now goes through [`ReadableStorage`]: a ranged
//! `read_at`/`size` API (mirroring the `zarrs_storage` readable-storage
//! split). Three backends ship here:
//!
//! * [`FileStorage`] — a local file, positioned reads (`pread` on unix, so
//!   concurrent readers never serialize on a seek lock);
//! * [`MemStorage`] — a container held fully in memory;
//! * [`FaultInjector`] — a deterministic, seeded fault-injecting wrapper
//!   around any backend (short reads, transient `io::Error`s, hard I/O
//!   failures, byte corruption, injected latency). This is what makes the
//!   storage layer's *failure* behavior testable rather than assumed: the
//!   fault-injection suite in `rust/tests/storage.rs` drives every decode
//!   path through scheduled faults and asserts precise errors, never
//!   panics.
//!
//! Short reads are part of the contract (`read_at` may return fewer bytes
//! than requested); callers that need a full range use [`read_exact_at`],
//! and callers that tolerate *transient* faults (interrupted syscalls,
//! storage-side timeouts) wrap it with [`read_exact_at_retry`] under a
//! [`RetryPolicy`].
//!
//! The **write side** mirrors the same design: [`WritableStorage`] is a
//! positioned `write_at`/`flush`/`sync`/`truncate` API implemented by
//! [`FileStorage`] (via [`FileStorage::create`] / [`FileStorage::open_rw`]),
//! [`MemStorage`], plain `Vec<u8>`, and the same [`FaultInjector`] wrapper
//! (short writes, transient errors, hard failures at an exact op count —
//! ENOSPC/preemption simulation — and latency, sharing one deterministic
//! op counter and RNG stream with the read side). Full-range writes go
//! through [`write_all_at`], and transient write faults heal under
//! [`write_all_at_retry`].

use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::sync::lock;
use crate::util::XorShift;

/// A byte source supporting ranged reads — the reader-side storage
/// abstraction behind [`crate::store::Store`].
///
/// Implementations must be usable from many threads at once (`Send +
/// Sync`); `read_at` takes `&self` so concurrent chunk fetches never
/// serialize in the trait layer.
pub trait ReadableStorage: Send + Sync {
    /// Read up to `buf.len()` bytes starting at absolute `offset` into
    /// `buf`, returning how many bytes were read. A return of `0` with a
    /// non-empty `buf` means end-of-storage. Short reads are allowed; use
    /// [`read_exact_at`] to loop a range to completion.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize>;

    /// Total size of the storage in bytes.
    fn size(&self) -> io::Result<u64>;

    /// Human-readable description for error messages (a path, `<memory>`,
    /// a wrapped backend).
    fn describe(&self) -> String;
}

impl<S: ReadableStorage + ?Sized> ReadableStorage for Arc<S> {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        (**self).read_at(offset, buf)
    }
    fn size(&self) -> io::Result<u64> {
        (**self).size()
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
}

/// Fill `buf` from `storage` starting at `offset`, looping over short
/// reads. Premature end-of-storage surfaces as [`io::ErrorKind::UnexpectedEof`];
/// every other `io::Error` (including transient kinds) is surfaced as-is —
/// retrying is policy, not mechanism, and lives in [`read_exact_at_retry`].
pub fn read_exact_at<S: ReadableStorage + ?Sized>(
    storage: &S,
    offset: u64,
    buf: &mut [u8],
) -> io::Result<()> {
    let mut filled = 0usize;
    while filled < buf.len() {
        let n = storage.read_at(offset + filled as u64, &mut buf[filled..])?;
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!(
                    "unexpected end of storage: wanted {} bytes at offset {}, got {} ({})",
                    buf.len(),
                    offset,
                    filled,
                    storage.describe()
                ),
            ));
        }
        filled += n;
    }
    Ok(())
}

/// Retry/backoff policy for *transient* storage faults (interrupted
/// syscalls, would-block, storage-side timeouts). Hard faults — permission
/// errors, corruption, premature EOF — are never retried.
///
/// The default shape is **linear** backoff (sleep before retry `k` is
/// `backoff × k`), which every existing caller keeps. Remote backends
/// layer on three opt-ins:
///
/// * [`RetryPolicy::exponential`] — sleep before retry `k` becomes
///   `backoff × 2^(k−1)` (capped, so the schedule cannot overflow);
/// * [`RetryPolicy::with_jitter`] — "equal jitter" drawn from a seeded
///   [`XorShift`] stream: half of each base delay is guaranteed, the
///   other half is a deterministic draw, so a fixed seed replays the
///   exact same sleep schedule on every run (the property the remote
///   chaos tests pin);
/// * [`RetryPolicy::with_deadline`] — an **absolute budget across all
///   attempts** (not per attempt): once sleeping again would cross the
///   budget, the last error surfaces instead.
///
/// The schedule itself is computed by [`RetrySchedule`], which every
/// retry loop in the crate shares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (1 = no retry).
    pub max_attempts: u32,
    /// Base delay: sleep before retry `k` is `backoff × k` (linear,
    /// default) or `backoff × 2^(k−1)` (exponential).
    pub backoff: Duration,
    /// Exponential instead of linear backoff growth.
    pub exponential: bool,
    /// Seed for deterministic "equal jitter" on each delay; `None`
    /// (default) applies the base delay exactly.
    pub jitter_seed: Option<u64>,
    /// Total time budget across *all* attempts and sleeps. `None`
    /// (default) means only `max_attempts` bounds the loop.
    pub deadline: Option<Duration>,
}

impl RetryPolicy {
    /// No retries: every fault surfaces immediately.
    pub fn none() -> Self {
        Self {
            max_attempts: 1,
            backoff: Duration::ZERO,
            exponential: false,
            jitter_seed: None,
            deadline: None,
        }
    }

    /// Retry transient faults up to `max_attempts` total attempts with
    /// linear `backoff` between them.
    pub fn transient(max_attempts: u32, backoff: Duration) -> Self {
        Self {
            max_attempts: max_attempts.max(1),
            backoff,
            exponential: false,
            jitter_seed: None,
            deadline: None,
        }
    }

    /// Switch to exponential backoff growth (`backoff × 2^(k−1)`,
    /// capped at `backoff × 2^16`).
    pub fn exponential(mut self) -> Self {
        self.exponential = true;
        self
    }

    /// Apply deterministic "equal jitter" to every delay, drawn from a
    /// [`XorShift`] stream seeded with `seed`.
    pub fn with_jitter(mut self, seed: u64) -> Self {
        self.jitter_seed = Some(seed);
        self
    }

    /// Bound the total time spent across all attempts (an absolute
    /// budget, not a per-attempt timeout).
    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline = Some(budget);
        self
    }

    /// Is `kind` a transient fault worth retrying?
    pub fn is_transient(kind: io::ErrorKind) -> bool {
        matches!(
            kind,
            io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
        )
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::none()
    }
}

/// Cap on the exponential-backoff doubling exponent (delays stop growing
/// at `backoff × 2^16`).
const MAX_BACKOFF_SHIFT: u32 = 16;

/// One retry loop's live schedule under a [`RetryPolicy`]: tracks the
/// retry count, the seeded jitter stream, and the absolute deadline.
/// Every retry loop in the crate ([`read_exact_at_retry`],
/// [`write_all_at_retry`], the server client's reconnect path, the
/// remote resilience layer) routes its sleeps through one of these, so
/// backoff semantics cannot drift between call sites.
pub struct RetrySchedule {
    policy: RetryPolicy,
    rng: XorShift,
    started: std::time::Instant,
    retries: u32,
}

impl RetrySchedule {
    pub fn new(policy: RetryPolicy) -> Self {
        Self {
            rng: XorShift::new(policy.jitter_seed.unwrap_or(0)),
            started: std::time::Instant::now(),
            retries: 0,
            policy,
        }
    }

    /// Retries taken so far (0 until the first [`Self::backoff_for`]
    /// grants one).
    pub fn retries(&self) -> u32 {
        self.retries
    }

    /// Time elapsed since the schedule was created (the deadline clock).
    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// Has the absolute deadline already passed?
    pub fn deadline_exceeded(&self) -> bool {
        self.policy
            .deadline
            .is_some_and(|budget| self.started.elapsed() >= budget)
    }

    /// The sleep before the next retry, advancing the retry count and
    /// the jitter stream. Pure in everything but the rng state — a fixed
    /// policy and seed produce the exact same sequence every run.
    pub fn next_delay(&mut self) -> Duration {
        self.retries += 1;
        let k = self.retries;
        let base = if self.policy.exponential {
            let mult = 1u32 << (k - 1).min(MAX_BACKOFF_SHIFT);
            self.policy.backoff.checked_mul(mult).unwrap_or(Duration::MAX)
        } else {
            self.policy.backoff.checked_mul(k).unwrap_or(Duration::MAX)
        };
        if self.policy.jitter_seed.is_none() || base.is_zero() {
            return base;
        }
        // Equal jitter: half the base delay is guaranteed, the other
        // half is a seeded deterministic draw.
        let nanos = u64::try_from(base.as_nanos()).unwrap_or(u64::MAX);
        let half = nanos / 2;
        let span = nanos - half;
        Duration::from_nanos(half + self.rng.next_u64() % (span + 1))
    }

    /// Decide the response to a fault of `kind`: `Some(sleep)` grants a
    /// retry after sleeping (attempt budget and absolute deadline
    /// permitting), `None` means the error must surface.
    pub fn backoff_for(&mut self, kind: io::ErrorKind) -> Option<Duration> {
        if !RetryPolicy::is_transient(kind) {
            return None;
        }
        if self.retries + 1 >= self.policy.max_attempts {
            return None;
        }
        let delay = self.next_delay();
        if let Some(budget) = self.policy.deadline {
            if self.started.elapsed() + delay >= budget {
                // The grant is withdrawn: sleeping would cross the
                // budget, so this does not count as a retry.
                self.retries -= 1;
                return None;
            }
        }
        Some(delay)
    }
}

/// [`read_exact_at`] under a [`RetryPolicy`]: transient faults are retried
/// (with the policy's backoff schedule) up to the attempt budget and
/// absolute deadline; the whole range is re-read from `offset` on each
/// attempt. Returns the number of retries performed (0 on a clean first
/// attempt) so callers can account them.
pub fn read_exact_at_retry<S: ReadableStorage + ?Sized>(
    storage: &S,
    offset: u64,
    buf: &mut [u8],
    policy: &RetryPolicy,
) -> io::Result<u32> {
    let mut schedule = RetrySchedule::new(*policy);
    loop {
        match read_exact_at(storage, offset, buf) {
            Ok(()) => return Ok(schedule.retries()),
            Err(e) => match schedule.backoff_for(e.kind()) {
                Some(delay) => {
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                }
                None => return Err(e),
            },
        }
    }
}

/// A byte sink supporting positioned writes — the writer-side storage
/// abstraction behind [`crate::store::StoreStreamWriter`].
///
/// Writers are exclusive (`&mut self`): the store write path is a single
/// sink thread, so unlike [`ReadableStorage`] there is no concurrent-access
/// requirement. Short writes are part of the contract (`write_at` may
/// accept fewer bytes than offered); callers that need the full span use
/// [`write_all_at`], and callers that tolerate transient faults wrap it
/// with [`write_all_at_retry`] under a [`RetryPolicy`].
pub trait WritableStorage: Send {
    /// Write up to `buf.len()` bytes at absolute `offset`, returning how
    /// many bytes were accepted (≥ 1 for a non-empty `buf` unless the
    /// backend errors). Writing past the current end extends the storage;
    /// any gap reads back as zeros (sparse-file semantics).
    fn write_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<usize>;

    /// Push buffered bytes toward the backend (no-op for unbuffered
    /// backends). Does **not** imply durability — that is [`Self::sync`].
    fn flush(&mut self) -> io::Result<()>;

    /// Durably persist everything written so far (`fsync` on files).
    fn sync(&mut self) -> io::Result<()>;

    /// Cut the storage to exactly `len` bytes (used by crash recovery to
    /// drop a torn tail before resuming).
    fn truncate(&mut self, len: u64) -> io::Result<()>;

    /// Human-readable description for error messages.
    fn describe(&self) -> String;
}

impl<W: WritableStorage + ?Sized> WritableStorage for &mut W {
    fn write_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<usize> {
        (**self).write_at(offset, buf)
    }
    fn flush(&mut self) -> io::Result<()> {
        (**self).flush()
    }
    fn sync(&mut self) -> io::Result<()> {
        (**self).sync()
    }
    fn truncate(&mut self, len: u64) -> io::Result<()> {
        (**self).truncate(len)
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
}

/// Write all of `buf` to `storage` at `offset`, looping over short writes.
/// A backend that accepts 0 bytes for a non-empty `buf` surfaces as
/// [`io::ErrorKind::WriteZero`]; every other error is surfaced as-is
/// (retrying is policy, not mechanism — see [`write_all_at_retry`]).
pub fn write_all_at<W: WritableStorage + ?Sized>(
    storage: &mut W,
    offset: u64,
    buf: &[u8],
) -> io::Result<()> {
    let mut done = 0usize;
    while done < buf.len() {
        let n = storage.write_at(offset + done as u64, &buf[done..])?;
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::WriteZero,
                format!(
                    "storage accepted 0 of {} bytes at offset {} ({})",
                    buf.len() - done,
                    offset + done as u64,
                    storage.describe()
                ),
            ));
        }
        done += n;
    }
    Ok(())
}

/// [`write_all_at`] under a [`RetryPolicy`]: transient faults are retried
/// (with the policy's backoff schedule) up to the attempt budget and
/// absolute deadline; the whole span is rewritten from `offset` on each
/// attempt (positioned writes are idempotent, so a partial first attempt
/// is simply overwritten). Returns the number of retries performed so
/// callers can account them.
pub fn write_all_at_retry<W: WritableStorage + ?Sized>(
    storage: &mut W,
    offset: u64,
    buf: &[u8],
    policy: &RetryPolicy,
) -> io::Result<u32> {
    let mut schedule = RetrySchedule::new(*policy);
    loop {
        match write_all_at(storage, offset, buf) {
            Ok(()) => return Ok(schedule.retries()),
            Err(e) => match schedule.backoff_for(e.kind()) {
                Some(delay) => {
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                }
                None => return Err(e),
            },
        }
    }
}

impl WritableStorage for Vec<u8> {
    fn write_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<usize> {
        write_into_vec(self, offset, buf)
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
    fn sync(&mut self) -> io::Result<()> {
        Ok(())
    }
    fn truncate(&mut self, len: u64) -> io::Result<()> {
        let len = usize::try_from(len)
            .map_err(|_| io::Error::other(format!("truncate length {len} overflows usize")))?;
        if len <= self.len() {
            Vec::truncate(self, len);
        } else {
            self.resize(len, 0);
        }
        Ok(())
    }
    fn describe(&self) -> String {
        format!("<vec: {} bytes>", self.len())
    }
}

/// Positioned write into a growable byte vector with sparse-file
/// semantics: a gap between the current end and `offset` zero-fills.
fn write_into_vec(bytes: &mut Vec<u8>, offset: u64, buf: &[u8]) -> io::Result<usize> {
    let offset = usize::try_from(offset)
        .map_err(|_| io::Error::other(format!("write offset {offset} overflows usize")))?;
    let end = offset
        .checked_add(buf.len())
        .ok_or_else(|| io::Error::other("write range overflows usize"))?;
    if end > bytes.len() {
        bytes.resize(end, 0);
    }
    bytes[offset..end].copy_from_slice(buf);
    Ok(buf.len())
}

/// Local-file backend. On unix the reads are positioned (`pread`), so any
/// number of threads can fetch chunks concurrently without a seek lock.
pub struct FileStorage {
    #[cfg(unix)]
    file: std::fs::File,
    #[cfg(not(unix))]
    file: Mutex<std::fs::File>,
    len: u64,
    path: PathBuf,
}

impl FileStorage {
    /// Open `path` read-only and stat its length. Archives are immutable
    /// once written, so the length is cached at open.
    pub fn open(path: &Path) -> io::Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        Ok(Self {
            #[cfg(unix)]
            file,
            #[cfg(not(unix))]
            file: Mutex::new(file),
            len,
            path: path.to_path_buf(),
        })
    }

    /// Create (or truncate) `path` read-write — the writer-side
    /// constructor used by the streaming store writer for `<path>.tmp`
    /// staging files.
    pub fn create(path: &Path) -> io::Result<Self> {
        let file = std::fs::File::options()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(Self {
            #[cfg(unix)]
            file,
            #[cfg(not(unix))]
            file: Mutex::new(file),
            len: 0,
            path: path.to_path_buf(),
        })
    }

    /// Open an existing `path` read-write without truncating — the crash
    /// recovery path (`resume_store_write`) reopens an interrupted staging
    /// file this way.
    pub fn open_rw(path: &Path) -> io::Result<Self> {
        let file = std::fs::File::options().read(true).write(true).open(path)?;
        let len = file.metadata()?.len();
        Ok(Self {
            #[cfg(unix)]
            file,
            #[cfg(not(unix))]
            file: Mutex::new(file),
            len,
            path: path.to_path_buf(),
        })
    }
}

impl ReadableStorage for FileStorage {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_at(buf, offset)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let mut file = lock(&self.file);
            file.seek(SeekFrom::Start(offset))?;
            file.read(buf)
        }
    }

    fn size(&self) -> io::Result<u64> {
        Ok(self.len)
    }

    fn describe(&self) -> String {
        self.path.display().to_string()
    }
}

impl WritableStorage for FileStorage {
    fn write_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<usize> {
        let n;
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            n = self.file.write_at(buf, offset)?;
        }
        #[cfg(not(unix))]
        {
            use std::io::{Seek, SeekFrom, Write};
            let mut file = lock(&self.file);
            file.seek(SeekFrom::Start(offset))?;
            n = file.write(buf)?;
        }
        self.len = self.len.max(offset + n as u64);
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        // `File` writes are unbuffered in userspace; nothing to push.
        Ok(())
    }

    fn sync(&mut self) -> io::Result<()> {
        #[cfg(unix)]
        {
            self.file.sync_all()
        }
        #[cfg(not(unix))]
        {
            lock(&self.file).sync_all()
        }
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        #[cfg(unix)]
        self.file.set_len(len)?;
        #[cfg(not(unix))]
        lock(&self.file).set_len(len)?;
        self.len = len;
        Ok(())
    }

    fn describe(&self) -> String {
        self.path.display().to_string()
    }
}

/// In-memory backend: the whole container as a shared byte buffer.
pub struct MemStorage {
    bytes: Arc<Vec<u8>>,
}

impl MemStorage {
    pub fn new(bytes: Vec<u8>) -> Self {
        Self {
            bytes: Arc::new(bytes),
        }
    }

    /// Share an existing buffer without copying.
    pub fn shared(bytes: Arc<Vec<u8>>) -> Self {
        Self { bytes }
    }

    /// The current contents (the crash-sweep tests write through a
    /// [`FaultInjector<MemStorage>`] and then salvage from this view).
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }
}

impl WritableStorage for MemStorage {
    fn write_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<usize> {
        // Clone-on-write: writers that shared the buffer out keep their
        // snapshot, this handle gets its own copy to mutate.
        write_into_vec(Arc::make_mut(&mut self.bytes), offset, buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }

    fn sync(&mut self) -> io::Result<()> {
        Ok(())
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        WritableStorage::truncate(Arc::make_mut(&mut self.bytes), len)
    }

    fn describe(&self) -> String {
        format!("<memory: {} bytes>", self.bytes.len())
    }
}

impl ReadableStorage for MemStorage {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        let len = self.bytes.len() as u64;
        if offset >= len {
            return Ok(0);
        }
        let start = offset as usize;
        let n = buf.len().min(self.bytes.len() - start);
        buf[..n].copy_from_slice(&self.bytes[start..start + n]);
        Ok(n)
    }

    fn size(&self) -> io::Result<u64> {
        Ok(self.bytes.len() as u64)
    }

    fn describe(&self) -> String {
        format!("<memory: {} bytes>", self.bytes.len())
    }
}

/// Deterministic fault schedule for [`FaultInjector`]. Every decision is a
/// pure function of the seeded RNG stream and the wrapper's operation
/// counter, so a single-threaded read sequence replays the exact same
/// faults on every run. (Under concurrency the *assignment* of op indices
/// to reads depends on thread interleaving; deterministic tests drive the
/// injector single-threaded.)
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// RNG seed for short-read split points and corruption positions.
    pub seed: u64,
    /// Split reads at a seeded point (at least 1 byte is still returned, so
    /// fault-free consumers that loop via [`read_exact_at`] stay correct).
    pub short_reads: bool,
    /// Split writes at a seeded point (at least 1 byte is still accepted,
    /// so fault-free producers that loop via [`write_all_at`] stay
    /// correct).
    pub short_writes: bool,
    /// Every `transient_every`-th operation (1-based op counter) fails with
    /// [`io::ErrorKind::Interrupted`] *before* touching the inner backend.
    /// `0` disables. With a value ≥ 2 an immediate retry is the next op
    /// index and cannot fault again, so retry success is deterministic.
    pub transient_every: u64,
    /// Hard (non-transient) I/O failure at exactly these 1-based op
    /// indices.
    pub fail_ops: Vec<u64>,
    /// Flip one byte (at a seeded position) of the data returned by exactly
    /// these 1-based op indices — downstream CRC-32 checks must catch it.
    pub corrupt_ops: Vec<u64>,
    /// Sleep this long before every read (simulated storage latency).
    pub latency: Duration,
}

impl FaultPlan {
    /// A passthrough plan: no faults of any kind. A [`FaultInjector`] with
    /// this plan must be byte-identical to its inner backend (the property
    /// test in `rust/tests/storage.rs` asserts exactly that).
    pub fn none() -> Self {
        Self::default()
    }
}

/// Counters of faults actually injected so far.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub ops: u64,
    pub short_reads: u64,
    pub short_writes: u64,
    pub transients: u64,
    pub failures: u64,
    pub corruptions: u64,
}

struct FaultState {
    plan: FaultPlan,
    rng: XorShift,
    counts: FaultCounts,
}

/// Shared handle onto a [`FaultInjector`]'s mutable fault schedule: tests
/// flip fault modes mid-run (e.g. enable corruption only *after* a clean
/// `Store::open`) and read the injection counters.
#[derive(Clone)]
pub struct FaultHandle {
    state: Arc<Mutex<FaultState>>,
}

impl FaultHandle {
    /// Replace the active plan (the op counter and RNG stream continue).
    pub fn set_plan(&self, plan: FaultPlan) {
        lock(&self.state).plan = plan;
    }

    /// Faults injected so far.
    pub fn counts(&self) -> FaultCounts {
        lock(&self.state).counts
    }
}

/// Fault-injecting wrapper around any [`ReadableStorage`] and/or
/// [`WritableStorage`] backend, scheduled deterministically by a
/// [`FaultPlan`]. Reads and writes share one op counter and RNG stream,
/// so a mixed sequence replays the same fault schedule on every run.
pub struct FaultInjector<S> {
    inner: S,
    state: Arc<Mutex<FaultState>>,
}

impl<S> FaultInjector<S> {
    pub fn new(inner: S, plan: FaultPlan) -> Self {
        let rng = XorShift::new(plan.seed);
        Self {
            inner,
            state: Arc::new(Mutex::new(FaultState {
                plan,
                rng,
                counts: FaultCounts::default(),
            })),
        }
    }

    /// A handle for inspecting/retargeting the fault schedule after the
    /// injector has been handed to a `Store`.
    pub fn handle(&self) -> FaultHandle {
        FaultHandle {
            state: Arc::clone(&self.state),
        }
    }

    /// Borrow the wrapped backend (e.g. to read back what a faulted write
    /// sequence actually persisted).
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    /// Unwrap the injector, returning the inner backend.
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: ReadableStorage> ReadableStorage for FaultInjector<S> {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        // Decide this op's fate under the lock (op counter + RNG stream are
        // the deterministic schedule), then perform the inner read outside
        // it so injected latency never serializes concurrent readers.
        let (take, corrupt_at, latency) = {
            let mut st = lock(&self.state);
            st.counts.ops += 1;
            let op = st.counts.ops;
            if st.plan.transient_every > 0 && op % st.plan.transient_every == 0 {
                st.counts.transients += 1;
                return Err(io::Error::new(
                    io::ErrorKind::Interrupted,
                    format!("injected transient storage fault (op {op})"),
                ));
            }
            if st.plan.fail_ops.contains(&op) {
                st.counts.failures += 1;
                return Err(io::Error::other(format!(
                    "injected storage failure (op {op})"
                )));
            }
            let mut take = buf.len();
            if st.plan.short_reads && buf.len() > 1 {
                take = 1 + st.rng.below(buf.len() - 1);
                if take < buf.len() {
                    st.counts.short_reads += 1;
                }
            }
            let corrupt_at = if st.plan.corrupt_ops.contains(&op) && take > 0 {
                st.counts.corruptions += 1;
                Some(st.rng.below(take))
            } else {
                None
            };
            (take, corrupt_at, st.plan.latency)
        };
        if !latency.is_zero() {
            std::thread::sleep(latency);
        }
        let n = self.inner.read_at(offset, &mut buf[..take])?;
        if let Some(pos) = corrupt_at {
            if n > 0 {
                buf[pos.min(n - 1)] ^= 0xFF;
            }
        }
        Ok(n)
    }

    fn size(&self) -> io::Result<u64> {
        self.inner.size()
    }

    fn describe(&self) -> String {
        format!("fault-injected {}", self.inner.describe())
    }
}

impl<S: WritableStorage> WritableStorage for FaultInjector<S> {
    fn write_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<usize> {
        // Same schedule discipline as reads: fate is decided under the
        // lock from the shared op counter and RNG stream. `corrupt_ops`
        // applies only to reads — a corrupted *write* would be persisted
        // and is the read sweep's job to detect, not the write path's.
        let (take, latency) = {
            let mut st = lock(&self.state);
            st.counts.ops += 1;
            let op = st.counts.ops;
            if st.plan.transient_every > 0 && op % st.plan.transient_every == 0 {
                st.counts.transients += 1;
                return Err(io::Error::new(
                    io::ErrorKind::Interrupted,
                    format!("injected transient storage fault (op {op})"),
                ));
            }
            if st.plan.fail_ops.contains(&op) {
                st.counts.failures += 1;
                return Err(io::Error::other(format!(
                    "injected storage failure (op {op})"
                )));
            }
            let mut take = buf.len();
            if st.plan.short_writes && buf.len() > 1 {
                take = 1 + st.rng.below(buf.len() - 1);
                if take < buf.len() {
                    st.counts.short_writes += 1;
                }
            }
            (take, st.plan.latency)
        };
        if !latency.is_zero() {
            std::thread::sleep(latency);
        }
        self.inner.write_at(offset, &buf[..take])
    }

    // Control operations pass through unfaulted: `fail_ops` indices stay
    // pinned to data ops, so a crash point k always means "the k-th
    // read/write", independent of how many flush/sync calls surround it.
    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }

    fn sync(&mut self) -> io::Result<()> {
        self.inner.sync()
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        self.inner.truncate(len)
    }

    fn describe(&self) -> String {
        format!("fault-injected {}", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem(n: usize) -> MemStorage {
        MemStorage::new((0..n).map(|i| (i % 251) as u8).collect())
    }

    #[test]
    fn mem_storage_ranged_reads_and_eof() {
        let s = mem(100);
        assert_eq!(s.size().unwrap(), 100);
        let mut buf = [0u8; 10];
        assert_eq!(s.read_at(90, &mut buf).unwrap(), 10);
        assert_eq!(buf[0], 90);
        assert_eq!(s.read_at(95, &mut buf).unwrap(), 5);
        assert_eq!(s.read_at(100, &mut buf).unwrap(), 0);
        assert_eq!(s.read_at(1000, &mut buf).unwrap(), 0);
    }

    #[test]
    fn file_storage_matches_memory() {
        let path = std::env::temp_dir().join("ffcz_storage_file_backend_test.bin");
        let bytes: Vec<u8> = (0..4096u32).map(|i| (i * 7 % 256) as u8).collect();
        std::fs::write(&path, &bytes).expect("writing the file-backend fixture");
        let f = FileStorage::open(&path).unwrap();
        assert_eq!(f.size().unwrap(), 4096);
        let mut a = vec![0u8; 777];
        let mut b = vec![0u8; 777];
        read_exact_at(&f, 1234, &mut a).unwrap();
        read_exact_at(&MemStorage::new(bytes.clone()), 1234, &mut b).unwrap();
        assert_eq!(a, b);
        // Premature EOF is precise.
        let mut big = vec![0u8; 64];
        let err = read_exact_at(&f, 4090, &mut big).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn short_reads_complete_through_read_exact_at() {
        let inj = FaultInjector::new(
            mem(2048),
            FaultPlan {
                seed: 7,
                short_reads: true,
                ..FaultPlan::none()
            },
        );
        let handle = inj.handle();
        let mut got = vec![0u8; 1500];
        read_exact_at(&inj, 100, &mut got).unwrap();
        let mut want = vec![0u8; 1500];
        read_exact_at(&mem(2048), 100, &mut want).unwrap();
        assert_eq!(got, want);
        assert!(handle.counts().short_reads > 0, "{:?}", handle.counts());
    }

    #[test]
    fn transient_faults_retry_deterministically() {
        let inj = FaultInjector::new(
            mem(256),
            FaultPlan {
                transient_every: 2,
                ..FaultPlan::none()
            },
        );
        let handle = inj.handle();
        let mut buf = [0u8; 16];
        // Op 1 clean, op 2 faults: without retry the second read errors.
        assert!(read_exact_at(&inj, 0, &mut buf).is_ok());
        let err = read_exact_at(&inj, 0, &mut buf).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Interrupted);
        // With retry every read succeeds: a faulted op is followed by a
        // clean op index, every time.
        for i in 0..8u64 {
            let retries =
                read_exact_at_retry(&inj, i, &mut buf, &RetryPolicy::transient(3, Duration::ZERO))
                    .unwrap();
            assert!(retries <= 1);
        }
        assert!(handle.counts().transients >= 4);
    }

    #[test]
    fn hard_failures_are_not_retried() {
        let inj = FaultInjector::new(
            mem(256),
            FaultPlan {
                fail_ops: vec![1],
                ..FaultPlan::none()
            },
        );
        let mut buf = [0u8; 16];
        let err = read_exact_at_retry(
            &inj,
            0,
            &mut buf,
            &RetryPolicy::transient(10, Duration::ZERO),
        )
        .unwrap_err();
        assert!(!RetryPolicy::is_transient(err.kind()));
        assert_eq!(inj.handle().counts().failures, 1);
    }

    #[test]
    fn corruption_flips_exactly_one_byte() {
        let inj = FaultInjector::new(
            mem(256),
            FaultPlan {
                seed: 11,
                corrupt_ops: vec![1],
                ..FaultPlan::none()
            },
        );
        let mut got = vec![0u8; 64];
        read_exact_at(&inj, 0, &mut got).unwrap();
        let mut want = vec![0u8; 64];
        read_exact_at(&mem(256), 0, &mut want).unwrap();
        let flipped: Vec<usize> = (0..64).filter(|&i| got[i] != want[i]).collect();
        assert_eq!(flipped.len(), 1, "{flipped:?}");
        assert_eq!(got[flipped[0]], want[flipped[0]] ^ 0xFF);
        assert_eq!(inj.handle().counts().corruptions, 1);
    }

    #[test]
    fn vec_and_mem_writes_match_and_zero_fill_gaps() {
        let mut v: Vec<u8> = Vec::new();
        write_all_at(&mut v, 0, b"hello").unwrap();
        write_all_at(&mut v, 8, b"world").unwrap();
        assert_eq!(&v[..5], b"hello");
        assert_eq!(&v[5..8], &[0, 0, 0], "gap must zero-fill");
        assert_eq!(&v[8..], b"world");
        WritableStorage::truncate(&mut v, 4).unwrap();
        assert_eq!(v, b"hell");

        let mut m = MemStorage::new(Vec::new());
        write_all_at(&mut m, 0, b"hello").unwrap();
        write_all_at(&mut m, 8, b"world").unwrap();
        let mut got = vec![0u8; 13];
        read_exact_at(&m, 0, &mut got).unwrap();
        assert_eq!(got, v_expect());
        WritableStorage::truncate(&mut m, 4).unwrap();
        assert_eq!(m.bytes(), b"hell");
    }

    fn v_expect() -> Vec<u8> {
        let mut e = b"hello".to_vec();
        e.extend_from_slice(&[0, 0, 0]);
        e.extend_from_slice(b"world");
        e
    }

    #[test]
    fn file_storage_write_read_roundtrip() {
        let path = std::env::temp_dir().join("ffcz_storage_file_write_test.bin");
        let mut f = FileStorage::create(&path).expect("creating the write fixture");
        write_all_at(&mut f, 0, b"abcdef").unwrap();
        write_all_at(&mut f, 3, b"XYZ").unwrap();
        WritableStorage::flush(&mut f).unwrap();
        f.sync().unwrap();
        assert_eq!(f.size().unwrap(), 6);
        let mut got = [0u8; 6];
        read_exact_at(&f, 0, &mut got).unwrap();
        assert_eq!(&got, b"abcXYZ");
        // Reopen read-write without truncating; extend past the end.
        drop(f);
        let mut f = FileStorage::open_rw(&path).expect("reopening the write fixture");
        assert_eq!(f.size().unwrap(), 6);
        write_all_at(&mut f, 6, b"tail").unwrap();
        f.truncate(8).unwrap();
        assert_eq!(f.size().unwrap(), 8);
        let mut got = [0u8; 8];
        read_exact_at(&f, 0, &mut got).unwrap();
        assert_eq!(&got, b"abcXYZta");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn short_writes_complete_through_write_all_at() {
        let mut inj = FaultInjector::new(
            Vec::<u8>::new(),
            FaultPlan {
                seed: 5,
                short_writes: true,
                ..FaultPlan::none()
            },
        );
        let handle = inj.handle();
        let payload: Vec<u8> = (0..1500).map(|i| (i % 241) as u8).collect();
        write_all_at(&mut inj, 0, &payload).unwrap();
        assert_eq!(inj.get_ref(), &payload);
        assert!(handle.counts().short_writes > 0, "{:?}", handle.counts());
    }

    #[test]
    fn transient_write_faults_heal_under_retry() {
        let mut inj = FaultInjector::new(
            Vec::<u8>::new(),
            FaultPlan {
                transient_every: 2,
                ..FaultPlan::none()
            },
        );
        let handle = inj.handle();
        // Op 1 clean, op 2 faults: without retry the second write errors.
        assert!(write_all_at(&mut inj, 0, b"aa").is_ok());
        let err = write_all_at(&mut inj, 2, b"bb").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Interrupted);
        // With retry every write lands: a faulted op is followed by a
        // clean op index, every time.
        for i in 0..8u64 {
            let retries = write_all_at_retry(
                &mut inj,
                2 + 2 * i,
                b"cc",
                &RetryPolicy::transient(3, Duration::ZERO),
            )
            .unwrap();
            assert!(retries <= 1);
        }
        assert_eq!(inj.get_ref().len(), 20);
        assert!(handle.counts().transients >= 4);
    }

    #[test]
    fn hard_write_failure_at_exact_op_is_not_retried() {
        let mut inj = FaultInjector::new(
            Vec::<u8>::new(),
            FaultPlan {
                fail_ops: vec![2],
                ..FaultPlan::none()
            },
        );
        assert!(write_all_at(&mut inj, 0, b"first").is_ok());
        let err = write_all_at_retry(
            &mut inj,
            5,
            b"second",
            &RetryPolicy::transient(10, Duration::ZERO),
        )
        .unwrap_err();
        assert!(!RetryPolicy::is_transient(err.kind()));
        assert_eq!(inj.handle().counts().failures, 1);
        // The failed op persisted nothing; the backend still holds only
        // the first write.
        assert_eq!(inj.into_inner(), b"first");
    }

    #[test]
    fn write_fault_schedule_replays_deterministically() {
        let run = || {
            let mut inj = FaultInjector::new(
                Vec::<u8>::new(),
                FaultPlan {
                    seed: 42,
                    short_writes: true,
                    transient_every: 5,
                    ..FaultPlan::none()
                },
            );
            let handle = inj.handle();
            let mut offset = 0u64;
            for i in 0..20u8 {
                let chunk = vec![i; 37];
                write_all_at_retry(
                    &mut inj,
                    offset,
                    &chunk,
                    &RetryPolicy::transient(4, Duration::ZERO),
                )
                .unwrap();
                offset += 37;
            }
            (inj.into_inner(), handle.counts())
        };
        let (bytes_a, counts_a) = run();
        let (bytes_b, counts_b) = run();
        assert_eq!(bytes_a, bytes_b);
        assert_eq!(counts_a, counts_b);
        assert!(counts_a.short_writes > 0 && counts_a.transients > 0);
    }

    #[test]
    fn exponential_jitter_schedule_is_pinned_for_a_fixed_seed() {
        // The exact sleep schedule is stable API for deterministic chaos
        // replay: 10ms base, exponential growth, equal jitter, seed 42.
        let policy = RetryPolicy::transient(8, Duration::from_millis(10))
            .exponential()
            .with_jitter(42);
        let mut schedule = RetrySchedule::new(policy);
        let got: Vec<u64> = (0..4).map(|_| schedule.next_delay().as_nanos() as u64).collect();
        assert_eq!(got, vec![6_126_959, 14_307_125, 37_461_424, 78_917_564]);
        // Equal jitter keeps every delay within [base/2, base].
        for (k, &d) in got.iter().enumerate() {
            let base = 10_000_000u64 << k;
            assert!(d >= base / 2 && d <= base, "retry {}: {d} outside [{}, {base}]", k + 1, base / 2);
        }
    }

    #[test]
    fn linear_jitter_schedule_is_pinned_for_a_fixed_seed() {
        let policy = RetryPolicy::transient(8, Duration::from_millis(4)).with_jitter(7);
        let mut schedule = RetrySchedule::new(policy);
        let got: Vec<u64> = (0..3).map(|_| schedule.next_delay().as_nanos() as u64).collect();
        assert_eq!(got, vec![2_491_041, 7_209_889, 9_251_495]);
    }

    #[test]
    fn unjittered_schedules_are_exact_and_grow_as_documented() {
        let linear = RetryPolicy::transient(8, Duration::from_millis(3));
        let mut schedule = RetrySchedule::new(linear);
        for k in 1u32..=4 {
            assert_eq!(schedule.next_delay(), Duration::from_millis(3) * k);
        }
        let expo = RetryPolicy::transient(8, Duration::from_millis(3)).exponential();
        let mut schedule = RetrySchedule::new(expo);
        for k in 1u32..=4 {
            assert_eq!(schedule.next_delay(), Duration::from_millis(3) * (1 << (k - 1)));
        }
    }

    #[test]
    fn backoff_for_enforces_attempts_transience_and_deadline() {
        // Attempt budget: 3 attempts = 2 retries.
        let policy = RetryPolicy::transient(3, Duration::ZERO);
        let mut schedule = RetrySchedule::new(policy);
        assert!(schedule.backoff_for(io::ErrorKind::Interrupted).is_some());
        assert!(schedule.backoff_for(io::ErrorKind::TimedOut).is_some());
        assert!(schedule.backoff_for(io::ErrorKind::Interrupted).is_none());
        assert_eq!(schedule.retries(), 2);

        // Hard faults are never granted a retry.
        let mut schedule = RetrySchedule::new(policy);
        assert!(schedule.backoff_for(io::ErrorKind::PermissionDenied).is_none());
        assert!(schedule.backoff_for(io::ErrorKind::UnexpectedEof).is_none());
        assert_eq!(schedule.retries(), 0);

        // A deadline of zero refuses the very first retry (sleeping
        // would cross the budget), and the refusal is not counted.
        let strict = RetryPolicy::transient(10, Duration::from_millis(5)).with_deadline(Duration::ZERO);
        let mut schedule = RetrySchedule::new(strict);
        assert!(schedule.deadline_exceeded());
        assert!(schedule.backoff_for(io::ErrorKind::Interrupted).is_none());
        assert_eq!(schedule.retries(), 0);
    }

    #[test]
    fn retry_deadline_bounds_the_whole_loop() {
        // Every op faults transiently; without the deadline the loop
        // would take ~10 attempts. The absolute budget cuts it short and
        // surfaces the transient error.
        let inj = FaultInjector::new(
            mem(256),
            FaultPlan {
                transient_every: 1,
                ..FaultPlan::none()
            },
        );
        let mut buf = [0u8; 16];
        let policy = RetryPolicy::transient(10, Duration::from_millis(20))
            .with_deadline(Duration::from_millis(30));
        let started = std::time::Instant::now();
        let err = read_exact_at_retry(&inj, 0, &mut buf, &policy).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Interrupted);
        assert!(
            started.elapsed() < Duration::from_secs(2),
            "deadline did not bound the retry loop"
        );
    }

    #[test]
    fn plan_can_be_retargeted_through_the_handle() {
        let inj = FaultInjector::new(mem(256), FaultPlan::none());
        let handle = inj.handle();
        let mut buf = [0u8; 8];
        assert!(read_exact_at(&inj, 0, &mut buf).is_ok());
        handle.set_plan(FaultPlan {
            transient_every: 1,
            ..FaultPlan::none()
        });
        assert!(read_exact_at(&inj, 0, &mut buf).is_err());
        handle.set_plan(FaultPlan::none());
        assert!(read_exact_at(&inj, 0, &mut buf).is_ok());
    }
}
