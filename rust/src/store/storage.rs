//! Storage-backend abstraction for reader I/O.
//!
//! [`crate::store::reader::Store`] historically read straight from a local
//! `File`. Production archives live behind many kinds of byte sources —
//! local files, memory-resident containers, object stores, test harnesses —
//! so all reader I/O now goes through [`ReadableStorage`]: a ranged
//! `read_at`/`size` API (mirroring the `zarrs_storage` readable-storage
//! split). Three backends ship here:
//!
//! * [`FileStorage`] — a local file, positioned reads (`pread` on unix, so
//!   concurrent readers never serialize on a seek lock);
//! * [`MemStorage`] — a container held fully in memory;
//! * [`FaultInjector`] — a deterministic, seeded fault-injecting wrapper
//!   around any backend (short reads, transient `io::Error`s, hard I/O
//!   failures, byte corruption, injected latency). This is what makes the
//!   storage layer's *failure* behavior testable rather than assumed: the
//!   fault-injection suite in `rust/tests/storage.rs` drives every decode
//!   path through scheduled faults and asserts precise errors, never
//!   panics.
//!
//! Short reads are part of the contract (`read_at` may return fewer bytes
//! than requested); callers that need a full range use [`read_exact_at`],
//! and callers that tolerate *transient* faults (interrupted syscalls,
//! storage-side timeouts) wrap it with [`read_exact_at_retry`] under a
//! [`RetryPolicy`].

use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::sync::lock;
use crate::util::XorShift;

/// A byte source supporting ranged reads — the reader-side storage
/// abstraction behind [`crate::store::Store`].
///
/// Implementations must be usable from many threads at once (`Send +
/// Sync`); `read_at` takes `&self` so concurrent chunk fetches never
/// serialize in the trait layer.
pub trait ReadableStorage: Send + Sync {
    /// Read up to `buf.len()` bytes starting at absolute `offset` into
    /// `buf`, returning how many bytes were read. A return of `0` with a
    /// non-empty `buf` means end-of-storage. Short reads are allowed; use
    /// [`read_exact_at`] to loop a range to completion.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize>;

    /// Total size of the storage in bytes.
    fn size(&self) -> io::Result<u64>;

    /// Human-readable description for error messages (a path, `<memory>`,
    /// a wrapped backend).
    fn describe(&self) -> String;
}

impl<S: ReadableStorage + ?Sized> ReadableStorage for Arc<S> {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        (**self).read_at(offset, buf)
    }
    fn size(&self) -> io::Result<u64> {
        (**self).size()
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
}

/// Fill `buf` from `storage` starting at `offset`, looping over short
/// reads. Premature end-of-storage surfaces as [`io::ErrorKind::UnexpectedEof`];
/// every other `io::Error` (including transient kinds) is surfaced as-is —
/// retrying is policy, not mechanism, and lives in [`read_exact_at_retry`].
pub fn read_exact_at<S: ReadableStorage + ?Sized>(
    storage: &S,
    offset: u64,
    buf: &mut [u8],
) -> io::Result<()> {
    let mut filled = 0usize;
    while filled < buf.len() {
        let n = storage.read_at(offset + filled as u64, &mut buf[filled..])?;
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!(
                    "unexpected end of storage: wanted {} bytes at offset {}, got {} ({})",
                    buf.len(),
                    offset,
                    filled,
                    storage.describe()
                ),
            ));
        }
        filled += n;
    }
    Ok(())
}

/// Retry/backoff policy for *transient* storage faults (interrupted
/// syscalls, would-block, storage-side timeouts). Hard faults — permission
/// errors, corruption, premature EOF — are never retried.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (1 = no retry).
    pub max_attempts: u32,
    /// Sleep before retry `k` is `backoff × k` (linear backoff).
    pub backoff: Duration,
}

impl RetryPolicy {
    /// No retries: every fault surfaces immediately.
    pub fn none() -> Self {
        Self {
            max_attempts: 1,
            backoff: Duration::ZERO,
        }
    }

    /// Retry transient faults up to `max_attempts` total attempts with
    /// linear `backoff` between them.
    pub fn transient(max_attempts: u32, backoff: Duration) -> Self {
        Self {
            max_attempts: max_attempts.max(1),
            backoff,
        }
    }

    /// Is `kind` a transient fault worth retrying?
    pub fn is_transient(kind: io::ErrorKind) -> bool {
        matches!(
            kind,
            io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
        )
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::none()
    }
}

/// [`read_exact_at`] under a [`RetryPolicy`]: transient faults are retried
/// (with linear backoff) up to the attempt budget; the whole range is
/// re-read from `offset` on each attempt. Returns the number of retries
/// performed (0 on a clean first attempt) so callers can account them.
pub fn read_exact_at_retry<S: ReadableStorage + ?Sized>(
    storage: &S,
    offset: u64,
    buf: &mut [u8],
    policy: &RetryPolicy,
) -> io::Result<u32> {
    let mut retries = 0u32;
    loop {
        match read_exact_at(storage, offset, buf) {
            Ok(()) => return Ok(retries),
            Err(e)
                if RetryPolicy::is_transient(e.kind()) && retries + 1 < policy.max_attempts =>
            {
                retries += 1;
                if !policy.backoff.is_zero() {
                    std::thread::sleep(policy.backoff * retries);
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Local-file backend. On unix the reads are positioned (`pread`), so any
/// number of threads can fetch chunks concurrently without a seek lock.
pub struct FileStorage {
    #[cfg(unix)]
    file: std::fs::File,
    #[cfg(not(unix))]
    file: Mutex<std::fs::File>,
    len: u64,
    path: PathBuf,
}

impl FileStorage {
    /// Open `path` read-only and stat its length. Archives are immutable
    /// once written, so the length is cached at open.
    pub fn open(path: &Path) -> io::Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        Ok(Self {
            #[cfg(unix)]
            file,
            #[cfg(not(unix))]
            file: Mutex::new(file),
            len,
            path: path.to_path_buf(),
        })
    }
}

impl ReadableStorage for FileStorage {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_at(buf, offset)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let mut file = lock(&self.file);
            file.seek(SeekFrom::Start(offset))?;
            file.read(buf)
        }
    }

    fn size(&self) -> io::Result<u64> {
        Ok(self.len)
    }

    fn describe(&self) -> String {
        self.path.display().to_string()
    }
}

/// In-memory backend: the whole container as a shared byte buffer.
pub struct MemStorage {
    bytes: Arc<Vec<u8>>,
}

impl MemStorage {
    pub fn new(bytes: Vec<u8>) -> Self {
        Self {
            bytes: Arc::new(bytes),
        }
    }

    /// Share an existing buffer without copying.
    pub fn shared(bytes: Arc<Vec<u8>>) -> Self {
        Self { bytes }
    }
}

impl ReadableStorage for MemStorage {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        let len = self.bytes.len() as u64;
        if offset >= len {
            return Ok(0);
        }
        let start = offset as usize;
        let n = buf.len().min(self.bytes.len() - start);
        buf[..n].copy_from_slice(&self.bytes[start..start + n]);
        Ok(n)
    }

    fn size(&self) -> io::Result<u64> {
        Ok(self.bytes.len() as u64)
    }

    fn describe(&self) -> String {
        format!("<memory: {} bytes>", self.bytes.len())
    }
}

/// Deterministic fault schedule for [`FaultInjector`]. Every decision is a
/// pure function of the seeded RNG stream and the wrapper's operation
/// counter, so a single-threaded read sequence replays the exact same
/// faults on every run. (Under concurrency the *assignment* of op indices
/// to reads depends on thread interleaving; deterministic tests drive the
/// injector single-threaded.)
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// RNG seed for short-read split points and corruption positions.
    pub seed: u64,
    /// Split reads at a seeded point (at least 1 byte is still returned, so
    /// fault-free consumers that loop via [`read_exact_at`] stay correct).
    pub short_reads: bool,
    /// Every `transient_every`-th operation (1-based op counter) fails with
    /// [`io::ErrorKind::Interrupted`] *before* touching the inner backend.
    /// `0` disables. With a value ≥ 2 an immediate retry is the next op
    /// index and cannot fault again, so retry success is deterministic.
    pub transient_every: u64,
    /// Hard (non-transient) I/O failure at exactly these 1-based op
    /// indices.
    pub fail_ops: Vec<u64>,
    /// Flip one byte (at a seeded position) of the data returned by exactly
    /// these 1-based op indices — downstream CRC-32 checks must catch it.
    pub corrupt_ops: Vec<u64>,
    /// Sleep this long before every read (simulated storage latency).
    pub latency: Duration,
}

impl FaultPlan {
    /// A passthrough plan: no faults of any kind. A [`FaultInjector`] with
    /// this plan must be byte-identical to its inner backend (the property
    /// test in `rust/tests/storage.rs` asserts exactly that).
    pub fn none() -> Self {
        Self::default()
    }
}

/// Counters of faults actually injected so far.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub ops: u64,
    pub short_reads: u64,
    pub transients: u64,
    pub failures: u64,
    pub corruptions: u64,
}

struct FaultState {
    plan: FaultPlan,
    rng: XorShift,
    counts: FaultCounts,
}

/// Shared handle onto a [`FaultInjector`]'s mutable fault schedule: tests
/// flip fault modes mid-run (e.g. enable corruption only *after* a clean
/// `Store::open`) and read the injection counters.
#[derive(Clone)]
pub struct FaultHandle {
    state: Arc<Mutex<FaultState>>,
}

impl FaultHandle {
    /// Replace the active plan (the op counter and RNG stream continue).
    pub fn set_plan(&self, plan: FaultPlan) {
        lock(&self.state).plan = plan;
    }

    /// Faults injected so far.
    pub fn counts(&self) -> FaultCounts {
        lock(&self.state).counts
    }
}

/// Fault-injecting wrapper around any [`ReadableStorage`] backend,
/// scheduled deterministically by a [`FaultPlan`].
pub struct FaultInjector<S> {
    inner: S,
    state: Arc<Mutex<FaultState>>,
}

impl<S: ReadableStorage> FaultInjector<S> {
    pub fn new(inner: S, plan: FaultPlan) -> Self {
        let rng = XorShift::new(plan.seed);
        Self {
            inner,
            state: Arc::new(Mutex::new(FaultState {
                plan,
                rng,
                counts: FaultCounts::default(),
            })),
        }
    }

    /// A handle for inspecting/retargeting the fault schedule after the
    /// injector has been handed to a `Store`.
    pub fn handle(&self) -> FaultHandle {
        FaultHandle {
            state: Arc::clone(&self.state),
        }
    }
}

impl<S: ReadableStorage> ReadableStorage for FaultInjector<S> {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        // Decide this op's fate under the lock (op counter + RNG stream are
        // the deterministic schedule), then perform the inner read outside
        // it so injected latency never serializes concurrent readers.
        let (take, corrupt_at, latency) = {
            let mut st = lock(&self.state);
            st.counts.ops += 1;
            let op = st.counts.ops;
            if st.plan.transient_every > 0 && op % st.plan.transient_every == 0 {
                st.counts.transients += 1;
                return Err(io::Error::new(
                    io::ErrorKind::Interrupted,
                    format!("injected transient storage fault (op {op})"),
                ));
            }
            if st.plan.fail_ops.contains(&op) {
                st.counts.failures += 1;
                return Err(io::Error::other(format!(
                    "injected storage failure (op {op})"
                )));
            }
            let mut take = buf.len();
            if st.plan.short_reads && buf.len() > 1 {
                take = 1 + st.rng.below(buf.len() - 1);
                if take < buf.len() {
                    st.counts.short_reads += 1;
                }
            }
            let corrupt_at = if st.plan.corrupt_ops.contains(&op) && take > 0 {
                st.counts.corruptions += 1;
                Some(st.rng.below(take))
            } else {
                None
            };
            (take, corrupt_at, st.plan.latency)
        };
        if !latency.is_zero() {
            std::thread::sleep(latency);
        }
        let n = self.inner.read_at(offset, &mut buf[..take])?;
        if let Some(pos) = corrupt_at {
            if n > 0 {
                buf[pos.min(n - 1)] ^= 0xFF;
            }
        }
        Ok(n)
    }

    fn size(&self) -> io::Result<u64> {
        self.inner.size()
    }

    fn describe(&self) -> String {
        format!("fault-injected {}", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem(n: usize) -> MemStorage {
        MemStorage::new((0..n).map(|i| (i % 251) as u8).collect())
    }

    #[test]
    fn mem_storage_ranged_reads_and_eof() {
        let s = mem(100);
        assert_eq!(s.size().unwrap(), 100);
        let mut buf = [0u8; 10];
        assert_eq!(s.read_at(90, &mut buf).unwrap(), 10);
        assert_eq!(buf[0], 90);
        assert_eq!(s.read_at(95, &mut buf).unwrap(), 5);
        assert_eq!(s.read_at(100, &mut buf).unwrap(), 0);
        assert_eq!(s.read_at(1000, &mut buf).unwrap(), 0);
    }

    #[test]
    fn file_storage_matches_memory() {
        let path = std::env::temp_dir().join("ffcz_storage_file_backend_test.bin");
        let bytes: Vec<u8> = (0..4096u32).map(|i| (i * 7 % 256) as u8).collect();
        std::fs::write(&path, &bytes).unwrap();
        let f = FileStorage::open(&path).unwrap();
        assert_eq!(f.size().unwrap(), 4096);
        let mut a = vec![0u8; 777];
        let mut b = vec![0u8; 777];
        read_exact_at(&f, 1234, &mut a).unwrap();
        read_exact_at(&MemStorage::new(bytes.clone()), 1234, &mut b).unwrap();
        assert_eq!(a, b);
        // Premature EOF is precise.
        let mut big = vec![0u8; 64];
        let err = read_exact_at(&f, 4090, &mut big).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn short_reads_complete_through_read_exact_at() {
        let inj = FaultInjector::new(
            mem(2048),
            FaultPlan {
                seed: 7,
                short_reads: true,
                ..FaultPlan::none()
            },
        );
        let handle = inj.handle();
        let mut got = vec![0u8; 1500];
        read_exact_at(&inj, 100, &mut got).unwrap();
        let mut want = vec![0u8; 1500];
        read_exact_at(&mem(2048), 100, &mut want).unwrap();
        assert_eq!(got, want);
        assert!(handle.counts().short_reads > 0, "{:?}", handle.counts());
    }

    #[test]
    fn transient_faults_retry_deterministically() {
        let inj = FaultInjector::new(
            mem(256),
            FaultPlan {
                transient_every: 2,
                ..FaultPlan::none()
            },
        );
        let handle = inj.handle();
        let mut buf = [0u8; 16];
        // Op 1 clean, op 2 faults: without retry the second read errors.
        assert!(read_exact_at(&inj, 0, &mut buf).is_ok());
        let err = read_exact_at(&inj, 0, &mut buf).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Interrupted);
        // With retry every read succeeds: a faulted op is followed by a
        // clean op index, every time.
        for i in 0..8u64 {
            let retries =
                read_exact_at_retry(&inj, i, &mut buf, &RetryPolicy::transient(3, Duration::ZERO))
                    .unwrap();
            assert!(retries <= 1);
        }
        assert!(handle.counts().transients >= 4);
    }

    #[test]
    fn hard_failures_are_not_retried() {
        let inj = FaultInjector::new(
            mem(256),
            FaultPlan {
                fail_ops: vec![1],
                ..FaultPlan::none()
            },
        );
        let mut buf = [0u8; 16];
        let err = read_exact_at_retry(
            &inj,
            0,
            &mut buf,
            &RetryPolicy::transient(10, Duration::ZERO),
        )
        .unwrap_err();
        assert!(!RetryPolicy::is_transient(err.kind()));
        assert_eq!(inj.handle().counts().failures, 1);
    }

    #[test]
    fn corruption_flips_exactly_one_byte() {
        let inj = FaultInjector::new(
            mem(256),
            FaultPlan {
                seed: 11,
                corrupt_ops: vec![1],
                ..FaultPlan::none()
            },
        );
        let mut got = vec![0u8; 64];
        read_exact_at(&inj, 0, &mut got).unwrap();
        let mut want = vec![0u8; 64];
        read_exact_at(&mem(256), 0, &mut want).unwrap();
        let flipped: Vec<usize> = (0..64).filter(|&i| got[i] != want[i]).collect();
        assert_eq!(flipped.len(), 1, "{flipped:?}");
        assert_eq!(got[flipped[0]], want[flipped[0]] ^ 0xFF);
        assert_eq!(inj.handle().counts().corruptions, 1);
    }

    #[test]
    fn plan_can_be_retargeted_through_the_handle() {
        let inj = FaultInjector::new(mem(256), FaultPlan::none());
        let handle = inj.handle();
        let mut buf = [0u8; 8];
        assert!(read_exact_at(&inj, 0, &mut buf).is_ok());
        handle.set_plan(FaultPlan {
            transient_every: 1,
            ..FaultPlan::none()
        });
        assert!(read_exact_at(&inj, 0, &mut buf).is_err());
        handle.set_plan(FaultPlan::none());
        assert!(read_exact_at(&inj, 0, &mut buf).is_ok());
    }
}
