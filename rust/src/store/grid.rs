//! Regular chunk grid over a row-major n-d array (zarrs-style).
//!
//! The grid tiles the array with fixed-size chunks anchored at the origin;
//! chunks on the trailing edge of each axis are clipped to the array bounds
//! (*edge chunks*), so every sample belongs to exactly one chunk. Chunks
//! are identified by a row-major linear index over the grid, or by a
//! zarr-style key (`c/1/0/3`) for display.
//!
//! This generalizes [`crate::coordinator::sharding`] — an axis-0-only grid
//! whose chunk extent divides the array extent produces exactly
//! `shard_field`'s contiguous slabs — to arbitrary-axis tiling with
//! random access.

use anyhow::{bail, Result};

/// A regular chunk grid: array shape + chunk shape, same dimensionality.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkGrid {
    array_shape: Vec<usize>,
    chunk_shape: Vec<usize>,
    /// Chunks per axis: `ceil(array / chunk)`.
    grid_shape: Vec<usize>,
}

impl ChunkGrid {
    pub fn new(array_shape: &[usize], chunk_shape: &[usize]) -> Result<Self> {
        if array_shape.is_empty() || array_shape.len() != chunk_shape.len() {
            bail!(
                "chunk shape {:?} does not match array shape {:?}",
                chunk_shape,
                array_shape
            );
        }
        if array_shape.iter().any(|&d| d == 0) || chunk_shape.iter().any(|&d| d == 0) {
            bail!("zero-extent axis in array {array_shape:?} or chunk {chunk_shape:?}");
        }
        let grid_shape = array_shape
            .iter()
            .zip(chunk_shape)
            .map(|(&a, &c)| a.div_ceil(c))
            .collect();
        Ok(Self {
            array_shape: array_shape.to_vec(),
            chunk_shape: chunk_shape.to_vec(),
            grid_shape,
        })
    }

    /// Grid that splits only along axis 0 into at most `n` slabs — the
    /// chunked-store analogue of [`crate::coordinator::sharding::shard_field`].
    pub fn axis0(array_shape: &[usize], n: usize) -> Result<Self> {
        if array_shape.is_empty() {
            bail!("empty array shape");
        }
        let d0 = array_shape[0];
        let k = n.clamp(1, d0.max(1));
        let mut chunk_shape = array_shape.to_vec();
        chunk_shape[0] = d0.div_ceil(k).max(1);
        Self::new(array_shape, &chunk_shape)
    }

    pub fn array_shape(&self) -> &[usize] {
        &self.array_shape
    }

    pub fn chunk_shape(&self) -> &[usize] {
        &self.chunk_shape
    }

    pub fn grid_shape(&self) -> &[usize] {
        &self.grid_shape
    }

    pub fn ndim(&self) -> usize {
        self.array_shape.len()
    }

    /// Total number of chunks.
    pub fn chunk_count(&self) -> usize {
        self.grid_shape.iter().product()
    }

    /// Row-major grid coordinates of a linear chunk index.
    pub fn chunk_coords(&self, index: usize) -> Vec<usize> {
        debug_assert!(index < self.chunk_count());
        let mut rem = index;
        let mut coords = vec![0usize; self.ndim()];
        for d in (0..self.ndim()).rev() {
            coords[d] = rem % self.grid_shape[d];
            rem /= self.grid_shape[d];
        }
        coords
    }

    /// Linear chunk index of row-major grid coordinates.
    pub fn chunk_index(&self, coords: &[usize]) -> usize {
        debug_assert_eq!(coords.len(), self.ndim());
        let mut lin = 0usize;
        for (d, &c) in coords.iter().enumerate() {
            debug_assert!(c < self.grid_shape[d]);
            lin = lin * self.grid_shape[d] + c;
        }
        lin
    }

    /// Array-space origin of the chunk at `coords`.
    pub fn chunk_origin(&self, coords: &[usize]) -> Vec<usize> {
        coords
            .iter()
            .zip(&self.chunk_shape)
            .map(|(&c, &s)| c * s)
            .collect()
    }

    /// Extent of the chunk at `coords`, clipped to the array bounds (edge
    /// chunks are smaller than the nominal chunk shape).
    pub fn chunk_extent(&self, coords: &[usize]) -> Vec<usize> {
        coords
            .iter()
            .zip(&self.chunk_shape)
            .zip(&self.array_shape)
            .map(|((&c, &s), &a)| s.min(a - c * s))
            .collect()
    }

    /// Zarr-style chunk key for display (`c/1/0/3`).
    pub fn chunk_key(&self, index: usize) -> String {
        let coords = self.chunk_coords(index);
        let mut key = String::from("c");
        for c in coords {
            key.push('/');
            key.push_str(&c.to_string());
        }
        key
    }

    /// Linear indices of every chunk intersecting the region
    /// `[origin, origin + shape)`, in ascending order. Errors if the region
    /// is malformed or extends past the array.
    pub fn chunks_intersecting(&self, origin: &[usize], shape: &[usize]) -> Result<Vec<usize>> {
        self.validate_region(origin, shape)?;
        if shape.iter().any(|&d| d == 0) {
            return Ok(Vec::new());
        }
        // Per-axis inclusive chunk-coordinate range covered by the region.
        let lo: Vec<usize> = origin
            .iter()
            .zip(&self.chunk_shape)
            .map(|(&o, &c)| o / c)
            .collect();
        let hi: Vec<usize> = origin
            .iter()
            .zip(shape)
            .zip(&self.chunk_shape)
            .map(|((&o, &s), &c)| (o + s - 1) / c)
            .collect();
        let mut out = Vec::new();
        let mut coords = lo.clone();
        'outer: loop {
            out.push(self.chunk_index(&coords));
            for d in (0..self.ndim()).rev() {
                coords[d] += 1;
                if coords[d] <= hi[d] {
                    continue 'outer;
                }
                coords[d] = lo[d];
                if d == 0 {
                    break 'outer;
                }
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// Check that `[origin, origin + shape)` lies inside the array.
    pub fn validate_region(&self, origin: &[usize], shape: &[usize]) -> Result<()> {
        if origin.len() != self.ndim() || shape.len() != self.ndim() {
            bail!(
                "region origin {:?} / shape {:?} dimensionality does not match array {:?}",
                origin,
                shape,
                self.array_shape
            );
        }
        for d in 0..self.ndim() {
            // origin/shape come from the CLI; checked add so absurd values
            // reject cleanly instead of wrapping in release builds.
            let in_bounds = matches!(
                origin[d].checked_add(shape[d]),
                Some(end) if end <= self.array_shape[d]
            );
            if !in_bounds {
                bail!(
                    "region [{} + {}) exceeds axis {} extent {}",
                    origin[d],
                    shape[d],
                    d,
                    self.array_shape[d]
                );
            }
        }
        Ok(())
    }
}

/// Copy the subarray `[origin, origin + shape)` out of a row-major array.
pub fn extract_subarray(
    data: &[f64],
    array_shape: &[usize],
    origin: &[usize],
    shape: &[usize],
) -> Vec<f64> {
    let n: usize = shape.iter().product();
    let mut out = vec![0.0f64; n];
    for_each_row(array_shape, origin, shape, |a_off, s_off, row| {
        out[s_off..s_off + row].copy_from_slice(&data[a_off..a_off + row]);
    });
    out
}

/// Copy `src` (row-major, `shape`) into the subarray `[origin, origin +
/// shape)` of a row-major destination array.
pub fn insert_subarray(
    dst: &mut [f64],
    array_shape: &[usize],
    origin: &[usize],
    src: &[f64],
    shape: &[usize],
) {
    debug_assert_eq!(src.len(), shape.iter().product::<usize>());
    for_each_row(array_shape, origin, shape, |a_off, s_off, row| {
        dst[a_off..a_off + row].copy_from_slice(&src[s_off..s_off + row]);
    });
}

/// Visit every contiguous last-axis row of the subarray `[origin, origin +
/// shape)`: `f(array_offset, sub_offset, row_len)`. Rows are contiguous in
/// both the array and the subarray, so callers can `copy_from_slice`.
fn for_each_row(
    array_shape: &[usize],
    origin: &[usize],
    shape: &[usize],
    mut f: impl FnMut(usize, usize, usize),
) {
    let ndim = array_shape.len();
    debug_assert_eq!(origin.len(), ndim);
    debug_assert_eq!(shape.len(), ndim);
    if shape.iter().any(|&d| d == 0) {
        return;
    }
    // Row-major strides of the enclosing array.
    let mut astride = vec![1usize; ndim];
    for d in (0..ndim.saturating_sub(1)).rev() {
        astride[d] = astride[d + 1] * array_shape[d + 1];
    }
    // Row-major strides of the subarray.
    let mut sstride = vec![1usize; ndim];
    for d in (0..ndim.saturating_sub(1)).rev() {
        sstride[d] = sstride[d + 1] * shape[d + 1];
    }
    let row = shape[ndim - 1];
    let mut idx = vec![0usize; ndim]; // last axis stays 0
    loop {
        let mut a_off = 0usize;
        let mut s_off = 0usize;
        for d in 0..ndim {
            a_off += (origin[d] + idx[d]) * astride[d];
            s_off += idx[d] * sstride[d];
        }
        f(a_off, s_off, row);
        // Odometer over every axis except the last.
        let mut d = ndim as isize - 2;
        loop {
            if d < 0 {
                return;
            }
            let du = d as usize;
            idx[du] += 1;
            if idx[du] < shape[du] {
                break;
            }
            idx[du] = 0;
            d -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shapes_and_edge_chunks() {
        let g = ChunkGrid::new(&[10, 6], &[4, 4]).unwrap();
        assert_eq!(g.grid_shape(), &[3, 2]);
        assert_eq!(g.chunk_count(), 6);
        // Interior chunk.
        assert_eq!(g.chunk_extent(&[0, 0]), vec![4, 4]);
        // Edge chunks are clipped.
        assert_eq!(g.chunk_extent(&[2, 1]), vec![2, 2]);
        assert_eq!(g.chunk_origin(&[2, 1]), vec![8, 4]);
    }

    #[test]
    fn index_coord_roundtrip_and_keys() {
        let g = ChunkGrid::new(&[8, 8, 8], &[4, 4, 4]).unwrap();
        for i in 0..g.chunk_count() {
            let c = g.chunk_coords(i);
            assert_eq!(g.chunk_index(&c), i);
        }
        assert_eq!(g.chunk_key(0), "c/0/0/0");
        assert_eq!(g.chunk_key(g.chunk_count() - 1), "c/1/1/1");
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(ChunkGrid::new(&[4, 4], &[4]).is_err());
        assert!(ChunkGrid::new(&[4, 0], &[2, 2]).is_err());
        assert!(ChunkGrid::new(&[4, 4], &[0, 2]).is_err());
        assert!(ChunkGrid::new(&[], &[]).is_err());
    }

    #[test]
    fn axis0_matches_shard_granularity() {
        let g = ChunkGrid::axis0(&[10, 3], 4).unwrap();
        assert_eq!(g.chunk_shape(), &[3, 3]);
        assert_eq!(g.grid_shape(), &[4, 1]);
        // More shards than rows clamps to one row per chunk.
        let g = ChunkGrid::axis0(&[3, 5], 100).unwrap();
        assert_eq!(g.chunk_shape(), &[1, 5]);
    }

    #[test]
    fn intersection_enumerates_covering_chunks() {
        let g = ChunkGrid::new(&[10, 6], &[4, 4]).unwrap();
        // Region fully inside chunk (0, 0).
        assert_eq!(g.chunks_intersecting(&[0, 0], &[3, 3]).unwrap(), vec![0]);
        // Region straddling all four chunk corners around (4, 4).
        let ids = g.chunks_intersecting(&[2, 2], &[4, 3]).unwrap();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        // Full array touches every chunk.
        assert_eq!(
            g.chunks_intersecting(&[0, 0], &[10, 6]).unwrap().len(),
            g.chunk_count()
        );
        // Empty region touches nothing.
        assert!(g.chunks_intersecting(&[1, 1], &[0, 2]).unwrap().is_empty());
        // Out-of-bounds region is rejected.
        assert!(g.chunks_intersecting(&[8, 4], &[4, 4]).is_err());
    }

    #[test]
    fn subarray_roundtrip_3d() {
        let shape = [4usize, 5, 6];
        let n: usize = shape.iter().product();
        let data: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let origin = [1usize, 2, 3];
        let sub_shape = [2usize, 2, 2];
        let sub = extract_subarray(&data, &shape, &origin, &sub_shape);
        assert_eq!(sub.len(), 8);
        // Spot-check one element: data[(2, 3, 4)] == sub[(1, 1, 1)].
        assert_eq!(sub[7], data[2 * 30 + 3 * 6 + 4]);
        let mut dst = vec![0.0f64; n];
        insert_subarray(&mut dst, &shape, &origin, &sub, &sub_shape);
        let back = extract_subarray(&dst, &shape, &origin, &sub_shape);
        assert_eq!(back, sub);
    }

    #[test]
    fn subarray_1d_and_full() {
        let data: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert_eq!(extract_subarray(&data, &[10], &[3], &[4]), data[3..7]);
        assert_eq!(extract_subarray(&data, &[10], &[0], &[10]), data);
    }
}
