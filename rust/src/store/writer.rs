//! Chunked store encoder: tile a field, encode chunks in parallel (each
//! through its codec chain), and assemble the `.ffcz` container (payloads
//! first, manifest appended, 24-byte footer last — see [`super::manifest`]
//! for the exact layout).

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::codec::{CodecChain, CodecChainSpec};
use crate::data::Field;
use crate::encoding::crc32;

use super::grid::{extract_subarray, ChunkGrid};
use super::manifest::{ChunkEntry, Manifest, FOOTER_MAGIC, STORE_MAGIC};
use super::parallel::par_try_map;

/// Options for store creation.
#[derive(Debug, Clone)]
pub struct StoreWriteOptions {
    /// Chunk shape (same dimensionality as the field).
    pub chunk_shape: Vec<usize>,
    /// Worker threads for per-chunk encoding.
    pub workers: usize,
    /// Per-chunk codec chain overrides, keyed by the grid's zarr-style
    /// chunk key (`"c/1/0"`); chunks not named here use the store default
    /// (e.g. a lossless chain for boundary chunks, FFCz elsewhere).
    /// Unknown keys are rejected at encode time.
    pub overrides: Vec<(String, CodecChainSpec)>,
}

impl StoreWriteOptions {
    pub fn new(chunk_shape: &[usize]) -> Self {
        Self {
            chunk_shape: chunk_shape.to_vec(),
            workers: 1,
            overrides: Vec::new(),
        }
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Encode the chunk with key `key` (e.g. `"c/0/1"`) through `chain`
    /// instead of the store default.
    pub fn override_chunk(mut self, key: &str, chain: CodecChainSpec) -> Self {
        self.overrides.push((key.to_string(), chain));
        self
    }

    /// Default chunking for a field: axis-0 slabs, `max(workers, 2)` of
    /// them (so even a single-worker write produces a multi-chunk store —
    /// partial reads stay partial), clamped to the axis-0 extent. The
    /// sharding-style default used by the CLI and the pipeline store sink.
    pub fn default_for(field_shape: &[usize], workers: usize) -> Result<Self> {
        let grid = ChunkGrid::axis0(field_shape, workers.max(2))?;
        Ok(Self {
            chunk_shape: grid.chunk_shape().to_vec(),
            workers: workers.max(1),
            overrides: Vec::new(),
        })
    }
}

/// Summary of one store write.
#[derive(Debug, Clone)]
pub struct StoreWriteReport {
    pub chunk_count: usize,
    pub payload_bytes: usize,
    pub manifest_bytes: usize,
    pub total_bytes: usize,
    /// True iff every chunk's dual-domain verification passed.
    pub all_chunks_ok: bool,
    pub elapsed: Duration,
}

/// Resolve the default chain plus overrides into a deduplicated chain
/// table and a per-chunk chain assignment.
fn resolve_chains(
    grid: &ChunkGrid,
    default: &CodecChainSpec,
    overrides: &[(String, CodecChainSpec)],
) -> Result<(Vec<CodecChainSpec>, Vec<usize>)> {
    let mut chains = vec![default.clone()];
    let mut assign = vec![0usize; grid.chunk_count()];
    if !overrides.is_empty() {
        let key_to_index: HashMap<String, usize> = (0..grid.chunk_count())
            .map(|i| (grid.chunk_key(i), i))
            .collect();
        for (key, chain) in overrides {
            let Some(&i) = key_to_index.get(key) else {
                bail!(
                    "codec override names chunk '{key}', but the {:?} grid has keys \
                     'c/0/…' through '{}'",
                    grid.grid_shape(),
                    grid.chunk_key(grid.chunk_count() - 1)
                );
            };
            let idx = match chains.iter().position(|c| c == chain) {
                Some(idx) => idx,
                None => {
                    chains.push(chain.clone());
                    chains.len() - 1
                }
            };
            assign[i] = idx;
        }
    }
    Ok((chains, assign))
}

/// Encode `field` as an in-memory `.ffcz` store. `chain` is the default
/// codec chain; per-chunk overrides come from
/// [`StoreWriteOptions::overrides`].
pub fn encode_store(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
) -> Result<(Vec<u8>, Manifest, StoreWriteReport)> {
    let t0 = Instant::now();
    let grid = ChunkGrid::new(field.shape(), &opts.chunk_shape)?;
    let (chains, assign) = resolve_chains(&grid, chain, &opts.overrides)?;
    let built: Vec<CodecChain> = chains
        .iter()
        .map(CodecChain::from_spec)
        .collect::<Result<_>>()?;

    let encoded = par_try_map(grid.chunk_count(), opts.workers, |i| {
        let coords = grid.chunk_coords(i);
        let origin = grid.chunk_origin(&coords);
        let extent = grid.chunk_extent(&coords);
        let chunk = Field::new(
            &extent,
            extract_subarray(field.data(), field.shape(), &origin, &extent),
            field.precision(),
        );
        built[assign[i]]
            .encode_chunk(&chunk)
            .with_context(|| format!("encoding chunk {}", grid.chunk_key(i)))
    })?;

    // Assemble: head magic, payloads, manifest, footer.
    let mut out = Vec::new();
    out.extend_from_slice(STORE_MAGIC);
    let mut chunks = Vec::with_capacity(encoded.len());
    for (i, enc) in encoded.iter().enumerate() {
        chunks.push(ChunkEntry {
            offset: out.len() as u64,
            length: enc.bytes.len() as u64,
            chain: assign[i],
            crc32: Some(crc32(&enc.bytes)),
            stats: enc.stats,
        });
        out.extend_from_slice(&enc.bytes);
    }
    let manifest = Manifest {
        shape: field.shape().to_vec(),
        precision: field.precision(),
        chunk_shape: opts.chunk_shape.clone(),
        chains,
        chunks,
    };
    let manifest_bytes = manifest.to_bytes();
    let manifest_offset = out.len() as u64;
    out.extend_from_slice(&manifest_bytes);
    out.extend_from_slice(&manifest_offset.to_le_bytes());
    out.extend_from_slice(&(manifest_bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(FOOTER_MAGIC);

    let report = StoreWriteReport {
        chunk_count: manifest.chunks.len(),
        payload_bytes: manifest.payload_bytes() as usize,
        manifest_bytes: manifest_bytes.len(),
        total_bytes: out.len(),
        all_chunks_ok: manifest.all_chunks_ok(),
        elapsed: t0.elapsed(),
    };
    Ok((out, manifest, report))
}

/// Encode `field` and write the store to `path`.
pub fn write_store(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &Path,
) -> Result<StoreWriteReport> {
    let (bytes, _, report) = encode_store(field, chain, opts)?;
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::correction::FfczConfig;
    use crate::data::synth::grf::GrfBuilder;

    #[test]
    fn encode_produces_consistent_manifest() {
        let field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(3).build();
        let spec = CodecChainSpec::lossless();
        let opts = StoreWriteOptions::new(&[5, 4]).workers(2);
        let (bytes, manifest, report) = encode_store(&field, &spec, &opts).unwrap();
        assert_eq!(report.chunk_count, 3 * 3);
        assert_eq!(manifest.chunks.len(), 9);
        assert!(report.all_chunks_ok);
        // Payload ranges tile [8, manifest_offset) without gaps, every
        // chunk checksummed against its payload and on the default chain.
        let mut cursor = STORE_MAGIC.len() as u64;
        for c in &manifest.chunks {
            assert_eq!(c.offset, cursor);
            assert_eq!(c.chain, 0);
            let payload = &bytes[c.offset as usize..(c.offset + c.length) as usize];
            assert_eq!(c.crc32, Some(crc32(payload)));
            cursor += c.length;
        }
        assert_eq!(report.total_bytes, bytes.len());
        assert_eq!(&bytes[..8], STORE_MAGIC);
        assert_eq!(&bytes[bytes.len() - 8..], FOOTER_MAGIC);
    }

    #[test]
    fn chunk_shape_mismatch_rejected() {
        let field = GrfBuilder::new(&[8, 8]).seed(1).build();
        let opts = StoreWriteOptions::new(&[4]);
        assert!(encode_store(&field, &CodecChainSpec::lossless(), &opts).is_err());
    }

    #[test]
    fn overrides_build_a_deduplicated_chain_table() {
        let field = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(5).build();
        let ffcz = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        // 2 × 2 grid; two overrides with the same lossless chain dedup to
        // one extra table entry.
        let opts = StoreWriteOptions::new(&[4, 4])
            .workers(2)
            .override_chunk("c/0/0", CodecChainSpec::lossless())
            .override_chunk("c/1/1", CodecChainSpec::lossless());
        let (_, manifest, report) = encode_store(&field, &ffcz, &opts).unwrap();
        assert!(report.all_chunks_ok);
        assert_eq!(manifest.chains.len(), 2);
        assert_eq!(manifest.chains[0], ffcz);
        assert_eq!(manifest.chains[1], CodecChainSpec::lossless());
        let assigned: Vec<usize> = manifest.chunks.iter().map(|c| c.chain).collect();
        assert_eq!(assigned, vec![1, 0, 0, 1]);
    }

    #[test]
    fn unknown_override_key_rejected() {
        let field = GrfBuilder::new(&[8, 8]).seed(1).build();
        let opts = StoreWriteOptions::new(&[4, 4])
            .override_chunk("c/9/9", CodecChainSpec::lossless());
        let err = encode_store(&field, &CodecChainSpec::lossless(), &opts)
            .unwrap_err()
            .to_string();
        assert!(err.contains("c/9/9"), "{err}");
    }
}
