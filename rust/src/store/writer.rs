//! Chunked store encoder: tile a field, encode chunks in parallel, and
//! assemble the `.ffcz` container (payloads first, manifest appended,
//! 24-byte footer last — see [`super::manifest`] for the exact layout).

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::data::Field;

use super::codec::CodecSpec;
use super::grid::{extract_subarray, ChunkGrid};
use super::manifest::{ChunkEntry, Manifest, FOOTER_MAGIC, STORE_MAGIC};
use super::parallel::par_try_map;

/// Options for store creation.
#[derive(Debug, Clone)]
pub struct StoreWriteOptions {
    /// Chunk shape (same dimensionality as the field).
    pub chunk_shape: Vec<usize>,
    /// Worker threads for per-chunk encoding.
    pub workers: usize,
}

impl StoreWriteOptions {
    pub fn new(chunk_shape: &[usize]) -> Self {
        Self {
            chunk_shape: chunk_shape.to_vec(),
            workers: 1,
        }
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Default chunking for a field: axis-0 slabs, `max(workers, 2)` of
    /// them (so even a single-worker write produces a multi-chunk store —
    /// partial reads stay partial), clamped to the axis-0 extent. The
    /// sharding-style default used by the CLI and the pipeline store sink.
    pub fn default_for(field_shape: &[usize], workers: usize) -> Result<Self> {
        let grid = ChunkGrid::axis0(field_shape, workers.max(2))?;
        Ok(Self {
            chunk_shape: grid.chunk_shape().to_vec(),
            workers: workers.max(1),
        })
    }
}

/// Summary of one store write.
#[derive(Debug, Clone)]
pub struct StoreWriteReport {
    pub chunk_count: usize,
    pub payload_bytes: usize,
    pub manifest_bytes: usize,
    pub total_bytes: usize,
    /// True iff every chunk's dual-domain verification passed.
    pub all_chunks_ok: bool,
    pub elapsed: Duration,
}

/// Encode `field` as an in-memory `.ffcz` store.
pub fn encode_store(
    field: &Field,
    spec: &CodecSpec,
    opts: &StoreWriteOptions,
) -> Result<(Vec<u8>, Manifest, StoreWriteReport)> {
    let t0 = Instant::now();
    let grid = ChunkGrid::new(field.shape(), &opts.chunk_shape)?;
    let codec = spec.build()?;

    let encoded = par_try_map(grid.chunk_count(), opts.workers, |i| {
        let coords = grid.chunk_coords(i);
        let origin = grid.chunk_origin(&coords);
        let extent = grid.chunk_extent(&coords);
        let chunk = Field::new(
            &extent,
            extract_subarray(field.data(), field.shape(), &origin, &extent),
            field.precision(),
        );
        codec
            .encode(&chunk)
            .with_context(|| format!("encoding chunk {}", grid.chunk_key(i)))
    })?;

    // Assemble: head magic, payloads, manifest, footer.
    let mut out = Vec::new();
    out.extend_from_slice(STORE_MAGIC);
    let mut chunks = Vec::with_capacity(encoded.len());
    for enc in &encoded {
        chunks.push(ChunkEntry {
            offset: out.len() as u64,
            length: enc.bytes.len() as u64,
            stats: enc.stats,
        });
        out.extend_from_slice(&enc.bytes);
    }
    let manifest = Manifest {
        shape: field.shape().to_vec(),
        precision: field.precision(),
        chunk_shape: opts.chunk_shape.clone(),
        codec: spec.clone(),
        chunks,
    };
    let manifest_bytes = manifest.to_bytes();
    let manifest_offset = out.len() as u64;
    out.extend_from_slice(&manifest_bytes);
    out.extend_from_slice(&manifest_offset.to_le_bytes());
    out.extend_from_slice(&(manifest_bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(FOOTER_MAGIC);

    let report = StoreWriteReport {
        chunk_count: manifest.chunks.len(),
        payload_bytes: manifest.payload_bytes() as usize,
        manifest_bytes: manifest_bytes.len(),
        total_bytes: out.len(),
        all_chunks_ok: manifest.all_chunks_ok(),
        elapsed: t0.elapsed(),
    };
    Ok((out, manifest, report))
}

/// Encode `field` and write the store to `path`.
pub fn write_store(
    field: &Field,
    spec: &CodecSpec,
    opts: &StoreWriteOptions,
    path: &Path,
) -> Result<StoreWriteReport> {
    let (bytes, _, report) = encode_store(field, spec, opts)?;
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::grf::GrfBuilder;

    #[test]
    fn encode_produces_consistent_manifest() {
        let field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(3).build();
        let spec = CodecSpec::Lossless;
        let opts = StoreWriteOptions::new(&[5, 4]).workers(2);
        let (bytes, manifest, report) = encode_store(&field, &spec, &opts).unwrap();
        assert_eq!(report.chunk_count, 3 * 3);
        assert_eq!(manifest.chunks.len(), 9);
        assert!(report.all_chunks_ok);
        // Payload ranges tile [8, manifest_offset) without gaps.
        let mut cursor = STORE_MAGIC.len() as u64;
        for c in &manifest.chunks {
            assert_eq!(c.offset, cursor);
            cursor += c.length;
        }
        assert_eq!(report.total_bytes, bytes.len());
        assert_eq!(&bytes[..8], STORE_MAGIC);
        assert_eq!(&bytes[bytes.len() - 8..], FOOTER_MAGIC);
    }

    #[test]
    fn chunk_shape_mismatch_rejected() {
        let field = GrfBuilder::new(&[8, 8]).seed(1).build();
        let opts = StoreWriteOptions::new(&[4]);
        assert!(encode_store(&field, &CodecSpec::Lossless, &opts).is_err());
    }
}
