//! Chunked store encoder: tile a field, encode chunks in parallel (each
//! through its codec chain), and produce the `.ffcz` container (payloads
//! first, manifest appended, 24-byte trailer last — normative layout in
//! `docs/FORMAT.md`, field-by-field notes in [`super::manifest`]).
//!
//! Two write paths share one byte format:
//!
//! * **streaming** ([`stream_store_to`] / [`write_store`], the default) —
//!   the worker pool hands finished chunk payloads to this (single writer)
//!   thread through a bounded in-flight window and each payload is spilled
//!   to the output as it completes, so peak payload memory is
//!   O((workers + queue_depth) × chunk), not O(field). The manifest and
//!   trailer are written last, which is exactly why readers locate the
//!   manifest through the trailer.
//! * **in-memory** ([`encode_store`] / [`write_store_in_memory`]) — the
//!   whole container is assembled in a `Vec<u8>` (useful for tests and
//!   `Store::from_bytes` round-trips; the CLI exposes it as
//!   `--in-memory`).
//!
//! Because the streaming sink consumes chunks in index order, both paths
//! produce **byte-identical** archives for any worker count.

use std::collections::HashMap;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::codec::{CodecChain, CodecChainSpec, EncodedChunk};
use crate::correction::CorrectionScratch;
use crate::data::{Field, Precision};
use crate::encoding::crc32;
use crate::telemetry;

use super::grid::{extract_subarray, ChunkGrid};
use super::manifest::{ChunkEntry, Manifest, FOOTER_LEN, FOOTER_MAGIC, STORE_MAGIC};
use super::parallel::{par_try_map_ordered_sink_with, par_try_map_with};

/// Options for store creation.
#[derive(Debug, Clone)]
pub struct StoreWriteOptions {
    /// Chunk shape (same dimensionality as the field).
    pub chunk_shape: Vec<usize>,
    /// Worker threads for per-chunk encoding.
    pub workers: usize,
    /// Extra in-flight chunk payloads the streaming writer may buffer
    /// beyond one per worker (the bounded hand-off window is
    /// `workers + queue_depth`). Irrelevant to the in-memory path.
    pub queue_depth: usize,
    /// Per-chunk codec chain overrides, keyed by the grid's zarr-style
    /// chunk key (`"c/1/0"`); chunks not named here use the store default
    /// (e.g. a lossless chain for boundary chunks, FFCz elsewhere).
    /// Unknown keys are rejected at encode time.
    pub overrides: Vec<(String, CodecChainSpec)>,
}

impl StoreWriteOptions {
    pub fn new(chunk_shape: &[usize]) -> Self {
        Self {
            chunk_shape: chunk_shape.to_vec(),
            workers: 1,
            queue_depth: 2,
            overrides: Vec::new(),
        }
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Bound the streaming writer's in-flight window to
    /// `workers + queue_depth` encoded-but-unwritten chunk payloads.
    pub fn queue_depth(mut self, queue_depth: usize) -> Self {
        self.queue_depth = queue_depth;
        self
    }

    /// Encode the chunk with key `key` (e.g. `"c/0/1"`) through `chain`
    /// instead of the store default.
    pub fn override_chunk(mut self, key: &str, chain: CodecChainSpec) -> Self {
        self.overrides.push((key.to_string(), chain));
        self
    }

    /// Default chunking for a field: axis-0 slabs, `max(workers, 2)` of
    /// them (so even a single-worker write produces a multi-chunk store —
    /// partial reads stay partial), clamped to the axis-0 extent. The
    /// sharding-style default used by the CLI and the pipeline store sink.
    pub fn default_for(field_shape: &[usize], workers: usize) -> Result<Self> {
        let grid = ChunkGrid::axis0(field_shape, workers.max(2))?;
        Ok(Self {
            chunk_shape: grid.chunk_shape().to_vec(),
            workers: workers.max(1),
            queue_depth: 2,
            overrides: Vec::new(),
        })
    }

    /// The streaming writer's bounded in-flight window: how many encoded
    /// chunk payloads may exist at once before workers stall.
    pub fn window(&self) -> usize {
        self.workers.max(1) + self.queue_depth
    }
}

/// Summary of one store write.
#[derive(Debug, Clone)]
pub struct StoreWriteReport {
    pub chunk_count: usize,
    pub payload_bytes: usize,
    pub manifest_bytes: usize,
    pub total_bytes: usize,
    /// True iff every chunk's dual-domain verification passed.
    pub all_chunks_ok: bool,
    /// High-water mark of encoded-but-unwritten chunk payload bytes (a
    /// peak-RSS proxy). The streaming path bounds this to the in-flight
    /// window; the in-memory path holds every payload, so it equals
    /// `payload_bytes` there.
    pub peak_payload_bytes: usize,
    /// True for the streaming write path, false for in-memory assembly.
    pub streamed: bool,
    /// Correction-scratch allocation events summed over all workers (plan
    /// first contacts, spectrum/workspace buffer growth — see
    /// [`CorrectionScratch::allocation_events`]). Each worker warms once
    /// per chunk shape; steady-state chunks add zero, so on a
    /// uniform-chunk grid this stays O(workers), not O(chunks). The
    /// throughput bench emits the per-chunk steady-state gauge derived
    /// from the same counter and CI asserts it is zero.
    pub scratch_alloc_events: usize,
    pub elapsed: Duration,
    /// Per-chunk encode breakdown (manifest stats joined with stage wall
    /// times from [`crate::codec::ChunkEncodeDetail`]), in chunk index
    /// order. Powers `archive create --stats` and
    /// [`StoreWriteReport::render_chunk_table`].
    pub chunk_reports: Vec<ChunkEncodeReport>,
}

impl StoreWriteReport {
    /// Human-readable per-chunk stats table (the `--stats` rendering).
    /// Empty string when no chunk reports were collected.
    pub fn render_chunk_table(&self) -> String {
        if self.chunk_reports.is_empty() {
            return String::new();
        }
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        let mut out = String::new();
        out.push_str(&format!(
            "{:<12} {:>5} {:>10} {:>10} {:>6} {:>5} {:>3} {:>2} {:>9} {:>9} {:>9} {:>9} {:>9}\n",
            "chunk",
            "chain",
            "bytes_in",
            "bytes_out",
            "ratio",
            "iters",
            "att",
            "fb",
            "base_ms",
            "pocs_ms",
            "verif_ms",
            "lossl_ms",
            "total_ms"
        ));
        for r in &self.chunk_reports {
            let ratio = if r.bytes_out > 0 {
                r.bytes_in as f64 / r.bytes_out as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<12} {:>5} {:>10} {:>10} {:>6.2} {:>5} {:>3} {:>2} {:>9.3} {:>9.3} {:>9.3} \
                 {:>9.3} {:>9.3}\n",
                r.key,
                r.chain,
                r.bytes_in,
                r.bytes_out,
                ratio,
                r.pocs_iterations,
                r.quant_attempts,
                if r.used_raw_fallback { "y" } else { "-" },
                ms(r.base_compress),
                ms(r.correct),
                ms(r.verify),
                ms(r.lossless),
                ms(r.total)
            ));
        }
        out
    }
}

/// Per-chunk breakdown of one store write: the manifest-persisted
/// verification stats joined with the in-memory stage measurements the
/// codec records while encoding.
#[derive(Debug, Clone)]
pub struct ChunkEncodeReport {
    /// Row-major chunk index.
    pub index: usize,
    /// Zarr-style chunk key (`"c/1/0"`).
    pub key: String,
    /// Chain-table index the chunk encoded through.
    pub chain: usize,
    /// Uncompressed chunk bytes.
    pub bytes_in: usize,
    /// Encoded payload bytes.
    pub bytes_out: usize,
    /// POCS iterations spent correcting this chunk.
    pub pocs_iterations: u32,
    /// Quantization retry-ladder attempts consumed.
    pub quant_attempts: u32,
    /// Whether the raw-edit fallback fired.
    pub used_raw_fallback: bool,
    pub base_compress: Duration,
    pub correct: Duration,
    pub verify: Duration,
    pub lossless: Duration,
    pub total: Duration,
}

fn chunk_report(grid: &ChunkGrid, i: usize, chain: usize, enc: &EncodedChunk) -> ChunkEncodeReport {
    let d = enc.detail;
    ChunkEncodeReport {
        index: i,
        key: grid.chunk_key(i),
        chain,
        bytes_in: d.bytes_in,
        bytes_out: enc.bytes.len(),
        pocs_iterations: enc.stats.pocs_iterations,
        quant_attempts: d.quant_attempts,
        used_raw_fallback: d.used_raw_fallback,
        base_compress: d.base_compress,
        correct: d.correct,
        verify: d.verify,
        lossless: d.lossless,
        total: d.total,
    }
}

/// Registered-metric handles for the store write path, fetched once.
struct WriteMetrics {
    scratch_alloc_events: telemetry::Counter,
    peak_payload_bytes: telemetry::Gauge,
}

fn write_metrics() -> &'static WriteMetrics {
    static METRICS: std::sync::OnceLock<WriteMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(|| WriteMetrics {
        scratch_alloc_events: telemetry::counter("store.encode.scratch_alloc_events"),
        peak_payload_bytes: telemetry::gauge("store.write.peak_payload_bytes"),
    })
}

/// POCS transform thread count a chain requests (1 when it has no
/// correction stage). `0` = auto, kept distinct from an explicit 1 so an
/// auto-threaded override never dedups onto an explicitly single-threaded
/// chain entry (or vice versa).
fn chain_threads(spec: &CodecChainSpec) -> usize {
    spec.correction.as_ref().map_or(1, |c| c.threads)
}

/// Cooperative per-chunk transform thread budget for chains that left
/// `threads` on auto (0): divide the machine between the cross-chunk
/// worker pool, so per-chunk line threading composes with `workers`
/// concurrent chunk encodes without oversubscription. One core per worker
/// is the floor. Callers pass the *effective* worker count
/// (`min(workers, chunks)`) so a pool bigger than the grid doesn't
/// undersubscribe the machine.
fn auto_thread_budget(workers: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    (cores / workers.max(1)).max(1)
}

/// Resolve `threads == 0` (auto) to the cooperative budget on every chain
/// with a correction stage. Explicit thread counts (≥ 1) always win.
/// Execution-only: `threads` is never serialized, so resolved and
/// unresolved chains produce identical manifests and archive bytes.
fn resolve_auto_threads(chains: &mut [CodecChainSpec], workers: usize) {
    let budget = auto_thread_budget(workers);
    for spec in chains.iter_mut() {
        if let Some(correction) = spec.correction.as_mut() {
            if correction.threads == 0 {
                correction.threads = budget;
            }
        }
    }
}

/// Resolve the default chain plus overrides into a deduplicated chain
/// table and a per-chunk chain assignment.
fn resolve_chains(
    grid: &ChunkGrid,
    default: &CodecChainSpec,
    overrides: &[(String, CodecChainSpec)],
) -> Result<(Vec<CodecChainSpec>, Vec<usize>)> {
    let mut chains = vec![default.clone()];
    let mut assign = vec![0usize; grid.chunk_count()];
    if !overrides.is_empty() {
        let key_to_index: HashMap<String, usize> = (0..grid.chunk_count())
            .map(|i| (grid.chunk_key(i), i))
            .collect();
        for (key, chain) in overrides {
            let Some(&i) = key_to_index.get(key) else {
                bail!(
                    "codec override names chunk '{key}', but the {:?} grid has keys \
                     'c/0/…' through '{}'",
                    grid.grid_shape(),
                    grid.chunk_key(grid.chunk_count() - 1)
                );
            };
            // Dedup requires the *execution* thread count to match too:
            // `CodecChainSpec::eq` deliberately ignores `threads` (it is
            // not codec identity and never serialized), but collapsing a
            // `threads=`-only override onto an existing entry would encode
            // the chunk with the existing entry's thread count. Entries
            // that differ only in threads serialize to identical bytes, so
            // the extra table slot costs a few manifest bytes at most.
            let idx = match chains
                .iter()
                .position(|c| c == chain && chain_threads(c) == chain_threads(chain))
            {
                Some(idx) => idx,
                None => {
                    chains.push(chain.clone());
                    chains.len() - 1
                }
            };
            assign[i] = idx;
        }
    }
    Ok((chains, assign))
}

/// Encode `field` as an in-memory `.ffcz` store. `chain` is the default
/// codec chain; per-chunk overrides come from
/// [`StoreWriteOptions::overrides`].
pub fn encode_store(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
) -> Result<(Vec<u8>, Manifest, StoreWriteReport)> {
    let t0 = Instant::now();
    let grid = ChunkGrid::new(field.shape(), &opts.chunk_shape)?;
    let write_span = telemetry::span("store.write").arg("chunks", grid.chunk_count() as u64);
    let write_span_id = write_span.id();
    let (mut chains, assign) = resolve_chains(&grid, chain, &opts.overrides)?;
    // Budget against the number of workers that will actually run (the
    // pool clamps itself to the chunk count).
    resolve_auto_threads(&mut chains, opts.workers.clamp(1, grid.chunk_count().max(1)));
    let built: Vec<CodecChain> = chains
        .iter()
        .map(CodecChain::from_spec)
        .collect::<Result<_>>()?;

    // Each worker owns one correction scratch across every chunk it
    // encodes; the counter audits that reuse (warm-up only, zero steady
    // state).
    let scratch_events = AtomicUsize::new(0);
    let encoded = par_try_map_with(
        grid.chunk_count(),
        opts.workers,
        CorrectionScratch::new,
        |i, scratch| {
            let _chunk_span = telemetry::span_with_parent("store.chunk.encode", write_span_id)
                .arg("chunk", i as u64);
            let coords = grid.chunk_coords(i);
            let origin = grid.chunk_origin(&coords);
            let extent = grid.chunk_extent(&coords);
            let chunk = Field::new(
                &extent,
                extract_subarray(field.data(), field.shape(), &origin, &extent),
                field.precision(),
            );
            let before = scratch.allocation_events();
            let enc = built[assign[i]]
                .encode_chunk_with_scratch(&chunk, scratch)
                .with_context(|| format!("encoding chunk {}", grid.chunk_key(i)))?;
            scratch_events.fetch_add(
                (scratch.allocation_events() - before) as usize,
                Ordering::Relaxed,
            );
            Ok(enc)
        },
    )?;

    // Assemble: head magic, payloads, manifest, footer.
    let mut out = Vec::new();
    out.extend_from_slice(STORE_MAGIC);
    let mut chunks = Vec::with_capacity(encoded.len());
    for (i, enc) in encoded.iter().enumerate() {
        chunks.push(ChunkEntry {
            offset: out.len() as u64,
            length: enc.bytes.len() as u64,
            chain: assign[i],
            crc32: Some(crc32(&enc.bytes)),
            stats: enc.stats,
        });
        out.extend_from_slice(&enc.bytes);
    }
    let manifest = Manifest {
        shape: field.shape().to_vec(),
        precision: field.precision(),
        chunk_shape: opts.chunk_shape.clone(),
        chains,
        chunks,
    };
    let manifest_bytes = manifest.to_bytes();
    let manifest_offset = out.len() as u64;
    out.extend_from_slice(&manifest_bytes);
    out.extend_from_slice(&manifest_offset.to_le_bytes());
    out.extend_from_slice(&(manifest_bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(FOOTER_MAGIC);

    let chunk_reports: Vec<ChunkEncodeReport> = encoded
        .iter()
        .enumerate()
        .map(|(i, enc)| chunk_report(&grid, i, assign[i], enc))
        .collect();
    let scratch_alloc_events = scratch_events.load(Ordering::Relaxed);
    let metrics = write_metrics();
    metrics.scratch_alloc_events.add(scratch_alloc_events as u64);
    metrics
        .peak_payload_bytes
        .max(manifest.payload_bytes());
    let report = StoreWriteReport {
        chunk_count: manifest.chunks.len(),
        payload_bytes: manifest.payload_bytes() as usize,
        manifest_bytes: manifest_bytes.len(),
        total_bytes: out.len(),
        all_chunks_ok: manifest.all_chunks_ok(),
        // Every payload is held until assembly: the in-memory scale wall.
        peak_payload_bytes: manifest.payload_bytes() as usize,
        streamed: false,
        scratch_alloc_events,
        elapsed: t0.elapsed(),
        chunk_reports,
    };
    Ok((out, manifest, report))
}

/// Incremental `.ffcz` container writer: the `StoreSink`-style streaming
/// API underneath [`stream_store_to`].
///
/// The container is written strictly front-to-back — head magic at
/// construction, one payload per [`StoreStreamWriter::append_chunk`] call
/// (in chunk index order), manifest and 24-byte trailer at
/// [`StoreStreamWriter::finish`] — so `W` only needs [`Write`], never
/// `Seek`, and a crash before `finish` leaves a file without the trailer,
/// which readers reject with a precise "truncated or partially-written"
/// error instead of decoding garbage.
pub struct StoreStreamWriter<W: Write> {
    out: W,
    shape: Vec<usize>,
    precision: Precision,
    chunk_shape: Vec<usize>,
    chains: Vec<CodecChainSpec>,
    chunk_count: usize,
    entries: Vec<ChunkEntry>,
    /// Next payload byte offset (tracked, not seeked).
    offset: u64,
}

impl<W: Write> StoreStreamWriter<W> {
    /// Start a container: validates the grid, writes the head magic.
    pub fn new(
        mut out: W,
        shape: &[usize],
        precision: Precision,
        chunk_shape: &[usize],
        chains: Vec<CodecChainSpec>,
    ) -> Result<Self> {
        if chains.is_empty() {
            bail!("store needs at least one codec chain (chain 0 is the default)");
        }
        let grid = ChunkGrid::new(shape, chunk_shape)?;
        out.write_all(STORE_MAGIC).context("writing store header")?;
        Ok(Self {
            out,
            shape: shape.to_vec(),
            precision,
            chunk_shape: chunk_shape.to_vec(),
            chains,
            chunk_count: grid.chunk_count(),
            entries: Vec::with_capacity(grid.chunk_count()),
            offset: STORE_MAGIC.len() as u64,
        })
    }

    /// Number of chunks appended so far (the next expected chunk index).
    pub fn chunks_written(&self) -> usize {
        self.entries.len()
    }

    /// Spill the payload of the next chunk (in row-major grid order) to
    /// the output and record its manifest entry. `chain` indexes the chain
    /// table passed to [`StoreStreamWriter::new`].
    pub fn append_chunk(&mut self, chain: usize, enc: &EncodedChunk) -> Result<()> {
        if self.entries.len() >= self.chunk_count {
            bail!(
                "store already holds all {} chunks; nothing more to append",
                self.chunk_count
            );
        }
        if chain >= self.chains.len() {
            bail!(
                "chunk {} references chain {chain}, but the table has {} entries",
                self.entries.len(),
                self.chains.len()
            );
        }
        self.out
            .write_all(&enc.bytes)
            .with_context(|| format!("writing payload of chunk {}", self.entries.len()))?;
        self.entries.push(ChunkEntry {
            offset: self.offset,
            length: enc.bytes.len() as u64,
            chain,
            crc32: Some(crc32(&enc.bytes)),
            stats: enc.stats,
        });
        self.offset += enc.bytes.len() as u64;
        Ok(())
    }

    /// Write the manifest and trailer, flush, and return the manifest plus
    /// the total container size. Fails if any chunk is missing — a partial
    /// container must never gain a valid trailer.
    pub fn finish(mut self) -> Result<(Manifest, u64)> {
        if self.entries.len() != self.chunk_count {
            bail!(
                "store finish with {} of {} chunks written",
                self.entries.len(),
                self.chunk_count
            );
        }
        let manifest = Manifest {
            shape: self.shape,
            precision: self.precision,
            chunk_shape: self.chunk_shape,
            chains: self.chains,
            chunks: self.entries,
        };
        let manifest_bytes = manifest.to_bytes();
        self.out
            .write_all(&manifest_bytes)
            .context("writing manifest")?;
        self.out
            .write_all(&self.offset.to_le_bytes())
            .context("writing trailer")?;
        self.out
            .write_all(&(manifest_bytes.len() as u64).to_le_bytes())
            .context("writing trailer")?;
        self.out.write_all(FOOTER_MAGIC).context("writing trailer")?;
        self.out.flush().context("flushing store")?;
        let total = self.offset + manifest_bytes.len() as u64 + FOOTER_LEN as u64;
        Ok((manifest, total))
    }
}

/// Encode `field` and stream the container to `out`: chunks are encoded on
/// `opts.workers` threads and each payload is written by this thread as
/// soon as it (and every earlier chunk) is done, holding at most
/// `opts.window()` payloads in memory. Produces bytes identical to
/// [`encode_store`] for any worker count.
pub fn stream_store_to<W: Write>(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    out: W,
) -> Result<(Manifest, StoreWriteReport)> {
    let t0 = Instant::now();
    let grid = ChunkGrid::new(field.shape(), &opts.chunk_shape)?;
    let write_span = telemetry::span("store.write").arg("chunks", grid.chunk_count() as u64);
    let write_span_id = write_span.id();
    let (mut chains, assign) = resolve_chains(&grid, chain, &opts.overrides)?;
    // Budget against the number of workers that will actually run (the
    // pool clamps itself to the chunk count).
    resolve_auto_threads(&mut chains, opts.workers.clamp(1, grid.chunk_count().max(1)));
    let built: Vec<CodecChain> = chains
        .iter()
        .map(CodecChain::from_spec)
        .collect::<Result<_>>()?;
    let mut writer = StoreStreamWriter::new(
        out,
        field.shape(),
        field.precision(),
        &opts.chunk_shape,
        chains,
    )?;

    // Payload-bytes-in-flight gauge (encoded, not yet written): the
    // peak-RSS proxy asserted by tests and reported by the bench.
    let in_flight = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    // Per-worker correction scratch, reused across every chunk a worker
    // encodes (audited by the allocation-event counter).
    let scratch_events = AtomicUsize::new(0);
    let mut chunk_reports: Vec<ChunkEncodeReport> = Vec::with_capacity(grid.chunk_count());
    par_try_map_ordered_sink_with(
        grid.chunk_count(),
        opts.workers,
        opts.window(),
        CorrectionScratch::new,
        |i, scratch| {
            let _chunk_span = telemetry::span_with_parent("store.chunk.encode", write_span_id)
                .arg("chunk", i as u64);
            let coords = grid.chunk_coords(i);
            let origin = grid.chunk_origin(&coords);
            let extent = grid.chunk_extent(&coords);
            let chunk = Field::new(
                &extent,
                extract_subarray(field.data(), field.shape(), &origin, &extent),
                field.precision(),
            );
            let before = scratch.allocation_events();
            let enc = built[assign[i]]
                .encode_chunk_with_scratch(&chunk, scratch)
                .with_context(|| format!("encoding chunk {}", grid.chunk_key(i)))?;
            scratch_events.fetch_add(
                (scratch.allocation_events() - before) as usize,
                Ordering::Relaxed,
            );
            let now = in_flight.fetch_add(enc.bytes.len(), Ordering::SeqCst) + enc.bytes.len();
            peak.fetch_max(now, Ordering::SeqCst);
            Ok(enc)
        },
        |i, enc| {
            let _sink_span = telemetry::span_with_parent("store.chunk.sink", write_span_id)
                .arg("chunk", i as u64)
                .arg("bytes", enc.bytes.len() as u64);
            writer.append_chunk(assign[i], &enc)?;
            chunk_reports.push(chunk_report(&grid, i, assign[i], &enc));
            in_flight.fetch_sub(enc.bytes.len(), Ordering::SeqCst);
            Ok(())
        },
    )?;
    let (manifest, total_bytes) = writer.finish()?;

    let manifest_bytes = total_bytes as usize
        - manifest.payload_bytes() as usize
        - STORE_MAGIC.len()
        - FOOTER_LEN;
    let scratch_alloc_events = scratch_events.load(Ordering::Relaxed);
    let peak_payload_bytes = peak.load(Ordering::SeqCst);
    let metrics = write_metrics();
    metrics.scratch_alloc_events.add(scratch_alloc_events as u64);
    metrics.peak_payload_bytes.max(peak_payload_bytes as u64);
    let report = StoreWriteReport {
        chunk_count: manifest.chunks.len(),
        payload_bytes: manifest.payload_bytes() as usize,
        manifest_bytes,
        total_bytes: total_bytes as usize,
        all_chunks_ok: manifest.all_chunks_ok(),
        peak_payload_bytes,
        streamed: true,
        scratch_alloc_events,
        elapsed: t0.elapsed(),
        chunk_reports,
    };
    Ok((manifest, report))
}

/// Encode `field` and write the store to `path`, **streaming** chunk
/// payloads to the file as they complete (see [`stream_store_to`]); peak
/// payload memory is bounded by `opts.window()` chunks. Use
/// [`write_store_in_memory`] to assemble the container in memory first.
///
/// The stream goes to a `<path>.tmp` sibling that is renamed over `path`
/// only after the trailer is flushed, so a failed or interrupted write
/// never clobbers an existing archive at `path` and never leaves a
/// trailer-less file under the final name.
pub fn write_store(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &Path,
) -> Result<StoreWriteReport> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    let file = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    let mut out = std::io::BufWriter::new(file);
    let result = stream_store_to(field, chain, opts, &mut out)
        .with_context(|| format!("writing {}", tmp.display()));
    drop(out);
    match result {
        Ok((_, report)) => {
            std::fs::rename(&tmp, path).with_context(|| {
                format!("renaming {} to {}", tmp.display(), path.display())
            })?;
            Ok(report)
        }
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// Encode `field` fully in memory, then write the store to `path` (the
/// pre-streaming behavior; peak memory is payload + container).
pub fn write_store_in_memory(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &Path,
) -> Result<StoreWriteReport> {
    let (bytes, _, report) = encode_store(field, chain, opts)?;
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::correction::FfczConfig;
    use crate::data::synth::grf::GrfBuilder;

    #[test]
    fn encode_produces_consistent_manifest() {
        let field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(3).build();
        let spec = CodecChainSpec::lossless();
        let opts = StoreWriteOptions::new(&[5, 4]).workers(2);
        let (bytes, manifest, report) = encode_store(&field, &spec, &opts).unwrap();
        assert_eq!(report.chunk_count, 3 * 3);
        assert_eq!(manifest.chunks.len(), 9);
        assert!(report.all_chunks_ok);
        // Payload ranges tile [8, manifest_offset) without gaps, every
        // chunk checksummed against its payload and on the default chain.
        let mut cursor = STORE_MAGIC.len() as u64;
        for c in &manifest.chunks {
            assert_eq!(c.offset, cursor);
            assert_eq!(c.chain, 0);
            let payload = &bytes[c.offset as usize..(c.offset + c.length) as usize];
            assert_eq!(c.crc32, Some(crc32(payload)));
            cursor += c.length;
        }
        assert_eq!(report.total_bytes, bytes.len());
        assert_eq!(&bytes[..8], STORE_MAGIC);
        assert_eq!(&bytes[bytes.len() - 8..], FOOTER_MAGIC);
        // Per-chunk reports mirror the manifest, in index order.
        assert_eq!(report.chunk_reports.len(), 9);
        for (i, r) in report.chunk_reports.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.bytes_out as u64, manifest.chunks[i].length);
            assert_eq!(r.pocs_iterations, manifest.chunks[i].stats.pocs_iterations);
        }
        // Chunk inputs tile the field exactly: Σ bytes_in = field bytes.
        let total_in: usize = report.chunk_reports.iter().map(|r| r.bytes_in).sum();
        assert_eq!(total_in, 12 * 10 * 8);
        let table = report.render_chunk_table();
        assert!(table.contains("chunk") && table.contains("c/0/0"), "{table}");
    }

    #[test]
    fn chunk_shape_mismatch_rejected() {
        let field = GrfBuilder::new(&[8, 8]).seed(1).build();
        let opts = StoreWriteOptions::new(&[4]);
        assert!(encode_store(&field, &CodecChainSpec::lossless(), &opts).is_err());
    }

    #[test]
    fn threads_only_override_keeps_its_own_chain_entry() {
        // `CodecChainSpec::eq` ignores `threads`, but a threads-only
        // override must NOT collapse onto the default chain entry — the
        // chunk would silently encode with the default's thread count.
        let grid = ChunkGrid::new(&[8, 8], &[4, 4]).unwrap();
        let default = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        let threaded =
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3).with_threads(4));
        let overrides = vec![("c/0/1".to_string(), threaded.clone())];
        let (chains, assign) = resolve_chains(&grid, &default, &overrides).unwrap();
        assert_eq!(chains.len(), 2, "threads-only override was deduped away");
        assert_eq!(assign, vec![0, 1, 0, 0]);
        assert_eq!(chains[1].ffcz_config().unwrap().threads, 4);
        // Wire bytes are still identical (threads is never serialized).
        assert_eq!(chains[0].to_bytes(), chains[1].to_bytes());
    }

    #[test]
    fn auto_threads_resolved_cooperatively_explicit_wins() {
        // Default-constructed configs request auto (threads == 0); the
        // writer resolves them to the cooperative budget. Explicit counts
        // pass through untouched.
        let auto = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        let explicit =
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3).with_threads(1));
        // Read the raw stage field: `ffcz_config()` clamps to ≥ 1 for
        // direct execution, which would mask the auto sentinel here.
        assert_eq!(
            auto.correction.as_ref().unwrap().threads,
            0,
            "default must be auto"
        );
        let mut chains = vec![auto, explicit, CodecChainSpec::lossless()];
        resolve_auto_threads(&mut chains, 2);
        let budget = auto_thread_budget(2);
        assert!(budget >= 1);
        assert_eq!(chains[0].correction.as_ref().unwrap().threads, budget);
        assert_eq!(chains[0].ffcz_config().unwrap().threads, budget);
        assert_eq!(
            chains[1].correction.as_ref().unwrap().threads,
            1,
            "explicit clobbered"
        );
        assert!(chains[2].correction.is_none());
        // More workers than cores degrades gracefully to 1 thread each.
        assert_eq!(auto_thread_budget(usize::MAX / 2), 1);
    }

    #[test]
    fn scratch_warms_once_per_worker_not_per_chunk() {
        // Same chunk shape, 4× the chunk count: the per-worker scratch
        // must warm up on the first chunk and add nothing afterwards, so
        // the allocation-event total is identical for both encodes.
        let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        let small = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(7).build();
        let large = GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(8).build();
        let opts = StoreWriteOptions::new(&[4, 4]).workers(1);
        let (_, _, small_report) = encode_store(&small, &spec, &opts).unwrap();
        let (_, _, large_report) = encode_store(&large, &spec, &opts).unwrap();
        assert!(small_report.scratch_alloc_events > 0, "warm-up must register");
        assert_eq!(
            small_report.scratch_alloc_events, large_report.scratch_alloc_events,
            "steady-state chunks allocated scratch (4 vs 16 chunks of [4, 4])"
        );
    }

    #[test]
    fn overrides_build_a_deduplicated_chain_table() {
        let field = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(5).build();
        let ffcz = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        // 2 × 2 grid; two overrides with the same lossless chain dedup to
        // one extra table entry.
        let opts = StoreWriteOptions::new(&[4, 4])
            .workers(2)
            .override_chunk("c/0/0", CodecChainSpec::lossless())
            .override_chunk("c/1/1", CodecChainSpec::lossless());
        let (_, manifest, report) = encode_store(&field, &ffcz, &opts).unwrap();
        assert!(report.all_chunks_ok);
        assert_eq!(manifest.chains.len(), 2);
        assert_eq!(manifest.chains[0], ffcz);
        assert_eq!(manifest.chains[1], CodecChainSpec::lossless());
        let assigned: Vec<usize> = manifest.chunks.iter().map(|c| c.chain).collect();
        assert_eq!(assigned, vec![1, 0, 0, 1]);
    }

    #[test]
    fn unknown_override_key_rejected() {
        let field = GrfBuilder::new(&[8, 8]).seed(1).build();
        let opts = StoreWriteOptions::new(&[4, 4])
            .override_chunk("c/9/9", CodecChainSpec::lossless());
        let err = encode_store(&field, &CodecChainSpec::lossless(), &opts)
            .unwrap_err()
            .to_string();
        assert!(err.contains("c/9/9"), "{err}");
    }

    #[test]
    fn streaming_matches_in_memory_byte_for_byte() {
        let field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(3).build();
        let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        for workers in [1usize, 3] {
            let opts = StoreWriteOptions::new(&[5, 4]).workers(workers).queue_depth(1);
            let (mem, mem_manifest, mem_report) = encode_store(&field, &spec, &opts).unwrap();
            let mut streamed = Vec::new();
            let (manifest, report) =
                stream_store_to(&field, &spec, &opts, &mut streamed).unwrap();
            assert_eq!(streamed, mem, "workers={workers}: byte streams diverge");
            assert_eq!(manifest, mem_manifest);
            assert!(report.streamed && !mem_report.streamed);
            assert_eq!(report.total_bytes, mem_report.total_bytes);
            assert_eq!(report.manifest_bytes, mem_report.manifest_bytes);
            assert!(report.peak_payload_bytes <= mem_report.peak_payload_bytes);
            // Both paths collect the same per-chunk breakdown (in order).
            assert_eq!(report.chunk_reports.len(), mem_report.chunk_reports.len());
            for (s, m) in report.chunk_reports.iter().zip(&mem_report.chunk_reports) {
                assert_eq!((s.index, &s.key, s.bytes_out), (m.index, &m.key, m.bytes_out));
                assert_eq!(s.pocs_iterations, m.pocs_iterations);
            }
        }
    }

    #[test]
    fn stream_writer_guards_chunk_count_and_chain_index() {
        let enc = EncodedChunk {
            bytes: vec![1, 2, 3],
            stats: crate::codec::ChunkStats::exact(),
            detail: Default::default(),
        };
        // 2 × 1 grid: exactly two chunks, one chain.
        let mut w = StoreStreamWriter::new(
            Vec::<u8>::new(),
            &[8, 4],
            Precision::Double,
            &[4, 4],
            vec![CodecChainSpec::lossless()],
        )
        .unwrap();
        assert!(w.append_chunk(1, &enc).is_err(), "chain index out of table");
        w.append_chunk(0, &enc).unwrap();
        assert_eq!(w.chunks_written(), 1);

        // Finishing with a chunk missing must not mint a valid trailer.
        let err = w.finish().unwrap_err().to_string();
        assert!(err.contains("1 of 2"), "{err}");

        let mut w = StoreStreamWriter::new(
            Vec::<u8>::new(),
            &[8, 4],
            Precision::Double,
            &[4, 4],
            vec![CodecChainSpec::lossless()],
        )
        .unwrap();
        w.append_chunk(0, &enc).unwrap();
        w.append_chunk(0, &enc).unwrap();
        assert!(w.append_chunk(0, &enc).is_err(), "third chunk on a 2-chunk grid");
    }
}
