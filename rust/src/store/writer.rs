//! Chunked store encoder: tile a field, encode chunks in parallel (each
//! through its codec chain), and produce the `.ffcz` container (payloads
//! first, manifest appended, 24-byte trailer last — normative layout in
//! `docs/FORMAT.md`, field-by-field notes in [`super::manifest`]).
//!
//! Two write paths share one byte format:
//!
//! * **streaming** ([`stream_store_to`] / [`write_store`], the default) —
//!   the worker pool hands finished chunk payloads to this (single writer)
//!   thread through a bounded in-flight window and each payload is spilled
//!   to the output as it completes, so peak payload memory is
//!   O((workers + queue_depth) × chunk), not O(field). The manifest and
//!   trailer are written last, which is exactly why readers locate the
//!   manifest through the trailer.
//! * **in-memory** ([`encode_store`] / [`write_store_in_memory`]) — the
//!   whole container is assembled in a `Vec<u8>` (useful for tests and
//!   `Store::from_bytes` round-trips; the CLI exposes it as
//!   `--in-memory`).
//!
//! Because the streaming sink consumes chunks in index order, both paths
//! produce **byte-identical** archives for any worker count.
//!
//! ## Crash consistency and recovery
//!
//! All streaming output goes through the [`WritableStorage`] abstraction
//! (file, in-memory, fault-injected backends; transient write faults heal
//! under the store's [`RetryPolicy`]). File writes **commit atomically**:
//! [`write_store`] streams into a `<path>.tmp` sibling, syncs, and renames
//! over `path` only after the trailer — the container's commit record — is
//! durable, so `path` either holds a complete archive or is untouched.
//! While streaming, the writer keeps a sidecar **recovery journal**
//! (`<path>.tmp.jrn`): one CRC-framed record per completed chunk payload.
//! After an interrupted write, [`Store::salvage`] cross-checks the journal
//! against the partial container to recover the contiguous prefix of
//! CRC-valid chunk payloads, and [`resume_store_write`] re-encodes only
//! the missing chunks — producing an archive **bit-identical** to an
//! uninterrupted write (per-chunk encoding is deterministic). The layout
//! and the normative commit/recovery rules live in `docs/FORMAT.md`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::codec::{ChunkStats, CodecChain, CodecChainSpec, EncodedChunk};
use crate::correction::CorrectionScratch;
use crate::data::{Field, Precision};
use crate::encoding::{crc32, fixed, varint};
use crate::telemetry;

use super::grid::{extract_subarray, ChunkGrid};
use super::manifest::{
    ChunkEntry, Manifest, FOOTER_LEN, FOOTER_MAGIC, JOURNAL_MAGIC, STORE_MAGIC,
};
use super::parallel::{par_try_map_ordered_sink_with, par_try_map_with};
use super::reader::Store;
use super::storage::{
    read_exact_at, write_all_at, write_all_at_retry, FaultCounts, FaultInjector, FaultPlan,
    FileStorage, ReadableStorage, RetryPolicy, WritableStorage,
};

/// Options for store creation.
#[derive(Debug, Clone)]
pub struct StoreWriteOptions {
    /// Chunk shape (same dimensionality as the field).
    pub chunk_shape: Vec<usize>,
    /// Worker threads for per-chunk encoding.
    pub workers: usize,
    /// Extra in-flight chunk payloads the streaming writer may buffer
    /// beyond one per worker (the bounded hand-off window is
    /// `workers + queue_depth`). Irrelevant to the in-memory path.
    pub queue_depth: usize,
    /// Per-chunk codec chain overrides, keyed by the grid's zarr-style
    /// chunk key (`"c/1/0"`); chunks not named here use the store default
    /// (e.g. a lossless chain for boundary chunks, FFCz elsewhere).
    /// Unknown keys are rejected at encode time.
    pub overrides: Vec<(String, CodecChainSpec)>,
    /// Retry policy for transient storage faults on the write path
    /// (positioned writes are idempotent, so a retried span is simply
    /// rewritten). Healed retries are tallied in
    /// [`StoreWriteReport::write_retries`] and the `store.write.retries`
    /// counter. Default: no retries.
    pub retry: RetryPolicy,
}

impl StoreWriteOptions {
    pub fn new(chunk_shape: &[usize]) -> Self {
        Self {
            chunk_shape: chunk_shape.to_vec(),
            workers: 1,
            queue_depth: 2,
            overrides: Vec::new(),
            retry: RetryPolicy::none(),
        }
    }

    /// Heal transient write faults (interrupted/would-block/timed-out) by
    /// rewriting the affected span under `policy`.
    pub fn retry_policy(mut self, policy: RetryPolicy) -> Self {
        self.retry = policy;
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Bound the streaming writer's in-flight window to
    /// `workers + queue_depth` encoded-but-unwritten chunk payloads.
    pub fn queue_depth(mut self, queue_depth: usize) -> Self {
        self.queue_depth = queue_depth;
        self
    }

    /// Encode the chunk with key `key` (e.g. `"c/0/1"`) through `chain`
    /// instead of the store default.
    pub fn override_chunk(mut self, key: &str, chain: CodecChainSpec) -> Self {
        self.overrides.push((key.to_string(), chain));
        self
    }

    /// Default chunking for a field: axis-0 slabs, `max(workers, 2)` of
    /// them (so even a single-worker write produces a multi-chunk store —
    /// partial reads stay partial), clamped to the axis-0 extent. The
    /// sharding-style default used by the CLI and the pipeline store sink.
    pub fn default_for(field_shape: &[usize], workers: usize) -> Result<Self> {
        let grid = ChunkGrid::axis0(field_shape, workers.max(2))?;
        Ok(Self {
            chunk_shape: grid.chunk_shape().to_vec(),
            workers: workers.max(1),
            queue_depth: 2,
            overrides: Vec::new(),
            retry: RetryPolicy::none(),
        })
    }

    /// The streaming writer's bounded in-flight window: how many encoded
    /// chunk payloads may exist at once before workers stall.
    pub fn window(&self) -> usize {
        self.workers.max(1) + self.queue_depth
    }
}

/// Summary of one store write.
#[derive(Debug, Clone)]
pub struct StoreWriteReport {
    pub chunk_count: usize,
    pub payload_bytes: usize,
    pub manifest_bytes: usize,
    pub total_bytes: usize,
    /// True iff every chunk's dual-domain verification passed.
    pub all_chunks_ok: bool,
    /// High-water mark of encoded-but-unwritten chunk payload bytes (a
    /// peak-RSS proxy). The streaming path bounds this to the in-flight
    /// window; the in-memory path holds every payload, so it equals
    /// `payload_bytes` there.
    pub peak_payload_bytes: usize,
    /// True for the streaming write path, false for in-memory assembly.
    pub streamed: bool,
    /// Transient write faults healed under [`StoreWriteOptions::retry`]
    /// (always 0 on the in-memory path, which performs no storage writes).
    /// Mirrored by the `store.write.retries` counter.
    pub write_retries: u64,
    /// Correction-scratch allocation events summed over all workers (plan
    /// first contacts, spectrum/workspace buffer growth — see
    /// [`CorrectionScratch::allocation_events`]). Each worker warms once
    /// per chunk shape; steady-state chunks add zero, so on a
    /// uniform-chunk grid this stays O(workers), not O(chunks). The
    /// throughput bench emits the per-chunk steady-state gauge derived
    /// from the same counter and CI asserts it is zero.
    pub scratch_alloc_events: usize,
    pub elapsed: Duration,
    /// Per-chunk encode breakdown (manifest stats joined with stage wall
    /// times from [`crate::codec::ChunkEncodeDetail`]), in chunk index
    /// order. Powers `archive create --stats` and
    /// [`StoreWriteReport::render_chunk_table`].
    pub chunk_reports: Vec<ChunkEncodeReport>,
}

impl StoreWriteReport {
    /// Human-readable per-chunk stats table (the `--stats` rendering).
    /// Empty string when no chunk reports were collected.
    pub fn render_chunk_table(&self) -> String {
        if self.chunk_reports.is_empty() {
            return String::new();
        }
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        let mut out = String::new();
        out.push_str(&format!(
            "{:<12} {:>5} {:>10} {:>10} {:>6} {:>5} {:>3} {:>2} {:>9} {:>9} {:>9} {:>9} {:>9}\n",
            "chunk",
            "chain",
            "bytes_in",
            "bytes_out",
            "ratio",
            "iters",
            "att",
            "fb",
            "base_ms",
            "pocs_ms",
            "verif_ms",
            "lossl_ms",
            "total_ms"
        ));
        for r in &self.chunk_reports {
            let ratio = if r.bytes_out > 0 {
                r.bytes_in as f64 / r.bytes_out as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<12} {:>5} {:>10} {:>10} {:>6.2} {:>5} {:>3} {:>2} {:>9.3} {:>9.3} {:>9.3} \
                 {:>9.3} {:>9.3}\n",
                r.key,
                r.chain,
                r.bytes_in,
                r.bytes_out,
                ratio,
                r.pocs_iterations,
                r.quant_attempts,
                if r.used_raw_fallback { "y" } else { "-" },
                ms(r.base_compress),
                ms(r.correct),
                ms(r.verify),
                ms(r.lossless),
                ms(r.total)
            ));
        }
        out
    }
}

/// Per-chunk breakdown of one store write: the manifest-persisted
/// verification stats joined with the in-memory stage measurements the
/// codec records while encoding.
#[derive(Debug, Clone)]
pub struct ChunkEncodeReport {
    /// Row-major chunk index.
    pub index: usize,
    /// Zarr-style chunk key (`"c/1/0"`).
    pub key: String,
    /// Chain-table index the chunk encoded through.
    pub chain: usize,
    /// Uncompressed chunk bytes.
    pub bytes_in: usize,
    /// Encoded payload bytes.
    pub bytes_out: usize,
    /// POCS iterations spent correcting this chunk.
    pub pocs_iterations: u32,
    /// Quantization retry-ladder attempts consumed.
    pub quant_attempts: u32,
    /// Whether the raw-edit fallback fired.
    pub used_raw_fallback: bool,
    pub base_compress: Duration,
    pub correct: Duration,
    pub verify: Duration,
    pub lossless: Duration,
    pub total: Duration,
}

fn chunk_report(grid: &ChunkGrid, i: usize, chain: usize, enc: &EncodedChunk) -> ChunkEncodeReport {
    let d = enc.detail;
    ChunkEncodeReport {
        index: i,
        key: grid.chunk_key(i),
        chain,
        bytes_in: d.bytes_in,
        bytes_out: enc.bytes.len(),
        pocs_iterations: enc.stats.pocs_iterations,
        quant_attempts: d.quant_attempts,
        used_raw_fallback: d.used_raw_fallback,
        base_compress: d.base_compress,
        correct: d.correct,
        verify: d.verify,
        lossless: d.lossless,
        total: d.total,
    }
}

/// Registered-metric handles for the store write path, fetched once.
struct WriteMetrics {
    scratch_alloc_events: telemetry::Counter,
    peak_payload_bytes: telemetry::Gauge,
    /// Transient write faults healed by rewriting the affected span.
    retries: telemetry::Counter,
    /// Archives atomically committed (staged write renamed into place).
    commits: telemetry::Counter,
    /// Chunks recovered from interrupted writes instead of re-encoded.
    salvaged_chunks: telemetry::Counter,
}

fn write_metrics() -> &'static WriteMetrics {
    static METRICS: std::sync::OnceLock<WriteMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(|| WriteMetrics {
        scratch_alloc_events: telemetry::counter("store.encode.scratch_alloc_events"),
        peak_payload_bytes: telemetry::gauge("store.write.peak_payload_bytes"),
        retries: telemetry::counter("store.write.retries"),
        commits: telemetry::counter("store.write.commits"),
        salvaged_chunks: telemetry::counter("store.write.salvaged_chunks"),
    })
}

/// POCS transform thread count a chain requests (1 when it has no
/// correction stage). `0` = auto, kept distinct from an explicit 1 so an
/// auto-threaded override never dedups onto an explicitly single-threaded
/// chain entry (or vice versa).
fn chain_threads(spec: &CodecChainSpec) -> usize {
    spec.correction.as_ref().map_or(1, |c| c.threads)
}

/// Cooperative per-chunk transform thread budget for chains that left
/// `threads` on auto (0): divide the machine between the cross-chunk
/// worker pool, so per-chunk line threading composes with `workers`
/// concurrent chunk encodes without oversubscription. One core per worker
/// is the floor. Callers pass the *effective* worker count
/// (`min(workers, chunks)`) so a pool bigger than the grid doesn't
/// undersubscribe the machine.
fn auto_thread_budget(workers: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    (cores / workers.max(1)).max(1)
}

/// Resolve `threads == 0` (auto) to the cooperative budget on every chain
/// with a correction stage. Explicit thread counts (≥ 1) always win.
/// Execution-only: `threads` is never serialized, so resolved and
/// unresolved chains produce identical manifests and archive bytes.
fn resolve_auto_threads(chains: &mut [CodecChainSpec], workers: usize) {
    let budget = auto_thread_budget(workers);
    for spec in chains.iter_mut() {
        if let Some(correction) = spec.correction.as_mut() {
            if correction.threads == 0 {
                correction.threads = budget;
            }
        }
    }
}

/// Resolve the default chain plus overrides into a deduplicated chain
/// table and a per-chunk chain assignment.
fn resolve_chains(
    grid: &ChunkGrid,
    default: &CodecChainSpec,
    overrides: &[(String, CodecChainSpec)],
) -> Result<(Vec<CodecChainSpec>, Vec<usize>)> {
    let mut chains = vec![default.clone()];
    let mut assign = vec![0usize; grid.chunk_count()];
    if !overrides.is_empty() {
        let key_to_index: HashMap<String, usize> = (0..grid.chunk_count())
            .map(|i| (grid.chunk_key(i), i))
            .collect();
        for (key, chain) in overrides {
            let Some(&i) = key_to_index.get(key) else {
                bail!(
                    "codec override names chunk '{key}', but the {:?} grid has keys \
                     'c/0/…' through '{}'",
                    grid.grid_shape(),
                    grid.chunk_key(grid.chunk_count() - 1)
                );
            };
            // Dedup requires the *execution* thread count to match too:
            // `CodecChainSpec::eq` deliberately ignores `threads` (it is
            // not codec identity and never serialized), but collapsing a
            // `threads=`-only override onto an existing entry would encode
            // the chunk with the existing entry's thread count. Entries
            // that differ only in threads serialize to identical bytes, so
            // the extra table slot costs a few manifest bytes at most.
            let idx = match chains
                .iter()
                .position(|c| c == chain && chain_threads(c) == chain_threads(chain))
            {
                Some(idx) => idx,
                None => {
                    chains.push(chain.clone());
                    chains.len() - 1
                }
            };
            assign[i] = idx;
        }
    }
    Ok((chains, assign))
}

/// Encode `field` as an in-memory `.ffcz` store. `chain` is the default
/// codec chain; per-chunk overrides come from
/// [`StoreWriteOptions::overrides`].
pub fn encode_store(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
) -> Result<(Vec<u8>, Manifest, StoreWriteReport)> {
    let t0 = Instant::now();
    let grid = ChunkGrid::new(field.shape(), &opts.chunk_shape)?;
    let write_span = telemetry::span("store.write").arg("chunks", grid.chunk_count() as u64);
    let write_span_id = write_span.id();
    let (mut chains, assign) = resolve_chains(&grid, chain, &opts.overrides)?;
    // Budget against the number of workers that will actually run (the
    // pool clamps itself to the chunk count).
    resolve_auto_threads(&mut chains, opts.workers.clamp(1, grid.chunk_count().max(1)));
    let built: Vec<CodecChain> = chains
        .iter()
        .map(CodecChain::from_spec)
        .collect::<Result<_>>()?;

    // Each worker owns one correction scratch across every chunk it
    // encodes; the counter audits that reuse (warm-up only, zero steady
    // state).
    let scratch_events = AtomicUsize::new(0);
    let encoded = par_try_map_with(
        grid.chunk_count(),
        opts.workers,
        CorrectionScratch::new,
        |i, scratch| {
            let _chunk_span = telemetry::span_with_parent("store.chunk.encode", write_span_id)
                .arg("chunk", i as u64);
            let coords = grid.chunk_coords(i);
            let origin = grid.chunk_origin(&coords);
            let extent = grid.chunk_extent(&coords);
            let chunk = Field::new(
                &extent,
                extract_subarray(field.data(), field.shape(), &origin, &extent),
                field.precision(),
            );
            let before = scratch.allocation_events();
            let enc = built[assign[i]]
                .encode_chunk_with_scratch(&chunk, scratch)
                .with_context(|| format!("encoding chunk {}", grid.chunk_key(i)))?;
            scratch_events.fetch_add(
                (scratch.allocation_events() - before) as usize,
                Ordering::Relaxed,
            );
            Ok(enc)
        },
    )?;

    // Assemble: head magic, payloads, manifest, footer.
    let mut out = Vec::new();
    out.extend_from_slice(STORE_MAGIC);
    let mut chunks = Vec::with_capacity(encoded.len());
    for (i, enc) in encoded.iter().enumerate() {
        chunks.push(ChunkEntry {
            offset: out.len() as u64,
            length: enc.bytes.len() as u64,
            chain: assign[i],
            crc32: Some(crc32(&enc.bytes)),
            stats: enc.stats,
        });
        out.extend_from_slice(&enc.bytes);
    }
    let manifest = Manifest {
        shape: field.shape().to_vec(),
        precision: field.precision(),
        chunk_shape: opts.chunk_shape.clone(),
        chains,
        chunks,
    };
    let manifest_bytes = manifest.to_bytes();
    let manifest_offset = out.len() as u64;
    out.extend_from_slice(&manifest_bytes);
    out.extend_from_slice(&manifest_offset.to_le_bytes());
    out.extend_from_slice(&(manifest_bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(FOOTER_MAGIC);

    let chunk_reports: Vec<ChunkEncodeReport> = encoded
        .iter()
        .enumerate()
        .map(|(i, enc)| chunk_report(&grid, i, assign[i], enc))
        .collect();
    let scratch_alloc_events = scratch_events.load(Ordering::Relaxed);
    let metrics = write_metrics();
    metrics.scratch_alloc_events.add(scratch_alloc_events as u64);
    metrics
        .peak_payload_bytes
        .max(manifest.payload_bytes());
    let report = StoreWriteReport {
        chunk_count: manifest.chunks.len(),
        payload_bytes: manifest.payload_bytes() as usize,
        manifest_bytes: manifest_bytes.len(),
        total_bytes: out.len(),
        all_chunks_ok: manifest.all_chunks_ok(),
        // Every payload is held until assembly: the in-memory scale wall.
        peak_payload_bytes: manifest.payload_bytes() as usize,
        streamed: false,
        write_retries: 0,
        scratch_alloc_events,
        elapsed: t0.elapsed(),
        chunk_reports,
    };
    Ok((out, manifest, report))
}

/// Sidecar recovery-journal sink: one CRC-framed record per completed
/// chunk payload, written next to the staged container (head magic
/// [`JOURNAL_MAGIC`]; record layout in `docs/FORMAT.md`). Best-effort
/// durable — the journal is never fsynced per record, so a torn tail only
/// costs re-encoding the chunks past it on resume.
struct JournalSink {
    out: Box<dyn WritableStorage>,
    /// Next journal byte offset.
    offset: u64,
}

impl JournalSink {
    /// Start a fresh journal: writes the head magic.
    fn create(mut out: Box<dyn WritableStorage>) -> Result<Self> {
        write_all_at(out.as_mut(), 0, JOURNAL_MAGIC).context("writing recovery-journal header")?;
        Ok(Self {
            out,
            offset: JOURNAL_MAGIC.len() as u64,
        })
    }

    /// Continue an existing journal at `offset` (a record boundary; the
    /// caller has already truncated any torn tail past it).
    fn resume(out: Box<dyn WritableStorage>, offset: u64) -> Self {
        Self { out, offset }
    }
}

/// Incremental `.ffcz` container writer: the `StoreSink`-style streaming
/// API underneath [`stream_store_to`].
///
/// The container is written strictly front-to-back — head magic at
/// construction, one payload per [`StoreStreamWriter::append_chunk`] call
/// (in chunk index order), manifest and 24-byte trailer at
/// [`StoreStreamWriter::finish`] — through positioned [`WritableStorage`]
/// writes at a tracked offset, never a seek. A crash before `finish`
/// leaves a file without the trailer (the commit record), which readers
/// reject with a precise "truncated or partially-written" error instead
/// of decoding garbage; [`Store::salvage`] can then recover the completed
/// chunk prefix through the recovery journal.
pub struct StoreStreamWriter<W: WritableStorage> {
    out: W,
    /// Transient-write-fault healing policy (see [`RetryPolicy`]).
    retry: RetryPolicy,
    /// Transient write faults healed so far under `retry`.
    retries: u64,
    /// Optional sidecar recovery journal, appended after each payload.
    journal: Option<JournalSink>,
    shape: Vec<usize>,
    precision: Precision,
    chunk_shape: Vec<usize>,
    chains: Vec<CodecChainSpec>,
    chunk_count: usize,
    entries: Vec<ChunkEntry>,
    /// Next payload byte offset (tracked, not seeked).
    offset: u64,
}

impl<W: WritableStorage> StoreStreamWriter<W> {
    /// Start a container: validates the grid, writes the head magic.
    pub fn new(
        mut out: W,
        shape: &[usize],
        precision: Precision,
        chunk_shape: &[usize],
        chains: Vec<CodecChainSpec>,
    ) -> Result<Self> {
        if chains.is_empty() {
            bail!("store needs at least one codec chain (chain 0 is the default)");
        }
        let grid = ChunkGrid::new(shape, chunk_shape)?;
        write_all_at(&mut out, 0, STORE_MAGIC).context("writing store header")?;
        Ok(Self {
            out,
            retry: RetryPolicy::none(),
            retries: 0,
            journal: None,
            shape: shape.to_vec(),
            precision,
            chunk_shape: chunk_shape.to_vec(),
            chains,
            chunk_count: grid.chunk_count(),
            entries: Vec::with_capacity(grid.chunk_count()),
            offset: STORE_MAGIC.len() as u64,
        })
    }

    /// Continue an interrupted container: `entries` is the salvaged chunk
    /// prefix already present in `out` (payloads tiling
    /// `[8, payload_end)`); no head magic is rewritten.
    fn resume(
        out: W,
        shape: &[usize],
        precision: Precision,
        chunk_shape: &[usize],
        chains: Vec<CodecChainSpec>,
        entries: Vec<ChunkEntry>,
    ) -> Result<Self> {
        if chains.is_empty() {
            bail!("store needs at least one codec chain (chain 0 is the default)");
        }
        let grid = ChunkGrid::new(shape, chunk_shape)?;
        if entries.len() > grid.chunk_count() {
            bail!(
                "salvaged {} chunks, but the {:?} grid has only {}",
                entries.len(),
                grid.grid_shape(),
                grid.chunk_count()
            );
        }
        let offset = entries
            .last()
            .map_or(STORE_MAGIC.len() as u64, |e| e.offset + e.length);
        Ok(Self {
            out,
            retry: RetryPolicy::none(),
            retries: 0,
            journal: None,
            shape: shape.to_vec(),
            precision,
            chunk_shape: chunk_shape.to_vec(),
            chains,
            chunk_count: grid.chunk_count(),
            entries,
            offset,
        })
    }

    /// Heal transient storage faults on subsequent writes under `policy`
    /// (positioned writes are idempotent: the span is simply rewritten).
    pub fn with_retry_policy(mut self, policy: RetryPolicy) -> Self {
        self.retry = policy;
        self
    }

    /// Number of chunks appended so far (the next expected chunk index).
    pub fn chunks_written(&self) -> usize {
        self.entries.len()
    }

    fn note_retries(&mut self, retries: u32) {
        if retries > 0 {
            self.retries += u64::from(retries);
            write_metrics().retries.add(u64::from(retries));
        }
    }

    /// Spill the payload of the next chunk (in row-major grid order) to
    /// the output and record its manifest entry. `chain` indexes the chain
    /// table passed to [`StoreStreamWriter::new`].
    pub fn append_chunk(&mut self, chain: usize, enc: &EncodedChunk) -> Result<()> {
        if self.entries.len() >= self.chunk_count {
            bail!(
                "store already holds all {} chunks; nothing more to append",
                self.chunk_count
            );
        }
        if chain >= self.chains.len() {
            bail!(
                "chunk {} references chain {chain}, but the table has {} entries",
                self.entries.len(),
                self.chains.len()
            );
        }
        let healed = write_all_at_retry(&mut self.out, self.offset, &enc.bytes, &self.retry)
            .with_context(|| format!("writing payload of chunk {}", self.entries.len()))?;
        self.note_retries(healed);
        let entry = ChunkEntry {
            offset: self.offset,
            length: enc.bytes.len() as u64,
            chain,
            crc32: Some(crc32(&enc.bytes)),
            stats: enc.stats,
        };
        self.offset += enc.bytes.len() as u64;
        // Journal the entry only after its payload landed: a record must
        // never describe bytes the container does not hold yet.
        if let Some(journal) = self.journal.as_mut() {
            let record = journal_record(self.entries.len(), &entry);
            write_all_at(journal.out.as_mut(), journal.offset, &record)
                .context("appending to the recovery journal")?;
            journal.offset += record.len() as u64;
        }
        self.entries.push(entry);
        Ok(())
    }

    /// Write the manifest and the 24-byte trailer — the commit record —
    /// then flush and sync, and return the manifest, the total container
    /// size, and the transient write faults healed along the way. Fails if
    /// any chunk is missing — a partial container must never gain a valid
    /// trailer.
    pub fn finish(self) -> Result<(Manifest, u64, u64)> {
        let Self {
            mut out,
            retry,
            mut retries,
            journal: _,
            shape,
            precision,
            chunk_shape,
            chains,
            chunk_count,
            entries,
            offset,
        } = self;
        if entries.len() != chunk_count {
            bail!(
                "store finish with {} of {} chunks written",
                entries.len(),
                chunk_count
            );
        }
        let manifest = Manifest {
            shape,
            precision,
            chunk_shape,
            chains,
            chunks: entries,
        };
        let manifest_bytes = manifest.to_bytes();
        let healed = write_all_at_retry(&mut out, offset, &manifest_bytes, &retry)
            .context("writing manifest")?;
        if healed > 0 {
            retries += u64::from(healed);
            write_metrics().retries.add(u64::from(healed));
        }
        // One positioned write for the whole trailer, strictly after the
        // manifest: until these 24 bytes land, the container stays
        // uncommitted and readers reject it as partial.
        let mut trailer = [0u8; FOOTER_LEN];
        trailer[..8].copy_from_slice(&offset.to_le_bytes());
        trailer[8..16].copy_from_slice(&(manifest_bytes.len() as u64).to_le_bytes());
        trailer[16..].copy_from_slice(FOOTER_MAGIC);
        let trailer_offset = offset + manifest_bytes.len() as u64;
        let healed = write_all_at_retry(&mut out, trailer_offset, &trailer, &retry)
            .context("writing trailer")?;
        if healed > 0 {
            retries += u64::from(healed);
            write_metrics().retries.add(u64::from(healed));
        }
        out.flush().context("flushing store")?;
        out.sync().context("syncing store")?;
        let total = trailer_offset + FOOTER_LEN as u64;
        Ok((manifest, total, retries))
    }
}

/// Encode `field` and stream the container to `out`: chunks are encoded on
/// `opts.workers` threads and each payload is written by this thread as
/// soon as it (and every earlier chunk) is done, holding at most
/// `opts.window()` payloads in memory. Produces bytes identical to
/// [`encode_store`] for any worker count.
pub fn stream_store_to<W: WritableStorage>(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    out: W,
) -> Result<(Manifest, StoreWriteReport)> {
    stream_store_core(field, chain, opts, out, None, Vec::new())
}

/// Shared streaming core under [`stream_store_to`], [`write_store`], and
/// [`resume_store_write`]: encodes chunks `salvaged.len()..chunk_count`
/// and appends them after the (possibly empty) salvaged prefix already
/// present in `out`, journaling each payload to `journal` when given.
fn stream_store_core<W: WritableStorage>(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    out: W,
    journal: Option<JournalSink>,
    salvaged: Vec<ChunkEntry>,
) -> Result<(Manifest, StoreWriteReport)> {
    let t0 = Instant::now();
    let grid = ChunkGrid::new(field.shape(), &opts.chunk_shape)?;
    let write_span = telemetry::span("store.write").arg("chunks", grid.chunk_count() as u64);
    let write_span_id = write_span.id();
    let (mut chains, assign) = resolve_chains(&grid, chain, &opts.overrides)?;
    let start = salvaged.len();
    // A salvaged prefix can only be extended byte-identically if this
    // invocation assigns those chunks the same chains the interrupted
    // write did (callers trim mismatches; this is the backstop).
    for (i, entry) in salvaged.iter().enumerate() {
        if assign.get(i) != Some(&entry.chain) {
            bail!(
                "salvaged chunk {i} was encoded through chain {}, but the requested \
                 options assign a different chain; cannot resume byte-identically",
                entry.chain
            );
        }
    }
    let remaining = grid.chunk_count() - start.min(grid.chunk_count());
    // Budget against the number of workers that will actually run (the
    // pool clamps itself to the remaining chunk count).
    resolve_auto_threads(&mut chains, opts.workers.clamp(1, remaining.max(1)));
    let built: Vec<CodecChain> = chains
        .iter()
        .map(CodecChain::from_spec)
        .collect::<Result<_>>()?;
    let mut writer = if start == 0 {
        StoreStreamWriter::new(
            out,
            field.shape(),
            field.precision(),
            &opts.chunk_shape,
            chains,
        )?
    } else {
        StoreStreamWriter::resume(
            out,
            field.shape(),
            field.precision(),
            &opts.chunk_shape,
            chains,
            salvaged,
        )?
    }
    .with_retry_policy(opts.retry);
    writer.journal = journal;

    // Payload-bytes-in-flight gauge (encoded, not yet written): the
    // peak-RSS proxy asserted by tests and reported by the bench.
    let in_flight = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    // Per-worker correction scratch, reused across every chunk a worker
    // encodes (audited by the allocation-event counter).
    let scratch_events = AtomicUsize::new(0);
    let mut chunk_reports: Vec<ChunkEncodeReport> = Vec::with_capacity(remaining);
    par_try_map_ordered_sink_with(
        remaining,
        opts.workers,
        opts.window(),
        CorrectionScratch::new,
        |j, scratch| {
            let i = start + j;
            let _chunk_span = telemetry::span_with_parent("store.chunk.encode", write_span_id)
                .arg("chunk", i as u64);
            let coords = grid.chunk_coords(i);
            let origin = grid.chunk_origin(&coords);
            let extent = grid.chunk_extent(&coords);
            let chunk = Field::new(
                &extent,
                extract_subarray(field.data(), field.shape(), &origin, &extent),
                field.precision(),
            );
            let before = scratch.allocation_events();
            let enc = built[assign[i]]
                .encode_chunk_with_scratch(&chunk, scratch)
                .with_context(|| format!("encoding chunk {}", grid.chunk_key(i)))?;
            scratch_events.fetch_add(
                (scratch.allocation_events() - before) as usize,
                Ordering::Relaxed,
            );
            let now = in_flight.fetch_add(enc.bytes.len(), Ordering::SeqCst) + enc.bytes.len();
            peak.fetch_max(now, Ordering::SeqCst);
            Ok(enc)
        },
        |j, enc| {
            let i = start + j;
            let _sink_span = telemetry::span_with_parent("store.chunk.sink", write_span_id)
                .arg("chunk", i as u64)
                .arg("bytes", enc.bytes.len() as u64);
            writer.append_chunk(assign[i], &enc)?;
            chunk_reports.push(chunk_report(&grid, i, assign[i], &enc));
            in_flight.fetch_sub(enc.bytes.len(), Ordering::SeqCst);
            Ok(())
        },
    )?;
    let (manifest, total_bytes, write_retries) = writer.finish()?;

    let manifest_bytes = total_bytes as usize
        - manifest.payload_bytes() as usize
        - STORE_MAGIC.len()
        - FOOTER_LEN;
    let scratch_alloc_events = scratch_events.load(Ordering::Relaxed);
    let peak_payload_bytes = peak.load(Ordering::SeqCst);
    let metrics = write_metrics();
    metrics.scratch_alloc_events.add(scratch_alloc_events as u64);
    metrics.peak_payload_bytes.max(peak_payload_bytes as u64);
    let report = StoreWriteReport {
        chunk_count: manifest.chunks.len(),
        payload_bytes: manifest.payload_bytes() as usize,
        manifest_bytes,
        total_bytes: total_bytes as usize,
        all_chunks_ok: manifest.all_chunks_ok(),
        peak_payload_bytes,
        streamed: true,
        write_retries,
        scratch_alloc_events,
        elapsed: t0.elapsed(),
        chunk_reports,
    };
    Ok((manifest, report))
}

/// Staging siblings of a final archive path: the temporary container the
/// streaming writer fills (atomically renamed over `path` on commit) and
/// its sidecar recovery journal.
pub fn staging_paths(path: &Path) -> (PathBuf, PathBuf) {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let mut jrn = path.as_os_str().to_os_string();
    jrn.push(".tmp.jrn");
    (PathBuf::from(tmp), PathBuf::from(jrn))
}

/// Commit a fully-written staged container: rename it over `path`, drop
/// the now-obsolete recovery journal, and count the commit.
fn commit_staged(tmp: &Path, jrn: &Path, path: &Path) -> Result<()> {
    std::fs::rename(tmp, path)
        .with_context(|| format!("renaming {} to {}", tmp.display(), path.display()))?;
    // The journal only describes the staged write; once the rename
    // commits, it must not outlive the archive it described.
    let _ = std::fs::remove_file(jrn);
    write_metrics().commits.incr();
    Ok(())
}

/// Staged write shared by [`write_store`] and [`write_store_faulted`]:
/// stream into `tmp` (journaling to `jrn`), optionally through a
/// [`FaultInjector`], and commit on success. Leaves `tmp`/`jrn` in place
/// on failure — the *callers* decide whether a failure is a clean error
/// (remove the staging pair) or a simulated crash (keep it salvageable).
fn write_store_staged(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &Path,
    tmp: &Path,
    jrn: &Path,
    plan: Option<FaultPlan>,
) -> Result<(StoreWriteReport, FaultCounts)> {
    let journal = JournalSink::create(Box::new(
        FileStorage::create(jrn).with_context(|| format!("creating {}", jrn.display()))?,
    ))?;
    let out = FileStorage::create(tmp).with_context(|| format!("creating {}", tmp.display()))?;
    let (report, counts) = match plan {
        Some(plan) => {
            let injector = FaultInjector::new(out, plan);
            let handle = injector.handle();
            let (_, report) =
                stream_store_core(field, chain, opts, injector, Some(journal), Vec::new())
                    .with_context(|| format!("writing {}", tmp.display()))?;
            (report, handle.counts())
        }
        None => {
            let (_, report) = stream_store_core(field, chain, opts, out, Some(journal), Vec::new())
                .with_context(|| format!("writing {}", tmp.display()))?;
            (report, FaultCounts::default())
        }
    };
    commit_staged(tmp, jrn, path)?;
    Ok((report, counts))
}

/// Encode `field` and write the store to `path`, **streaming** chunk
/// payloads to the file as they complete (see [`stream_store_to`]); peak
/// payload memory is bounded by `opts.window()` chunks. Use
/// [`write_store_in_memory`] to assemble the container in memory first.
///
/// The write **commits atomically**: the stream goes to a `<path>.tmp`
/// sibling (with a `<path>.tmp.jrn` recovery journal) that is fsynced and
/// renamed over `path` only after the trailer — the commit record — is
/// written, so a failed or interrupted write never clobbers an existing
/// archive at `path` and never leaves a trailer-less file under the final
/// name. On a clean error both staging files are removed; after a *crash*
/// (process death mid-write) they remain, and [`resume_store_write`]
/// salvages them.
pub fn write_store(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &Path,
) -> Result<StoreWriteReport> {
    let (tmp, jrn) = staging_paths(path);
    match write_store_staged(field, chain, opts, path, &tmp, &jrn, None) {
        Ok((report, _)) => Ok(report),
        Err(e) => {
            // A clean failure must leave no partial state behind.
            let _ = std::fs::remove_file(&tmp);
            let _ = std::fs::remove_file(&jrn);
            Err(e)
        }
    }
}

/// Chaos variant of [`write_store`]: the staged `<path>.tmp` file is
/// wrapped in a [`FaultInjector`] driven by `plan`. On success it commits
/// exactly like [`write_store`] and returns the fault tally alongside the
/// report; on failure it **keeps** `<path>.tmp` and `<path>.tmp.jrn` —
/// simulating a crash at the injected failure point — so tests and
/// `ffcz archive repair` can salvage and resume. The final `path` is
/// never touched by a failed write either way.
pub fn write_store_faulted(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &Path,
    plan: FaultPlan,
) -> Result<(StoreWriteReport, FaultCounts)> {
    let (tmp, jrn) = staging_paths(path);
    write_store_staged(field, chain, opts, path, &tmp, &jrn, Some(plan))
}

/// Outcome of [`resume_store_write`].
#[derive(Debug, Clone)]
pub struct RepairReport {
    /// Chunks recovered from the interrupted write (not re-encoded).
    pub salvaged_chunks: usize,
    /// Chunks (re-)encoded to complete the archive.
    pub reencoded_chunks: usize,
    /// Write report of the completing pass; its `chunk_reports` cover
    /// only the re-encoded chunks.
    pub write: StoreWriteReport,
}

/// Complete an interrupted [`write_store`] at `path`: salvage the valid
/// chunk prefix from `<path>.tmp` + `<path>.tmp.jrn` (see
/// [`Store::salvage`]), re-encode only the missing chunks from `field`,
/// and commit. Because per-chunk encoding is deterministic, the committed
/// archive is **bit-identical** to an uninterrupted write — provided
/// `field`, `chain`, and `opts` match the interrupted invocation (a
/// mismatched prefix is detected through chain assignment where possible
/// and otherwise discarded by re-encoding from scratch; `archive verify`
/// checks the result either way). When no staging files exist, this is a
/// plain [`write_store`].
pub fn resume_store_write(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &Path,
) -> Result<RepairReport> {
    let (tmp, jrn) = staging_paths(path);
    let fresh = |write: StoreWriteReport| RepairReport {
        salvaged_chunks: 0,
        reencoded_chunks: write.chunk_count,
        write,
    };
    if !tmp.exists() {
        return Ok(fresh(write_store(field, chain, opts, path)?));
    }
    let journal_bytes = std::fs::read(&jrn).unwrap_or_default();
    let salvage = {
        let partial =
            FileStorage::open(&tmp).with_context(|| format!("opening {}", tmp.display()))?;
        Store::salvage(&partial, &journal_bytes)?
    };

    // Keep only the prefix whose chain assignment matches what this
    // invocation would produce — anything past a mismatch (different
    // options than the interrupted write) cannot be extended
    // byte-identically.
    let grid = ChunkGrid::new(field.shape(), &opts.chunk_shape)?;
    let (_, assign) = resolve_chains(&grid, chain, &opts.overrides)?;
    let keep = salvage
        .entries
        .iter()
        .zip(assign.iter())
        .take_while(|(entry, &chain_index)| entry.chain == chain_index)
        .count();
    if keep == 0 {
        let _ = std::fs::remove_file(&tmp);
        let _ = std::fs::remove_file(&jrn);
        return Ok(fresh(write_store(field, chain, opts, path)?));
    }
    let entries: Vec<ChunkEntry> = salvage.entries[..keep].to_vec();
    let payload_end = entries
        .last()
        .map_or(STORE_MAGIC.len() as u64, |e| e.offset + e.length);
    let journal_end = salvage.journal_end(keep);
    write_metrics().salvaged_chunks.add(keep as u64);

    // Drop any torn bytes past the salvageable prefix, then extend. On
    // failure the (truncated) staging pair stays: the resume itself is
    // retryable.
    let mut out =
        FileStorage::open_rw(&tmp).with_context(|| format!("reopening {}", tmp.display()))?;
    out.truncate(payload_end)
        .context("truncating the partial archive to its salvageable prefix")?;
    let mut journal_store =
        FileStorage::open_rw(&jrn).with_context(|| format!("reopening {}", jrn.display()))?;
    journal_store
        .truncate(journal_end)
        .context("truncating the recovery journal to its salvageable prefix")?;
    let journal = JournalSink::resume(Box::new(journal_store), journal_end);

    let (_, write) = stream_store_core(field, chain, opts, out, Some(journal), entries)
        .with_context(|| format!("resuming {}", tmp.display()))?;
    commit_staged(&tmp, &jrn, path)?;
    Ok(RepairReport {
        salvaged_chunks: keep,
        reencoded_chunks: grid.chunk_count() - keep,
        write,
    })
}

/// Serialize one recovery-journal record: varint body length, body,
/// CRC-32 of the body (u32 LE). The body mirrors a [`ChunkEntry`]: chunk
/// index, chain, payload offset, payload length (varints), payload CRC-32
/// (u32 LE), then the verification stats — a flags byte (bit 0
/// `spatial_ok`, bit 1 `frequency_ok`), two f64 LE ratios, and a varint
/// POCS iteration count. The framing CRC makes torn tails detectable; the
/// f64 round trip is bit-exact, so a resumed manifest matches an
/// uninterrupted one byte for byte.
fn journal_record(index: usize, entry: &ChunkEntry) -> Vec<u8> {
    let mut body = Vec::with_capacity(48);
    varint::write(&mut body, index as u64);
    varint::write(&mut body, entry.chain as u64);
    varint::write(&mut body, entry.offset);
    varint::write(&mut body, entry.length);
    body.extend_from_slice(&entry.crc32.unwrap_or_default().to_le_bytes());
    let flags = u8::from(entry.stats.spatial_ok) | (u8::from(entry.stats.frequency_ok) << 1);
    body.push(flags);
    body.extend_from_slice(&entry.stats.max_spatial_ratio.to_le_bytes());
    body.extend_from_slice(&entry.stats.max_frequency_ratio.to_le_bytes());
    varint::write(&mut body, u64::from(entry.stats.pocs_iterations));
    let mut record = Vec::with_capacity(body.len() + 8);
    varint::write(&mut record, body.len() as u64);
    record.extend_from_slice(&body);
    record.extend_from_slice(&crc32(&body).to_le_bytes());
    record
}

/// One parsed recovery-journal record.
struct JournalRecord {
    /// Chunk index the record claims to describe.
    index: u64,
    entry: ChunkEntry,
    /// Journal byte offset just past this record.
    end: u64,
}

/// Parse the valid prefix of a recovery journal. Tolerant of torn tails:
/// scanning stops at the first truncated, CRC-mismatched, or malformed
/// record (a crash mid-journal-append costs only the chunks past it), and
/// a missing or wrong head magic yields an empty prefix. Never panics on
/// any input.
fn parse_journal(bytes: &[u8]) -> Vec<JournalRecord> {
    let mut records = Vec::new();
    if bytes.len() < JOURNAL_MAGIC.len() || &bytes[..JOURNAL_MAGIC.len()] != JOURNAL_MAGIC {
        return records;
    }
    let mut cursor = JOURNAL_MAGIC.len();
    loop {
        let mut pos = cursor;
        let Ok(body_len) = varint::read(bytes, &mut pos) else {
            break;
        };
        let Ok(body_len) = usize::try_from(body_len) else {
            break;
        };
        if body_len > bytes.len().saturating_sub(pos).saturating_sub(4) {
            break; // torn tail: the body or its framing CRC is cut off
        }
        let body = &bytes[pos..pos + body_len];
        let mut crc_pos = pos + body_len;
        let Ok(expect) = fixed::read_u32_le(bytes, &mut crc_pos, "journal record CRC") else {
            break;
        };
        if crc32(body) != expect {
            break;
        }
        let Some((index, entry)) = parse_journal_body(body) else {
            break;
        };
        records.push(JournalRecord {
            index,
            entry,
            end: crc_pos as u64,
        });
        cursor = crc_pos;
    }
    records
}

/// Decode one journal record body (already CRC-verified framing); `None`
/// on any truncation or overflow.
fn parse_journal_body(body: &[u8]) -> Option<(u64, ChunkEntry)> {
    let mut pos = 0usize;
    let index = varint::read(body, &mut pos).ok()?;
    let chain = usize::try_from(varint::read(body, &mut pos).ok()?).ok()?;
    let offset = varint::read(body, &mut pos).ok()?;
    let length = varint::read(body, &mut pos).ok()?;
    let payload_crc = fixed::read_u32_le(body, &mut pos, "journal payload CRC").ok()?;
    let flags = *body.get(pos)?;
    pos += 1;
    let max_spatial_ratio = fixed::read_f64_le(body, &mut pos, "journal spatial ratio").ok()?;
    let max_frequency_ratio = fixed::read_f64_le(body, &mut pos, "journal frequency ratio").ok()?;
    let pocs_iterations = u32::try_from(varint::read(body, &mut pos).ok()?).ok()?;
    Some((
        index,
        ChunkEntry {
            offset,
            length,
            chain,
            crc32: Some(payload_crc),
            stats: ChunkStats {
                spatial_ok: flags & 1 != 0,
                frequency_ok: flags & 2 != 0,
                max_spatial_ratio,
                max_frequency_ratio,
                pocs_iterations,
            },
        },
    ))
}

/// The recoverable prefix of an interrupted store write, produced by
/// [`Store::salvage`].
#[derive(Debug, Clone)]
pub struct Salvage {
    /// Manifest entries for the contiguous prefix of CRC-valid chunk
    /// payloads (chunk indices `0..entries.len()`, in order).
    pub entries: Vec<ChunkEntry>,
    /// Container byte offset just past the last salvageable payload —
    /// where a resumed write continues (8, the head magic length, when
    /// nothing is salvageable).
    pub payload_end: u64,
    /// Per-entry journal end offsets (record boundaries), so callers can
    /// truncate the journal after trimming the prefix further.
    journal_ends: Vec<u64>,
}

impl Salvage {
    /// Number of salvageable chunks.
    pub fn chunks(&self) -> usize {
        self.entries.len()
    }

    /// Journal byte length covering exactly the first `keep` entries
    /// (just the head magic when `keep` is 0).
    fn journal_end(&self, keep: usize) -> u64 {
        match keep.checked_sub(1).and_then(|i| self.journal_ends.get(i)) {
            Some(&end) => end,
            None => JOURNAL_MAGIC.len() as u64,
        }
    }
}

impl Store {
    /// Scan an interrupted archive write for its recoverable prefix.
    ///
    /// `storage` is the partial container (`<path>.tmp`); `journal` is the
    /// raw sidecar recovery journal (`<path>.tmp.jrn`). A chunk is
    /// salvageable iff its journal record is intact (framing CRC), its
    /// index and payload offset continue the contiguous prefix from the
    /// head magic, its payload lies fully within the partial container,
    /// and the payload bytes match the journal's CRC-32. Scanning stops at
    /// the first violation: everything before it is exactly what an
    /// uninterrupted write would have produced; everything after it is
    /// re-encoded by [`resume_store_write`]. Structural damage — torn
    /// files, bad magics, corrupt records — shortens the prefix rather
    /// than erroring; only real storage I/O failures are errors.
    pub fn salvage(storage: &dyn ReadableStorage, journal: &[u8]) -> Result<Salvage> {
        let _span = telemetry::span("store.salvage");
        let size = storage
            .size()
            .with_context(|| format!("stat {}", storage.describe()))?;
        let mut out = Salvage {
            entries: Vec::new(),
            payload_end: STORE_MAGIC.len() as u64,
            journal_ends: Vec::new(),
        };
        // Without an intact head magic the container never got started.
        if size < STORE_MAGIC.len() as u64 {
            return Ok(out);
        }
        let mut head = [0u8; 8];
        read_exact_at(storage, 0, &mut head)
            .with_context(|| format!("reading store header of {}", storage.describe()))?;
        if head != *STORE_MAGIC {
            return Ok(out);
        }
        let mut buf = Vec::new();
        for record in parse_journal(journal) {
            if record.index != out.entries.len() as u64 || record.entry.offset != out.payload_end {
                break; // record does not continue the contiguous prefix
            }
            let Some(end) = record.entry.offset.checked_add(record.entry.length) else {
                break;
            };
            if end > size {
                break; // payload torn off by the crash
            }
            let Ok(len) = usize::try_from(record.entry.length) else {
                break;
            };
            buf.resize(len, 0);
            read_exact_at(storage, record.entry.offset, &mut buf).with_context(|| {
                format!(
                    "reading salvage candidate chunk {} of {}",
                    record.index,
                    storage.describe()
                )
            })?;
            if record.entry.crc32 != Some(crc32(&buf)) {
                break; // torn or corrupt payload
            }
            out.payload_end = end;
            out.journal_ends.push(record.end);
            out.entries.push(record.entry);
        }
        Ok(out)
    }
}

/// Encode `field` fully in memory, then write the store to `path` (the
/// pre-streaming behavior; peak memory is payload + container).
pub fn write_store_in_memory(
    field: &Field,
    chain: &CodecChainSpec,
    opts: &StoreWriteOptions,
    path: &Path,
) -> Result<StoreWriteReport> {
    let (bytes, _, report) = encode_store(field, chain, opts)?;
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::correction::FfczConfig;
    use crate::data::synth::grf::GrfBuilder;

    #[test]
    fn encode_produces_consistent_manifest() {
        let field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(3).build();
        let spec = CodecChainSpec::lossless();
        let opts = StoreWriteOptions::new(&[5, 4]).workers(2);
        let (bytes, manifest, report) = encode_store(&field, &spec, &opts).unwrap();
        assert_eq!(report.chunk_count, 3 * 3);
        assert_eq!(manifest.chunks.len(), 9);
        assert!(report.all_chunks_ok);
        // Payload ranges tile [8, manifest_offset) without gaps, every
        // chunk checksummed against its payload and on the default chain.
        let mut cursor = STORE_MAGIC.len() as u64;
        for c in &manifest.chunks {
            assert_eq!(c.offset, cursor);
            assert_eq!(c.chain, 0);
            let payload = &bytes[c.offset as usize..(c.offset + c.length) as usize];
            assert_eq!(c.crc32, Some(crc32(payload)));
            cursor += c.length;
        }
        assert_eq!(report.total_bytes, bytes.len());
        assert_eq!(&bytes[..8], STORE_MAGIC);
        assert_eq!(&bytes[bytes.len() - 8..], FOOTER_MAGIC);
        // Per-chunk reports mirror the manifest, in index order.
        assert_eq!(report.chunk_reports.len(), 9);
        for (i, r) in report.chunk_reports.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.bytes_out as u64, manifest.chunks[i].length);
            assert_eq!(r.pocs_iterations, manifest.chunks[i].stats.pocs_iterations);
        }
        // Chunk inputs tile the field exactly: Σ bytes_in = field bytes.
        let total_in: usize = report.chunk_reports.iter().map(|r| r.bytes_in).sum();
        assert_eq!(total_in, 12 * 10 * 8);
        let table = report.render_chunk_table();
        assert!(table.contains("chunk") && table.contains("c/0/0"), "{table}");
    }

    #[test]
    fn chunk_shape_mismatch_rejected() {
        let field = GrfBuilder::new(&[8, 8]).seed(1).build();
        let opts = StoreWriteOptions::new(&[4]);
        assert!(encode_store(&field, &CodecChainSpec::lossless(), &opts).is_err());
    }

    #[test]
    fn threads_only_override_keeps_its_own_chain_entry() {
        // `CodecChainSpec::eq` ignores `threads`, but a threads-only
        // override must NOT collapse onto the default chain entry — the
        // chunk would silently encode with the default's thread count.
        let grid = ChunkGrid::new(&[8, 8], &[4, 4]).unwrap();
        let default = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        let threaded =
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3).with_threads(4));
        let overrides = vec![("c/0/1".to_string(), threaded.clone())];
        let (chains, assign) = resolve_chains(&grid, &default, &overrides).unwrap();
        assert_eq!(chains.len(), 2, "threads-only override was deduped away");
        assert_eq!(assign, vec![0, 1, 0, 0]);
        assert_eq!(chains[1].ffcz_config().unwrap().threads, 4);
        // Wire bytes are still identical (threads is never serialized).
        assert_eq!(chains[0].to_bytes(), chains[1].to_bytes());
    }

    #[test]
    fn auto_threads_resolved_cooperatively_explicit_wins() {
        // Default-constructed configs request auto (threads == 0); the
        // writer resolves them to the cooperative budget. Explicit counts
        // pass through untouched.
        let auto = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        let explicit =
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3).with_threads(1));
        // Read the raw stage field: `ffcz_config()` clamps to ≥ 1 for
        // direct execution, which would mask the auto sentinel here.
        assert_eq!(
            auto.correction.as_ref().unwrap().threads,
            0,
            "default must be auto"
        );
        let mut chains = vec![auto, explicit, CodecChainSpec::lossless()];
        resolve_auto_threads(&mut chains, 2);
        let budget = auto_thread_budget(2);
        assert!(budget >= 1);
        assert_eq!(chains[0].correction.as_ref().unwrap().threads, budget);
        assert_eq!(chains[0].ffcz_config().unwrap().threads, budget);
        assert_eq!(
            chains[1].correction.as_ref().unwrap().threads,
            1,
            "explicit clobbered"
        );
        assert!(chains[2].correction.is_none());
        // More workers than cores degrades gracefully to 1 thread each.
        assert_eq!(auto_thread_budget(usize::MAX / 2), 1);
    }

    #[test]
    fn scratch_warms_once_per_worker_not_per_chunk() {
        // Same chunk shape, 4× the chunk count: the per-worker scratch
        // must warm up on the first chunk and add nothing afterwards, so
        // the allocation-event total is identical for both encodes.
        let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        let small = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(7).build();
        let large = GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(8).build();
        let opts = StoreWriteOptions::new(&[4, 4]).workers(1);
        let (_, _, small_report) = encode_store(&small, &spec, &opts).unwrap();
        let (_, _, large_report) = encode_store(&large, &spec, &opts).unwrap();
        assert!(small_report.scratch_alloc_events > 0, "warm-up must register");
        assert_eq!(
            small_report.scratch_alloc_events, large_report.scratch_alloc_events,
            "steady-state chunks allocated scratch (4 vs 16 chunks of [4, 4])"
        );
    }

    #[test]
    fn overrides_build_a_deduplicated_chain_table() {
        let field = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(5).build();
        let ffcz = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        // 2 × 2 grid; two overrides with the same lossless chain dedup to
        // one extra table entry.
        let opts = StoreWriteOptions::new(&[4, 4])
            .workers(2)
            .override_chunk("c/0/0", CodecChainSpec::lossless())
            .override_chunk("c/1/1", CodecChainSpec::lossless());
        let (_, manifest, report) = encode_store(&field, &ffcz, &opts).unwrap();
        assert!(report.all_chunks_ok);
        assert_eq!(manifest.chains.len(), 2);
        assert_eq!(manifest.chains[0], ffcz);
        assert_eq!(manifest.chains[1], CodecChainSpec::lossless());
        let assigned: Vec<usize> = manifest.chunks.iter().map(|c| c.chain).collect();
        assert_eq!(assigned, vec![1, 0, 0, 1]);
    }

    #[test]
    fn unknown_override_key_rejected() {
        let field = GrfBuilder::new(&[8, 8]).seed(1).build();
        let opts = StoreWriteOptions::new(&[4, 4])
            .override_chunk("c/9/9", CodecChainSpec::lossless());
        let err = encode_store(&field, &CodecChainSpec::lossless(), &opts)
            .unwrap_err()
            .to_string();
        assert!(err.contains("c/9/9"), "{err}");
    }

    #[test]
    fn streaming_matches_in_memory_byte_for_byte() {
        let field = GrfBuilder::new(&[12, 10]).lognormal(1.0).seed(3).build();
        let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        for workers in [1usize, 3] {
            let opts = StoreWriteOptions::new(&[5, 4]).workers(workers).queue_depth(1);
            let (mem, mem_manifest, mem_report) = encode_store(&field, &spec, &opts).unwrap();
            let mut streamed = Vec::new();
            let (manifest, report) =
                stream_store_to(&field, &spec, &opts, &mut streamed).unwrap();
            assert_eq!(streamed, mem, "workers={workers}: byte streams diverge");
            assert_eq!(manifest, mem_manifest);
            assert!(report.streamed && !mem_report.streamed);
            assert_eq!(report.total_bytes, mem_report.total_bytes);
            assert_eq!(report.manifest_bytes, mem_report.manifest_bytes);
            assert!(report.peak_payload_bytes <= mem_report.peak_payload_bytes);
            // Both paths collect the same per-chunk breakdown (in order).
            assert_eq!(report.chunk_reports.len(), mem_report.chunk_reports.len());
            for (s, m) in report.chunk_reports.iter().zip(&mem_report.chunk_reports) {
                assert_eq!((s.index, &s.key, s.bytes_out), (m.index, &m.key, m.bytes_out));
                assert_eq!(s.pocs_iterations, m.pocs_iterations);
            }
        }
    }

    #[test]
    fn stream_writer_guards_chunk_count_and_chain_index() {
        let enc = EncodedChunk {
            bytes: vec![1, 2, 3],
            stats: crate::codec::ChunkStats::exact(),
            detail: Default::default(),
        };
        // 2 × 1 grid: exactly two chunks, one chain.
        let mut w = StoreStreamWriter::new(
            Vec::<u8>::new(),
            &[8, 4],
            Precision::Double,
            &[4, 4],
            vec![CodecChainSpec::lossless()],
        )
        .unwrap();
        assert!(w.append_chunk(1, &enc).is_err(), "chain index out of table");
        w.append_chunk(0, &enc).unwrap();
        assert_eq!(w.chunks_written(), 1);

        // Finishing with a chunk missing must not mint a valid trailer.
        let err = w.finish().unwrap_err().to_string();
        assert!(err.contains("1 of 2"), "{err}");

        let mut w = StoreStreamWriter::new(
            Vec::<u8>::new(),
            &[8, 4],
            Precision::Double,
            &[4, 4],
            vec![CodecChainSpec::lossless()],
        )
        .unwrap();
        w.append_chunk(0, &enc).unwrap();
        w.append_chunk(0, &enc).unwrap();
        assert!(w.append_chunk(0, &enc).is_err(), "third chunk on a 2-chunk grid");
    }

    fn entry_for(offset: u64, payload: &[u8], chain: usize, iters: u32) -> ChunkEntry {
        ChunkEntry {
            offset,
            length: payload.len() as u64,
            chain,
            crc32: Some(crc32(payload)),
            stats: ChunkStats {
                spatial_ok: true,
                frequency_ok: iters % 2 == 0,
                max_spatial_ratio: 0.25 + iters as f64,
                max_frequency_ratio: 0.75,
                pocs_iterations: iters,
            },
        }
    }

    #[test]
    fn journal_records_roundtrip_and_tolerate_torn_tails() {
        let payloads: Vec<Vec<u8>> = vec![vec![0xAA; 50], vec![0xBB; 30], vec![0xCC; 17]];
        let mut offset = STORE_MAGIC.len() as u64;
        let mut entries = Vec::new();
        let mut journal = JOURNAL_MAGIC.to_vec();
        for (i, p) in payloads.iter().enumerate() {
            let e = entry_for(offset, p, i % 2, i as u32);
            offset += e.length;
            journal.extend_from_slice(&journal_record(i, &e));
            entries.push(e);
        }

        // The full journal parses back to exactly the entries written,
        // stats and all (f64 ratios are bit-exact through the round trip).
        let records = parse_journal(&journal);
        assert_eq!(records.len(), 3);
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.index, i as u64);
            assert_eq!(r.entry, entries[i]);
        }
        assert_eq!(records[2].end, journal.len() as u64);

        // Every byte-level truncation parses to only the records it fully
        // contains — in order, never partial, never a panic.
        let mut seen_partial = false;
        for cut in 0..journal.len() {
            let prefix = parse_journal(&journal[..cut]);
            assert!(prefix.len() <= 3, "cut={cut}");
            seen_partial |= !prefix.is_empty() && prefix.len() < 3;
            for (i, r) in prefix.iter().enumerate() {
                assert_eq!(r.index, i as u64, "cut={cut}");
                assert_eq!(r.entry, entries[i], "cut={cut}");
            }
        }
        assert!(seen_partial, "some truncation must yield a proper prefix");

        // A flipped byte inside the middle record kills it and everything
        // after it (the framing CRC catches the damage).
        let first_len = journal_record(0, &entries[0]).len();
        let mut corrupt = journal.clone();
        corrupt[JOURNAL_MAGIC.len() + first_len + 3] ^= 0x40;
        assert_eq!(parse_journal(&corrupt).len(), 1);

        // Wrong head magic yields nothing, as does an empty journal.
        let mut bad = journal.clone();
        bad[0] ^= 0xFF;
        assert!(parse_journal(&bad).is_empty());
        assert!(parse_journal(&[]).is_empty());
    }

    #[test]
    fn salvage_recovers_exactly_the_crc_valid_prefix() {
        use super::super::storage::MemStorage;
        let p0 = vec![0x11u8; 40];
        let p1 = vec![0x22u8; 25];
        let mut container = STORE_MAGIC.to_vec();
        container.extend_from_slice(&p0);
        container.extend_from_slice(&p1);
        let e0 = entry_for(8, &p0, 0, 2);
        let e1 = entry_for(48, &p1, 0, 4);
        let mut journal = JOURNAL_MAGIC.to_vec();
        journal.extend_from_slice(&journal_record(0, &e0));
        journal.extend_from_slice(&journal_record(1, &e1));

        // Intact container + journal: both chunks salvage, and the resume
        // point sits just past the last payload.
        let s = Store::salvage(&MemStorage::new(container.clone()), &journal).unwrap();
        assert_eq!(s.chunks(), 2);
        assert_eq!(s.entries, vec![e0.clone(), e1.clone()]);
        assert_eq!(s.payload_end, 73);
        assert_eq!(s.journal_end(2), journal.len() as u64);
        assert_eq!(s.journal_end(0), JOURNAL_MAGIC.len() as u64);

        // Container torn mid-payload-1: only chunk 0 salvages.
        let s = Store::salvage(&MemStorage::new(container[..60].to_vec()), &journal).unwrap();
        assert_eq!(s.chunks(), 1);
        assert_eq!(s.payload_end, 48);

        // A corrupt byte in payload 1 stops the scan at the CRC check.
        let mut corrupt = container.clone();
        corrupt[50] ^= 1;
        let s = Store::salvage(&MemStorage::new(corrupt), &journal).unwrap();
        assert_eq!(s.chunks(), 1);

        // Missing head magic: nothing salvageable, resume restarts at 8.
        let s = Store::salvage(&MemStorage::new(b"not a store".to_vec()), &journal).unwrap();
        assert_eq!(s.chunks(), 0);
        assert_eq!(s.payload_end, 8);
        let s = Store::salvage(&MemStorage::new(Vec::new()), &journal).unwrap();
        assert_eq!(s.chunks(), 0);

        // A journal record that skips an index does not extend the prefix.
        let mut skipped = JOURNAL_MAGIC.to_vec();
        skipped.extend_from_slice(&journal_record(0, &e0));
        skipped.extend_from_slice(&journal_record(2, &e1));
        let s = Store::salvage(&MemStorage::new(container), &skipped).unwrap();
        assert_eq!(s.chunks(), 1);
    }

    #[test]
    fn stream_writer_reports_healed_write_retries() {
        use super::super::storage::{FaultInjector, FaultPlan};
        let field = GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(11).build();
        let spec = CodecChainSpec::lossless();
        let opts = StoreWriteOptions::new(&[4, 4]).workers(1);
        let (clean, _, _) = encode_store(&field, &spec, &opts).unwrap();

        // Fault every 2nd op with a transient error; the retry policy
        // rewrites each faulted span and the bytes come out identical to
        // an unfaulted write.
        let plan = FaultPlan {
            transient_every: 2,
            ..FaultPlan::none()
        };
        let mut injector = FaultInjector::new(Vec::new(), plan.clone());
        let handle = injector.handle();
        let retrying = opts
            .clone()
            .retry_policy(RetryPolicy::transient(4, Duration::from_millis(0)));
        let (_, report) = stream_store_to(&field, &spec, &retrying, &mut injector).unwrap();
        assert!(report.write_retries > 0, "transient faults must be healed");
        assert_eq!(report.write_retries, handle.counts().transients);
        assert_eq!(injector.get_ref(), &clean, "healed write must be byte-identical");

        // Same write without a retry policy fails on the first transient.
        let injector = FaultInjector::new(Vec::new(), plan);
        assert!(stream_store_to(&field, &spec, &opts, injector).is_err());
    }
}
