//! Per-chunk codec pipeline.
//!
//! A [`ChunkCodec`] turns one chunk (a small [`Field`]) into bytes and
//! back. The pipeline composes the crate's existing stages:
//!
//! * **FFCz** ([`CodecSpec::Ffcz`]) — any registered base
//!   [`Compressor`](crate::compressors::Compressor)
//!   (`sz-like`, `zfp-like`, `sperr-like`, `identity`), optionally followed
//!   by the FFCz POCS correction stage, serialized as a per-chunk
//!   [`FfczArchive`] (which already carries the entropy-coded edit payload
//!   and the lossless backend);
//! * **Lossless** ([`CodecSpec::Lossless`]) — bit-exact f64 samples through
//!   [`crate::encoding::lossless_compress`].
//!
//! Relative bounds are resolved *per chunk* (against the chunk's own value
//! span and spectrum), matching the per-shard bound semantics of
//! [`crate::coordinator::sharding`]: the dual-domain guarantee holds for
//! every chunk independently, which is exactly the granularity a partial
//! reader observes.

use anyhow::{bail, Result};

use crate::compressors::{by_name, ErrorBound};
use crate::correction::{self, CorrectionStats, EditsBlock, FfczArchive, FfczConfig};
use crate::data::{Field, Precision};
use crate::encoding::{lossless_compress, lossless_decompress, varint};

use super::manifest::ChunkStats;

/// One encoded chunk plus the dual-domain verification stats recorded in
/// the manifest.
#[derive(Debug, Clone)]
pub struct EncodedChunk {
    pub bytes: Vec<u8>,
    pub stats: ChunkStats,
}

/// A per-chunk encode/decode pipeline. Implementations must be shareable
/// across the store's worker threads.
pub trait ChunkCodec: Send + Sync {
    /// The serializable description of this codec (stored in the manifest).
    fn spec(&self) -> CodecSpec;

    /// Encode one chunk, verifying the advertised bounds.
    fn encode(&self, chunk: &Field) -> Result<EncodedChunk>;

    /// Decode a chunk; `shape`/`precision` come from the manifest and the
    /// decoded field must match them.
    fn decode(&self, bytes: &[u8], shape: &[usize], precision: Precision) -> Result<Field>;
}

/// Serializable codec description (the manifest's `codec` entry).
#[derive(Debug, Clone, PartialEq)]
pub enum CodecSpec {
    /// Bit-exact: raw little-endian f64 samples through the lossless
    /// backend.
    Lossless,
    /// Error-bounded base compressor, optionally followed by the FFCz
    /// dual-domain correction stage.
    Ffcz {
        /// Base compressor registry name (`sz-like`, …).
        base: String,
        /// Relative spatial bound E (per chunk).
        spatial_rel: f64,
        /// Relative frequency bound Δ (per chunk); `None` = base compressor
        /// only, no correction stage and no frequency guarantee.
        frequency_rel: Option<f64>,
    },
}

impl CodecSpec {
    /// Instantiate the codec. Errors if the base compressor is unknown.
    pub fn build(&self) -> Result<Box<dyn ChunkCodec>> {
        match self {
            CodecSpec::Lossless => Ok(Box::new(LosslessChunkCodec)),
            CodecSpec::Ffcz {
                base,
                spatial_rel,
                frequency_rel,
            } => {
                if by_name(base).is_none() {
                    bail!("unknown base compressor '{base}' in codec spec");
                }
                Ok(Box::new(FfczChunkCodec {
                    base: base.clone(),
                    spatial_rel: *spatial_rel,
                    frequency_rel: *frequency_rel,
                }))
            }
        }
    }

    /// One-line human description (for `archive inspect`).
    pub fn describe(&self) -> String {
        match self {
            CodecSpec::Lossless => "lossless (bit-exact f64)".to_string(),
            CodecSpec::Ffcz {
                base,
                spatial_rel,
                frequency_rel: Some(db),
            } => format!("{base} + FFCz (eb {spatial_rel:.3e}, db {db:.3e}, per chunk)"),
            CodecSpec::Ffcz {
                base, spatial_rel, ..
            } => format!("{base} (eb {spatial_rel:.3e}, per chunk, no frequency bound)"),
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            CodecSpec::Lossless => out.push(0u8),
            CodecSpec::Ffcz {
                base,
                spatial_rel,
                frequency_rel,
            } => {
                out.push(1u8);
                varint::write(&mut out, base.len() as u64);
                out.extend_from_slice(base.as_bytes());
                out.extend_from_slice(&spatial_rel.to_le_bytes());
                match frequency_rel {
                    None => out.push(0u8),
                    Some(db) => {
                        out.push(1u8);
                        out.extend_from_slice(&db.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    pub fn from_bytes(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let tag = *buf.get(*pos).ok_or_else(|| anyhow::anyhow!("truncated codec spec"))?;
        *pos += 1;
        match tag {
            0 => Ok(CodecSpec::Lossless),
            1 => {
                let name_len = varint::read(buf, pos)? as usize;
                if *pos + name_len > buf.len() {
                    bail!("truncated codec base name");
                }
                let base = String::from_utf8(buf[*pos..*pos + name_len].to_vec())?;
                *pos += name_len;
                let spatial_rel = read_f64(buf, pos)?;
                let has_freq = *buf
                    .get(*pos)
                    .ok_or_else(|| anyhow::anyhow!("truncated codec spec"))?;
                *pos += 1;
                let frequency_rel = match has_freq {
                    0 => None,
                    1 => Some(read_f64(buf, pos)?),
                    x => bail!("bad frequency flag {x} in codec spec"),
                };
                Ok(CodecSpec::Ffcz {
                    base,
                    spatial_rel,
                    frequency_rel,
                })
            }
            x => bail!("unknown codec spec tag {x}"),
        }
    }
}

pub(crate) fn read_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
    if *pos + 8 > buf.len() {
        bail!("truncated f64");
    }
    let v = f64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

fn check_decoded(field: &Field, shape: &[usize], precision: Precision) -> Result<()> {
    if field.shape() != shape {
        bail!(
            "decoded chunk shape {:?} does not match manifest {:?}",
            field.shape(),
            shape
        );
    }
    let _ = precision; // precision is re-tagged by the caller
    Ok(())
}

/// Base compressor + optional FFCz correction, one archive per chunk.
struct FfczChunkCodec {
    base: String,
    spatial_rel: f64,
    frequency_rel: Option<f64>,
}

impl ChunkCodec for FfczChunkCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Ffcz {
            base: self.base.clone(),
            spatial_rel: self.spatial_rel,
            frequency_rel: self.frequency_rel,
        }
    }

    fn encode(&self, chunk: &Field) -> Result<EncodedChunk> {
        let base = by_name(&self.base)
            .ok_or_else(|| anyhow::anyhow!("unknown base compressor '{}'", self.base))?;
        let Some(db) = self.frequency_rel else {
            // Base-only mode: no correction stage at all. The payload is
            // still framed as an FfczArchive (with an empty edit block) so
            // decode shares one path; only the spatial bound is verified,
            // and `frequency_ok = true, ratio 0` records "not requested".
            let bound = ErrorBound::Relative(self.spatial_rel);
            let payload = base.compress(chunk, bound)?;
            let recon = base.decompress(&payload)?;
            let e = bound.absolute_for(chunk);
            let max_err = chunk
                .data()
                .iter()
                .zip(recon.data())
                .map(|(x, r)| (r - x).abs())
                .fold(0.0f64, f64::max);
            let archive = FfczArchive {
                base_name: self.base.clone(),
                base_payload: payload,
                edits: EditsBlock::Raw {
                    n: chunk.len(),
                    spat: Vec::new(),
                    freq: Vec::new(),
                },
                stats: CorrectionStats {
                    converged: true,
                    ..CorrectionStats::default()
                },
            };
            return Ok(EncodedChunk {
                bytes: archive.to_bytes(),
                stats: ChunkStats {
                    spatial_ok: max_err <= e,
                    frequency_ok: true,
                    max_spatial_ratio: max_err / e,
                    max_frequency_ratio: 0.0,
                    pocs_iterations: 0,
                },
            });
        };
        let cfg = FfczConfig::relative(self.spatial_rel, db);
        let archive = correction::compress(chunk, base.as_ref(), &cfg)?;
        // Dual-domain verification against the original chunk; the outcome
        // is recorded per chunk in the manifest.
        let recon = correction::decompress(&archive)?;
        let report = correction::verify(chunk, &recon, &cfg);
        let stats = ChunkStats {
            spatial_ok: report.spatial_ok,
            frequency_ok: report.frequency_ok,
            max_spatial_ratio: report.max_spatial_ratio,
            max_frequency_ratio: report.max_frequency_ratio,
            pocs_iterations: archive.stats.iterations as u32,
        };
        Ok(EncodedChunk {
            bytes: archive.to_bytes(),
            stats,
        })
    }

    fn decode(&self, bytes: &[u8], shape: &[usize], precision: Precision) -> Result<Field> {
        let archive = FfczArchive::from_bytes(bytes)?;
        let field = correction::decompress(&archive)?;
        check_decoded(&field, shape, precision)?;
        Ok(Field::new(shape, field.into_data(), precision))
    }
}

/// Bit-exact baseline codec.
struct LosslessChunkCodec;

impl ChunkCodec for LosslessChunkCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Lossless
    }

    fn encode(&self, chunk: &Field) -> Result<EncodedChunk> {
        let mut raw = Vec::with_capacity(chunk.len() * 8);
        for &v in chunk.data() {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        Ok(EncodedChunk {
            bytes: lossless_compress(&raw),
            stats: ChunkStats::exact(),
        })
    }

    fn decode(&self, bytes: &[u8], shape: &[usize], precision: Precision) -> Result<Field> {
        let raw = lossless_decompress(bytes)?;
        let n: usize = shape.iter().product();
        if raw.len() != n * 8 {
            bail!(
                "lossless chunk decodes to {} bytes, expected {}",
                raw.len(),
                n * 8
            );
        }
        let data: Vec<f64> = raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Field::new(shape, data, precision))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::grf::GrfBuilder;

    fn grf_chunk() -> Field {
        GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(11).build()
    }

    #[test]
    fn spec_roundtrips_bytes() {
        for spec in [
            CodecSpec::Lossless,
            CodecSpec::Ffcz {
                base: "sz-like".into(),
                spatial_rel: 1e-3,
                frequency_rel: Some(1e-3),
            },
            CodecSpec::Ffcz {
                base: "zfp-like".into(),
                spatial_rel: 1e-2,
                frequency_rel: None,
            },
        ] {
            let bytes = spec.to_bytes();
            let mut pos = 0;
            let back = CodecSpec::from_bytes(&bytes, &mut pos).unwrap();
            assert_eq!(back, spec);
            assert_eq!(pos, bytes.len());
        }
    }

    #[test]
    fn spec_rejects_unknown_base_and_bad_bytes() {
        let spec = CodecSpec::Ffcz {
            base: "nope".into(),
            spatial_rel: 1e-3,
            frequency_rel: None,
        };
        assert!(spec.build().is_err());
        let mut pos = 0;
        assert!(CodecSpec::from_bytes(&[9], &mut pos).is_err());
        let mut pos = 0;
        assert!(CodecSpec::from_bytes(&[], &mut pos).is_err());
    }

    #[test]
    fn lossless_codec_is_bit_exact() {
        let chunk = grf_chunk();
        let codec = CodecSpec::Lossless.build().unwrap();
        let enc = codec.encode(&chunk).unwrap();
        assert!(enc.stats.spatial_ok && enc.stats.frequency_ok);
        let dec = codec
            .decode(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        assert_eq!(dec.data(), chunk.data());
    }

    #[test]
    fn ffcz_codec_roundtrips_within_bounds() {
        let chunk = grf_chunk();
        let spec = CodecSpec::Ffcz {
            base: "sz-like".into(),
            spatial_rel: 1e-3,
            frequency_rel: Some(1e-3),
        };
        let codec = spec.build().unwrap();
        let enc = codec.encode(&chunk).unwrap();
        assert!(enc.stats.spatial_ok && enc.stats.frequency_ok);
        assert!(enc.stats.max_spatial_ratio <= 1.0 + 1e-9);
        let dec = codec
            .decode(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        assert_eq!(dec.shape(), chunk.shape());
        let e = chunk.value_span() * 1e-3;
        for (a, b) in chunk.data().iter().zip(dec.data()) {
            assert!((a - b).abs() <= e * (1.0 + 1e-9));
        }
    }

    #[test]
    fn base_only_mode_skips_correction_but_bounds_spatially() {
        let chunk = grf_chunk();
        let spec = CodecSpec::Ffcz {
            base: "sz-like".into(),
            spatial_rel: 1e-3,
            frequency_rel: None,
        };
        let codec = spec.build().unwrap();
        let enc = codec.encode(&chunk).unwrap();
        assert!(enc.stats.spatial_ok);
        assert!(enc.stats.frequency_ok, "frequency bound not requested");
        assert_eq!(enc.stats.pocs_iterations, 0, "no POCS in base-only mode");
        assert_eq!(enc.stats.max_frequency_ratio, 0.0);
        let dec = codec
            .decode(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        let e = chunk.value_span() * 1e-3;
        for (a, b) in chunk.data().iter().zip(dec.data()) {
            assert!((a - b).abs() <= e * (1.0 + 1e-9));
        }
    }

    #[test]
    fn decode_rejects_wrong_shape() {
        let chunk = grf_chunk();
        let codec = CodecSpec::Lossless.build().unwrap();
        let enc = codec.encode(&chunk).unwrap();
        assert!(codec.decode(&enc.bytes, &[4, 4], chunk.precision()).is_err());
    }
}
