//! Remote HTTP-range storage backend.
//!
//! [`HttpStorage`] implements [`ReadableStorage`] over plain HTTP/1.1
//! `GET` requests with `Range: bytes=…` headers on `std::net::TcpStream`
//! — dependency-free like the rest of the crate (the build is offline;
//! there is no `reqwest`/`hyper` here, and no TLS). The exact client
//! profile it speaks — and the minimal server behavior it requires — is
//! documented normatively in `docs/STORAGE.md`; any HTTP server that
//! honors single-range requests (object-store gateways, `nginx`, the
//! in-process [`HttpRangeServer`] below) is a valid endpoint.
//!
//! Transport failures map onto `io::ErrorKind`s the storage retry layer
//! already understands: conditions a retry can heal (stale keep-alive
//! connections, resets, truncated bodies, wrong-length ranges,
//! `429`/`5xx` responses, socket timeouts) surface as **transient**
//! kinds (`Interrupted`/`TimedOut`), permanent protocol problems (no
//! range support, malformed or unexpected responses) as hard errors.
//! The backend itself never retries and never sleeps — retries,
//! deadlines, hedging, and circuit breaking are the
//! [`super::resilience::ResilientStorage`] wrapper's job.
//!
//! Connections are reused: successful exchanges return their socket to a
//! small keep-alive pool, so hedged reads and parallel `read_region`
//! workers do not pay a TCP handshake per chunk; any error drops the
//! connection on the floor and the next request dials fresh.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::sync::lock;

use super::storage::ReadableStorage;

/// Cap on response status line + header bytes (a well-formed range
/// response needs far less; anything bigger is a protocol violation).
const MAX_HEADER_BYTES: usize = 16 * 1024;

/// Idle keep-alive connections retained per backend.
const POOL_CAP: usize = 4;

/// Default socket read/write timeout (a stalled endpoint surfaces as a
/// transient `TimedOut`, which retry policies and the resilience layer's
/// deadline know how to handle).
const DEFAULT_TIMEOUT: Duration = Duration::from_secs(10);

/// A transient (retryable) transport error.
fn transient(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::Interrupted, msg)
}

/// `ReadableStorage` over HTTP/1.1 range requests.
///
/// ```no_run
/// use ffcz::store::{HttpStorage, Store};
/// let storage = HttpStorage::open("http://archive-host:8080/nyx/baryon.ffcz").unwrap();
/// let store = Store::open_storage(storage).unwrap();
/// let region = store.read_region(&[0, 0, 0], &[64, 64, 64], 4).unwrap();
/// ```
pub struct HttpStorage {
    /// `host[:port]` exactly as written in the URL (the `Host` header).
    authority: String,
    /// `host:port` as dialed (port 80 made explicit).
    addr: String,
    /// Absolute request path (`/` if the URL had none).
    path: String,
    len: u64,
    timeout: Duration,
    pool: Mutex<Vec<TcpStream>>,
}

impl HttpStorage {
    /// Open `url` (`http://host[:port]/path`) and discover the remote
    /// object's size with a 1-byte probe request. `https://` URLs are
    /// refused — the dependency-free client speaks plain HTTP only.
    pub fn open(url: &str) -> io::Result<Self> {
        Self::open_with_timeout(url, DEFAULT_TIMEOUT)
    }

    /// [`Self::open`] with an explicit socket read/write timeout
    /// (`Duration::ZERO` disables timeouts — tests only).
    pub fn open_with_timeout(url: &str, timeout: Duration) -> io::Result<Self> {
        let (authority, addr, path) = split_url(url)?;
        let mut storage = Self {
            authority,
            addr,
            path,
            len: 0,
            timeout,
            pool: Mutex::new(Vec::new()),
        };
        storage.len = storage.discover_len()?;
        Ok(storage)
    }

    /// The endpoint this backend talks to (`host[:port]`) — the circuit
    /// breaker's sharing key.
    pub fn endpoint(&self) -> &str {
        &self.authority
    }

    /// The full URL this backend reads.
    pub fn url(&self) -> String {
        format!("http://{}{}", self.authority, self.path)
    }

    fn checkout(&self) -> io::Result<TcpStream> {
        if let Some(conn) = lock(&self.pool).pop() {
            return Ok(conn);
        }
        let conn = TcpStream::connect(&self.addr)?;
        let _ = conn.set_nodelay(true);
        if !self.timeout.is_zero() {
            conn.set_read_timeout(Some(self.timeout))?;
            conn.set_write_timeout(Some(self.timeout))?;
        }
        Ok(conn)
    }

    fn checkin(&self, conn: TcpStream) {
        let mut pool = lock(&self.pool);
        if pool.len() < POOL_CAP {
            pool.push(conn);
        }
    }

    /// One request/response exchange for `bytes=offset..=last`; on
    /// success the body lands in `buf` and the connection goes back to
    /// the pool. Any error drops the connection.
    fn fetch(&self, offset: u64, want: usize, buf: &mut [u8]) -> io::Result<usize> {
        let mut conn = self.checkout()?;
        match self.exchange(&mut conn, offset, want, buf) {
            Ok((n, reusable)) => {
                if reusable {
                    self.checkin(conn);
                }
                Ok(n)
            }
            Err(e) => Err(e),
        }
    }

    fn exchange(
        &self,
        conn: &mut TcpStream,
        offset: u64,
        want: usize,
        buf: &mut [u8],
    ) -> io::Result<(usize, bool)> {
        let last = offset + (want as u64 - 1);
        write_request(conn, &self.authority, &self.path, offset, last)
            .map_err(|e| transient(format!("writing range request: {e}")))?;
        let head = ResponseHead::read_from(conn)?;
        match head.code {
            206 => {
                let Some(cl) = head.content_length else {
                    return Err(transient(
                        "206 response without Content-Length (chunked bodies are unsupported)"
                            .to_string(),
                    ));
                };
                if let Some((start, _end)) = head.range_span {
                    if start != offset {
                        return Err(transient(format!(
                            "Content-Range starts at {start}, requested {offset}"
                        )));
                    }
                }
                if cl > want as u64 {
                    return Err(transient(format!(
                        "wrong-length range: {cl} body bytes for a {want}-byte request"
                    )));
                }
                let n = cl as usize;
                read_body(conn, &mut buf[..n])?;
                Ok((n, head.keep_alive))
            }
            200 => Err(io::Error::new(
                io::ErrorKind::Unsupported,
                format!(
                    "{} ignored the Range header (status 200) — not a range-capable endpoint",
                    self.url()
                ),
            )),
            // Requested range past the end: end-of-storage, nothing to
            // reuse (the error body is unread).
            416 => Ok((0, false)),
            429 | 500..=599 => Err(transient(format!(
                "endpoint {} answered HTTP {} (retryable)",
                self.authority, head.code
            ))),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected HTTP status {other} from {}", self.url()),
            )),
        }
    }

    /// Probe the object size: a `bytes=0-0` request whose `Content-Range`
    /// total is the answer (a `416` with `bytes */N` means a zero-length
    /// object and still carries the total).
    fn discover_len(&self) -> io::Result<u64> {
        let mut conn = self.checkout()?;
        write_request(&mut conn, &self.authority, &self.path, 0, 0)
            .map_err(|e| transient(format!("writing size probe: {e}")))?;
        let head = ResponseHead::read_from(&mut conn)?;
        match head.code {
            206 => {
                let Some(total) = head.range_total else {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("{} did not report a Content-Range total", self.url()),
                    ));
                };
                // Drain the 1-byte probe body so the connection is
                // reusable.
                let mut probe = [0u8; 1];
                let cl = head.content_length.unwrap_or(0);
                if cl == 1 && read_body(&mut conn, &mut probe).is_ok() {
                    self.checkin(conn);
                }
                Ok(total)
            }
            416 => head.range_total.map_or_else(
                || {
                    Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("{} did not report a Content-Range total", self.url()),
                    ))
                },
                Ok,
            ),
            200 => Err(io::Error::new(
                io::ErrorKind::Unsupported,
                format!(
                    "{} ignored the Range header (status 200) — not a range-capable endpoint",
                    self.url()
                ),
            )),
            404 => Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("{} answered HTTP 404", self.url()),
            )),
            429 | 500..=599 => Err(transient(format!(
                "endpoint {} answered HTTP {} (retryable)",
                self.authority, head.code
            ))),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected HTTP status {other} from {}", self.url()),
            )),
        }
    }
}

impl ReadableStorage for HttpStorage {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() || offset >= self.len {
            return Ok(0);
        }
        let tail = usize::try_from(self.len - offset).unwrap_or(usize::MAX);
        let want = buf.len().min(tail);
        self.fetch(offset, want, buf)
    }

    fn size(&self) -> io::Result<u64> {
        Ok(self.len)
    }

    fn describe(&self) -> String {
        self.url()
    }
}

/// `http://host[:port]/path` → (authority, dial address, path).
fn split_url(url: &str) -> io::Result<(String, String, String)> {
    if url.starts_with("https://") {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            format!("https is not supported by the dependency-free client: {url}"),
        ));
    }
    let Some(rest) = url.strip_prefix("http://") else {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("not an http:// URL: {url}"),
        ));
    };
    let (authority, path) = match rest.find('/') {
        Some(i) => (&rest[..i], &rest[i..]),
        None => (rest, "/"),
    };
    if authority.is_empty() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("empty host in URL: {url}"),
        ));
    }
    let addr = if authority.contains(':') {
        authority.to_string()
    } else {
        format!("{authority}:80")
    };
    Ok((authority.to_string(), addr, path.to_string()))
}

fn write_request(
    conn: &mut TcpStream,
    authority: &str,
    path: &str,
    first: u64,
    last: u64,
) -> io::Result<()> {
    let req = format!(
        "GET {path} HTTP/1.1\r\nHost: {authority}\r\nRange: bytes={first}-{last}\r\nConnection: keep-alive\r\nUser-Agent: ffcz\r\n\r\n"
    );
    conn.write_all(req.as_bytes())?;
    conn.flush()
}

/// Fill `buf` from the response body, mapping premature EOF and socket
/// errors to transient kinds (the connection died mid-body; a retry
/// reissues the whole range).
fn read_body(conn: &mut TcpStream, buf: &mut [u8]) -> io::Result<()> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match conn.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(transient(format!(
                    "truncated response body: got {filled} of {} bytes",
                    buf.len()
                )))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if super::storage::RetryPolicy::is_transient(e.kind()) => return Err(e),
            Err(e) => return Err(transient(format!("reading response body: {e}"))),
        }
    }
    Ok(())
}

/// Parsed status line + the few headers the range profile cares about.
struct ResponseHead {
    code: u16,
    content_length: Option<u64>,
    /// `Content-Range: bytes S-E/…` span, if present.
    range_span: Option<(u64, u64)>,
    /// `Content-Range: bytes …/T` total, if not `*`.
    range_total: Option<u64>,
    keep_alive: bool,
}

impl ResponseHead {
    /// Read status line + headers (through the blank line). Connection
    /// death or timeout before the head completes is transient — the
    /// request can be reissued on a fresh connection.
    fn read_from(conn: &mut TcpStream) -> io::Result<Self> {
        let mut head = Vec::with_capacity(256);
        let mut byte = [0u8; 1];
        while !head.ends_with(b"\r\n\r\n") {
            if head.len() >= MAX_HEADER_BYTES {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("response headers exceed {MAX_HEADER_BYTES} bytes"),
                ));
            }
            match conn.read(&mut byte) {
                Ok(0) => {
                    return Err(transient(format!(
                        "connection closed after {} header bytes",
                        head.len()
                    )))
                }
                Ok(_) => head.push(byte[0]),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if super::storage::RetryPolicy::is_transient(e.kind()) => return Err(e),
                Err(e) => return Err(transient(format!("reading response headers: {e}"))),
            }
        }
        Self::parse(&head)
    }

    fn parse(head: &[u8]) -> io::Result<Self> {
        let text = std::str::from_utf8(head).map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidData, "response headers are not UTF-8")
        })?;
        let mut lines = text.split("\r\n");
        let status = lines.next().unwrap_or("");
        // "HTTP/1.1 206 Partial Content" → 206.
        let code = status
            .split_whitespace()
            .nth(1)
            .and_then(|c| c.parse::<u16>().ok())
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("malformed HTTP status line: {status:?}"),
                )
            })?;
        let mut parsed = Self {
            code,
            content_length: None,
            range_span: None,
            range_total: None,
            keep_alive: true,
        };
        for line in lines {
            let Some((name, value)) = line.split_once(':') else {
                continue;
            };
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                parsed.content_length = value.parse().ok();
            } else if name.eq_ignore_ascii_case("content-range") {
                if let Some((span, total)) = parse_content_range(value) {
                    parsed.range_span = span;
                    parsed.range_total = total;
                }
            } else if name.eq_ignore_ascii_case("connection")
                && value.eq_ignore_ascii_case("close")
            {
                parsed.keep_alive = false;
            }
        }
        Ok(parsed)
    }
}

/// `bytes S-E/T` → `(Some((S, E)) | None for "*", Some(T) | None for "*")`.
fn parse_content_range(value: &str) -> Option<(Option<(u64, u64)>, Option<u64>)> {
    let rest = value.strip_prefix("bytes ")?;
    let (range, total) = rest.split_once('/')?;
    let total = if total.trim() == "*" {
        None
    } else {
        Some(total.trim().parse().ok()?)
    };
    let span = if range.trim() == "*" {
        None
    } else {
        let (s, e) = range.split_once('-')?;
        Some((s.trim().parse().ok()?, e.trim().parse().ok()?))
    };
    Some((span, total))
}

// ------------------------------------------------------------ fixture --

/// How often the accept loop and idle connection handlers of a
/// [`HttpRangeServer`] re-check the stop flag.
const SERVER_POLL: Duration = Duration::from_millis(20);

/// A minimal in-process HTTP/1.1 range server over in-memory byte
/// buffers — the loopback endpoint behind the remote-backend benches,
/// doc examples, and integration tests. It implements exactly the server
/// side of the client profile in `docs/STORAGE.md`: single-range `GET`s
/// answer `206 Partial Content` with `Content-Range` and
/// `Content-Length`; rangeless `GET`s answer `200` with the whole body;
/// a range starting past the end answers `416` with the object total;
/// unknown paths answer `404`.
pub struct HttpRangeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpRangeServer {
    /// Serve `files` (name → bytes, reachable at `/name`) on an
    /// ephemeral loopback port.
    pub fn start(files: Vec<(String, Vec<u8>)>) -> io::Result<Self> {
        Self::start_on("127.0.0.1:0", files)
    }

    /// [`Self::start`] on an explicit address. Tests use this to restart
    /// a fixture on the port a killed instance occupied — the endpoint
    /// "coming back" that circuit-breaker recovery needs to observe.
    pub fn start_on(addr: &str, files: Vec<(String, Vec<u8>)>) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let table: Arc<HashMap<String, Arc<Vec<u8>>>> = Arc::new(
            files
                .into_iter()
                .map(|(name, bytes)| (format!("/{name}"), Arc::new(bytes)))
                .collect(),
        );
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("ffcz-http-fixture".to_string())
            .spawn(move || range_server_loop(listener, table, accept_stop))?;
        Ok(Self {
            addr,
            stop,
            accept: Some(accept),
        })
    }

    /// Serve one buffer as `/data`; returns the server and its full URL.
    pub fn single(bytes: Vec<u8>) -> io::Result<(Self, String)> {
        let server = Self::start(vec![("data".to_string(), bytes)])?;
        let url = server.url_for("data");
        Ok((server, url))
    }

    /// `http://127.0.0.1:port` — the `--remote-root` form.
    pub fn root_url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Full URL of a served file.
    pub fn url_for(&self, name: &str) -> String {
        format!("http://{}/{name}", self.addr)
    }

    /// Stop accepting and join every connection handler.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for HttpRangeServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn range_server_loop(
    listener: TcpListener,
    table: Arc<HashMap<String, Arc<Vec<u8>>>>,
    stop: Arc<AtomicBool>,
) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((conn, _peer)) => {
                let conn_table = Arc::clone(&table);
                let conn_stop = Arc::clone(&stop);
                if let Ok(handle) = std::thread::Builder::new()
                    .name("ffcz-http-fixture-conn".to_string())
                    .spawn(move || serve_range_connection(conn, &conn_table, &conn_stop))
                {
                    handlers.push(handle);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => std::thread::sleep(SERVER_POLL),
            Err(_) => std::thread::sleep(SERVER_POLL),
        }
    }
    for handle in handlers {
        let _ = handle.join();
    }
}

fn serve_range_connection(
    mut conn: TcpStream,
    table: &HashMap<String, Arc<Vec<u8>>>,
    stop: &AtomicBool,
) {
    if conn.set_nonblocking(false).is_err() {
        return;
    }
    let _ = conn.set_read_timeout(Some(SERVER_POLL));
    let _ = conn.set_nodelay(true);
    while !stop.load(Ordering::SeqCst) {
        let head = match read_request_head(&mut conn) {
            Ok(Some(head)) => head,
            Ok(None) => continue, // idle; poll the stop flag again
            Err(_) => return,     // peer went away or spoke garbage
        };
        let Some((path, range)) = parse_request_head(&head) else {
            let _ = conn.write_all(b"HTTP/1.1 400 Bad Request\r\nContent-Length: 0\r\n\r\n");
            return;
        };
        let Some(bytes) = table.get(&path) else {
            if conn
                .write_all(b"HTTP/1.1 404 Not Found\r\nContent-Length: 0\r\n\r\n")
                .is_err()
            {
                return;
            }
            continue;
        };
        if write_range_reply(&mut conn, bytes, range).is_err() {
            return;
        }
    }
}

/// Read one request's status line + headers. `Ok(None)` means a read
/// timeout before any byte (idle connection); EOF before any byte ends
/// the connection via `Err`.
fn read_request_head(conn: &mut TcpStream) -> io::Result<Option<Vec<u8>>> {
    let mut head = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        if head.len() >= MAX_HEADER_BYTES {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "request headers too large",
            ));
        }
        match conn.read(&mut byte) {
            Ok(0) => return Err(io::Error::from(io::ErrorKind::UnexpectedEof)),
            Ok(_) => head.push(byte[0]),
            Err(e)
                if head.is_empty()
                    && matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                    ) =>
            {
                return Ok(None)
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            // A mid-request stall (timeout with a partial head) drops the
            // connection rather than pinning the handler thread.
            Err(e) => return Err(e),
        }
    }
    Ok(Some(head))
}

/// Extract the request path and the first-range span from a `GET`.
fn parse_request_head(head: &[u8]) -> Option<(String, Option<(u64, Option<u64>)>)> {
    let text = std::str::from_utf8(head).ok()?;
    let mut lines = text.split("\r\n");
    let request = lines.next()?;
    let mut parts = request.split_whitespace();
    if parts.next()? != "GET" {
        return None;
    }
    let path = parts.next()?.to_string();
    let mut range = None;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        if name.eq_ignore_ascii_case("range") {
            let spec = value.trim().strip_prefix("bytes=")?;
            let (first, last) = spec.split_once('-')?;
            let first: u64 = first.trim().parse().ok()?;
            let last: Option<u64> = if last.trim().is_empty() {
                None
            } else {
                Some(last.trim().parse().ok()?)
            };
            range = Some((first, last));
        }
    }
    Some((path, range))
}

fn write_range_reply(
    conn: &mut TcpStream,
    bytes: &[u8],
    range: Option<(u64, Option<u64>)>,
) -> io::Result<()> {
    let total = bytes.len() as u64;
    let Some((first, last)) = range else {
        // Rangeless GET: the whole object with a 200.
        let head = format!("HTTP/1.1 200 OK\r\nContent-Length: {total}\r\n\r\n");
        conn.write_all(head.as_bytes())?;
        return conn.write_all(bytes);
    };
    if first >= total {
        let head = format!(
            "HTTP/1.1 416 Range Not Satisfiable\r\nContent-Range: bytes */{total}\r\nContent-Length: 0\r\n\r\n"
        );
        return conn.write_all(head.as_bytes());
    }
    let last = last.unwrap_or(total - 1).min(total - 1);
    let body = &bytes[first as usize..=last as usize];
    let head = format!(
        "HTTP/1.1 206 Partial Content\r\nContent-Range: bytes {first}-{last}/{total}\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    conn.write_all(head.as_bytes())?;
    conn.write_all(body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::storage::read_exact_at;

    fn fixture_bytes(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i * 31 % 251) as u8).collect()
    }

    #[test]
    fn url_parsing_is_strict() {
        assert!(split_url("http://h/p").is_ok());
        assert_eq!(
            split_url("http://h:8080/a/b.ffcz").unwrap(),
            (
                "h:8080".to_string(),
                "h:8080".to_string(),
                "/a/b.ffcz".to_string()
            )
        );
        assert_eq!(
            split_url("http://h").unwrap(),
            ("h".to_string(), "h:80".to_string(), "/".to_string())
        );
        assert!(split_url("https://h/p").is_err());
        assert!(split_url("ftp://h/p").is_err());
        assert!(split_url("http:///p").is_err());
    }

    #[test]
    fn content_range_parses_all_documented_forms() {
        assert_eq!(
            parse_content_range("bytes 0-0/1234"),
            Some((Some((0, 0)), Some(1234)))
        );
        assert_eq!(
            parse_content_range("bytes 5-9/*"),
            Some((Some((5, 9)), None))
        );
        assert_eq!(parse_content_range("bytes */77"), Some((None, Some(77))));
        assert_eq!(parse_content_range("lines 0-0/5"), None);
        assert_eq!(parse_content_range("bytes garbage"), None);
    }

    #[test]
    fn http_storage_reads_match_memory_ground_truth() {
        let bytes = fixture_bytes(10_000);
        let (server, url) = HttpRangeServer::single(bytes.clone()).unwrap();
        let storage = HttpStorage::open(&url).unwrap();
        assert_eq!(storage.size().unwrap(), 10_000);

        let mut got = vec![0u8; 3000];
        read_exact_at(&storage, 4321, &mut got).unwrap();
        assert_eq!(&got[..], &bytes[4321..7321]);

        // Reads clipped at end-of-object and past it.
        let mut tail = vec![0u8; 64];
        assert_eq!(storage.read_at(9_990, &mut tail).unwrap(), 10);
        assert_eq!(&tail[..10], &bytes[9_990..]);
        assert_eq!(storage.read_at(10_000, &mut tail).unwrap(), 0);
        assert_eq!(storage.read_at(99_999, &mut tail).unwrap(), 0);
        server.shutdown();
    }

    #[test]
    fn connections_are_reused_across_requests() {
        let bytes = fixture_bytes(4096);
        let (server, url) = HttpRangeServer::single(bytes.clone()).unwrap();
        let storage = HttpStorage::open(&url).unwrap();
        let mut buf = vec![0u8; 128];
        for i in 0..16u64 {
            read_exact_at(&storage, i * 100, &mut buf).unwrap();
            assert_eq!(&buf[..], &bytes[(i * 100) as usize..][..128]);
        }
        assert_eq!(
            lock(&storage.pool).len(),
            1,
            "sequential requests must reuse one pooled connection"
        );
        server.shutdown();
    }

    #[test]
    fn missing_object_is_a_not_found_error() {
        let server = HttpRangeServer::start(vec![("a".to_string(), vec![1, 2, 3])]).unwrap();
        let err = HttpStorage::open(&server.url_for("missing")).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::NotFound);
        server.shutdown();
    }

    #[test]
    fn empty_object_has_zero_size() {
        let (server, url) = HttpRangeServer::single(Vec::new()).unwrap();
        let storage = HttpStorage::open(&url).unwrap();
        assert_eq!(storage.size().unwrap(), 0);
        let mut buf = [0u8; 8];
        assert_eq!(storage.read_at(0, &mut buf).unwrap(), 0);
        server.shutdown();
    }
}
