//! Chunked spectral archive store (`.ffcz` container).
//!
//! FFCz corrects whole fields in memory, but the target workloads (Nyx
//! snapshots, S3D combustion fields, HEDM diffraction stacks) live on disk
//! as multi-GB arrays read in subregions. This subsystem — modelled on the
//! zarrs ecosystem's chunked stores and codec pipelines — turns a corrected
//! field into a self-describing, randomly-accessible archive:
//!
//! * [`grid`] — a regular chunk grid with edge-chunk clipping and
//!   zarr-style chunk keys;
//! * [`crate::codec`] — the composable per-chunk codec chains: any
//!   registered base compressor, an optional FFCz correction stage with
//!   the full [`crate::correction::FfczConfig`] bound space, and
//!   bytes→bytes lossless stages;
//! * [`manifest`] — the versioned binary manifest (version 2): shape,
//!   precision, the codec **chain table**, and a per-chunk table of byte
//!   ranges, chain indices, CRC-32 checksums, and dual-domain
//!   verification stats (version 1 archives remain readable through a
//!   migration shim);
//! * [`parallel`] — the `std::thread` worker pool that fans per-chunk
//!   encode/decode work across cores, plus the bounded-window ordered sink
//!   ([`par_try_map_ordered_sink`]) behind the streaming writer;
//! * [`storage`] — the byte-source/sink abstractions
//!   ([`ReadableStorage`]: ranged `read_at`/`size`; [`WritableStorage`]:
//!   positioned `write_at`/`flush`/`sync`/`truncate`), with local-file,
//!   in-memory, and deterministic fault-injecting backends plus the
//!   transient-fault [`RetryPolicy`] (linear or exponential backoff with
//!   seeded deterministic jitter) shared by both directions;
//! * [`remote`] — a dependency-free HTTP/1.1 `Range` client backend
//!   ([`HttpStorage`]) with connection reuse, plus the in-process
//!   [`HttpRangeServer`] loopback fixture tests and benches build on;
//! * [`resilience`] — [`ResilientStorage`], wrapping any backend with
//!   per-read deadlines, retries, a per-endpoint circuit breaker
//!   ([`Breaker`], typed [`BreakerOpen`] fail-fast), and hedged reads —
//!   the normative contract lives in `docs/STORAGE.md`;
//! * [`writer`] / [`reader`] — container production (streaming by default:
//!   chunk payloads spill to the output as they complete, holding at most
//!   `workers + queue_depth` payloads in memory; per-chunk codec overrides
//!   via [`StoreWriteOptions::overrides`]; atomic temp-file + rename
//!   commits with a sidecar recovery journal, salvageable through
//!   [`Store::salvage`] / [`resume_store_write`]) and trailer-aware,
//!   manifest-only open with partial [`Store::read_region`] decode and
//!   whole-archive [`Store::verify`].
//!
//! The on-disk container format is specified normatively, byte by byte, in
//! `docs/FORMAT.md` at the repository root; [`manifest`] documents the
//! same layout from the implementation side.
//!
//! Because every chunk is corrected independently, the dual-domain bound
//! (`spatial_ok && frequency_ok`) holds *per chunk* — exactly the guarantee
//! a partial reader needs, and the same granularity
//! [`crate::coordinator::sharding`] uses for streamed instances. Per-chunk
//! chains extend this: e.g. bit-exact lossless boundary chunks with FFCz
//! interior chunks in one archive.
//!
//! ```
//! use ffcz::codec::CodecChainSpec;
//! use ffcz::correction::FfczConfig;
//! use ffcz::data::synth::grf::GrfBuilder;
//! use ffcz::store::{Store, StoreWriteOptions};
//!
//! let field = GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(1).build();
//! let chain = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
//! // Boundary chunk c/0/0 stays bit-exact; the rest go through FFCz.
//! let opts = StoreWriteOptions::new(&[8, 8])
//!     .workers(2)
//!     .override_chunk("c/0/0", CodecChainSpec::lossless());
//! let (bytes, manifest, _report) = ffcz::store::encode_store(&field, &chain, &opts).unwrap();
//! assert!(manifest.all_chunks_ok());
//! assert_eq!(manifest.chains.len(), 2);
//!
//! let store = Store::from_bytes(bytes).unwrap();
//! let window = store.read_region(&[4, 4], &[8, 8], 2).unwrap();
//! assert_eq!(window.shape(), &[8, 8]);
//! // Only the 4 chunks overlapping the window were decoded.
//! assert_eq!(store.chunks_decoded(), 4);
//! ```

pub mod grid;
pub mod manifest;
pub mod parallel;
pub mod reader;
pub mod remote;
pub mod resilience;
pub mod storage;
pub mod writer;

pub use crate::codec::{ChunkStats, CodecChain, CodecChainSpec, EncodedChunk};
pub use grid::{extract_subarray, insert_subarray, ChunkGrid};
pub use manifest::{ChunkEntry, Manifest};
pub use parallel::{
    par_try_map, par_try_map_ordered_sink, par_try_map_ordered_sink_with, par_try_map_with,
};
pub use reader::{ChunkVerifyReport, RegionRead, Store, VerifyReport};
pub use remote::{HttpRangeServer, HttpStorage};
pub use resilience::{
    breaker_open_in_chain, breaker_open_of, deadline_exceeded_in_chain, deadline_exceeded_of,
    Breaker, BreakerConfig, BreakerOpen, DeadlineExceeded, HedgeConfig, ResilienceOptions,
    ResilientStorage,
};
pub use storage::{
    read_exact_at, read_exact_at_retry, write_all_at, write_all_at_retry, FaultCounts,
    FaultHandle, FaultInjector, FaultPlan, FileStorage, MemStorage, ReadableStorage, RetryPolicy,
    RetrySchedule, WritableStorage,
};
pub use writer::{
    encode_store, resume_store_write, staging_paths, stream_store_to, write_store,
    write_store_faulted, write_store_in_memory, RepairReport, Salvage, StoreStreamWriter,
    StoreWriteOptions, StoreWriteReport,
};

/// Legacy name of the store codec description, kept for one release so
/// downstream code migrates gradually. The enum variants are gone — build
/// chains with [`CodecChainSpec::lossless`], [`CodecChainSpec::ffcz`], or
/// [`CodecChainSpec::base_only`] instead.
#[deprecated(note = "use ffcz::codec::CodecChainSpec (CodecSpec's enum variants are retired)")]
pub type CodecSpec = crate::codec::CodecChainSpec;
