//! Chunked spectral archive store (`.ffcz` container).
//!
//! FFCz corrects whole fields in memory, but the target workloads (Nyx
//! snapshots, S3D combustion fields, HEDM diffraction stacks) live on disk
//! as multi-GB arrays read in subregions. This subsystem — modelled on the
//! zarrs ecosystem's chunked stores and codec pipelines — turns a corrected
//! field into a self-describing, randomly-accessible archive:
//!
//! * [`grid`] — a regular chunk grid with edge-chunk clipping and
//!   zarr-style chunk keys;
//! * [`codec`] — the per-chunk codec pipeline: any base [`crate::compressors::Compressor`]
//!   composed with the FFCz POCS correction stage and the lossless backend,
//!   or a bit-exact lossless baseline;
//! * [`manifest`] — the versioned binary manifest: shape, precision, chunk
//!   grid, codec chain, and per-chunk byte ranges + dual-domain
//!   verification stats;
//! * [`parallel`] — the `std::thread` worker pool that fans per-chunk
//!   encode/decode work across cores;
//! * [`writer`] / [`reader`] — container assembly and manifest-only open
//!   with partial [`Store::read_region`] decode.
//!
//! Because every chunk is corrected independently, the dual-domain bound
//! (`spatial_ok && frequency_ok`) holds *per chunk* — exactly the guarantee
//! a partial reader needs, and the same granularity
//! [`crate::coordinator::sharding`] uses for streamed instances.
//!
//! ```
//! use ffcz::data::synth::grf::GrfBuilder;
//! use ffcz::store::{CodecSpec, Store, StoreWriteOptions};
//!
//! let field = GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(1).build();
//! let spec = CodecSpec::Ffcz {
//!     base: "sz-like".into(),
//!     spatial_rel: 1e-3,
//!     frequency_rel: Some(1e-3),
//! };
//! let opts = StoreWriteOptions::new(&[8, 8]).workers(2);
//! let (bytes, manifest, _report) = ffcz::store::encode_store(&field, &spec, &opts).unwrap();
//! assert!(manifest.all_chunks_ok());
//!
//! let store = Store::from_bytes(bytes).unwrap();
//! let window = store.read_region(&[4, 4], &[8, 8], 2).unwrap();
//! assert_eq!(window.shape(), &[8, 8]);
//! // Only the 4 chunks overlapping the window were decoded.
//! assert_eq!(store.chunks_decoded(), 4);
//! ```

pub mod codec;
pub mod grid;
pub mod manifest;
pub mod parallel;
pub mod reader;
pub mod writer;

pub use codec::{ChunkCodec, CodecSpec, EncodedChunk};
pub use grid::{extract_subarray, insert_subarray, ChunkGrid};
pub use manifest::{ChunkEntry, ChunkStats, Manifest};
pub use parallel::par_try_map;
pub use reader::Store;
pub use writer::{encode_store, write_store, StoreWriteOptions, StoreWriteReport};
