//! LEB128 variable-length integers (used by container headers).

use anyhow::{bail, Result};

/// Append `v` as LEB128.
pub fn write(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read a LEB128 value at `*pos`, advancing it.
pub fn read(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        if *pos >= buf.len() {
            bail!("truncated varint");
        }
        let byte = buf[*pos];
        *pos += 1;
        if shift >= 64 {
            bail!("varint overflow");
        }
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Zigzag encoding for signed values.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse zigzag.
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_values() {
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        let mut buf = Vec::new();
        for &v in &vals {
            write(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn truncation_errors() {
        let mut buf = Vec::new();
        write(&mut buf, u64::MAX);
        buf.pop();
        let mut pos = 0;
        assert!(read(&buf, &mut pos).is_err());
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [-5i64, -1, 0, 1, 5, i64::MIN, i64::MAX] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }
}
