//! The lossless back end: ZSTD (the same library the paper uses), with a
//! tiny self-describing frame so empty inputs and future codecs are handled
//! uniformly.
//!
//! The frame is one codec byte followed by the codec's body. The codec
//! bytes are normative format constants (`docs/FORMAT.md` § 1.2): builds
//! bundling the vendored offline zstd shim write [`LOSSLESS_CODEC_ZSTD`]
//! frames in the shim's own `ZSHM` coding, while
//! [`LOSSLESS_CODEC_LIBZSTD`] is reserved for frames a build linked
//! against the real C libzstd would write. Keeping the two bytes
//! distinct means a shim build rejects real-zstd archives with an
//! actionable error instead of failing deep inside the wrong decoder.

use anyhow::{bail, Context, Result};

/// Raw passthrough frame: the body is the uncompressed payload
/// (emitted whenever compression would expand the data).
pub const LOSSLESS_CODEC_RAW: u8 = 0;
/// The zstd backend this build links — currently the vendored offline
/// shim (`ZSHM` frames), not the zstd wire format.
pub const LOSSLESS_CODEC_ZSTD: u8 = 1;
/// Reserved for frames produced by a build linked against the real C
/// libzstd. Never written by shim builds; [`lossless_decompress`]
/// rejects it with a "rebuild with real zstd" error.
pub const LOSSLESS_CODEC_LIBZSTD: u8 = 2;

/// Compress a byte buffer with ZSTD level 3 (the zstd CLI default). Falls
/// back to a raw frame if compression would expand the data.
pub fn lossless_compress(data: &[u8]) -> Vec<u8> {
    let compressed = zstd::encode_all(data, 3).expect("in-memory zstd cannot fail");
    let mut out = Vec::with_capacity(compressed.len() + 1);
    if compressed.len() < data.len() {
        out.push(LOSSLESS_CODEC_ZSTD);
        out.extend_from_slice(&compressed);
    } else {
        out.push(LOSSLESS_CODEC_RAW);
        out.extend_from_slice(data);
    }
    out
}

/// Inverse of [`lossless_compress`].
pub fn lossless_decompress(frame: &[u8]) -> Result<Vec<u8>> {
    let Some((&codec, body)) = frame.split_first() else {
        bail!("empty lossless frame");
    };
    match codec {
        LOSSLESS_CODEC_RAW => Ok(body.to_vec()),
        LOSSLESS_CODEC_ZSTD => zstd::decode_all(body).context("zstd decode"),
        LOSSLESS_CODEC_LIBZSTD => bail!(
            "lossless frame uses codec byte {LOSSLESS_CODEC_LIBZSTD} (real libzstd); \
             this build bundles the vendored zstd shim and cannot decode it — \
             rebuild with real zstd to read this archive"
        ),
        x => bail!("unknown lossless codec {x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn roundtrip_compressible() {
        let data = vec![7u8; 100_000];
        let c = lossless_compress(&data);
        assert!(c.len() < 1000, "highly repetitive data should shrink");
        assert_eq!(lossless_decompress(&c).unwrap(), data);
    }

    #[test]
    fn roundtrip_incompressible_uses_raw() {
        let mut rng = XorShift::new(3);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let c = lossless_compress(&data);
        assert!(c.len() <= data.len() + 1);
        assert_eq!(lossless_decompress(&c).unwrap(), data);
    }

    #[test]
    fn roundtrip_empty() {
        let c = lossless_compress(&[]);
        assert_eq!(lossless_decompress(&c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn garbage_errors() {
        assert!(lossless_decompress(&[]).is_err());
        assert!(lossless_decompress(&[9, 1, 2, 3]).is_err());
        assert!(lossless_decompress(&[LOSSLESS_CODEC_ZSTD, 0xFF, 0xFF]).is_err());
    }

    /// The reserved real-libzstd codec byte must be rejected with an
    /// actionable message, not fed to the shim decoder.
    #[test]
    fn libzstd_frames_are_rejected_with_a_rebuild_hint() {
        let err = lossless_decompress(&[LOSSLESS_CODEC_LIBZSTD, 0x28, 0xB5, 0x2F, 0xFD])
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("rebuild with real zstd"),
            "error must tell the user how to recover: {err}"
        );
    }
}
