//! The lossless back end: ZSTD (the same library the paper uses), with a
//! tiny self-describing frame so empty inputs and future codecs are handled
//! uniformly.

use anyhow::{bail, Context, Result};

const CODEC_ZSTD: u8 = 1;
const CODEC_RAW: u8 = 0;

/// Compress a byte buffer with ZSTD level 3 (the zstd CLI default). Falls
/// back to a raw frame if compression would expand the data.
pub fn lossless_compress(data: &[u8]) -> Vec<u8> {
    let compressed = zstd::encode_all(data, 3).expect("in-memory zstd cannot fail");
    let mut out = Vec::with_capacity(compressed.len() + 1);
    if compressed.len() < data.len() {
        out.push(CODEC_ZSTD);
        out.extend_from_slice(&compressed);
    } else {
        out.push(CODEC_RAW);
        out.extend_from_slice(data);
    }
    out
}

/// Inverse of [`lossless_compress`].
pub fn lossless_decompress(frame: &[u8]) -> Result<Vec<u8>> {
    let Some((&codec, body)) = frame.split_first() else {
        bail!("empty lossless frame");
    };
    match codec {
        CODEC_RAW => Ok(body.to_vec()),
        CODEC_ZSTD => zstd::decode_all(body).context("zstd decode"),
        x => bail!("unknown lossless codec {x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn roundtrip_compressible() {
        let data = vec![7u8; 100_000];
        let c = lossless_compress(&data);
        assert!(c.len() < 1000, "highly repetitive data should shrink");
        assert_eq!(lossless_decompress(&c).unwrap(), data);
    }

    #[test]
    fn roundtrip_incompressible_uses_raw() {
        let mut rng = XorShift::new(3);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let c = lossless_compress(&data);
        assert!(c.len() <= data.len() + 1);
        assert_eq!(lossless_decompress(&c).unwrap(), data);
    }

    #[test]
    fn roundtrip_empty() {
        let c = lossless_compress(&[]);
        assert_eq!(lossless_decompress(&c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn garbage_errors() {
        assert!(lossless_decompress(&[]).is_err());
        assert!(lossless_decompress(&[9, 1, 2, 3]).is_err());
        assert!(lossless_decompress(&[CODEC_ZSTD, 0xFF, 0xFF]).is_err());
    }
}
