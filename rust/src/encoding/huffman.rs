//! Canonical Huffman coding over `u16` symbols.
//!
//! The paper compresses quantized edits with "Huffman coding followed by
//! ZSTD" (§IV-B); this module is the Huffman half. Codes are *canonical*:
//! the header stores only the bit length of each present symbol, and both
//! sides rebuild identical codebooks from the lengths. Code lengths are
//! capped at [`MAX_CODE_LEN`] via the standard depth-limiting fixup.
//!
//! Header layout:
//! `[varint n_symbols][varint payload_bit_len]` then for each present
//! symbol `[varint symbol][6-bit length]`, then the bit payload.

use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use super::bitio::{BitReader, BitWriter};
use super::varint;

/// Maximum Huffman code length (fits the u32 decode accumulator easily).
pub const MAX_CODE_LEN: u32 = 24;

/// Encode a symbol stream. Returns a self-describing byte buffer.
pub fn huffman_encode(symbols: &[u16]) -> Vec<u8> {
    let mut out = Vec::new();
    if symbols.is_empty() {
        varint::write(&mut out, 0);
        return out;
    }
    // Frequency table.
    let mut freq: std::collections::HashMap<u16, u64> = std::collections::HashMap::new();
    for &s in symbols {
        *freq.entry(s).or_insert(0) += 1;
    }
    let lengths = code_lengths(&freq);
    let codes = canonical_codes(&lengths);

    // Header.
    let mut present: Vec<(u16, u32)> = lengths.iter().map(|(&s, &l)| (s, l)).collect();
    present.sort_unstable();
    varint::write(&mut out, present.len() as u64);

    // Payload bits. A single-symbol alphabet is fully described by the
    // header (the decoder replicates the symbol), so the payload is empty.
    let mut w = BitWriter::new();
    if present.len() > 1 {
        for &s in symbols {
            let (code, len) = codes[&s];
            w.write_bits(code as u64, len);
        }
    }
    let bit_len = w.bit_len();
    varint::write(&mut out, bit_len as u64);
    for &(s, l) in &present {
        varint::write(&mut out, s as u64);
        out.push(l as u8);
    }
    out.extend_from_slice(&w.finish());
    out
}

/// Decode a buffer produced by [`huffman_encode`]. `count` is the number of
/// symbols expected (stored by the caller's container).
pub fn huffman_decode(buf: &[u8], count: usize) -> Result<Vec<u16>> {
    let mut pos = 0usize;
    let n_symbols = varint::read(buf, &mut pos)? as usize;
    if n_symbols == 0 {
        if count != 0 {
            bail!("empty huffman stream but {count} symbols expected");
        }
        return Ok(Vec::new());
    }
    let bit_len = varint::read(buf, &mut pos)? as usize;
    let mut lengths: Vec<(u16, u32)> = Vec::with_capacity(n_symbols);
    for _ in 0..n_symbols {
        let s = varint::read(buf, &mut pos)? as u16;
        if pos >= buf.len() {
            bail!("truncated huffman header");
        }
        let l = buf[pos] as u32;
        pos += 1;
        if l == 0 || l > MAX_CODE_LEN {
            bail!("invalid code length {l}");
        }
        lengths.push((s, l));
    }

    // Single-symbol degenerate stream: all symbols identical.
    if n_symbols == 1 {
        return Ok(vec![lengths[0].0; count]);
    }

    // Build canonical decode tables: first_code/first_index per length.
    let map: std::collections::HashMap<u16, u32> = lengths.iter().cloned().collect();
    let codes = canonical_codes(&map);
    // symbol list ordered by (length, symbol) — canonical order.
    let mut ordered: Vec<(u32, u16)> = lengths.iter().map(|&(s, l)| (l, s)).collect();
    ordered.sort_unstable();
    let max_len = ordered.last().map(|&(l, _)| l).unwrap_or(0);
    let mut len_count = vec![0u32; (max_len + 2) as usize];
    for &(l, _) in &ordered {
        len_count[l as usize] += 1;
    }
    let mut first_code = vec![0u32; (max_len + 2) as usize];
    let mut first_index = vec![0usize; (max_len + 2) as usize];
    {
        let mut idx = 0usize;
        let mut code = 0u32;
        for l in 1..=max_len {
            first_code[l as usize] = code;
            first_index[l as usize] = idx;
            idx += len_count[l as usize] as usize;
            code = (code + len_count[l as usize]) << 1;
        }
    }
    let _ = codes;

    let payload = &buf[pos..];
    if bit_len > payload.len() * 8 {
        bail!("truncated huffman payload");
    }
    let mut r = BitReader::new(payload);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut code = 0u32;
        let mut l = 0u32;
        loop {
            let bit = match r.read_bit() {
                Some(b) => b,
                None => bail!("huffman payload exhausted"),
            };
            code = (code << 1) | bit as u32;
            l += 1;
            if l > max_len {
                bail!("code longer than max length");
            }
            let cnt = len_count[l as usize] as usize;
            if cnt > 0 {
                let fc = first_code[l as usize];
                if code >= fc && (code - fc) < cnt as u32 {
                    let sym = ordered[first_index[l as usize] + (code - fc) as usize].1;
                    out.push(sym);
                    break;
                }
            }
        }
    }
    Ok(out)
}

/// Package-merge-free length computation: standard heap-based Huffman tree,
/// then depth-limit fixup to `MAX_CODE_LEN` (Kraft-sum repair).
fn code_lengths(freq: &std::collections::HashMap<u16, u64>) -> std::collections::HashMap<u16, u32> {
    #[derive(PartialEq, Eq)]
    struct Node {
        weight: u64,
        id: usize,
    }
    impl Ord for Node {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            // min-heap via reversed compare; tie-break on id for determinism
            o.weight.cmp(&self.weight).then(o.id.cmp(&self.id))
        }
    }
    impl PartialOrd for Node {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }

    let mut syms: Vec<(u16, u64)> = freq.iter().map(|(&s, &f)| (s, f)).collect();
    syms.sort_unstable();
    let n = syms.len();
    let mut out = std::collections::HashMap::new();
    if n == 1 {
        out.insert(syms[0].0, 1);
        return out;
    }

    // parent pointers over 2n-1 nodes
    let mut parent = vec![usize::MAX; 2 * n - 1];
    let mut heap = BinaryHeap::new();
    for (i, &(_, f)) in syms.iter().enumerate() {
        heap.push(Node { weight: f, id: i });
    }
    let mut next = n;
    while heap.len() > 1 {
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        parent[a.id] = next;
        parent[b.id] = next;
        heap.push(Node {
            weight: a.weight + b.weight,
            id: next,
        });
        next += 1;
    }
    // Depth of each leaf.
    let mut lengths: Vec<u32> = (0..n)
        .map(|i| {
            let mut d = 0;
            let mut j = i;
            while parent[j] != usize::MAX {
                j = parent[j];
                d += 1;
            }
            d
        })
        .collect();

    // Depth-limit fixup: clamp and repair the Kraft inequality.
    if lengths.iter().any(|&l| l > MAX_CODE_LEN) {
        for l in lengths.iter_mut() {
            *l = (*l).min(MAX_CODE_LEN);
        }
        // Kraft sum in units of 2^-MAX_CODE_LEN.
        let unit = 1u64 << MAX_CODE_LEN;
        let mut kraft: u64 = lengths.iter().map(|&l| unit >> l).sum();
        // While over-subscribed, lengthen the shortest-weight longest codes.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(lengths[i]));
        while kraft > unit {
            // find a symbol with length < MAX to lengthen (halves its cost)
            let i = *order
                .iter()
                .find(|&&i| lengths[i] < MAX_CODE_LEN)
                .expect("fixable");
            kraft -= (unit >> lengths[i]) / 2;
            lengths[i] += 1;
            order.sort_by_key(|&i| std::cmp::Reverse(lengths[i]));
        }
    }
    for (i, &(s, _)) in syms.iter().enumerate() {
        out.insert(s, lengths[i]);
    }
    out
}

/// Canonical code assignment from lengths: symbols sorted by (length,
/// symbol) get consecutive codes.
fn canonical_codes(
    lengths: &std::collections::HashMap<u16, u32>,
) -> std::collections::HashMap<u16, (u32, u32)> {
    let mut ordered: Vec<(u32, u16)> = lengths.iter().map(|(&s, &l)| (l, s)).collect();
    ordered.sort_unstable();
    let mut codes = std::collections::HashMap::new();
    let mut code = 0u32;
    let mut prev_len = 0u32;
    for &(l, s) in &ordered {
        code <<= l - prev_len;
        codes.insert(s, (code, l));
        code += 1;
        prev_len = l;
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn roundtrip_simple() {
        let syms = vec![1u16, 2, 2, 3, 3, 3, 3, 7, 7, 1];
        let enc = huffman_encode(&syms);
        let dec = huffman_decode(&enc, syms.len()).unwrap();
        assert_eq!(syms, dec);
    }

    #[test]
    fn roundtrip_empty_and_single() {
        let enc = huffman_encode(&[]);
        assert_eq!(huffman_decode(&enc, 0).unwrap(), Vec::<u16>::new());
        let syms = vec![42u16; 1000];
        let enc = huffman_encode(&syms);
        assert!(enc.len() < 20, "degenerate stream should be tiny");
        assert_eq!(huffman_decode(&enc, 1000).unwrap(), syms);
    }

    #[test]
    fn roundtrip_random_skewed() {
        let mut rng = XorShift::new(5);
        // Geometric-ish distribution over 64 symbols.
        let syms: Vec<u16> = (0..20_000)
            .map(|_| {
                let mut s = 0u16;
                while rng.next_f64() < 0.5 && s < 63 {
                    s += 1;
                }
                s
            })
            .collect();
        let enc = huffman_encode(&syms);
        let dec = huffman_decode(&enc, syms.len()).unwrap();
        assert_eq!(syms, dec);
        // Skewed data should compress well below 6 bits/symbol.
        assert!(
            (enc.len() * 8) as f64 / (syms.len() as f64) < 3.0,
            "bits/sym {}",
            (enc.len() * 8) as f64 / syms.len() as f64
        );
    }

    #[test]
    fn roundtrip_uniform_u16() {
        let mut rng = XorShift::new(6);
        let syms: Vec<u16> = (0..5000).map(|_| rng.next_u64() as u16).collect();
        let enc = huffman_encode(&syms);
        let dec = huffman_decode(&enc, syms.len()).unwrap();
        assert_eq!(syms, dec);
    }

    #[test]
    fn corrupt_stream_errors_not_panics() {
        let syms = vec![1u16, 2, 3, 4, 5, 6, 7, 8];
        let mut enc = huffman_encode(&syms);
        enc.truncate(enc.len() / 2);
        assert!(huffman_decode(&enc, syms.len()).is_err());
    }
}
