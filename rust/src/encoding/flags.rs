//! Bit-packed binary flag vectors.
//!
//! The paper stores the positions of non-zero edits as "binary vectors of
//! length N … packed into 8-bit integers" (§IV-B). This module packs a
//! `&[bool]` into bytes (MSB-first within each byte) and back.

/// Pack booleans into bytes, 8 per byte, MSB first.
pub fn pack_flags(flags: &[bool]) -> Vec<u8> {
    let mut out = vec![0u8; flags.len().div_ceil(8)];
    for (i, &f) in flags.iter().enumerate() {
        if f {
            out[i / 8] |= 0x80 >> (i % 8);
        }
    }
    out
}

/// Unpack `n` booleans from a packed buffer.
pub fn unpack_flags(packed: &[u8], n: usize) -> Vec<bool> {
    assert!(packed.len() * 8 >= n, "packed buffer too short");
    (0..n).map(|i| packed[i / 8] & (0x80 >> (i % 8)) != 0).collect()
}

/// Count set flags without unpacking.
pub fn count_set(packed: &[u8]) -> usize {
    packed.iter().map(|b| b.count_ones() as usize).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn roundtrip_various_lengths() {
        let mut rng = XorShift::new(1);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 1000] {
            let flags: Vec<bool> = (0..n).map(|_| rng.next_f64() < 0.3).collect();
            let packed = pack_flags(&flags);
            assert_eq!(packed.len(), n.div_ceil(8));
            assert_eq!(unpack_flags(&packed, n), flags);
        }
    }

    #[test]
    fn count_matches() {
        let flags = vec![true, false, true, true, false, false, false, true, true];
        let packed = pack_flags(&flags);
        assert_eq!(count_set(&packed), 5);
    }
}
