//! CRC-32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) — the checksum
//! recorded per chunk in the store's manifest v2 so payload-region
//! corruption is rejected with a precise error instead of surfacing as a
//! downstream codec parse failure.

/// Byte-at-a-time lookup table, built at compile time.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// The CRC-32/IEEE check value: `crc32(b"123456789")`. Normative in
/// `docs/FORMAT.md` § 1.2 — an independent implementation that does not
/// produce this value reads the wrong polynomial/reflection convention.
pub const CRC32_CHECK: u32 = 0xCBF4_3926;

/// CRC-32 of `data` (IEEE: init `0xFFFFFFFF`, final xor `0xFFFFFFFF`).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    crc ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The standard CRC-32/IEEE check values.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), CRC32_CHECK);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn detects_single_byte_flips() {
        let data: Vec<u8> = (0..64u8).collect();
        let reference = crc32(&data);
        for i in 0..data.len() {
            let mut bad = data.clone();
            bad[i] ^= 0x01;
            assert_ne!(crc32(&bad), reference, "flip at byte {i} undetected");
        }
    }
}
