//! Bounds-checked reads of fixed-width little-endian values from byte
//! cursors. Decode paths must never panic on truncated or corrupt
//! input (the `panic-policy` lint enforces this), so the
//! length-check + `try_into` dance every reader used to hand-roll
//! lives here once, behind `Result`.

use anyhow::{bail, Result};

/// Read exactly `N` bytes at `*pos`, advancing the cursor. Fails with
/// a `truncated {what}` error instead of panicking when the buffer is
/// short.
pub fn take<const N: usize>(buf: &[u8], pos: &mut usize, what: &str) -> Result<[u8; N]> {
    let Some(bytes) = buf.get(*pos..).and_then(|b| b.get(..N)) else {
        bail!("truncated {what}: need {N} bytes at offset {}", *pos);
    };
    let mut out = [0u8; N];
    out.copy_from_slice(bytes);
    *pos += N;
    Ok(out)
}

/// `u32` LE at `*pos`.
pub fn read_u32_le(buf: &[u8], pos: &mut usize, what: &str) -> Result<u32> {
    Ok(u32::from_le_bytes(take::<4>(buf, pos, what)?))
}

/// `u64` LE at `*pos`.
pub fn read_u64_le(buf: &[u8], pos: &mut usize, what: &str) -> Result<u64> {
    Ok(u64::from_le_bytes(take::<8>(buf, pos, what)?))
}

/// `f64` LE at `*pos`.
pub fn read_f64_le(buf: &[u8], pos: &mut usize, what: &str) -> Result<f64> {
    Ok(f64::from_le_bytes(take::<8>(buf, pos, what)?))
}

/// Infallible slice→array copy for chunks whose length is already
/// guaranteed by construction (a `chunks_exact(N)` iterator): the
/// conversion the fallible `try_into().unwrap()` idiom used to do.
pub fn exact<const N: usize>(chunk: &[u8]) -> [u8; N] {
    debug_assert_eq!(chunk.len(), N, "exact::<{N}> on a {}-byte chunk", chunk.len());
    let mut out = [0u8; N];
    out.copy_from_slice(chunk);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reads_and_advances() {
        let buf = [1u8, 2, 3, 4, 5];
        let mut pos = 1;
        assert_eq!(take::<2>(&buf, &mut pos, "x").unwrap(), [2, 3]);
        assert_eq!(pos, 3);
        assert_eq!(take::<2>(&buf, &mut pos, "x").unwrap(), [4, 5]);
        assert_eq!(pos, 5);
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let buf = [1u8, 2, 3];
        let mut pos = 2;
        let err = take::<4>(&buf, &mut pos, "header field").unwrap_err();
        assert!(err.to_string().contains("truncated header field"), "{err}");
        // The cursor does not advance past a failed read.
        assert_eq!(pos, 2);
        let mut end = 3;
        assert!(read_f64_le(&buf, &mut end, "tail").is_err());
    }

    #[test]
    fn typed_reads_round_trip() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&(-1.5f64).to_le_bytes());
        let mut pos = 0;
        assert_eq!(read_u32_le(&buf, &mut pos, "a").unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_u64_le(&buf, &mut pos, "b").unwrap(), u64::MAX);
        assert_eq!(read_f64_le(&buf, &mut pos, "c").unwrap(), -1.5);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn exact_converts_chunks() {
        let data = [1u8, 0, 2, 0];
        let words: Vec<u16> = data.chunks_exact(2).map(|c| u16::from_le_bytes(exact(c))).collect();
        assert_eq!(words, [1, 2]);
    }
}
