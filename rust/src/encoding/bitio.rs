//! MSB-first bit-stream reader/writer.

/// Write bits into a growing byte buffer, most-significant bit first.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits currently staged in `acc` (0..8).
    nbits: u32,
    acc: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `n` bits of `value` (n ≤ 64), MSB first.
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        let mut rem = n;
        while rem > 0 {
            let take = (8 - self.nbits).min(rem);
            let shift = rem - take;
            let bits = ((value >> shift) & ((1u64 << take) - 1)) as u8;
            // nbits + take ≤ 8, so the high bits shifted out are zero.
            self.acc = (((self.acc as u16) << take) as u8) | bits;
            self.nbits += take;
            rem -= take;
            if self.nbits == 8 {
                self.buf.push(self.acc);
                self.acc = 0;
                self.nbits = 0;
            }
        }
    }

    /// Append a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush and return the byte buffer (final partial byte zero-padded).
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.acc <<= 8 - self.nbits;
            self.buf.push(self.acc);
        }
        self.buf
    }
}

/// Read bits from a byte slice, MSB first.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Read `n` bits (n ≤ 64) as the low bits of a u64. Returns `None` if
    /// the stream is exhausted.
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        if self.pos + n as usize > self.buf.len() * 8 {
            return None;
        }
        let mut out = 0u64;
        let mut rem = n;
        while rem > 0 {
            let byte = self.buf[self.pos / 8];
            let offset = (self.pos % 8) as u32;
            let avail = 8 - offset;
            let take = avail.min(rem);
            let bits = (byte >> (avail - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | bits as u64;
            self.pos += take as usize;
            rem -= take;
        }
        Some(out)
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        self.read_bits(1).map(|b| b != 0)
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn roundtrip_fixed_widths() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFFFF, 16);
        w.write_bits(0, 1);
        w.write_bits(0x123456789ABCDEF0, 64);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_bits(16).unwrap(), 0xFFFF);
        assert_eq!(r.read_bits(1).unwrap(), 0);
        assert_eq!(r.read_bits(64).unwrap(), 0x123456789ABCDEF0);
    }

    #[test]
    fn roundtrip_random_widths() {
        let mut rng = XorShift::new(11);
        let items: Vec<(u64, u32)> = (0..500)
            .map(|_| {
                let n = 1 + rng.below(64) as u32;
                let v = rng.next_u64() & (u64::MAX >> (64 - n));
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &items {
            assert_eq!(r.read_bits(n).unwrap(), v);
        }
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut w = BitWriter::new();
        w.write_bits(0b1, 1);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(8).unwrap(), 0b1000_0000);
        assert!(r.read_bits(1).is_none());
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        w.write_bits(0, 13);
        assert_eq!(w.bit_len(), 13);
        assert_eq!(w.finish().len(), 2);
    }
}
