//! Entropy coding and bit-level utilities shared by the base compressors
//! and the FFCz edit codec: bit I/O, canonical Huffman coding, bit-packed
//! flag vectors, varints, CRC-32 payload checksums, and the Huffman→ZSTD
//! lossless cascade the paper applies to quantized edits (§IV-B).

pub mod bitio;
pub mod crc32;
pub mod fixed;
pub mod flags;
pub mod huffman;
pub mod lossless;
pub mod varint;

pub use bitio::{BitReader, BitWriter};
pub use crc32::{crc32, CRC32_CHECK};
pub use flags::{pack_flags, unpack_flags};
pub use huffman::{huffman_decode, huffman_encode};
pub use lossless::{
    lossless_compress, lossless_decompress, LOSSLESS_CODEC_LIBZSTD, LOSSLESS_CODEC_RAW,
    LOSSLESS_CODEC_ZSTD,
};
