//! Table IV: stage-level performance metrics — execution time, effective
//! bandwidth, arithmetic intensity, and the speedup of the optimized
//! engine over a deliberately-naive scalar baseline.
//!
//! The paper compares CUDA kernels against an OpenMP CPU implementation;
//! this testbed has no GPU, so the roles map to: **optimized native Rust
//! engine** (the tuned path) vs **naive scalar baseline** (per-element
//! recomputation, no twiddle caching — the "unoptimized CPU" stand-in).
//! Shape to reproduce: FFT stages have the highest arithmetic intensity;
//! projections/compaction are bandwidth-bound streaming passes (AI < 1).

use std::time::Instant;

use anyhow::Result;

use super::fig9::instrumented_pocs;
use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{szlike::SzLike, Compressor, ErrorBound};
use crate::correction::{Bounds, PocsParams, QuantizedEdits};
use crate::data::synth;
use crate::encoding::{huffman_encode, lossless_compress};
use crate::fourier::{dft_naive, Complex};

pub fn run(opts: &ExpOptions) -> Result<()> {
    let s = opts.scale;
    let field = synth::grf::GrfBuilder::new(&[s, s, s])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(101)
        .build();
    let n = field.len();
    let base = SzLike::default();
    let payload = base.compress(&field, ErrorBound::Relative(1e-3))?;
    let recon = base.decompress(&payload)?;
    let eps0: Vec<f64> = recon
        .data()
        .iter()
        .zip(field.data())
        .map(|(r, x)| r - x)
        .collect();
    let e_abs = ErrorBound::Relative(1e-3).absolute_for(&field);
    let (_, rfe) = crate::metrics::spectral_metrics(&field, &recon);
    let d_abs = {
        let buf: Vec<Complex> = field
            .data()
            .iter()
            .map(|&v| Complex::new(v, 0.0))
            .collect();
        let max_mag = crate::fourier::fftn(&buf, field.shape())
            .iter()
            .map(|c| c.abs())
            .fold(0.0f64, f64::max);
        (rfe / 10.0) * max_mag
    };
    let params = PocsParams {
        spatial: Bounds::Global(e_abs),
        frequency: Bounds::Global(d_abs),
        max_iters: 200,
        threads: 1,
    };

    // --- stage metrics from the instrumented engine
    let t = instrumented_pocs(&eps0, field.shape(), &params);
    let iters = t.iterations.max(1) as f64;
    let bytes_pass = (n * 16) as f64; // one complex vector streamed per pass

    let mut table = Table::new(
        "Table IV analogue — per-stage metrics (native engine)",
        &["stage", "time/iter ms", "BW GB/s", "AI flop/byte", "notes"],
    );
    let logn = (n as f64).log2();
    let rows: Vec<(&str, f64, f64, f64, &str)> = vec![
        (
            "forwardFFT",
            t.fft / iters,
            bytes_pass * logn.ceil(),
            // ~5·N·log2 N flops over ~16·N·log2 N bytes touched
            5.0 / 16.0,
            "compute-leaning",
        ),
        (
            "CheckConvergence",
            t.check / iters,
            bytes_pass,
            0.25,
            "memory-bound",
        ),
        (
            "ProjectOntoFCube",
            t.project_f / iters,
            2.0 * bytes_pass,
            0.13,
            "memory-bound",
        ),
        (
            "inverseFFT",
            t.ifft / iters,
            bytes_pass * logn.ceil(),
            5.0 / 16.0,
            "compute-leaning",
        ),
        (
            "ProjectOntoSCube",
            t.project_s / iters,
            2.0 * bytes_pass,
            0.13,
            "memory-bound",
        ),
    ];
    for (name, secs, bytes, ai, note) in rows {
        let bw = if secs > 0.0 { bytes / secs / 1e9 } else { 0.0 };
        table.row(vec![
            name.to_string(),
            fmt_num(secs * 1e3),
            fmt_num(bw),
            fmt_num(ai),
            note.to_string(),
        ]);
    }

    // --- edit post-processing stages (measured on real edit vectors)
    let result = crate::correction::alternating_projection(&eps0, field.shape(), &params);
    let t0 = Instant::now();
    let q = QuantizedEdits::quantize(&result.spat_edits);
    let quant_ms = t0.elapsed().as_secs_f64() * 1e3;
    let syms: Vec<u16> = q.q.iter().map(|&g| g as u16).collect();
    let t0 = Instant::now();
    let h = huffman_encode(&syms);
    let _z = lossless_compress(&h);
    let lossless_ms = t0.elapsed().as_secs_f64() * 1e3;
    table.row(vec![
        "Compact+QuantizeEdits".into(),
        fmt_num(quant_ms),
        fmt_num((n * 8) as f64 / (quant_ms / 1e3).max(1e-9) / 1e9),
        fmt_num(0.33),
        "memory-bound".into(),
    ]);
    table.row(vec![
        "LosslesslyCompressEdits".into(),
        fmt_num(lossless_ms),
        fmt_num((syms.len() * 2) as f64 / (lossless_ms / 1e3).max(1e-9) / 1e9),
        fmt_num(0.05),
        "memory-bound".into(),
    ]);
    table.print();
    table.write_csv(&opts.out_dir.join("table4.csv"))?;

    // --- speedup over the naive scalar baseline (O(N²) DFT + per-element
    // trig, the paper's unoptimized-comparator role). Measured on a
    // subsampled slice so the naive path stays affordable, then scaled.
    let probe = 2048.min(n);
    let probe_input: Vec<Complex> = eps0[..probe]
        .iter()
        .map(|&e| Complex::new(e, 0.0))
        .collect();
    let t0 = Instant::now();
    let _ = dft_naive(&probe_input);
    let naive_probe = t0.elapsed().as_secs_f64();
    let naive_full_est = naive_probe * (n as f64 / probe as f64).powi(2);
    let fast_per_fft = t.fft / iters;
    let speedup = naive_full_est / fast_per_fft.max(1e-12);
    println!(
        "transform speedup vs naive O(N²) DFT baseline: {:.0}× \
         (naive est. {:.1} s vs planned FFT {:.2} ms; paper reports 14.7–321× GPU-vs-CPU)",
        speedup,
        naive_full_est,
        fast_per_fft * 1e3
    );
    Ok(())
}
