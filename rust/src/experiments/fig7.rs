//! Fig. 7: (a–c) throughput of base compression vs FFCz editing;
//! (d) timeline of the pipelined compression–editing workflow.
//!
//! Shape to reproduce: editing is faster than base compression (so it is
//! not the bottleneck) except for the mostly-zero HEDM frame under the
//! zfp-like fast path; the pipelined makespan ≈ compression-only makespan.

use std::time::Instant;

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{paper_compressors, ErrorBound};
use crate::coordinator::{run_pipeline, ExecMode, PipelineConfig};
use crate::correction::{self, FfczConfig};
use crate::data::synth;

pub fn run(opts: &ExpOptions) -> Result<()> {
    throughput_table(opts)?;
    pipeline_timeline(opts)?;
    Ok(())
}

fn throughput_table(opts: &ExpOptions) -> Result<()> {
    let suite = synth::benchmark_suite(opts.scale);
    let mut table = Table::new(
        "Fig. 7(a–c) analogue — throughput (MB/s), ε rel = 0.1%",
        &["dataset", "base", "compress MB/s", "edit MB/s", "edit/compress ×"],
    );
    for (name, field) in &suite {
        let mb = field.original_bytes() as f64 / 1e6;
        for base in paper_compressors() {
            let t0 = Instant::now();
            let payload = base.compress(field, ErrorBound::Relative(1e-3))?;
            let t_comp = t0.elapsed().as_secs_f64();
            let recon = base.decompress(&payload)?;
            let delta_rel = super::tail_clip_delta_rel(field, &recon);
            let cfg = FfczConfig::relative(1e-3, delta_rel);
            let t1 = Instant::now();
            let _archive = correction::correct_reconstruction(
                field,
                &recon,
                base.name(),
                payload,
                &cfg,
            )?;
            let t_edit = t1.elapsed().as_secs_f64();
            table.row(vec![
                name.clone(),
                base.name().to_string(),
                fmt_num(mb / t_comp),
                fmt_num(mb / t_edit),
                fmt_num(t_comp / t_edit),
            ]);
        }
    }
    table.print();
    table.write_csv(&opts.out_dir.join("fig7_throughput.csv"))?;
    Ok(())
}

fn pipeline_timeline(opts: &ExpOptions) -> Result<()> {
    let s = opts.scale;
    let instances: Vec<_> = (0..4)
        .map(|i| {
            (
                format!("snap{i}"),
                synth::grf::GrfBuilder::new(&[s, s, s])
                    .lognormal(1.2)
                    .seed(200 + i as u64)
                    .build(),
            )
        })
        .collect();
    let base = crate::compressors::szlike::SzLike::default();
    let ffcz = FfczConfig::relative(1e-3, 1e-4);

    let mut cfg = PipelineConfig::new(ffcz);
    let piped = run_pipeline(instances.clone(), &base, &cfg)?;
    cfg.mode = ExecMode::Sequential;
    let seq = run_pipeline(instances, &base, &cfg)?;

    println!("## Fig. 7(d) analogue — pipelined timeline");
    print!("{}", piped.timeline_text());
    println!(
        "sequential makespan {:.1} ms vs pipelined {:.1} ms (hide ratio {:.2})",
        seq.makespan.as_secs_f64() * 1e3,
        piped.makespan.as_secs_f64() * 1e3,
        seq.makespan.as_secs_f64() / piped.makespan.as_secs_f64(),
    );

    let mut table = Table::new(
        "pipeline summary",
        &["mode", "makespan ms", "compress Σ ms", "edit Σ ms"],
    );
    for (mode, r) in [("pipelined", &piped), ("sequential", &seq)] {
        table.row(vec![
            mode.to_string(),
            fmt_num(r.makespan.as_secs_f64() * 1e3),
            fmt_num(r.compress_total.as_secs_f64() * 1e3),
            fmt_num(r.edit_total.as_secs_f64() * 1e3),
        ]);
    }
    table.print();
    table.write_csv(&opts.out_dir.join("fig7_pipeline.csv"))?;
    Ok(())
}
