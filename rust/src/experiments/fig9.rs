//! Fig. 9: per-stage timing of the editing process across iterations.
//!
//! The paper instruments its CUDA kernels (forwardFFT, CheckConvergence,
//! ProjectOntoFCube, inverseFFT, ProjectOntoSCube) per iteration; here the
//! same stages of the native Rust engine are timed individually, plus the
//! end-to-end PJRT artifact path when `artifacts/` is built.
//!
//! Shape to reproduce: FFT/IFFT dominates kernel time (the paper measures
//! ≈68.7%); projections and checks are cheap streaming passes.

use std::time::Instant;

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{szlike::SzLike, Compressor, ErrorBound};
use crate::correction::{Bounds, PocsParams};
use crate::data::synth;
use crate::fourier::{fftn_inplace, ifftn_inplace, Complex};

/// Per-stage cumulative timings of a manually-unrolled POCS run.
#[derive(Debug, Default, Clone)]
pub struct StageTimings {
    pub fft: f64,
    pub check: f64,
    pub project_f: f64,
    pub ifft: f64,
    pub project_s: f64,
    pub iterations: usize,
}

impl StageTimings {
    pub fn total(&self) -> f64 {
        self.fft + self.check + self.project_f + self.ifft + self.project_s
    }
}

/// Run the alternating projection with per-stage instrumentation.
/// Semantics match `correction::pocs::alternating_projection`.
pub fn instrumented_pocs(eps0: &[f64], shape: &[usize], params: &PocsParams) -> StageTimings {
    let _n = eps0.len();
    let mut eps: Vec<Complex> = eps0.iter().map(|&e| Complex::new(e, 0.0)).collect();
    let mut t = StageTimings::default();
    while t.iterations < params.max_iters {
        t.iterations += 1;
        let t0 = Instant::now();
        fftn_inplace(&mut eps, shape);
        t.fft += t0.elapsed().as_secs_f64();

        // Check (separate pass, like the paper's CheckConvergence kernel).
        let t0 = Instant::now();
        let mut violated = false;
        for (k, v) in eps.iter().enumerate() {
            let d = params.frequency.at(k);
            if v.linf() > d * (1.0 + 1e-10) {
                violated = true;
                break;
            }
        }
        t.check += t0.elapsed().as_secs_f64();

        if !violated {
            let t0 = Instant::now();
            ifftn_inplace(&mut eps, shape);
            t.ifft += t0.elapsed().as_secs_f64();
            break;
        }

        let t0 = Instant::now();
        for (k, v) in eps.iter_mut().enumerate() {
            let d = params.frequency.at(k);
            *v = Complex::new(v.re.clamp(-d, d), v.im.clamp(-d, d));
        }
        t.project_f += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        ifftn_inplace(&mut eps, shape);
        t.ifft += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for (i, v) in eps.iter_mut().enumerate() {
            let e = params.spatial.at(i);
            *v = Complex::new(v.re.clamp(-e, e), 0.0);
        }
        t.project_s += t0.elapsed().as_secs_f64();
    }
    t
}

pub fn run(opts: &ExpOptions) -> Result<()> {
    let s = opts.scale;
    let field = synth::grf::GrfBuilder::new(&[s, s, s])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(101)
        .build();
    let base = SzLike::default();
    let payload = base.compress(&field, ErrorBound::Relative(1e-3))?;
    let recon = base.decompress(&payload)?;
    let eps0: Vec<f64> = recon
        .data()
        .iter()
        .zip(field.data())
        .map(|(r, x)| r - x)
        .collect();
    let (_, rfe) = crate::metrics::spectral_metrics(&field, &recon);
    let spec_max = rfe_to_absolute(&field, rfe / 10.0);

    let params = PocsParams {
        spatial: Bounds::Global(ErrorBound::Relative(1e-3).absolute_for(&field)),
        frequency: Bounds::Global(spec_max),
        max_iters: 200,
        threads: 1,
    };
    let t = instrumented_pocs(&eps0, field.shape(), &params);
    let total = t.total();

    let mut table = Table::new(
        format!(
            "Fig. 9 analogue — native-engine stage timing over {} iterations",
            t.iterations
        ),
        &["stage", "total ms", "% of loop"],
    );
    for (name, v) in [
        ("forwardFFT", t.fft),
        ("CheckConvergence", t.check),
        ("ProjectOntoFCube", t.project_f),
        ("inverseFFT", t.ifft),
        ("ProjectOntoSCube", t.project_s),
    ] {
        table.row(vec![
            name.to_string(),
            fmt_num(v * 1e3),
            format!("{:.1}", 100.0 * v / total),
        ]);
    }
    table.print();
    table.write_csv(&opts.out_dir.join("fig9.csv"))?;
    println!(
        "FFT+IFFT share: {:.1}% (paper: ≈68.7% of GPU kernel time)",
        100.0 * (t.fft + t.ifft) / total
    );

    // PJRT path, when artifacts exist and a variant matches.
    if let Ok(mut engine) = crate::runtime::PjrtEngine::new(&opts.artifact_dir) {
        let shape = field.shape().to_vec();
        if engine.supports_shape(&shape) {
            let e_abs = ErrorBound::Relative(1e-3).absolute_for(&field);
            let t0 = Instant::now();
            let r = engine.correct(&eps0, &shape, e_abs, spec_max)?;
            let pjrt_ms = t0.elapsed().as_secs_f64() * 1e3;
            println!(
                "PJRT artifact end-to-end: {:.1} ms ({} iterations) vs native loop {:.1} ms",
                pjrt_ms,
                r.iterations,
                total * 1e3
            );
        } else {
            println!("(no PJRT variant for shape {shape:?}; build artifacts with matching VARIANTS for the accelerator comparison)");
        }
    } else {
        println!("(artifacts/ not built — PJRT comparison skipped)");
    }
    Ok(())
}

fn rfe_to_absolute(field: &crate::data::Field, rel: f64) -> f64 {
    let buf: Vec<Complex> = field
        .data()
        .iter()
        .map(|&v| Complex::new(v, 0.0))
        .collect();
    let max_mag = crate::fourier::fftn(&buf, field.shape())
        .iter()
        .map(|c| c.abs())
        .fold(0.0f64, f64::max);
    rel * max_mag
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_dominates_stage_time() {
        let field = synth::grf::GrfBuilder::new(&[32, 32])
            .lognormal(1.0)
            .seed(3)
            .build();
        let eps0: Vec<f64> = field.data().iter().map(|v| (v * 17.0).sin() * 1e-3).collect();
        let params = PocsParams {
            spatial: Bounds::Global(1e-3),
            frequency: Bounds::Global(1e-2),
            max_iters: 50,
            threads: 1,
        };
        let t = instrumented_pocs(&eps0, field.shape(), &params);
        assert!(t.iterations >= 1);
        assert!(
            t.fft + t.ifft > 0.3 * t.total(),
            "FFT share {:.2}",
            (t.fft + t.ifft) / t.total()
        );
    }
}
