//! Fig. 5: sparsity of the active edits vs the density of their total
//! effect per domain.
//!
//! Shape to reproduce: active spatial and frequency edits are few and
//! sparsely distributed, while the *total* edit effect in either single
//! domain (spatial + IFFT(freq), or freq + FFT(spatial)) touches every
//! component.

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::szlike::SzLike;
use crate::correction::{self, apply, FfczConfig};
use crate::data::synth;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let s = opts.scale;
    let field = synth::grf::GrfBuilder::new(&[s, s, s])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(101)
        .build();
    let n = field.len();
    let base = SzLike::default();

    let mut table = Table::new(
        "Fig. 5 analogue — edit sparsity (sz-like base)",
        &[
            "δ(rel)",
            "act. spat",
            "act. freq",
            "act. spat %",
            "act. freq %",
            "dense total-spat %",
            "dense total-freq %",
        ],
    );
    for delta_rel in [1e-2, 1e-3] {
        let cfg = FfczConfig::relative(1e-3, delta_rel);
        let archive = correction::compress(&field, &base, &cfg)?;
        let (a_s, a_f) = archive.edits.active_counts();
        // Total (per-domain) edits — dense by construction.
        let ts = apply::total_spatial_edits(&archive.edits, field.shape());
        let tf = apply::total_frequency_edits(&archive.edits, field.shape());
        let eps_mach = 1e-300;
        let dense_s = ts.iter().filter(|v| v.abs() > eps_mach).count();
        let dense_f = tf.iter().filter(|c| c.abs() > eps_mach).count();
        table.row(vec![
            format!("{delta_rel:.0e}"),
            a_s.to_string(),
            a_f.to_string(),
            fmt_num(100.0 * a_s as f64 / n as f64),
            fmt_num(100.0 * a_f as f64 / n as f64),
            fmt_num(100.0 * dense_s as f64 / n as f64),
            fmt_num(100.0 * dense_f as f64 / n as f64),
        ]);
    }
    table.print();
    table.write_csv(&opts.out_dir.join("fig5.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn active_edits_sparse_but_totals_dense() {
        let field = synth::grf::GrfBuilder::new(&[16, 16, 16])
            .lognormal(1.2)
            .seed(7)
            .build();
        let cfg = FfczConfig::relative(1e-3, 3e-4);
        let archive = correction::compress(&field, &SzLike::default(), &cfg).unwrap();
        let (a_s, a_f) = archive.edits.active_counts();
        let n = field.len();
        assert!(a_f > 0, "some frequency edits must exist");
        assert!(a_s + a_f < n, "active edits must be sparse: {a_s}+{a_f} of {n}");
        if a_f > 0 {
            let ts = apply::total_spatial_edits(&archive.edits, field.shape());
            let dense = ts.iter().filter(|v| v.abs() > 0.0).count();
            assert!(
                dense > n / 2,
                "total spatial effect must be dense: {dense} of {n}"
            );
        }
    }
}
