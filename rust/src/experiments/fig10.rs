//! Fig. 10: power-spectrum ratio ribbon — with pointwise per-component
//! frequency bounds, every reconstructed power-spectrum bin stays within
//! ±0.1% of the truth, while the base compressor at the same bitrate
//! exits the ribbon.

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{szlike::SzLike, Compressor, ErrorBound};
use crate::correction::{self, FfczConfig};
use crate::data::synth;
use crate::fourier::power_spectrum;

/// The paper's ribbon: 0.1% relative error per power-spectrum bin.
pub const RIBBON: f64 = 1e-3;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let s = opts.scale;
    let field = synth::grf::GrfBuilder::new(&[s, s, s])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(101)
        .build();
    let ps_true = power_spectrum(&field);
    let base = SzLike::default();

    let cfg = FfczConfig::power_spectrum(1e-3, RIBBON);
    let archive = correction::compress(&field, &base, &cfg)?;
    let recon_ffcz = correction::decompress(&archive)?;
    let ps_ffcz = power_spectrum(&recon_ffcz);

    // Base compressor at (approximately) the same bitrate: tighten ε until
    // its payload is at least as large as ours, then compare ribbons.
    let target = archive.total_bytes();
    let mut eb = 1e-3;
    let mut payload = base.compress(&field, ErrorBound::Relative(eb))?;
    for _ in 0..20 {
        if payload.len() >= target {
            break;
        }
        eb /= 2.0;
        payload = base.compress(&field, ErrorBound::Relative(eb))?;
    }
    let recon_base = base.decompress(&payload)?;
    let ps_base = power_spectrum(&recon_base);

    let mut table = Table::new(
        format!("Fig. 10 analogue — P(k) ratio (ribbon ±{RIBBON:.1e})"),
        &["k", "ratio sz-like", "ratio sz+FFCz", "in ribbon (base)", "in ribbon (FFCz)"],
    );
    let rel_base = ps_base.relative_error(&ps_true);
    let rel_ffcz = ps_ffcz.relative_error(&ps_true);
    let mut base_out = 0usize;
    let mut ffcz_out = 0usize;
    let peak = ps_true.power.iter().fold(0.0f64, |a, &b| a.max(b));
    for k in 0..ps_true.len() {
        if ps_true.count[k] == 0 || ps_true.power[k] <= peak * 1e-18 {
            continue;
        }
        let in_base = rel_base[k].abs() <= RIBBON;
        let in_ffcz = rel_ffcz[k].abs() <= RIBBON;
        base_out += usize::from(!in_base);
        ffcz_out += usize::from(!in_ffcz);
        table.row(vec![
            k.to_string(),
            fmt_num(1.0 + rel_base[k]),
            fmt_num(1.0 + rel_ffcz[k]),
            in_base.to_string(),
            in_ffcz.to_string(),
        ]);
    }
    table.print();
    table.write_csv(&opts.out_dir.join("fig10.csv"))?;
    println!(
        "bins outside ribbon — base: {base_out}, FFCz: {ffcz_out} \
         (bitrates: base {:.4}, FFCz {:.4} bits/value)",
        crate::metrics::bitrate(&field, payload.len()),
        crate::metrics::bitrate(&field, target),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffcz_stays_inside_ribbon() {
        let field = synth::grf::GrfBuilder::new(&[24, 24])
            .lognormal(1.2)
            .seed(8)
            .build();
        let cfg = FfczConfig::power_spectrum(1e-2, RIBBON);
        let archive = correction::compress(&field, &SzLike::default(), &cfg).unwrap();
        let recon = correction::decompress(&archive).unwrap();
        let ps_true = power_spectrum(&field);
        let ps = power_spectrum(&recon);
        assert!(ps.max_relative_error(&ps_true) <= RIBBON * 1.1);
    }
}
