//! Aligned text tables + CSV writing for the experiment drivers.

use std::path::Path;

use anyhow::Result;

/// A simple column-aligned table that renders to the terminal and to CSV.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                // left-align first column, right-align the rest
                if i == 0 {
                    line.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", c, w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&format!(
            "{}\n",
            "-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1))
        ));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::new();
        out.push_str(&csv_line(&self.headers));
        for row in &self.rows {
            out.push_str(&csv_line(row));
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

fn csv_line(cells: &[String]) -> String {
    let quoted: Vec<String> = cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect();
    format!("{}\n", quoted.join(","))
}

/// Compact scientific/fixed formatting for metric cells.
pub fn fmt_num(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if !v.is_finite() {
        format!("{v}")
    } else if v.abs() >= 10000.0 || v.abs() < 0.001 {
        format!("{v:.3e}")
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "x"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["long-name".into(), "22.5".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("long-name"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["with,comma".into(), "q\"q".into()]);
        let dir = std::env::temp_dir().join("ffcz_tbl");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"with,comma\""));
        assert!(s.contains("\"q\"\"q\""));
    }

    #[test]
    fn fmt_num_ranges() {
        assert_eq!(fmt_num(0.0), "0");
        assert_eq!(fmt_num(12345.0), "1.234e4");
        assert_eq!(fmt_num(123.45), "123.5");
        assert_eq!(fmt_num(1.2345), "1.234");
        assert_eq!(fmt_num(0.0001), "1.000e-4");
    }
}
