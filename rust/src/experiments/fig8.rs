//! Fig. 8: PSNR vs bitrate in the spatial domain — FFCz must not cost
//! spatial fidelity.
//!
//! Shape to reproduce: the FFCz curve coincides with (or slightly beats,
//! since editing can only *shrink* spatial errors) the base curve, at a
//! mildly higher bitrate.

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{szlike::SzLike, Compressor, ErrorBound};
use crate::correction::{self, FfczConfig};
use crate::data::synth;
use crate::metrics;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let s = opts.scale;
    let field = synth::grf::GrfBuilder::new(&[s, s, s])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(101)
        .build();
    let base = SzLike::default();
    let mut table = Table::new(
        "Fig. 8 analogue — spatial PSNR vs bitrate (sz-like, nyx-baryon-like)",
        &["method", "ε(rel)", "bitrate", "PSNR dB"],
    );
    for eb in [1e-2, 1e-3, 1e-4] {
        let payload = base.compress(&field, ErrorBound::Relative(eb))?;
        let recon = base.decompress(&payload)?;
        table.row(vec![
            "sz-like".into(),
            format!("{eb:.0e}"),
            fmt_num(metrics::bitrate(&field, payload.len())),
            fmt_num(metrics::psnr(&field, &recon)),
        ]);
        let delta_rel = super::tail_clip_delta_rel(&field, &recon);
        let cfg = FfczConfig::relative(eb, delta_rel);
        let archive =
            correction::correct_reconstruction(&field, &recon, base.name(), payload, &cfg)?;
        let recon2 = correction::decompress(&archive)?;
        table.row(vec![
            "sz-like+FFCz".into(),
            format!("{eb:.0e}"),
            fmt_num(metrics::bitrate(&field, archive.total_bytes())),
            fmt_num(metrics::psnr(&field, &recon2)),
        ]);
    }
    table.print();
    table.write_csv(&opts.out_dir.join("fig8.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn editing_does_not_cost_psnr() {
        let field = synth::grf::GrfBuilder::new(&[16, 16, 16])
            .lognormal(1.2)
            .seed(13)
            .build();
        let base = SzLike::default();
        let payload = base.compress(&field, ErrorBound::Relative(1e-3)).unwrap();
        let recon = base.decompress(&payload).unwrap();
        let psnr_base = metrics::psnr(&field, &recon);
        let (_, rfe) = metrics::spectral_metrics(&field, &recon);
        let cfg = FfczConfig::relative(1e-3, rfe / 10.0);
        let archive =
            correction::correct_reconstruction(&field, &recon, base.name(), payload, &cfg)
                .unwrap();
        let recon2 = correction::decompress(&archive).unwrap();
        let psnr_ffcz = metrics::psnr(&field, &recon2);
        // The projection shrinks errors; PSNR must not degrade materially.
        assert!(
            psnr_ffcz >= psnr_base - 0.1,
            "PSNR {psnr_base:.2} → {psnr_ffcz:.2}"
        );
    }
}
