//! Fig. 1: power spectra of the cosmology-like field under base
//! compression vs FFCz editing at matched bitrate.
//!
//! Shape to reproduce: the base compressor's spectrum departs from the
//! truth at high wavenumbers; the FFCz-edited spectrum tracks it across
//! the whole range.

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{sperrlike::SperrLike, szlike::SzLike, Compressor, ErrorBound};
use crate::correction::{self, FfczConfig};
use crate::data::synth;
use crate::fourier::power_spectrum;
use crate::metrics;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let s = opts.scale;
    let field = synth::grf::GrfBuilder::new(&[s, s, s])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(101)
        .build();
    let ps_true = power_spectrum(&field);

    let mut table = Table::new(
        "Fig. 1 analogue — P(k) relative error by method (matched spatial ε)",
        &["k", "P(k) true", "relerr sz-like", "relerr sz+FFCz", "relerr sperr-like", "relerr sperr+FFCz"],
    );

    let spatial_rel = 1e-3;
    let cfg = FfczConfig::power_spectrum(spatial_rel, 1e-3);

    let mut series: Vec<Vec<f64>> = Vec::new();
    let mut bitrates: Vec<(String, f64)> = Vec::new();
    for base in [
        Box::new(SzLike::default()) as Box<dyn Compressor>,
        Box::new(SperrLike::default()),
    ] {
        // Base alone.
        let payload = base.compress(&field, ErrorBound::Relative(spatial_rel))?;
        let recon_base = base.decompress(&payload)?;
        let ps_base = power_spectrum(&recon_base);
        series.push(ps_base.relative_error(&ps_true));
        bitrates.push((
            format!("{} native", base.name()),
            metrics::bitrate(&field, payload.len()),
        ));
        // FFCz-edited.
        let archive = correction::compress(&field, base.as_ref(), &cfg)?;
        let recon_ffcz = correction::decompress(&archive)?;
        let ps_ffcz = power_spectrum(&recon_ffcz);
        series.push(ps_ffcz.relative_error(&ps_true));
        bitrates.push((
            format!("{} +FFCz", base.name()),
            metrics::bitrate(&field, archive.total_bytes()),
        ));
    }

    for k in 1..ps_true.len() {
        if ps_true.count[k] == 0 || ps_true.power[k] <= 0.0 {
            continue;
        }
        table.row(vec![
            k.to_string(),
            fmt_num(ps_true.power[k]),
            fmt_num(series[0][k]),
            fmt_num(series[1][k]),
            fmt_num(series[2][k]),
            fmt_num(series[3][k]),
        ]);
    }
    table.print();
    table.write_csv(&opts.out_dir.join("fig1.csv"))?;
    for (name, b) in bitrates {
        println!("bitrate {name}: {b:.4} bits/value");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffcz_tracks_spectrum_where_base_departs() {
        let field = synth::grf::GrfBuilder::new(&[24, 24])
            .lognormal(1.2)
            .seed(5)
            .build();
        let base = SzLike::default();
        let cfg = FfczConfig::power_spectrum(1e-2, 1e-3);
        let ps_true = power_spectrum(&field);
        let payload = base.compress(&field, ErrorBound::Relative(1e-2)).unwrap();
        let recon_base = base.decompress(&payload).unwrap();
        let archive = correction::compress(&field, &base, &cfg).unwrap();
        let recon_ffcz = correction::decompress(&archive).unwrap();
        let err_base = power_spectrum(&recon_base).max_relative_error(&ps_true);
        let err_ffcz = power_spectrum(&recon_ffcz).max_relative_error(&ps_true);
        assert!(
            err_ffcz < err_base && err_ffcz <= 1.1e-3,
            "ffcz {err_ffcz} vs base {err_base}"
        );
    }
}
