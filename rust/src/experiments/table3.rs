//! Table III: iterations, active edits, and runtime of the alternating
//! projection as the frequency bound Δ sweeps over decades.
//!
//! Shape to reproduce: intermediate Δ needs the most iterations (the s-
//! and f-cubes partially overlap); tiny Δ terminates in one pass with huge
//! frequency-edit counts and zero active spatial edits (the f-cube lies
//! inside the s-cube).

use std::time::Instant;

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{szlike::SzLike, Compressor, ErrorBound};
use crate::correction::{alternating_projection, Bounds, PocsParams};
use crate::data::synth;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let field = synth::grf::GrfBuilder::new(&[opts.scale, opts.scale, opts.scale])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(101)
        .build();
    let base = SzLike::default();
    let eb_rel = 1e-3;
    let payload = base.compress(&field, ErrorBound::Relative(eb_rel))?;
    let recon = base.decompress(&payload)?;
    let eps0: Vec<f64> = recon
        .data()
        .iter()
        .zip(field.data())
        .map(|(r, x)| r - x)
        .collect();
    let e_abs = ErrorBound::Relative(eb_rel).absolute_for(&field);
    // Δ sweep in decades relative to max |X_k| (the paper sweeps δ(%)).
    let spec_max = {
        let buf: Vec<crate::fourier::Complex> = field
            .data()
            .iter()
            .map(|&v| crate::fourier::Complex::new(v, 0.0))
            .collect();
        crate::fourier::fftn(&buf, field.shape())
            .iter()
            .map(|c| c.abs())
            .fold(0.0f64, f64::max)
    };

    let mut table = Table::new(
        "Table III analogue — POCS behaviour vs Δ (sz-like base, ε rel = 0.1%)",
        &["δ(rel)", "# iters", "# act. spat", "# act. freq", "time (ms)", "converged"],
    );
    for exp in 2..=6 {
        let delta_rel = 10.0f64.powi(-exp);
        let params = PocsParams {
            spatial: Bounds::Global(e_abs),
            frequency: Bounds::Global(delta_rel * spec_max),
            max_iters: 500,
            threads: 1,
        };
        let t0 = Instant::now();
        let r = alternating_projection(&eps0, field.shape(), &params);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        table.row(vec![
            format!("1e-{exp}"),
            r.iterations.to_string(),
            r.active_spat.to_string(),
            r.active_freq.to_string(),
            fmt_num(ms),
            r.converged.to_string(),
        ]);
    }
    table.print();
    table.write_csv(&opts.out_dir.join("table3.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_delta_regime_matches_paper() {
        // Δ → tiny: 1 iteration, 0 active spatial edits, many freq edits.
        let field = synth::grf::GrfBuilder::new(&[16, 16, 16])
            .lognormal(1.2)
            .seed(9)
            .build();
        let base = SzLike::default();
        let payload = base.compress(&field, ErrorBound::Relative(1e-3)).unwrap();
        let recon = base.decompress(&payload).unwrap();
        let eps0: Vec<f64> = recon
            .data()
            .iter()
            .zip(field.data())
            .map(|(r, x)| r - x)
            .collect();
        let e_abs = ErrorBound::Relative(1e-3).absolute_for(&field);
        let params = PocsParams {
            spatial: Bounds::Global(e_abs),
            frequency: Bounds::Global(1e-9),
            max_iters: 100,
            threads: 1,
        };
        let r = alternating_projection(&eps0, field.shape(), &params);
        assert!(r.converged);
        assert!(r.iterations <= 3, "iters {}", r.iterations);
        assert_eq!(r.active_spat, 0);
        assert!(r.active_freq > field.len() / 2);
    }
}
