//! Fig. 6: SSNR vs bitrate for the three base compressors and FFCz on top.
//!
//! Shape to reproduce: at matched bitrate, FFCz curves sit above the
//! corresponding baselines (higher frequency-domain accuracy per bit).

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{paper_compressors, ErrorBound};
use crate::correction::{self, FfczConfig};
use crate::data::synth;
use crate::metrics;

/// Spatial bound sweep that traces out the rate axis.
pub const EB_SWEEP: [f64; 4] = [1e-2, 1e-3, 1e-4, 1e-5];

pub fn run(opts: &ExpOptions) -> Result<()> {
    let suite = synth::benchmark_suite(opts.scale);
    let mut table = Table::new(
        "Fig. 6 analogue — SSNR (dB) vs bitrate (bits/value)",
        &["dataset", "method", "ε(rel)", "bitrate", "SSNR dB"],
    );
    // Keep the run affordable: cosmology + combustion + EEG cover the
    // dataset families; HEDM is exercised in fig7.
    for (name, field) in suite
        .iter()
        .filter(|(n, _)| n == "nyx-baryon" || n == "s3d-co2" || n == "eeg")
    {
        for base in paper_compressors() {
            for &eb in &EB_SWEEP {
                // Base alone.
                let payload = base.compress(field, ErrorBound::Relative(eb))?;
                let recon = base.decompress(&payload)?;
                let (ssnr, _) = metrics::spectral_metrics(field, &recon);
                table.row(vec![
                    name.clone(),
                    base.name().to_string(),
                    format!("{eb:.0e}"),
                    fmt_num(metrics::bitrate(field, payload.len())),
                    fmt_num(ssnr),
                ]);
                // FFCz on top (paper: edit the ε = 0.1% output, bound the
                // frequency error to 1% of the native max RFE).
                let delta_rel = super::tail_clip_delta_rel(field, &recon);
                let cfg = FfczConfig::relative(eb, delta_rel);
                let archive = correction::correct_reconstruction(
                    field,
                    &recon,
                    base.name(),
                    payload,
                    &cfg,
                )?;
                let recon2 = correction::decompress(&archive)?;
                let (ssnr2, _) = metrics::spectral_metrics(field, &recon2);
                table.row(vec![
                    name.clone(),
                    format!("{}+FFCz", base.name()),
                    format!("{eb:.0e}"),
                    fmt_num(metrics::bitrate(field, archive.total_bytes())),
                    fmt_num(ssnr2),
                ]);
            }
        }
    }
    table.print();
    table.write_csv(&opts.out_dir.join("fig6.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::szlike::SzLike;
    use crate::compressors::Compressor;

    #[test]
    fn ffcz_improves_ssnr_at_small_extra_cost() {
        let field = synth::grf::GrfBuilder::new(&[16, 16, 16])
            .lognormal(2.4) // Nyx-like dynamic range ⇒ heavy-tailed error spectrum
            .seed(11)
            .build();
        let base = SzLike::default();
        let payload = base.compress(&field, ErrorBound::Relative(1e-3)).unwrap();
        let recon = base.decompress(&payload).unwrap();
        let (ssnr_base, rfe) = metrics::spectral_metrics(&field, &recon);
        let bits_base = metrics::bitrate(&field, payload.len());
        let cfg = FfczConfig::relative(1e-3, rfe / 10.0);
        let archive =
            correction::correct_reconstruction(&field, &recon, base.name(), payload, &cfg)
                .unwrap();
        let recon2 = correction::decompress(&archive).unwrap();
        let (ssnr_ffcz, rfe_ffcz) = metrics::spectral_metrics(&field, &recon2);
        let bits_ffcz = metrics::bitrate(&field, archive.total_bytes());
        // The Δ = RFE/10 point trims the heavy tail: the max frequency
        // error must drop ~10×, SSNR must not degrade, and the bitrate
        // cost must stay modest. (Large SSNR jumps need tighter Δ — the
        // sweep in `run` shows the full trade-off curve.)
        assert!(
            rfe_ffcz < rfe / 5.0,
            "max RFE {rfe:.3e} → {rfe_ffcz:.3e}"
        );
        assert!(
            ssnr_ffcz >= ssnr_base - 0.1,
            "SSNR {ssnr_base:.1} → {ssnr_ffcz:.1}"
        );
        assert!(
            bits_ffcz < bits_base * 2.0,
            "bitrate {bits_base:.3} → {bits_ffcz:.3}"
        );
    }
}
