//! Table II: compression ratios of (1) base compressor with spatial bound
//! only, (2) base compressor satisfying BOTH bounds by trial-and-error
//! tightening of the spatial bound, and (3) our augmentation.
//!
//! Shape to reproduce: trial-and-error collapses the ratio (often by
//! orders of magnitude); FFCz costs ≲15–20% for the prediction-based base
//! and ≈0 for transform-based bases.

use anyhow::Result;

use super::{tables::fmt_num, ExpOptions, Table};
use crate::compressors::{paper_compressors, Compressor, ErrorBound};
use crate::correction::{self, FfczConfig};
use crate::data::{synth, Field};
use crate::metrics;

/// Operating point: relative spatial bound 0.1% (the paper's setting); the
/// RFE target is the base compressor's max frequency error reduced 10×.
/// The paper uses 100× on 512³ Nyx fields whose 6-decade dynamic range
/// gives the error spectrum a ~100× heavy tail; at our 32³ scale the tail
/// is ~10-80×, so 10× is the regime-equivalent choice (sparse violator
/// set — see EXPERIMENTS.md).
pub const SPATIAL_REL: f64 = 1e-3;
pub const RFE_SHRINK: f64 = 10.0;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let suite = synth::benchmark_suite(opts.scale);
    let mut table = Table::new(
        "Table II analogue — compression ratio (ε rel = 0.1%, Δ = p99.9 tail clip)",
        &[
            "dataset",
            "base",
            "ratio ε-only",
            "ratio trial&error",
            "ratio our aug.",
            "aug. overhead %",
            "RFE gain ×",
        ],
    );
    for (name, field) in &suite {
        for base in paper_compressors() {
            let row = one_cell(name, field, base.as_ref())?;
            table.row(row);
        }
    }
    table.print();
    table.write_csv(&opts.out_dir.join("table2.csv"))?;
    Ok(())
}

fn one_cell(name: &str, field: &Field, base: &dyn Compressor) -> Result<Vec<String>> {
    // (1) native: spatial bound only.
    let payload = base.compress(field, ErrorBound::Relative(SPATIAL_REL))?;
    let recon = base.decompress(&payload)?;
    let ratio_native = metrics::compression_ratio(field, payload.len());
    let (_, rfe_native) = metrics::spectral_metrics(field, &recon);

    // Frequency target: clip the top 0.1% of frequency-error components
    // (the paper's sparse-edit regime; see super::tail_clip_delta_rel).
    let delta_rel = super::tail_clip_delta_rel(field, &recon).max(rfe_native / 1e4);
    let rfe_gain = rfe_native / delta_rel;

    // (2) trial-and-error: tighten the spatial bound until the frequency
    // target holds with NO edits (what users do today, §I).
    let ratio_trial = trial_and_error(field, base, delta_rel)?;

    // (3) our augmentation.
    let cfg = FfczConfig {
        spatial: correction::BoundSpec::Relative(SPATIAL_REL),
        frequency: correction::FrequencyBound::Uniform(correction::BoundSpec::Relative(
            delta_rel,
        )),
        max_iters: 200,
        max_quant_retries: 3,
        threads: 1,
    };
    let archive = correction::compress(field, base, &cfg)?;
    let ratio_ours = metrics::compression_ratio(field, archive.total_bytes());
    let overhead = 100.0 * (ratio_native / ratio_ours - 1.0);

    Ok(vec![
        name.to_string(),
        base.name().to_string(),
        fmt_num(ratio_native),
        fmt_num(ratio_trial),
        fmt_num(ratio_ours),
        format!("{overhead:.2}"),
        format!("{rfe_gain:.1}"),
    ])
}

/// Geometric tightening of the spatial bound until max RFE ≤ target.
/// Returns the achieved compression ratio (the cost of today's practice).
pub fn trial_and_error(field: &Field, base: &dyn Compressor, delta_rel: f64) -> Result<f64> {
    let mut eb = SPATIAL_REL;
    for _ in 0..24 {
        let payload = base.compress(field, ErrorBound::Relative(eb))?;
        let recon = base.decompress(&payload)?;
        let (_, rfe) = metrics::spectral_metrics(field, &recon);
        if rfe <= delta_rel {
            return Ok(metrics::compression_ratio(field, payload.len()));
        }
        eb /= 2.0;
    }
    // Could not reach the target even at eb/2²⁴ — report the last ratio.
    let payload = base.compress(field, ErrorBound::Relative(eb))?;
    Ok(metrics::compression_ratio(field, payload.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::szlike::SzLike;

    #[test]
    fn trial_and_error_costs_ratio() {
        let field = synth::grf::GrfBuilder::new(&[16, 16, 16])
            .lognormal(1.0)
            .seed(3)
            .build();
        let base = SzLike::default();
        let payload = base
            .compress(&field, ErrorBound::Relative(SPATIAL_REL))
            .unwrap();
        let recon = base.decompress(&payload).unwrap();
        let native = metrics::compression_ratio(&field, payload.len());
        let (_, rfe) = metrics::spectral_metrics(&field, &recon);
        let trial = trial_and_error(&field, &base, rfe / 50.0).unwrap();
        assert!(
            trial < native,
            "tightening must cost ratio: {trial} vs {native}"
        );
    }

    #[test]
    fn augmentation_beats_trial_and_error() {
        let field = synth::grf::GrfBuilder::new(&[16, 16, 16])
            .lognormal(2.4) // Nyx-like dynamic range ⇒ heavy-tailed error spectrum
            .seed(4)
            .build();
        let row = one_cell("t", &field, &SzLike::default()).unwrap();
        let trial: f64 = row[3].replace("e", "E").parse::<f64>().unwrap_or(0.0);
        let ours: f64 = row[4].replace("e", "E").parse::<f64>().unwrap_or(0.0);
        assert!(
            ours > trial,
            "our aug. must beat trial-and-error: {ours} vs {trial}"
        );
    }
}
