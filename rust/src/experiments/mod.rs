//! Experiment drivers: one per table/figure of the paper's evaluation
//! (§V). Each driver regenerates the corresponding artifact on the
//! synthetic benchmark suite and emits an aligned text table plus CSV
//! under `results/`.
//!
//! | id     | paper artifact | claim reproduced                             |
//! |--------|----------------|----------------------------------------------|
//! | fig1   | Fig. 1         | spectrum tracking at equal bitrate           |
//! | table2 | Table II       | ratio: native vs trial-and-error vs ours     |
//! | fig5   | Fig. 5         | sparsity of active edits                     |
//! | fig6   | Fig. 6         | SSNR vs bitrate                              |
//! | fig7   | Fig. 7         | throughput + pipelined timeline              |
//! | fig8   | Fig. 8         | PSNR vs bitrate (spatial fidelity kept)      |
//! | table3 | Table III      | iterations / active edits vs Δ               |
//! | fig9   | Fig. 9         | per-stage timing breakdown                   |
//! | table4 | Table IV       | stage-level time/BW/speedup (native vs PJRT) |
//! | fig10  | Fig. 10        | power-spectrum ribbon                        |

pub mod fig1;
pub mod fig10;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table2;
pub mod table3;
pub mod table4;
mod tables;

use anyhow::{bail, Result};
pub use tables::Table;

/// Frequency-bound selection used across the experiment drivers: clip the
/// top 0.1% of frequency-error components of the base reconstruction
/// (`Δ = p99.9(‖δ_k‖∞)`), expressed relative to `max_k |X_k|`.
///
/// The paper picks per-dataset RFE targets ("selected such that the max
/// frequency error is reduced 100×"); on 512³ fields with 6-decade dynamic
/// range that 100× target clips only a sparse tail. Our 32³ substitutes
/// have shorter tails, so the regime-equivalent selection is the explicit
/// tail quantile — it reproduces the paper's *sparse-edit* operating point
/// on every dataset family (see EXPERIMENTS.md §Operating points).
pub fn tail_clip_delta_rel(
    field: &crate::data::Field,
    recon: &crate::data::Field,
) -> f64 {
    use crate::fourier::Complex;
    let eps: Vec<Complex> = recon
        .data()
        .iter()
        .zip(field.data())
        .map(|(r, x)| Complex::new(r - x, 0.0))
        .collect();
    let delta = crate::fourier::fftn(&eps, field.shape());
    let mut linf: Vec<f64> = delta.iter().map(|c| c.linf()).collect();
    linf.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = linf[((linf.len() as f64 * 0.999) as usize).min(linf.len() - 1)];
    let spec = crate::fourier::fftn(
        &field
            .data()
            .iter()
            .map(|&v| Complex::new(v, 0.0))
            .collect::<Vec<_>>(),
        field.shape(),
    );
    let max_mag = spec.iter().map(|c| c.abs()).fold(0.0f64, f64::max);
    (q / max_mag.max(f64::MIN_POSITIVE)).max(1e-15)
}

/// Options shared by all experiment drivers.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Edge-size class of the synthetic suite (3D fields are scale³).
    pub scale: usize,
    /// Output directory for CSV artifacts.
    pub out_dir: std::path::PathBuf,
    /// Artifact directory for PJRT-path experiments (fig9/table4).
    pub artifact_dir: std::path::PathBuf,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            scale: 32,
            out_dir: "results".into(),
            artifact_dir: "artifacts".into(),
        }
    }
}

/// All experiment ids, in paper order.
pub const ALL: [&str; 10] = [
    "fig1", "table2", "fig5", "fig6", "fig7", "fig8", "table3", "fig9", "table4", "fig10",
];

/// Run one experiment by id, printing its tables and writing CSVs.
pub fn run(id: &str, opts: &ExpOptions) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    match id {
        "fig1" => fig1::run(opts),
        "table2" => table2::run(opts),
        "fig5" => fig5::run(opts),
        "fig6" => fig6::run(opts),
        "fig7" => fig7::run(opts),
        "fig8" => fig8::run(opts),
        "table3" => table3::run(opts),
        "fig9" => fig9::run(opts),
        "table4" => table4::run(opts),
        "fig10" => fig10::run(opts),
        "all" => {
            for id in ALL {
                println!("\n================ {id} ================");
                run(id, opts)?;
            }
            Ok(())
        }
        _ => bail!("unknown experiment '{id}' (known: {ALL:?} or 'all')"),
    }
}
