//! SPERR-style wavelet error-bounded compressor.
//!
//! Pipeline, mirroring SPERR's structure (Li, Lindstrom & Clyne, IPDPS'23):
//! 1. **Multi-level CDF 9/7 wavelet transform** over the whole field
//!    (separable lifting per axis, symmetric extension, ceil/floor split
//!    for odd lengths);
//! 2. **Coefficient coding** — SPERR proper uses SPECK set partitioning;
//!    this implementation uses uniform deadzone quantization of the
//!    coefficients with canonical Huffman + ZSTD, which preserves the
//!    properties the paper leans on (global transform ⇒ strong spectral
//!    retention; whole-dataset multi-level scan ⇒ slowest of the three);
//! 3. **Outlier correction** — like SPERR, the encoder reconstructs and
//!    stores exact corrections for samples that still violate the pointwise
//!    bound, making the error bound unconditional.

mod wavelet;

use anyhow::{bail, Result};

use super::{Compressor, ErrorBound};
use crate::data::{Field, Precision};
use crate::encoding::{
    fixed, huffman_decode, huffman_encode, lossless_compress, lossless_decompress, varint,
};

pub use wavelet::{cdf97_forward_nd, cdf97_inverse_nd, max_levels};

const CODE_OFFSET: i64 = 32768;
const MAX_CODE: i64 = 32767;

/// SPERR-style compressor.
pub struct SperrLike {
    /// Number of wavelet decomposition levels (capped by the field size).
    pub levels: usize,
}

impl Default for SperrLike {
    fn default() -> Self {
        Self { levels: 4 }
    }
}

impl Compressor for SperrLike {
    fn name(&self) -> &'static str {
        "sperr-like"
    }

    fn compress(&self, field: &Field, bound: ErrorBound) -> Result<Vec<u8>> {
        let eb = bound.absolute_for(field);
        if eb <= 0.0 {
            bail!("error bound must be positive");
        }
        let shape = field.shape().to_vec();
        let levels = self.levels.min(max_levels(&shape));
        let mut coeffs = field.data().to_vec();
        cdf97_forward_nd(&mut coeffs, &shape, levels);

        // Deadzone quantization. The CDF 9/7 synthesis has bounded L∞ gain;
        // quantum eb/2 keeps most samples in bound (measured: a handful of
        // outliers per 32³ block at eb/2) and the outlier pass catches the
        // rest — trading ~2 bits/coefficient of rate for sparse exact
        // corrections, the same trade SPERR itself makes.
        let quantum = eb / 2.0;
        let mut codes: Vec<u16> = Vec::with_capacity(coeffs.len());
        let mut escapes: Vec<i64> = Vec::new();
        let mut recon_coeffs = vec![0.0f64; coeffs.len()];
        for (i, &c) in coeffs.iter().enumerate() {
            let q = (c / quantum).round() as i64;
            if q.abs() <= MAX_CODE {
                codes.push((q + CODE_OFFSET) as u16);
            } else {
                codes.push(0);
                escapes.push(q);
            }
            recon_coeffs[i] = q as f64 * quantum;
        }

        // Local reconstruction for the outlier pass.
        cdf97_inverse_nd(&mut recon_coeffs, &shape, levels);
        let mut outlier_pos: Vec<u64> = Vec::new();
        let mut outlier_val: Vec<f64> = Vec::new();
        for (i, (&orig, &rec)) in field.data().iter().zip(&recon_coeffs).enumerate() {
            if (rec - orig).abs() > eb {
                outlier_pos.push(i as u64);
                outlier_val.push(orig);
            }
        }

        // ---- payload
        let mut out = Vec::new();
        out.extend_from_slice(b"SPL1");
        out.push(match field.precision() {
            Precision::Single => 0,
            Precision::Double => 1,
        });
        out.push(levels as u8);
        varint::write(&mut out, field.ndim() as u64);
        for &d in &shape {
            varint::write(&mut out, d as u64);
        }
        out.extend_from_slice(&eb.to_le_bytes());

        let enc_codes = lossless_compress(&huffman_encode(&codes));
        varint::write(&mut out, enc_codes.len() as u64);
        out.extend_from_slice(&enc_codes);

        let mut esc_bytes = Vec::new();
        varint::write(&mut esc_bytes, escapes.len() as u64);
        for &e in &escapes {
            varint::write(&mut esc_bytes, varint::zigzag(e));
        }
        let enc_esc = lossless_compress(&esc_bytes);
        varint::write(&mut out, enc_esc.len() as u64);
        out.extend_from_slice(&enc_esc);

        let mut ob = Vec::new();
        varint::write(&mut ob, outlier_pos.len() as u64);
        let mut prev = 0u64;
        for &p in &outlier_pos {
            varint::write(&mut ob, p - prev); // delta-coded positions
            prev = p;
        }
        for &v in &outlier_val {
            ob.extend_from_slice(&v.to_le_bytes());
        }
        let enc_ob = lossless_compress(&ob);
        varint::write(&mut out, enc_ob.len() as u64);
        out.extend_from_slice(&enc_ob);
        Ok(out)
    }

    fn decompress(&self, payload: &[u8]) -> Result<Field> {
        if payload.len() < 6 || &payload[..4] != b"SPL1" {
            bail!("not a sperr-like payload");
        }
        let precision = match payload[4] {
            0 => Precision::Single,
            1 => Precision::Double,
            x => bail!("bad precision {x}"),
        };
        let levels = payload[5] as usize;
        let mut pos = 6usize;
        let ndim = varint::read(payload, &mut pos)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(varint::read(payload, &mut pos)? as usize);
        }
        let eb = fixed::read_f64_le(payload, &mut pos, "header error bound")?;
        let quantum = eb / 2.0;
        let n: usize = shape.iter().product();

        let read_section = |payload: &[u8], pos: &mut usize| -> Result<Vec<u8>> {
            let len = varint::read(payload, pos)? as usize;
            if *pos + len > payload.len() {
                bail!("truncated section");
            }
            let raw = lossless_decompress(&payload[*pos..*pos + len])?;
            *pos += len;
            Ok(raw)
        };

        let code_raw = read_section(payload, &mut pos)?;
        let codes = huffman_decode(&code_raw, n)?;

        let esc_bytes = read_section(payload, &mut pos)?;
        let mut epos = 0usize;
        let n_esc = varint::read(&esc_bytes, &mut epos)? as usize;
        let mut escapes = Vec::with_capacity(n_esc);
        for _ in 0..n_esc {
            escapes.push(varint::unzigzag(varint::read(&esc_bytes, &mut epos)?));
        }

        let ob = read_section(payload, &mut pos)?;
        let mut opos = 0usize;
        let n_out = varint::read(&ob, &mut opos)? as usize;
        let mut outlier_pos_v = Vec::with_capacity(n_out);
        let mut acc = 0u64;
        for _ in 0..n_out {
            acc += varint::read(&ob, &mut opos)?;
            outlier_pos_v.push(acc as usize);
        }
        let mut outlier_val_v = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            outlier_val_v.push(fixed::read_f64_le(&ob, &mut opos, "outlier value")?);
        }

        // ---- reconstruct
        let mut coeffs = vec![0.0f64; n];
        let mut ei = 0usize;
        for (i, &code) in codes.iter().enumerate() {
            let q = if code == 0 {
                let q = *escapes
                    .get(ei)
                    .ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?;
                ei += 1;
                q
            } else {
                code as i64 - CODE_OFFSET
            };
            coeffs[i] = q as f64 * quantum;
        }
        cdf97_inverse_nd(&mut coeffs, &shape, levels);
        for (p, v) in outlier_pos_v.into_iter().zip(outlier_val_v) {
            if p >= n {
                bail!("outlier position out of range");
            }
            coeffs[p] = v;
        }
        Ok(Field::new(&shape, coeffs, precision))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn bound_holds_on_suite() {
        let c = SperrLike::default();
        for (name, field) in synth::benchmark_suite(16) {
            for eb_rel in [1e-2, 1e-3] {
                let bound = ErrorBound::Relative(eb_rel);
                let eb = bound.absolute_for(&field);
                let payload = c.compress(&field, bound).unwrap();
                let recon = c.decompress(&payload).unwrap();
                let max_err = field
                    .data()
                    .iter()
                    .zip(recon.data())
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                assert!(
                    max_err <= eb * (1.0 + 1e-12),
                    "{name}: max_err {max_err} > eb {eb}"
                );
            }
        }
    }

    #[test]
    fn smooth_field_compresses_comparably_to_szlike() {
        // On a very smooth field the global wavelet should compress in the
        // same ballpark as the local predictor (SPERR proper wins via SPECK
        // significance coding, which this implementation replaces with
        // dense Huffman — see module docs).
        let field = synth::turbulence::TurbulenceBuilder::new(&[32, 32, 32])
            .dissipation_frac(0.1)
            .seed(6)
            .build();
        let sp = SperrLike::default()
            .compress(&field, ErrorBound::Relative(1e-3))
            .unwrap();
        let sz = crate::compressors::szlike::SzLike::default()
            .compress(&field, ErrorBound::Relative(1e-3))
            .unwrap();
        let sp_ratio = field.original_bytes() as f64 / sp.len() as f64;
        let sz_ratio = field.original_bytes() as f64 / sz.len() as f64;
        assert!(
            sp_ratio > 0.4 * sz_ratio,
            "sperr-like {sp_ratio:.1} vs sz-like {sz_ratio:.1}"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(SperrLike::default().decompress(b"xx").is_err());
    }
}
