//! CDF 9/7 wavelet transform via lifting (the JPEG2000 irreversible
//! filter, also SPERR's transform), with symmetric boundary extension,
//! arbitrary lengths (ceil/floor low/high split), and multi-level
//! separable N-D application on the shrinking low-pass subbox.

/// Lifting constants (Daubechies–Sweldens factorization of CDF 9/7).
const ALPHA: f64 = -1.586_134_342_059_924;
const BETA: f64 = -0.052_980_118_572_961;
const GAMMA: f64 = 0.882_911_075_530_934;
const DELTA: f64 = 0.443_506_852_043_971;
/// Scaling constant K; low band is scaled by 1/K, high band by K.
const K: f64 = 1.230_174_104_914_001;

/// Max number of decomposition levels such that every dimension stays ≥ 8
/// at the coarsest level (capped at 6, plenty for compression).
pub fn max_levels(shape: &[usize]) -> usize {
    let mut levels = 0usize;
    let mut dims: Vec<usize> = shape.to_vec();
    while levels < 6 && dims.iter().all(|&d| d >= 8) {
        for d in dims.iter_mut() {
            *d = d.div_ceil(2);
        }
        levels += 1;
    }
    levels
}

/// One forward lifting pass over a contiguous line, then deinterleave into
/// `[low | high]`. `n ≥ 2`.
fn forward_line(x: &mut [f64], scratch: &mut [f64]) {
    let n = x.len();
    debug_assert!(n >= 2);
    // Symmetric extension helper (whole-sample symmetry).
    let at = |x: &[f64], i: isize| -> f64 {
        let n = x.len() as isize;
        let j = if i < 0 {
            -i
        } else if i >= n {
            2 * (n - 1) - i
        } else {
            i
        };
        x[j as usize]
    };
    // Predict 1 (odd), update 1 (even), predict 2, update 2.
    for i in (1..n).step_by(2) {
        x[i] += ALPHA * (at(x, i as isize - 1) + at(x, i as isize + 1));
    }
    for i in (0..n).step_by(2) {
        x[i] += BETA * (at(x, i as isize - 1) + at(x, i as isize + 1));
    }
    for i in (1..n).step_by(2) {
        x[i] += GAMMA * (at(x, i as isize - 1) + at(x, i as isize + 1));
    }
    for i in (0..n).step_by(2) {
        x[i] += DELTA * (at(x, i as isize - 1) + at(x, i as isize + 1));
    }
    // Scale and deinterleave.
    let n_low = n.div_ceil(2);
    for i in 0..n {
        if i % 2 == 0 {
            scratch[i / 2] = x[i] / K;
        } else {
            scratch[n_low + i / 2] = x[i] * K;
        }
    }
    x.copy_from_slice(&scratch[..n]);
}

/// Inverse of [`forward_line`].
fn inverse_line(x: &mut [f64], scratch: &mut [f64]) {
    let n = x.len();
    debug_assert!(n >= 2);
    let n_low = n.div_ceil(2);
    // Re-interleave and unscale.
    for i in 0..n {
        if i % 2 == 0 {
            scratch[i] = x[i / 2] * K;
        } else {
            scratch[i] = x[n_low + i / 2] / K;
        }
    }
    x.copy_from_slice(&scratch[..n]);
    let at = |x: &[f64], i: isize| -> f64 {
        let n = x.len() as isize;
        let j = if i < 0 {
            -i
        } else if i >= n {
            2 * (n - 1) - i
        } else {
            i
        };
        x[j as usize]
    };
    // Undo lifting in reverse order with negated coefficients.
    for i in (0..n).step_by(2) {
        x[i] -= DELTA * (at(x, i as isize - 1) + at(x, i as isize + 1));
    }
    for i in (1..n).step_by(2) {
        x[i] -= GAMMA * (at(x, i as isize - 1) + at(x, i as isize + 1));
    }
    for i in (0..n).step_by(2) {
        x[i] -= BETA * (at(x, i as isize - 1) + at(x, i as isize + 1));
    }
    for i in (1..n).step_by(2) {
        x[i] -= ALPHA * (at(x, i as isize - 1) + at(x, i as isize + 1));
    }
}

/// Apply `op` along `axis` of the `sub` subbox of a row-major array with
/// full shape `shape`.
fn apply_axis(
    data: &mut [f64],
    shape: &[usize],
    sub: &[usize],
    axis: usize,
    forward: bool,
) {
    let len = sub[axis];
    if len < 2 {
        return;
    }
    let ndim = shape.len();
    let mut strides = vec![1usize; ndim];
    for d in (0..ndim.saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    let mut line = vec![0.0f64; len];
    let mut scratch = vec![0.0f64; len];
    // Iterate the subbox lines: odometer over all dims except `axis`.
    let mut idx = vec![0usize; ndim];
    loop {
        // Gather, transform, scatter one line.
        let base: usize = idx
            .iter()
            .zip(&strides)
            .enumerate()
            .map(|(d, (&i, &s))| if d == axis { 0 } else { i * s })
            .sum();
        let st = strides[axis];
        for (j, l) in line.iter_mut().enumerate() {
            *l = data[base + j * st];
        }
        if forward {
            forward_line(&mut line, &mut scratch);
        } else {
            inverse_line(&mut line, &mut scratch);
        }
        for (j, l) in line.iter().enumerate() {
            data[base + j * st] = *l;
        }
        // Odometer, skipping `axis`.
        let mut d = ndim;
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            if d == axis {
                continue;
            }
            idx[d] += 1;
            if idx[d] < sub[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

/// Multi-level forward CDF 9/7 over an N-D row-major array.
pub fn cdf97_forward_nd(data: &mut [f64], shape: &[usize], levels: usize) {
    let mut sub: Vec<usize> = shape.to_vec();
    for _ in 0..levels {
        for axis in 0..shape.len() {
            apply_axis(data, shape, &sub, axis, true);
        }
        for d in sub.iter_mut() {
            *d = d.div_ceil(2);
        }
    }
}

/// Multi-level inverse CDF 9/7.
pub fn cdf97_inverse_nd(data: &mut [f64], shape: &[usize], levels: usize) {
    // Recompute the subbox sizes of every level, then undo coarsest-first.
    let mut subs: Vec<Vec<usize>> = Vec::with_capacity(levels);
    let mut sub: Vec<usize> = shape.to_vec();
    for _ in 0..levels {
        subs.push(sub.clone());
        for d in sub.iter_mut() {
            *d = d.div_ceil(2);
        }
    }
    for sub in subs.into_iter().rev() {
        for axis in (0..shape.len()).rev() {
            apply_axis(data, shape, &sub, axis, false);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn random(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = XorShift::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn line_roundtrip_even_and_odd_lengths() {
        for n in [2usize, 3, 8, 9, 17, 64, 100] {
            let orig = random(n, n as u64);
            let mut x = orig.clone();
            let mut s = vec![0.0; n];
            forward_line(&mut x, &mut s);
            inverse_line(&mut x, &mut s);
            for (a, b) in x.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-12, "n={n}");
            }
        }
    }

    #[test]
    fn constant_signal_has_zero_details() {
        let n = 32;
        let mut x = vec![7.5f64; n];
        let mut s = vec![0.0; n];
        forward_line(&mut x, &mut s);
        // High band = second half; must vanish for constants.
        for &d in &x[n / 2..] {
            assert!(d.abs() < 1e-10, "detail {d}");
        }
        // Low band carries the (scaled) signal.
        for &l in &x[..n / 2] {
            assert!((l - 7.5).abs() < 1e-9, "low {l}");
        }
    }

    #[test]
    fn linear_ramp_details_vanish() {
        // CDF 9/7 has 4 vanishing moments: linear signals produce zero
        // detail away from boundaries.
        let n = 64;
        let mut x: Vec<f64> = (0..n).map(|i| 3.0 * i as f64).collect();
        let mut s = vec![0.0; n];
        forward_line(&mut x, &mut s);
        for &d in &x[n / 2 + 2..n - 2] {
            assert!(d.abs() < 1e-9, "interior detail {d}");
        }
    }

    #[test]
    fn nd_roundtrip_multilevel() {
        for (shape, levels) in [
            (vec![16usize], 2usize),
            (vec![16, 16], 2),
            (vec![9, 13], 1),
            (vec![8, 8, 8], 1),
            (vec![17, 9, 12], 1),
        ] {
            let n: usize = shape.iter().product();
            let orig = random(n, 42);
            let mut x = orig.clone();
            cdf97_forward_nd(&mut x, &shape, levels);
            cdf97_inverse_nd(&mut x, &shape, levels);
            for (a, b) in x.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-10, "shape {shape:?} levels {levels}");
            }
        }
    }

    #[test]
    fn energy_compaction_on_smooth_signal() {
        // The DC-gain-1 scaling convention is not energy preserving, so
        // compaction is measured within the transform domain: the 16
        // coarsest low-band coefficients must carry nearly everything.
        let n = 128;
        let mut x: Vec<f64> = (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * i as f64 / n as f64).sin())
            .collect();
        cdf97_forward_nd(&mut x, &[n], 3);
        let total: f64 = x.iter().map(|v| v * v).sum();
        let mut mags: Vec<f64> = x.iter().map(|v| v * v).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let top: f64 = mags[..16].iter().sum();
        assert!(top / total > 0.95, "compaction {}", top / total);
    }

    #[test]
    fn max_levels_reasonable() {
        assert_eq!(max_levels(&[256, 256, 256]), 6);
        assert_eq!(max_levels(&[16]), 2);
        assert_eq!(max_levels(&[4]), 0);
        assert_eq!(max_levels(&[64, 8]), 1);
    }
}
