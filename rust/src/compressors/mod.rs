//! Error-bounded lossy base compressors.
//!
//! The paper plugs FFCz on top of three state-of-the-art compressors — SZ3
//! (prediction-based), ZFP (block-transform), SPERR (wavelet). None of them
//! exist in the offline crate universe, so each algorithm *family* is
//! re-implemented from scratch:
//!
//! * [`szlike`] — multidimensional Lorenzo/interpolation prediction with
//!   error-bounded linear quantization and a Huffman+ZSTD back end;
//! * [`zfplike`] — fixed 4^d blocks, a reversible decorrelating transform,
//!   grouped bit-plane coding, and an all-zero-block fast path;
//! * [`sperrlike`] — CDF 9/7 lifting wavelet with SPECK-style significance
//!   coding and an outlier-correction pass for the pointwise bound.
//!
//! All three uphold the same contract: every reconstructed sample deviates
//! from the original by at most the requested [`ErrorBound`] (verified by
//! integration tests across the full synthetic suite).

pub mod identity;
pub mod sperrlike;
pub mod szlike;
pub mod zfplike;

use anyhow::Result;

use crate::data::Field;

/// A pointwise error bound request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ErrorBound {
    /// Absolute: `|x̂ − x| ≤ eb`.
    Absolute(f64),
    /// Relative to the field's value range: `|x̂ − x| ≤ eb · (max − min)`.
    Relative(f64),
}

impl ErrorBound {
    /// Resolve to an absolute bound for a given field.
    pub fn absolute_for(&self, field: &Field) -> f64 {
        match *self {
            ErrorBound::Absolute(e) => e,
            ErrorBound::Relative(r) => {
                let span = field.value_span();
                // A constant field still needs a usable bound.
                if span == 0.0 {
                    r.max(f64::MIN_POSITIVE)
                } else {
                    r * span
                }
            }
        }
    }
}

/// An error-bounded lossy compressor.
pub trait Compressor: Send + Sync {
    /// Short identifier (`"sz-like"`, …) used in archives and reports.
    fn name(&self) -> &'static str;

    /// Compress `field` under `bound`; the payload must round-trip through
    /// [`Compressor::decompress`] with every sample within the bound.
    fn compress(&self, field: &Field, bound: ErrorBound) -> Result<Vec<u8>>;

    /// Reconstruct a field from a payload produced by this compressor.
    fn decompress(&self, payload: &[u8]) -> Result<Field>;
}

/// Look up a **built-in** compressor by its `name()`. Most callers want
/// [`crate::codec::build_compressor`] instead, which also resolves base
/// compressors registered at runtime with [`crate::codec::register_codec`];
/// this function is the registry's built-in tier.
pub fn by_name(name: &str) -> Option<Box<dyn Compressor>> {
    match name {
        "sz-like" => Some(Box::new(szlike::SzLike::default())),
        "zfp-like" => Some(Box::new(zfplike::ZfpLike::default())),
        "sperr-like" => Some(Box::new(sperrlike::SperrLike::default())),
        "identity" => Some(Box::new(identity::Identity)),
        _ => None,
    }
}

/// The three paper compressors, boxed, for sweep-style experiments.
pub fn paper_compressors() -> Vec<Box<dyn Compressor>> {
    vec![
        Box::new(szlike::SzLike::default()),
        Box::new(zfplike::ZfpLike::default()),
        Box::new(sperrlike::SperrLike::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Precision;

    #[test]
    fn bound_resolution() {
        let f = Field::new(&[4], vec![0.0, 2.0, 4.0, 10.0], Precision::Double);
        assert_eq!(ErrorBound::Absolute(0.5).absolute_for(&f), 0.5);
        assert_eq!(ErrorBound::Relative(0.01).absolute_for(&f), 0.1);
    }

    #[test]
    fn constant_field_relative_bound_nonzero() {
        let f = Field::new(&[4], vec![3.0; 4], Precision::Double);
        assert!(ErrorBound::Relative(0.01).absolute_for(&f) > 0.0);
    }

    #[test]
    fn registry_contains_paper_compressors() {
        for name in ["sz-like", "zfp-like", "sperr-like", "identity"] {
            assert!(by_name(name).is_some(), "{name} missing");
        }
        assert!(by_name("nope").is_none());
        assert_eq!(paper_compressors().len(), 3);
    }
}
