//! ZFP's reversible integer decorrelating transform on 4-sample lanes.
//!
//! Forward (Lindstrom 2014, the non-orthogonal lifted transform):
//! ```text
//! x += w; x >>= 1; w -= x;
//! z += y; z >>= 1; y -= z;
//! x += z; x >>= 1; z -= x;
//! w += y; w >>= 1; y -= w;
//! w += y >> 1; y -= w >> 1;
//! ```
//! applied along every dimension of a 4^d block. Like ZFP itself, the
//! right-shifts drop one low-order bit on odd sums, so forward+inverse is
//! reversible only up to a few ULPs of the integer grid — the block
//! floating-point scaling leaves ≥ 30 headroom bits so this sits far below
//! any requested error bound (and the outlier pass enforces the bound
//! unconditionally regardless).

/// Block edge length (ZFP uses 4).
pub const BLOCK_EDGE: usize = 4;

/// Forward lift of one 4-vector.
#[inline]
pub fn lift4(v: &mut [i64; 4]) {
    let [mut x, mut y, mut z, mut w] = *v;
    x += w;
    x >>= 1;
    w -= x;
    z += y;
    z >>= 1;
    y -= z;
    x += z;
    x >>= 1;
    z -= x;
    w += y;
    w >>= 1;
    y -= w;
    w += y >> 1;
    y -= w >> 1;
    *v = [x, y, z, w];
}

/// Inverse lift of one 4-vector.
#[inline]
pub fn unlift4(v: &mut [i64; 4]) {
    let [mut x, mut y, mut z, mut w] = *v;
    y += w >> 1;
    w -= y >> 1;
    y += w;
    w <<= 1;
    w -= y;
    z += x;
    x <<= 1;
    x -= z;
    y += z;
    z <<= 1;
    z -= y;
    w += x;
    x <<= 1;
    x -= w;
    *v = [x, y, z, w];
}

/// Forward transform of a 4^d block (row-major), lifting along every axis.
pub fn lift_block(block: &mut [i64], ndim: usize) {
    for_each_lane(block, ndim, lift4);
}

/// Inverse transform of a 4^d block.
pub fn inverse_lift_block(block: &mut [i64], ndim: usize) {
    for_each_lane(block, ndim, unlift4);
}

fn for_each_lane(block: &mut [i64], ndim: usize, f: impl Fn(&mut [i64; 4])) {
    let n = BLOCK_EDGE.pow(ndim as u32);
    debug_assert_eq!(block.len(), n);
    for axis in 0..ndim {
        // stride along `axis` in a row-major 4^d block
        let stride = BLOCK_EDGE.pow((ndim - 1 - axis) as u32);
        let lanes = n / BLOCK_EDGE;
        for lane in 0..lanes {
            // Decompose lane index into (outer, inner) around the axis.
            let inner = lane % stride;
            let outer = lane / stride;
            let base = outer * stride * BLOCK_EDGE + inner;
            let mut v = [
                block[base],
                block[base + stride],
                block[base + 2 * stride],
                block[base + 3 * stride],
            ];
            f(&mut v);
            block[base] = v[0];
            block[base + stride] = v[1];
            block[base + 2 * stride] = v[2];
            block[base + 3 * stride] = v[3];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn lift4_roundtrip_within_ulps() {
        let mut rng = XorShift::new(1);
        for _ in 0..1000 {
            let orig = [
                (rng.next_u64() as i32 / 4) as i64,
                (rng.next_u64() as i32 / 4) as i64,
                (rng.next_u64() as i32 / 4) as i64,
                (rng.next_u64() as i32 / 4) as i64,
            ];
            let mut v = orig;
            lift4(&mut v);
            unlift4(&mut v);
            for (a, b) in v.iter().zip(&orig) {
                assert!((a - b).abs() <= 4, "{v:?} vs {orig:?}");
            }
        }
    }

    #[test]
    fn block_roundtrip_within_ulps_all_dims() {
        let mut rng = XorShift::new(2);
        for ndim in 1..=3usize {
            let n = BLOCK_EDGE.pow(ndim as u32);
            let orig: Vec<i64> = (0..n).map(|_| (rng.next_u64() as i32 / 8) as i64).collect();
            let mut b = orig.clone();
            lift_block(&mut b, ndim);
            assert_ne!(b, orig, "transform should change data");
            inverse_lift_block(&mut b, ndim);
            // Each inverse axis doubles earlier axes' 1-ulp losses
            // (`x <<= 1` steps), so 3D can accumulate ~2⁶ of error — still
            // 2⁻²⁴ relative to the 30-bit block-float scale.
            for (a, x) in b.iter().zip(&orig) {
                assert!((a - x).abs() <= 128, "ndim={ndim}: {a} vs {x}");
            }
        }
    }

    #[test]
    fn constant_block_energy_compacts_to_dc() {
        // A constant block transforms to a single nonzero (DC) coefficient.
        let mut b = vec![1000i64; 64];
        lift_block(&mut b, 3);
        let nonzero = b.iter().filter(|&&c| c != 0).count();
        assert_eq!(nonzero, 1, "constant block should compact to DC");
    }

    #[test]
    fn smooth_ramp_compacts_energy() {
        // Linear ramp: most energy lands in few coefficients.
        let b0: Vec<i64> = (0..16).map(|i| (i as i64) * 1000).collect();
        let mut b = b0.clone();
        lift_block(&mut b, 2);
        let mut mags: Vec<i64> = b.iter().map(|c| c.abs()).collect();
        mags.sort_unstable_by(|a, b| b.cmp(a));
        let top4: i64 = mags[..4].iter().sum();
        let total: i64 = mags.iter().sum();
        assert!(top4 as f64 / total as f64 > 0.9);
    }
}
