//! ZFP-style block-transform error-bounded compressor.
//!
//! Pipeline (mirroring ZFP's fixed-accuracy mode):
//! 1. Partition the field into 4^d blocks (edge-replicated padding for
//!    partial blocks).
//! 2. **All-zero fast path**: a block of exact zeros emits a single flag
//!    bit — this is the mechanism behind the paper's Observation 3 anomaly
//!    on the mostly-zero HEDM dataset.
//! 3. **Block-floating-point**: samples share the block's max exponent and
//!    are scaled to signed integers.
//! 4. **Decorrelating transform**: ZFP's reversible integer lifting
//!    transform, applied separably along each dimension of the block.
//! 5. **Quantization** of transform coefficients to the accuracy goal, then
//!    canonical Huffman + ZSTD across all blocks.
//! 6. **Outlier correction**: compression reconstructs each block and
//!    stores exact corrections for any sample that would exceed the bound,
//!    making the pointwise guarantee unconditional (ZFP's analytic bound is
//!    replaced by an enforced one).

mod transform;

use anyhow::{bail, Result};

use super::{Compressor, ErrorBound};
use crate::data::{Field, Precision};
use crate::encoding::{
    fixed, huffman_decode, huffman_encode, lossless_compress, lossless_decompress, varint,
};

pub use transform::{inverse_lift_block, lift_block, BLOCK_EDGE};

/// Scale used when converting block samples to integers (bits of integer
/// precision below the block exponent).
const INT_BITS: i32 = 30;

/// Symbol range for quantized coefficients (escape = 0).
const CODE_OFFSET: i64 = 32768;
const MAX_CODE: i64 = 32767;

/// ZFP-style compressor.
#[derive(Default)]
pub struct ZfpLike;

impl Compressor for ZfpLike {
    fn name(&self) -> &'static str {
        "zfp-like"
    }

    fn compress(&self, field: &Field, bound: ErrorBound) -> Result<Vec<u8>> {
        let eb = bound.absolute_for(field);
        if eb <= 0.0 {
            bail!("error bound must be positive");
        }
        let ndim = field.ndim();
        if ndim > 3 {
            bail!("zfp-like supports 1–3D");
        }
        let shape = field.shape();
        let data = field.data();
        let block_elems = BLOCK_EDGE.pow(ndim as u32);
        let blocks = block_grid(shape);
        let n_blocks: usize = blocks.iter().product();

        let mut zero_flags: Vec<bool> = Vec::with_capacity(n_blocks);
        let mut exponents: Vec<i16> = Vec::new();
        let mut codes: Vec<u16> = Vec::new();
        let mut escapes: Vec<i64> = Vec::new();
        // Outlier corrections: (block-local linear sample idx, exact value).
        let mut outlier_pos: Vec<u32> = Vec::new();
        let mut outlier_val: Vec<f64> = Vec::new();
        let mut n_outliers_per_block: Vec<u32> = Vec::with_capacity(n_blocks);

        let mut block = vec![0.0f64; block_elems];
        let mut ints = vec![0i64; block_elems];
        for b in 0..n_blocks {
            gather_block(data, shape, &blocks, b, &mut block);
            if block.iter().all(|&v| v == 0.0) {
                zero_flags.push(true);
                continue;
            }
            zero_flags.push(false);

            // Block-floating-point: common exponent.
            let maxabs = block.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
            let e = maxabs.log2().ceil() as i32;
            exponents.push(e as i16);
            let scale = (2.0f64).powi(INT_BITS - e);
            for (i, &v) in block.iter().enumerate() {
                ints[i] = (v * scale).round() as i64;
            }
            lift_block(&mut ints, ndim);

            // Quantize coefficients: quantum chosen so worst-case inverse
            // error stays within eb/2 (empirically the inverse transform's
            // L∞ gain per coefficient is ≤ 1 for this lifting; we keep a
            // 4× safety margin and enforce the bound via outliers anyway).
            let quantum = ((eb / 4.0) * scale / block_elems as f64).max(1.0);
            let mut recon_ints = vec![0i64; block_elems];
            for (i, &c) in ints.iter().enumerate() {
                let q = (c as f64 / quantum).round() as i64;
                if q.abs() <= MAX_CODE {
                    codes.push((q + CODE_OFFSET) as u16);
                } else {
                    codes.push(0);
                    escapes.push(q);
                }
                recon_ints[i] = (q as f64 * quantum).round() as i64;
            }
            // Verify bound on the locally-reconstructed block.
            inverse_lift_block(&mut recon_ints, ndim);
            let inv_scale = 1.0 / scale;
            let mut n_out = 0u32;
            for i in 0..block_elems {
                let r = recon_ints[i] as f64 * inv_scale;
                if (r - block[i]).abs() > eb {
                    outlier_pos.push(i as u32);
                    outlier_val.push(block[i]);
                    n_out += 1;
                }
            }
            n_outliers_per_block.push(n_out);
        }

        // ---- assemble payload
        let mut out = Vec::new();
        out.extend_from_slice(b"ZFL1");
        out.push(match field.precision() {
            Precision::Single => 0,
            Precision::Double => 1,
        });
        varint::write(&mut out, ndim as u64);
        for &d in shape {
            varint::write(&mut out, d as u64);
        }
        out.extend_from_slice(&eb.to_le_bytes());

        let flag_bytes = crate::encoding::pack_flags(&zero_flags);
        let enc_flags = lossless_compress(&flag_bytes);
        varint::write(&mut out, enc_flags.len() as u64);
        out.extend_from_slice(&enc_flags);

        let mut exp_bytes = Vec::with_capacity(exponents.len() * 2);
        for &e in &exponents {
            exp_bytes.extend_from_slice(&e.to_le_bytes());
        }
        let enc_exp = lossless_compress(&exp_bytes);
        varint::write(&mut out, enc_exp.len() as u64);
        out.extend_from_slice(&enc_exp);

        varint::write(&mut out, codes.len() as u64);
        let enc_codes = lossless_compress(&huffman_encode(&codes));
        varint::write(&mut out, enc_codes.len() as u64);
        out.extend_from_slice(&enc_codes);

        let mut esc_bytes = Vec::new();
        varint::write(&mut esc_bytes, escapes.len() as u64);
        for &e in &escapes {
            varint::write(&mut esc_bytes, varint::zigzag(e));
        }
        let enc_esc = lossless_compress(&esc_bytes);
        varint::write(&mut out, enc_esc.len() as u64);
        out.extend_from_slice(&enc_esc);

        let mut out_bytes = Vec::new();
        varint::write(&mut out_bytes, n_outliers_per_block.len() as u64);
        for &c in &n_outliers_per_block {
            varint::write(&mut out_bytes, c as u64);
        }
        for &p in &outlier_pos {
            varint::write(&mut out_bytes, p as u64);
        }
        for &v in &outlier_val {
            out_bytes.extend_from_slice(&v.to_le_bytes());
        }
        let enc_out = lossless_compress(&out_bytes);
        varint::write(&mut out, enc_out.len() as u64);
        out.extend_from_slice(&enc_out);
        Ok(out)
    }

    fn decompress(&self, payload: &[u8]) -> Result<Field> {
        if payload.len() < 5 || &payload[..4] != b"ZFL1" {
            bail!("not a zfp-like payload");
        }
        let precision = match payload[4] {
            0 => Precision::Single,
            1 => Precision::Double,
            x => bail!("bad precision {x}"),
        };
        let mut pos = 5usize;
        let ndim = varint::read(payload, &mut pos)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(varint::read(payload, &mut pos)? as usize);
        }
        let eb = fixed::read_f64_le(payload, &mut pos, "header error bound")?;
        let _ = eb;

        let read_section = |payload: &[u8], pos: &mut usize| -> Result<Vec<u8>> {
            let len = varint::read(payload, pos)? as usize;
            if *pos + len > payload.len() {
                bail!("truncated section");
            }
            let raw = lossless_decompress(&payload[*pos..*pos + len])?;
            *pos += len;
            Ok(raw)
        };

        let blocks = block_grid(&shape);
        let n_blocks: usize = blocks.iter().product();
        let block_elems = BLOCK_EDGE.pow(ndim as u32);

        let flag_bytes = read_section(payload, &mut pos)?;
        let zero_flags = crate::encoding::unpack_flags(&flag_bytes, n_blocks);

        let exp_bytes = read_section(payload, &mut pos)?;
        let exponents: Vec<i16> = exp_bytes
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes(fixed::exact(c)))
            .collect();

        let n_codes = varint::read(payload, &mut pos)? as usize;
        let code_raw = read_section(payload, &mut pos)?;
        let codes = huffman_decode(&code_raw, n_codes)?;

        let esc_bytes = read_section(payload, &mut pos)?;
        let mut epos = 0usize;
        let n_esc = varint::read(&esc_bytes, &mut epos)? as usize;
        let mut escapes = Vec::with_capacity(n_esc);
        for _ in 0..n_esc {
            escapes.push(varint::unzigzag(varint::read(&esc_bytes, &mut epos)?));
        }

        let out_bytes = read_section(payload, &mut pos)?;
        let mut opos = 0usize;
        let n_nonzero = varint::read(&out_bytes, &mut opos)? as usize;
        let mut n_out_per_block = Vec::with_capacity(n_nonzero);
        for _ in 0..n_nonzero {
            n_out_per_block.push(varint::read(&out_bytes, &mut opos)? as usize);
        }
        let total_out: usize = n_out_per_block.iter().sum();
        let mut outlier_pos_v = Vec::with_capacity(total_out);
        for _ in 0..total_out {
            outlier_pos_v.push(varint::read(&out_bytes, &mut opos)? as usize);
        }
        let mut outlier_val_v = Vec::with_capacity(total_out);
        for _ in 0..total_out {
            outlier_val_v.push(fixed::read_f64_le(&out_bytes, &mut opos, "outlier value")?);
        }

        // ---- reconstruct
        let n: usize = shape.iter().product();
        let mut recon = vec![0.0f64; n];
        let mut ci = 0usize; // code cursor
        let mut ei = 0usize; // escape cursor
        let mut xi = 0usize; // nonzero block cursor
        let mut oi = 0usize; // outlier cursor
        let mut ints = vec![0i64; block_elems];
        let mut block = vec![0.0f64; block_elems];
        for b in 0..n_blocks {
            if zero_flags[b] {
                // zeros: nothing to do (recon initialized to 0)
                continue;
            }
            let e = *exponents
                .get(xi)
                .ok_or_else(|| anyhow::anyhow!("exponent stream exhausted"))?
                as i32;
            let scale = (2.0f64).powi(INT_BITS - e);
            let quantum = {
                // Must match compression: quantum = max(eb/4·scale/elems, 1)
                ((eb / 4.0) * scale / block_elems as f64).max(1.0)
            };
            for v in ints.iter_mut() {
                let code = *codes
                    .get(ci)
                    .ok_or_else(|| anyhow::anyhow!("code stream exhausted"))?;
                ci += 1;
                let q = if code == 0 {
                    let q = *escapes
                        .get(ei)
                        .ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?;
                    ei += 1;
                    q
                } else {
                    code as i64 - CODE_OFFSET
                };
                *v = (q as f64 * quantum).round() as i64;
            }
            inverse_lift_block(&mut ints, ndim);
            let inv_scale = 1.0 / scale;
            for (i, &c) in ints.iter().enumerate() {
                block[i] = c as f64 * inv_scale;
            }
            // Apply outliers.
            let n_out = n_out_per_block
                .get(xi)
                .copied()
                .ok_or_else(|| anyhow::anyhow!("outlier counts exhausted"))?;
            for _ in 0..n_out {
                let p = outlier_pos_v[oi];
                block[p] = outlier_val_v[oi];
                oi += 1;
            }
            scatter_block(&mut recon, &shape, &blocks, b, &block);
            xi += 1;
        }
        Ok(Field::new(&shape, recon, precision))
    }
}

/// Number of blocks along each dimension.
fn block_grid(shape: &[usize]) -> Vec<usize> {
    shape.iter().map(|&d| d.div_ceil(BLOCK_EDGE)).collect()
}

/// Copy block `b` (row-major over the block grid) into `out`
/// (edge-replicated padding for partial blocks).
fn gather_block(data: &[f64], shape: &[usize], blocks: &[usize], b: usize, out: &mut [f64]) {
    let ndim = shape.len();
    // Block multi-index.
    let mut bid = vec![0usize; ndim];
    let mut rem = b;
    for d in (0..ndim).rev() {
        bid[d] = rem % blocks[d];
        rem /= blocks[d];
    }
    let mut strides = vec![1usize; ndim];
    for d in (0..ndim.saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    let block_elems = BLOCK_EDGE.pow(ndim as u32);
    for (li, o) in out.iter_mut().enumerate().take(block_elems) {
        let mut lin = 0usize;
        let mut rem = li;
        for d in (0..ndim).rev() {
            let off = rem % BLOCK_EDGE;
            rem /= BLOCK_EDGE;
            // Edge-replicate out-of-range coordinates.
            let c = (bid[d] * BLOCK_EDGE + off).min(shape[d] - 1);
            lin += c * strides[d];
        }
        *o = data[lin];
    }
}

/// Write block `b` back, ignoring padded lanes.
fn scatter_block(data: &mut [f64], shape: &[usize], blocks: &[usize], b: usize, block: &[f64]) {
    let ndim = shape.len();
    let mut bid = vec![0usize; ndim];
    let mut rem = b;
    for d in (0..ndim).rev() {
        bid[d] = rem % blocks[d];
        rem /= blocks[d];
    }
    let mut strides = vec![1usize; ndim];
    for d in (0..ndim.saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    let block_elems = BLOCK_EDGE.pow(ndim as u32);
    'elem: for (li, &v) in block.iter().enumerate().take(block_elems) {
        let mut lin = 0usize;
        let mut rem = li;
        for d in (0..ndim).rev() {
            let off = rem % BLOCK_EDGE;
            rem /= BLOCK_EDGE;
            let c = bid[d] * BLOCK_EDGE + off;
            if c >= shape[d] {
                continue 'elem; // padded lane
            }
            lin += c * strides[d];
        }
        data[lin] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn bound_holds_on_suite() {
        let c = ZfpLike;
        for (name, field) in synth::benchmark_suite(16) {
            for eb_rel in [1e-2, 1e-3] {
                let bound = ErrorBound::Relative(eb_rel);
                let eb = bound.absolute_for(&field);
                let payload = c.compress(&field, bound).unwrap();
                let recon = c.decompress(&payload).unwrap();
                let max_err = field
                    .data()
                    .iter()
                    .zip(recon.data())
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                assert!(
                    max_err <= eb * (1.0 + 1e-12),
                    "{name}: max_err {max_err} > eb {eb}"
                );
            }
        }
    }

    #[test]
    fn zero_field_is_tiny() {
        let f = Field::zeros(&[64, 64], Precision::Double);
        let payload = ZfpLike.compress(&f, ErrorBound::Absolute(1e-3)).unwrap();
        // 256 blocks → ~32 flag bytes + headers; should be well under 200 B.
        assert!(payload.len() < 200, "payload {} B", payload.len());
        let recon = ZfpLike.decompress(&payload).unwrap();
        assert!(recon.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sparse_field_fast_path_kicks_in() {
        // Mostly-zero diffraction frame: most blocks take the 1-bit path.
        // Ring/peak counts are scaled down to the 128² frame so the peak
        // footprint stays a few percent (HEDM-like sparsity).
        let f = synth::diffraction::DiffractionBuilder::new([128, 128])
            .rings(2)
            .peaks_per_ring(6)
            .noise_fraction(0.0)
            .seed(3)
            .build();
        let dense = synth::grf::GrfBuilder::new(&[128, 128]).seed(3).build();
        let p_sparse = ZfpLike.compress(&f, ErrorBound::Absolute(1e-4)).unwrap();
        let p_dense = ZfpLike
            .compress(&dense, ErrorBound::Absolute(1e-4))
            .unwrap();
        assert!(
            p_sparse.len() * 3 < p_dense.len(),
            "sparse {} vs dense {}",
            p_sparse.len(),
            p_dense.len()
        );
    }

    #[test]
    fn partial_blocks_roundtrip() {
        // 5×7 exercises edge replication + scatter cropping.
        let data: Vec<f64> = (0..35).map(|i| (i as f64 * 0.71).sin()).collect();
        let f = Field::new(&[5, 7], data, Precision::Double);
        let payload = ZfpLike.compress(&f, ErrorBound::Absolute(1e-6)).unwrap();
        let recon = ZfpLike.decompress(&payload).unwrap();
        for (a, b) in f.data().iter().zip(recon.data()) {
            assert!((a - b).abs() <= 1e-6);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(ZfpLike.decompress(b"nope").is_err());
    }
}
