//! Multidimensional Lorenzo predictor.
//!
//! The Lorenzo predictor estimates a sample from its already-visited
//! corner neighbours with inclusion–exclusion signs; in 2D:
//! `p(i,j) = x(i-1,j) + x(i,j-1) − x(i-1,j-1)`, and in d dimensions the
//! alternating sum over the 2^d − 1 non-empty corner offsets. Missing
//! neighbours (at the boundary) contribute 0, which degrades gracefully to
//! lower-dimensional Lorenzo on faces/edges.

use super::Prediction;

pub struct LorenzoPredictor;

impl Prediction for LorenzoPredictor {
    fn forward(&self, shape: &[usize], recon: &mut [f64], f: &mut dyn FnMut(usize, f64) -> f64) {
        let ndim = shape.len();
        // Row-major strides.
        let mut strides = vec![1usize; ndim];
        for d in (0..ndim.saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        let n: usize = shape.iter().product();
        let mut idx = vec![0usize; ndim];

        for lin in 0..n {
            let p = lorenzo_predict(&idx, &strides, recon, lin);
            let r = f(lin, p);
            recon[lin] = r;
            // Increment multi-index.
            for d in (0..ndim).rev() {
                idx[d] += 1;
                if idx[d] < shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }
}

/// Inclusion–exclusion prediction at one site; boundary neighbours count 0.
#[inline]
fn lorenzo_predict(idx: &[usize], strides: &[usize], recon: &[f64], lin: usize) -> f64 {
    let ndim = idx.len();
    let mut p = 0.0;
    for m in 1u32..(1 << ndim) {
        let mut valid = true;
        let mut off = 0usize;
        for d in 0..ndim {
            if m >> d & 1 == 1 {
                if idx[d] == 0 {
                    valid = false;
                    break;
                }
                off += strides[d];
            }
        }
        if !valid {
            continue;
        }
        let sign = if m.count_ones() % 2 == 1 { 1.0 } else { -1.0 };
        p += sign * recon[lin - off];
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Collect (index, prediction) pairs feeding back exact values, so the
    /// predictions equal classic Lorenzo on the original data.
    fn run(shape: &[usize], data: &[f64]) -> Vec<f64> {
        let mut recon = vec![0.0; data.len()];
        let mut preds = vec![0.0; data.len()];
        LorenzoPredictor.forward(shape, &mut recon, &mut |i, p| {
            preds[i] = p;
            data[i]
        });
        preds
    }

    #[test]
    fn first_element_predicts_zero() {
        let preds = run(&[4], &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(preds[0], 0.0);
        // 1D Lorenzo = previous value.
        assert_eq!(preds[1], 5.0);
        assert_eq!(preds[3], 7.0);
    }

    #[test]
    fn linear_ramp_2d_is_predicted_exactly() {
        // f(i,j) = 3i + 2j + 1 is affine ⇒ 2D Lorenzo residual is 0 away
        // from the boundary.
        let (h, w) = (5usize, 6usize);
        let data: Vec<f64> = (0..h * w)
            .map(|lin| {
                let (i, j) = (lin / w, lin % w);
                3.0 * i as f64 + 2.0 * j as f64 + 1.0
            })
            .collect();
        let preds = run(&[h, w], &data);
        for i in 1..h {
            for j in 1..w {
                let lin = i * w + j;
                assert!(
                    (preds[lin] - data[lin]).abs() < 1e-12,
                    "at ({i},{j}): {} vs {}",
                    preds[lin],
                    data[lin]
                );
            }
        }
    }

    #[test]
    fn trilinear_field_3d_predicted_exactly() {
        // The 3D Lorenzo residual is the mixed difference ΔᵢΔⱼΔₖf, which
        // vanishes for any sum of functions of at most two of the three
        // index variables.
        let s = [4usize, 4, 4];
        let data: Vec<f64> = (0..64)
            .map(|lin| {
                let i = (lin / 16) as f64;
                let j = ((lin / 4) % 4) as f64;
                let k = (lin % 4) as f64;
                2.0 * i - j + 4.0 * k + i * j + j * k + i * k
            })
            .collect();
        let preds = run(&s, &data);
        for i in 1..4usize {
            for j in 1..4usize {
                for k in 1..4usize {
                    let lin = i * 16 + j * 4 + k;
                    assert!((preds[lin] - data[lin]).abs() < 1e-10);
                }
            }
        }
    }
}
