//! SZ3-style prediction-based error-bounded compressor.
//!
//! Pipeline (mirroring the SZ3 modular framework):
//! 1. **Prediction** — each sample is predicted from already-reconstructed
//!    neighbours, either by the multidimensional Lorenzo predictor or by a
//!    level-wise linear interpolation predictor (SZ3's default for smooth
//!    fields);
//! 2. **Error-bounded quantization** — the residual is quantized with
//!    quantum `2·eb`, so reconstruction error is ≤ `eb` by construction;
//!    residuals outside the code range become *unpredictable literals*
//!    stored verbatim;
//! 3. **Entropy coding** — quantization codes go through canonical Huffman
//!    then ZSTD; literals are ZSTD-packed.
//!
//! Like SZ3, prediction is strictly local, so spectral fidelity is *not*
//! preserved — exactly the weakness FFCz corrects (paper Observation 1
//! attributes SZ3's larger edit overhead to this locality).

mod interp;
mod lorenzo;

use anyhow::{bail, Result};

use super::{Compressor, ErrorBound};
use crate::data::{Field, Precision};
use crate::encoding::{
    fixed, huffman_decode, huffman_encode, lossless_compress, lossless_decompress, varint,
};

pub use interp::InterpPredictor;
pub use lorenzo::LorenzoPredictor;

/// Quantization code range: codes are offset into u16 symbols; 0 is the
/// escape symbol for unpredictable literals.
const CODE_OFFSET: i64 = 32768;
const MAX_CODE: i64 = 32767;

/// Predictor selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Predictor {
    /// Multidimensional Lorenzo (good for noisy fields, 1–3D).
    Lorenzo,
    /// Level-wise linear interpolation (good for smooth fields).
    Interpolation,
}

/// SZ3-style compressor.
pub struct SzLike {
    pub predictor: Predictor,
}

impl Default for SzLike {
    fn default() -> Self {
        Self {
            predictor: Predictor::Lorenzo,
        }
    }
}

impl SzLike {
    pub fn with_predictor(predictor: Predictor) -> Self {
        Self { predictor }
    }
}

/// Internal trait for prediction schemes that work on the reconstructed
/// buffer (shared by compress and decompress so they stay in lock-step).
pub(crate) trait Prediction {
    /// Visit indices in prediction order, calling `f(linear_index,
    /// prediction)`. `f` returns the reconstructed value to store so later
    /// predictions see quantized data.
    fn forward(&self, shape: &[usize], recon: &mut [f64], f: &mut dyn FnMut(usize, f64) -> f64);
}

impl Compressor for SzLike {
    fn name(&self) -> &'static str {
        "sz-like"
    }

    fn compress(&self, field: &Field, bound: ErrorBound) -> Result<Vec<u8>> {
        let eb = bound.absolute_for(field);
        if eb <= 0.0 {
            bail!("error bound must be positive");
        }
        let quantum = 2.0 * eb;
        let n = field.len();
        let data = field.data();
        let mut recon = vec![0.0f64; n];
        let mut codes: Vec<u16> = Vec::with_capacity(n);
        let mut literals: Vec<f64> = Vec::new();

        let pred: Box<dyn Prediction> = match self.predictor {
            Predictor::Lorenzo => Box::new(LorenzoPredictor),
            Predictor::Interpolation => Box::new(InterpPredictor),
        };
        pred.forward(field.shape(), &mut recon, &mut |i, p| {
            let residual = data[i] - p;
            let q = (residual / quantum).round() as i64;
            if q.abs() <= MAX_CODE {
                let r = p + q as f64 * quantum;
                // Guard against FP rounding pushing past the bound.
                if (r - data[i]).abs() <= eb {
                    codes.push((q + CODE_OFFSET) as u16);
                    return r;
                }
            }
            codes.push(0); // escape
            literals.push(data[i]);
            data[i]
        });

        // Assemble payload.
        let mut out = Vec::new();
        out.extend_from_slice(b"SZL1");
        out.push(match field.precision() {
            Precision::Single => 0,
            Precision::Double => 1,
        });
        out.push(match self.predictor {
            Predictor::Lorenzo => 0,
            Predictor::Interpolation => 1,
        });
        varint::write(&mut out, field.ndim() as u64);
        for &d in field.shape() {
            varint::write(&mut out, d as u64);
        }
        out.extend_from_slice(&eb.to_le_bytes());

        let enc_codes = lossless_compress(&huffman_encode(&codes));
        varint::write(&mut out, enc_codes.len() as u64);
        out.extend_from_slice(&enc_codes);

        let mut lit_bytes = Vec::with_capacity(literals.len() * 8);
        for &v in &literals {
            lit_bytes.extend_from_slice(&v.to_le_bytes());
        }
        let enc_lits = lossless_compress(&lit_bytes);
        varint::write(&mut out, literals.len() as u64);
        varint::write(&mut out, enc_lits.len() as u64);
        out.extend_from_slice(&enc_lits);
        Ok(out)
    }

    fn decompress(&self, payload: &[u8]) -> Result<Field> {
        if payload.len() < 6 || &payload[..4] != b"SZL1" {
            bail!("not an sz-like payload");
        }
        let precision = match payload[4] {
            0 => Precision::Single,
            1 => Precision::Double,
            x => bail!("bad precision {x}"),
        };
        let predictor = match payload[5] {
            0 => Predictor::Lorenzo,
            1 => Predictor::Interpolation,
            x => bail!("bad predictor {x}"),
        };
        let mut pos = 6usize;
        let ndim = varint::read(payload, &mut pos)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(varint::read(payload, &mut pos)? as usize);
        }
        let n: usize = shape.iter().product();
        let eb = fixed::read_f64_le(payload, &mut pos, "header error bound")?;
        let quantum = 2.0 * eb;

        let code_len = varint::read(payload, &mut pos)? as usize;
        if pos + code_len > payload.len() {
            bail!("truncated code section");
        }
        let codes = huffman_decode(&lossless_decompress(&payload[pos..pos + code_len])?, n)?;
        pos += code_len;

        let n_lit = varint::read(payload, &mut pos)? as usize;
        let lit_len = varint::read(payload, &mut pos)? as usize;
        if pos + lit_len > payload.len() {
            bail!("truncated literal section");
        }
        let lit_bytes = lossless_decompress(&payload[pos..pos + lit_len])?;
        if lit_bytes.len() != n_lit * 8 {
            bail!("literal count mismatch");
        }
        let literals: Vec<f64> = lit_bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(fixed::exact(c)))
            .collect();

        let mut recon = vec![0.0f64; n];
        let mut ci = 0usize;
        let mut li = 0usize;
        let pred: Box<dyn Prediction> = match predictor {
            Predictor::Lorenzo => Box::new(LorenzoPredictor),
            Predictor::Interpolation => Box::new(InterpPredictor),
        };
        let mut fail: Option<&'static str> = None;
        pred.forward(&shape, &mut recon, &mut |_, p| {
            let code = codes.get(ci).copied().unwrap_or(0);
            ci += 1;
            if code == 0 {
                match literals.get(li) {
                    Some(&v) => {
                        li += 1;
                        v
                    }
                    None => {
                        fail = Some("literal stream exhausted");
                        0.0
                    }
                }
            } else {
                p + (code as i64 - CODE_OFFSET) as f64 * quantum
            }
        });
        if let Some(msg) = fail {
            bail!(msg);
        }
        Ok(Field::new(&shape, recon, precision))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn roundtrip_bound_check(c: &SzLike, field: &Field, eb_rel: f64) {
        let bound = ErrorBound::Relative(eb_rel);
        let eb = bound.absolute_for(field);
        let payload = c.compress(field, bound).unwrap();
        let recon = c.decompress(&payload).unwrap();
        assert_eq!(recon.shape(), field.shape());
        assert_eq!(recon.precision(), field.precision());
        let max_err = field
            .data()
            .iter()
            .zip(recon.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err <= eb * (1.0 + 1e-12), "max_err {max_err} > eb {eb}");
    }

    #[test]
    fn bound_holds_on_suite_lorenzo() {
        let c = SzLike::default();
        for (name, field) in synth::benchmark_suite(16) {
            for eb in [1e-2, 1e-3] {
                roundtrip_bound_check(&c, &field, eb);
            }
            let _ = name;
        }
    }

    #[test]
    fn bound_holds_on_suite_interp() {
        let c = SzLike::with_predictor(Predictor::Interpolation);
        for (_, field) in synth::benchmark_suite(16) {
            roundtrip_bound_check(&c, &field, 1e-3);
        }
    }

    #[test]
    fn smooth_fields_compress_well() {
        let field = synth::turbulence::TurbulenceBuilder::new(&[32, 32, 32])
            .seed(5)
            .build();
        let c = SzLike::default();
        let payload = c.compress(&field, ErrorBound::Relative(1e-2)).unwrap();
        let ratio = field.original_bytes() as f64 / payload.len() as f64;
        assert!(ratio > 10.0, "ratio {ratio}");
    }

    #[test]
    fn rejects_garbage() {
        let c = SzLike::default();
        assert!(c.decompress(b"garbage").is_err());
        assert!(c.decompress(b"").is_err());
    }

    #[test]
    fn rejects_nonpositive_bound() {
        let c = SzLike::default();
        let f = Field::new(&[4], vec![1.0; 4], Precision::Double);
        assert!(c.compress(&f, ErrorBound::Absolute(0.0)).is_err());
    }
}
