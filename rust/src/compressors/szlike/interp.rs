//! Level-wise linear interpolation predictor (SZ3's default scheme for
//! smooth fields).
//!
//! The data is refined from a coarse anchor lattice to the full grid. At
//! each level with stride `s` (halving per level), a pass per dimension
//! predicts points whose coordinate along that dimension is an odd multiple
//! of `h = s/2` by averaging the two lattice neighbours at `±h` (falling
//! back to the single left neighbour at the boundary). Every grid point is
//! visited exactly once: a point belongs to the pass of the *last* dimension
//! attaining its minimal power-of-two level.

use super::Prediction;

pub struct InterpPredictor;

impl Prediction for InterpPredictor {
    fn forward(&self, shape: &[usize], recon: &mut [f64], f: &mut dyn FnMut(usize, f64) -> f64) {
        let ndim = shape.len();
        let mut strides = vec![1usize; ndim];
        for d in (0..ndim.saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        // Largest stride: the biggest power of two strictly less than the
        // largest dimension (so the anchor lattice has ≥ 2 points per dim
        // where possible).
        let maxdim = shape.iter().copied().max().unwrap_or(1);
        let mut s_max = 1usize;
        while s_max * 2 < maxdim {
            s_max *= 2;
        }

        // --- Anchor pass: all points with every coordinate ≡ 0 (mod s_max),
        // delta-predicted from the previous anchor in scan order.
        let mut prev = 0.0f64;
        for_each_lattice(shape, &|d| coords_multiples(shape[d], s_max), &mut |idx| {
            let lin = lin_of(idx, &strides);
            let r = f(lin, prev);
            recon[lin] = r;
            prev = r;
        });

        // --- Refinement passes.
        let mut s = s_max;
        while s >= 2 {
            let h = s / 2;
            for d in 0..ndim {
                // Coordinate sets per dimension for this (s, d) pass.
                let coord_fn = |dd: usize| -> Vec<usize> {
                    if dd == d {
                        coords_odd_multiples(shape[dd], h, s)
                    } else if dd < d {
                        coords_multiples(shape[dd], h)
                    } else {
                        coords_multiples(shape[dd], s)
                    }
                };
                for_each_lattice(shape, &coord_fn, &mut |idx| {
                    let lin = lin_of(idx, &strides);
                    let c = idx[d];
                    let left = recon[lin - h * strides[d]];
                    let p = if c + h < shape[d] {
                        0.5 * (left + recon[lin + h * strides[d]])
                    } else {
                        left
                    };
                    let r = f(lin, p);
                    recon[lin] = r;
                });
            }
            s = h;
        }
    }
}

#[inline]
fn lin_of(idx: &[usize], strides: &[usize]) -> usize {
    idx.iter().zip(strides).map(|(&i, &s)| i * s).sum()
}

/// `0, step, 2·step, …  < n`.
fn coords_multiples(n: usize, step: usize) -> Vec<usize> {
    (0..n).step_by(step).collect()
}

/// `h, h+s, h+2s, … < n` (odd multiples of h when s = 2h).
fn coords_odd_multiples(n: usize, h: usize, s: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut c = h;
    while c < n {
        v.push(c);
        c += s;
    }
    v
}

/// Odometer over the cartesian product of per-dimension coordinate lists.
fn for_each_lattice(
    shape: &[usize],
    coords: &dyn Fn(usize) -> Vec<usize>,
    f: &mut dyn FnMut(&[usize]),
) {
    let ndim = shape.len();
    let lists: Vec<Vec<usize>> = (0..ndim).map(coords).collect();
    if lists.iter().any(|l| l.is_empty()) {
        return;
    }
    let mut pos = vec![0usize; ndim];
    let mut idx: Vec<usize> = lists.iter().map(|l| l[0]).collect();
    loop {
        f(&idx);
        let mut d = ndim;
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            pos[d] += 1;
            if pos[d] < lists[d].len() {
                idx[d] = lists[d][pos[d]];
                break;
            }
            pos[d] = 0;
            idx[d] = lists[d][0];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run the predictor feeding back exact values; returns (order, preds).
    fn run(shape: &[usize], data: &[f64]) -> (Vec<usize>, Vec<f64>) {
        let mut recon = vec![0.0; data.len()];
        let mut order = Vec::new();
        let mut preds = vec![f64::NAN; data.len()];
        InterpPredictor.forward(shape, &mut recon, &mut |i, p| {
            order.push(i);
            preds[i] = p;
            data[i]
        });
        (order, preds)
    }

    #[test]
    fn visits_every_point_exactly_once() {
        for shape in [vec![17usize], vec![8, 8], vec![5, 7], vec![4, 6, 9]] {
            let n: usize = shape.iter().product();
            let data = vec![1.0; n];
            let (order, _) = run(&shape, &data);
            let mut seen = vec![false; n];
            for &i in &order {
                assert!(!seen[i], "double visit at {i} in shape {shape:?}");
                seen[i] = true;
            }
            assert!(seen.iter().all(|&s| s), "missed points in shape {shape:?}");
        }
    }

    #[test]
    fn linear_signal_interpolates_exactly() {
        // On a linear ramp all interpolation predictions (away from the
        // right boundary fallback) are exact.
        let n = 33usize;
        let data: Vec<f64> = (0..n).map(|i| 2.0 * i as f64).collect();
        let (_, preds) = run(&[n], &data);
        // Interior odd points at the finest level: prediction must be exact.
        for i in (1..n - 1).step_by(2) {
            assert!((preds[i] - data[i]).abs() < 1e-12, "at {i}");
        }
    }

    #[test]
    fn bilinear_2d_interpolates_exactly_along_axes() {
        let (h, w) = (9usize, 9);
        let data: Vec<f64> = (0..h * w)
            .map(|lin| {
                let (i, j) = (lin / w, lin % w);
                1.5 * i as f64 + 0.5 * j as f64
            })
            .collect();
        let (_, preds) = run(&[h, w], &data);
        // All but anchors and boundary-fallback points should be exact.
        let mut exact = 0;
        let mut total = 0;
        for i in 0..h {
            for j in 0..w {
                let lin = i * w + j;
                if preds[lin].is_nan() {
                    continue;
                }
                total += 1;
                if (preds[lin] - data[lin]).abs() < 1e-12 {
                    exact += 1;
                }
            }
        }
        assert!(exact as f64 / total as f64 > 0.85, "{exact}/{total}");
    }
}
