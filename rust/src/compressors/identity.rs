//! Lossless "compressor" that stores the field verbatim (zstd-packed).
//! Useful for tests and as a worst-case bitrate baseline.

use anyhow::Result;

use super::{Compressor, ErrorBound};
use crate::data::{io, Field};
use crate::encoding::{lossless_compress, lossless_decompress};

/// Identity codec: zero error, poor ratio.
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn compress(&self, field: &Field, _bound: ErrorBound) -> Result<Vec<u8>> {
        let mut raw = Vec::new();
        // Exact f64 payload: identity must round-trip the in-memory samples
        // bit-for-bit even when the source precision tag is Single.
        io::write_ffld_exact(field, &mut raw)?;
        Ok(lossless_compress(&raw))
    }

    fn decompress(&self, payload: &[u8]) -> Result<Field> {
        let raw = lossless_decompress(payload)?;
        io::read_ffld(&raw[..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Precision;

    #[test]
    fn roundtrip_is_exact() {
        let f = Field::new(&[2, 5], (0..10).map(|i| i as f64 * 0.3).collect(), Precision::Single);
        let c = Identity;
        let payload = c.compress(&f, ErrorBound::Absolute(1.0)).unwrap();
        let g = c.decompress(&payload).unwrap();
        assert_eq!(f, g);
    }
}
