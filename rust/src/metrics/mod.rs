//! Quality and cost metrics used throughout the paper's evaluation:
//! PSNR (spatial), SSNR (spectral, §V-A), relative frequency error, max
//! absolute/pointwise error, bitrate, and compression ratio.

use crate::data::Field;
use crate::fourier::{fftn, Complex};

/// Collected quality metrics for a (original, reconstruction) pair.
#[derive(Debug, Clone)]
pub struct QualityReport {
    /// Peak signal-to-noise ratio in the spatial domain (dB).
    pub psnr_db: f64,
    /// Spectral signal-to-noise ratio (dB), paper §V-A.
    pub ssnr_db: f64,
    /// Max absolute spatial error.
    pub max_abs_err: f64,
    /// Max relative frequency error (RFE): max_l |δ_l| / max_k |X_k|.
    pub max_rfe: f64,
    /// Root-mean-square spatial error.
    pub rmse: f64,
}

impl QualityReport {
    /// Compute all metrics. `O(N log N)` (one FFT per field).
    pub fn compute(original: &Field, reconstruction: &Field) -> Self {
        assert_eq!(original.shape(), reconstruction.shape());
        let psnr_db = psnr(original, reconstruction);
        let (ssnr_db, max_rfe) = spectral_metrics(original, reconstruction);
        let (max_abs_err, rmse) = spatial_errors(original, reconstruction);
        Self {
            psnr_db,
            ssnr_db,
            max_abs_err,
            max_rfe,
            rmse,
        }
    }
}

/// Max absolute error and RMSE.
pub fn spatial_errors(a: &Field, b: &Field) -> (f64, f64) {
    let mut max_err = 0.0f64;
    let mut se = 0.0f64;
    for (&x, &y) in a.data().iter().zip(b.data()) {
        let e = (y - x).abs();
        max_err = max_err.max(e);
        se += e * e;
    }
    (max_err, (se / a.len() as f64).sqrt())
}

/// Peak signal-to-noise ratio in dB: `20 log10(range / RMSE)`.
pub fn psnr(original: &Field, reconstruction: &Field) -> f64 {
    let (_, rmse) = spatial_errors(original, reconstruction);
    let range = original.value_span();
    if rmse == 0.0 {
        f64::INFINITY
    } else if range == 0.0 {
        f64::NEG_INFINITY
    } else {
        20.0 * (range / rmse).log10()
    }
}

/// Spectral signal-to-noise ratio (dB) and max relative frequency error.
///
/// `SSNR = 10 log10( Σ|X_k|² / Σ|X_k − X̂_k|² )`,
/// `RFE_l = |δ_l| / max_k |X_k|` (paper §V-A).
pub fn spectral_metrics(original: &Field, reconstruction: &Field) -> (f64, f64) {
    let to_complex = |f: &Field| -> Vec<Complex> {
        f.data().iter().map(|&v| Complex::new(v, 0.0)).collect()
    };
    let x = fftn(&to_complex(original), original.shape());
    let x_hat = fftn(&to_complex(reconstruction), reconstruction.shape());
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    let mut max_mag = 0.0f64;
    let mut max_err = 0.0f64;
    for (a, b) in x.iter().zip(&x_hat) {
        sig += a.norm_sqr();
        noise += (*b - *a).norm_sqr();
        max_mag = max_mag.max(a.abs());
        max_err = max_err.max((*b - *a).abs());
    }
    let ssnr = if noise == 0.0 {
        f64::INFINITY
    } else if sig == 0.0 {
        f64::NEG_INFINITY
    } else {
        10.0 * (sig / noise).log10()
    };
    let rfe = if max_mag == 0.0 { 0.0 } else { max_err / max_mag };
    (ssnr, rfe)
}

/// Compression ratio: original bytes / compressed bytes.
pub fn compression_ratio(field: &Field, compressed_bytes: usize) -> f64 {
    field.original_bytes() as f64 / compressed_bytes.max(1) as f64
}

/// Bitrate: compressed bits per sample.
pub fn bitrate(field: &Field, compressed_bytes: usize) -> f64 {
    (compressed_bytes * 8) as f64 / field.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Precision;
    use crate::util::XorShift;

    fn noisy_pair(n: usize, amp: f64, seed: u64) -> (Field, Field) {
        let mut rng = XorShift::new(seed);
        let orig: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin() * 10.0).collect();
        let recon: Vec<f64> = orig.iter().map(|&v| v + rng.uniform(-amp, amp)).collect();
        (
            Field::new(&[n], orig, Precision::Double),
            Field::new(&[n], recon, Precision::Double),
        )
    }

    #[test]
    fn identical_fields_infinite_snr() {
        let (a, _) = noisy_pair(256, 0.0, 1);
        let r = QualityReport::compute(&a, &a);
        assert!(r.psnr_db.is_infinite() && r.ssnr_db.is_infinite());
        assert_eq!(r.max_abs_err, 0.0);
        assert_eq!(r.max_rfe, 0.0);
    }

    #[test]
    fn psnr_decreases_with_noise() {
        let (a, b1) = noisy_pair(1024, 0.01, 2);
        let (_, b2) = noisy_pair(1024, 0.1, 2);
        assert!(psnr(&a, &b1) > psnr(&a, &b2) + 15.0);
    }

    #[test]
    fn parseval_ties_psnr_and_mse() {
        // By Parseval, spatial MSE == spectral MSE / N (forward unnormalized),
        // so SSNR == 10 log10(Σ|X|² / (N·MSE_spatial)).
        let (a, b) = noisy_pair(512, 0.05, 3);
        let (_, rmse) = spatial_errors(&a, &b);
        let (ssnr, _) = spectral_metrics(&a, &b);
        let x = fftn(
            &a.data().iter().map(|&v| Complex::new(v, 0.0)).collect::<Vec<_>>(),
            a.shape(),
        );
        let sig: f64 = x.iter().map(|c| c.norm_sqr()).sum();
        let expect = 10.0 * (sig / (512.0 * rmse * rmse * 512.0)).log10();
        assert!((ssnr - expect).abs() < 1e-6, "{ssnr} vs {expect}");
    }

    #[test]
    fn ratio_and_bitrate() {
        let f = Field::zeros(&[1000], Precision::Single);
        assert_eq!(compression_ratio(&f, 400), 10.0);
        assert_eq!(bitrate(&f, 400), 3.2);
    }

    #[test]
    fn max_abs_err_is_linf() {
        let a = Field::new(&[3], vec![0.0, 0.0, 0.0], Precision::Double);
        let b = Field::new(&[3], vec![0.1, -0.5, 0.2], Precision::Double);
        let r = QualityReport::compute(&a, &b);
        assert!((r.max_abs_err - 0.5).abs() < 1e-15);
    }
}
