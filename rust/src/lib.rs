//! # FFCz — Fast Fourier Correction for spectrum-preserving lossy compression
//!
//! This crate is a from-scratch reproduction of the FFCz system (Ren et al.,
//! CS.DC 2026): a post-hoc *correction* layer that edits the output of any
//! error-bounded lossy compressor so that reconstruction error is bounded in
//! **both** the spatial domain (`|ε_n| ≤ E`) and the frequency domain
//! (`|Re δ_k| ≤ Δ`, `|Im δ_k| ≤ Δ` with `δ = FFT(ε)`).
//!
//! The crate contains everything the paper depends on, built from scratch:
//!
//! * [`fourier`] — FFTs (split-radix-family radix-4 pow-2 kernel with a
//!   radix-2 oracle / Bluestein for arbitrary sizes), real half-spectrum
//!   transforms ([`fourier::rfftn`] / [`fourier::NdRealFft`] — the POCS
//!   hot path: half the arithmetic of the complex transform,
//!   allocation-free scratch plans, multi-threaded line sweeps with
//!   per-axis-length gather blocks), N-D transforms, and radially-binned
//!   power spectra;
//! * [`compressors`] — three error-bounded base compressors in the style of
//!   SZ3 (prediction-based), ZFP (block-transform), and SPERR (wavelet);
//! * [`correction`] — the FFCz contribution itself: POCS alternating
//!   projection between the *s-cube* and *f-cube*, plus edit compaction,
//!   quantization, entropy coding, and the reusable
//!   [`correction::CorrectionScratch`] that makes the encode retry ladder
//!   allocation-free in steady state;
//! * [`codec`] — composable per-chunk codec chains: a runtime registry of
//!   base compressors and bytes→bytes stages, an optional FFCz correction
//!   stage with the full bound space, and a self-describing versioned
//!   chain spec;
//! * [`coordinator`] — a streaming pipeline that overlaps base compression
//!   of instance *i+1* with FFCz editing of instance *i* (paper Fig. 7d),
//!   with an optional chunked-store sink for streamed instances;
//! * [`store`] — a zarrs-style chunked archive (`.ffcz` container): regular
//!   chunk grid, per-chunk FFCz codec pipeline, parallel encode/decode,
//!   partial `read_region` decode, and pluggable storage backends — local
//!   file, in-memory, seeded fault injector, and a remote HTTP-range
//!   backend behind a resilience layer (retries, deadlines, per-endpoint
//!   circuit breaker, hedged reads; `docs/STORAGE.md` is the normative
//!   contract);
//! * [`server`] — a concurrent archive read server: a daemon that opens
//!   many `.ffcz` stores and serves `read_region` / `stat` requests over
//!   a length-prefixed TCP protocol (`docs/SERVER.md`), sharing each
//!   archive's decoded-chunk LRU and codec table across connections;
//! * [`runtime`] — a PJRT executor that runs the AOT-compiled JAX/Pallas
//!   implementation of the projection loop from `artifacts/*.hlo.txt`;
//! * [`data`] — n-dimensional fields and seeded synthetic generators that
//!   stand in for the paper's Nyx / S3D / HEDM / EEG datasets;
//! * [`metrics`] — PSNR, SSNR, relative frequency error, bitrate, ratios;
//! * [`telemetry`] — observability: a process-wide metrics registry
//!   (counters/gauges/histograms with a stable-JSON snapshot), RAII span
//!   tracing exported as Chrome `trace_event` JSON (`--trace-out`), and
//!   leveled CLI diagnostics — disabled-by-default recording that is
//!   measurably free when off;
//! * [`experiments`] — drivers that regenerate every table and figure of the
//!   paper's evaluation section.
//!
//! ## Quickstart
//!
//! ```
//! use ffcz::prelude::*;
//!
//! // A small synthetic cosmology-like field.
//! let field = ffcz::data::synth::grf::GrfBuilder::new(&[32, 32, 32])
//!     .spectral_index(2.0)
//!     .seed(7)
//!     .build();
//!
//! // Base compressor + dual-domain bounds.
//! let base = SzLike::default();
//! let cfg = FfczConfig::relative(1e-3, 1e-3);
//! let archive = ffcz::correction::compress(&field, &base, &cfg).unwrap();
//! let recon = ffcz::correction::decompress(&archive).unwrap();
//!
//! // Both domains are now bounded.
//! let report = ffcz::correction::verify(&field, &recon, &cfg);
//! assert!(report.spatial_ok && report.frequency_ok);
//! ```
//!
//! ## Architecture
//!
//! Dataflow of a store write, with the module that owns each stage:
//!
//! ```text
//! field ──[store::grid]──▶ chunks ──[compressors]──▶ base payload
//!                                        │
//!                         [correction] FFCz POCS edit stage (optional)
//!                                        │
//!                         [encoding]   lossless bytes stages (optional)
//!                                        │
//!          [codec] one CodecChain payload per chunk
//!                                        │
//!          [store::writer] streamed into the .ffcz container
//!                          (payloads spill as chunks finish; manifest
//!                           + 24-byte trailer written last)
//! ```
//!
//! Reads run the same chain backwards: [`store::Store`] opens trailer +
//! manifest only, fetches the chunks a [`store::Store::read_region`]
//! window intersects, CRC-checks each payload, and decodes through the
//! chunk's chain — all byte fetches going through the
//! [`store::ReadableStorage`] backends (file, memory, fault-injecting),
//! and [`server`] exposes those reads to concurrent network clients.
//! Above the chunk level, [`coordinator`] pipelines
//! instance streams (and lands them in stores via
//! [`coordinator::run_pipeline_to_store`]); [`data`], [`metrics`], and
//! [`experiments`] supply fields, quality metrics, and the paper's
//! figures; the `ffcz` binary (`main.rs`) wraps it all in a CLI.
//!
//! Two cross-cutting decisions shape the code: every guarantee is **per
//! chunk** (which is what makes partial decode, per-chunk codec
//! overrides, and worker-pool parallelism composable), and every codec is
//! resolved through a **runtime registry** by name
//! ([`codec::register_codec`]), never a closed enum.
//!
//! ## Archive format
//!
//! Two on-disk containers exist. A whole-field [`correction::FfczArchive`]
//! (`.fz`) is a single base payload plus the entropy-coded edit block. The
//! chunked **`.ffcz` store** ([`store`]) scales that to disk-resident
//! arrays read in subregions:
//!
//! ```text
//! "FFCZSTR1"            8-byte head magic
//! chunk payloads        one codec-chain output per chunk, row-major order
//! manifest              versioned binary manifest (see below)
//! trailer               manifest offset u64 LE · manifest len u64 LE ·
//!                       "FFCZEND1"              (24 bytes total)
//! ```
//!
//! The **normative, third-party-implementable byte-level specification**
//! of this container — header, payload framing, CRC-32 placement, chain
//! table, manifest v1 vs v2, trailer, and the CLI `--chunk-codec`
//! grammar — lives in `docs/FORMAT.md` at the repository root; the test
//! `tests/format_doc.rs` keeps it honest by walking real archives with an
//! independent parser built from that document alone.
//!
//! The manifest (version 2, varint-based — see [`store::manifest`] for the
//! field-by-field layout) records the array shape and source precision,
//! the regular chunk grid, a **codec chain table** (each entry a
//! serialized [`codec::CodecChainSpec`]: raw-f64 or any registered base
//! compressor, an optional FFCz correction stage carrying the full
//! [`correction::FfczConfig`] — absolute, relative, and power-spectrum
//! bounds — and bytes→bytes lossless stages), and a per-chunk table of
//! byte ranges, chain indices, CRC-32 payload checksums, and dual-domain
//! verification stats: bit-packed `spatial_ok` / `frequency_ok` flags and
//! the max spatial/frequency bound ratios measured at encode time. The
//! per-chunk chain index is what makes mixed archives possible — e.g.
//! bit-exact lossless boundary chunks around FFCz-corrected interior
//! chunks.
//!
//! Manifest **version 1** archives (single store-wide codec, two relative
//! bounds only, no checksums) remain readable: the legacy codec spec is
//! lifted onto an equivalent chain at parse time and checksum verification
//! is skipped. Writers always emit version 2. Readers parse trailer +
//! manifest only and fetch chunks on demand, so
//! [`store::Store::read_region`] decodes exactly the chunks intersecting
//! the requested window, CRC-verifying each payload before it reaches a
//! codec. Writers **stream** by default — chunk payloads spill to the
//! file through a bounded in-flight window as they are encoded
//! ([`store::stream_store_to`]), so peak payload memory is
//! O(workers × chunk) rather than O(field), and an interrupted write is
//! rejected at open with a precise truncation error because the trailer
//! never made it to disk.

pub mod codec;
pub mod compressors;
pub mod coordinator;
pub mod correction;
pub mod data;
pub mod encoding;
pub mod experiments;
pub mod fourier;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod store;
pub mod telemetry;
pub mod util;

/// Convenient re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::codec::{register_codec, CodecChain, CodecChainSpec};
    pub use crate::compressors::{
        sperrlike::SperrLike, szlike::SzLike, zfplike::ZfpLike, Compressor, ErrorBound,
    };
    pub use crate::correction::{
        compress, decompress, verify, BoundSpec, CorrectionScratch, FfczConfig,
    };
    pub use crate::data::Field;
    pub use crate::fourier::{Complex, Fft};
    pub use crate::metrics::QualityReport;
    pub use crate::store::{Store, StoreWriteOptions};
}
