//! Raw binary field I/O in the SDRBench convention (flat little-endian
//! f32/f64 arrays, shape supplied out of band), plus a small self-describing
//! `.ffld` container used by the CLI so shapes travel with the data.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::encoding::fixed;

use super::{Field, Precision};

const FFLD_MAGIC: &[u8; 4] = b"FFLD";

/// Read a flat little-endian array (SDRBench style). `shape` and
/// `precision` must be known by the caller.
pub fn read_raw(path: &Path, shape: &[usize], precision: Precision) -> Result<Field> {
    let n: usize = shape.iter().product();
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let expect = n * precision.bytes();
    if bytes.len() != expect {
        bail!(
            "{}: expected {} bytes for shape {:?} ({}), found {}",
            path.display(),
            expect,
            shape,
            precision.name(),
            bytes.len()
        );
    }
    let data = match precision {
        Precision::Single => bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(fixed::exact(c)) as f64)
            .collect(),
        Precision::Double => bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(fixed::exact(c)))
            .collect(),
    };
    Ok(Field::new(shape, data, precision))
}

/// Write a flat little-endian array in the field's source precision.
pub fn write_raw(field: &Field, path: &Path) -> Result<()> {
    let mut out = Vec::with_capacity(field.original_bytes());
    match field.precision() {
        Precision::Single => {
            for &v in field.data() {
                out.extend_from_slice(&(v as f32).to_le_bytes());
            }
        }
        Precision::Double => {
            for &v in field.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Serialize a field with shape metadata (`.ffld` container).
///
/// Payloads are stored in the field's *source precision* (format tags 2/3):
/// a single-precision field costs 4 bytes per sample instead of the 8 the
/// legacy layout (tags 0/1, always-f64 payload) spent. [`read_ffld`] still
/// accepts the legacy layout.
pub fn write_ffld<W: Write>(field: &Field, mut w: W) -> Result<()> {
    w.write_all(FFLD_MAGIC)?;
    w.write_all(&[match field.precision() {
        Precision::Single => 2u8,
        Precision::Double => 3u8,
    }])?;
    w.write_all(&(field.ndim() as u32).to_le_bytes())?;
    for &d in field.shape() {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    match field.precision() {
        Precision::Single => {
            for &v in field.data() {
                w.write_all(&(v as f32).to_le_bytes())?;
            }
        }
        Precision::Double => {
            for &v in field.data() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

/// Serialize with a full-width f64 payload regardless of the precision tag
/// (the legacy 0/1 layout). For in-memory containers where bit-exact
/// roundtrip of the f64 samples matters more than size — the identity
/// compressor's payload — not for files, where [`write_ffld`] is smaller.
pub fn write_ffld_exact<W: Write>(field: &Field, mut w: W) -> Result<()> {
    w.write_all(FFLD_MAGIC)?;
    w.write_all(&[match field.precision() {
        Precision::Single => 0u8,
        Precision::Double => 1u8,
    }])?;
    w.write_all(&(field.ndim() as u32).to_le_bytes())?;
    for &d in field.shape() {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    for &v in field.data() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Deserialize a `.ffld` container (current tags 2/3 or the legacy 0/1
/// layout, which stored every payload as f64 regardless of precision).
pub fn read_ffld<R: Read>(mut r: R) -> Result<Field> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != FFLD_MAGIC {
        bail!("not an FFLD container");
    }
    let mut b1 = [0u8; 1];
    r.read_exact(&mut b1)?;
    // (precision, f32 payload?)
    let (precision, narrow_payload) = match b1[0] {
        0 => (Precision::Single, false), // legacy: tagged single, f64 payload
        1 => (Precision::Double, false),
        2 => (Precision::Single, true),
        3 => (Precision::Double, false),
        x => bail!("bad precision tag {x}"),
    };
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let ndim = u32::from_le_bytes(b4) as usize;
    if ndim == 0 || ndim > 8 {
        bail!("unreasonable ndim {ndim}");
    }
    let mut shape = Vec::with_capacity(ndim);
    let mut b8 = [0u8; 8];
    for _ in 0..ndim {
        r.read_exact(&mut b8)?;
        shape.push(u64::from_le_bytes(b8) as usize);
    }
    let n: usize = shape.iter().product();
    let mut data = Vec::with_capacity(n);
    if narrow_payload {
        let mut f4 = [0u8; 4];
        for _ in 0..n {
            r.read_exact(&mut f4)?;
            data.push(f32::from_le_bytes(f4) as f64);
        }
    } else {
        for _ in 0..n {
            r.read_exact(&mut b8)?;
            data.push(f64::from_le_bytes(b8));
        }
    }
    Ok(Field::new(&shape, data, precision))
}

/// Convenience: write `.ffld` to a path.
pub fn save(field: &Field, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    write_ffld(field, std::io::BufWriter::new(f))
}

/// Convenience: read `.ffld` from a path.
pub fn load(path: &Path) -> Result<Field> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    read_ffld(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_field() -> Field {
        Field::new(
            &[2, 3],
            vec![1.0, -2.5, 3.25, 0.0, 1e-8, 4.75],
            Precision::Single,
        )
    }

    #[test]
    fn ffld_roundtrip_double_exact() {
        let f = Field::new(
            &[2, 3],
            vec![1.0, -2.5, 3.25, 0.0, 1e-8, 4.75],
            Precision::Double,
        );
        let mut buf = Vec::new();
        write_ffld(&f, &mut buf).unwrap();
        let g = read_ffld(&buf[..]).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn ffld_single_stores_f32_payload() {
        let f = sample_field();
        let mut buf = Vec::new();
        write_ffld(&f, &mut buf).unwrap();
        // Header (4 magic + 1 tag + 4 ndim + 2×8 shape) + 6 × 4-byte samples.
        assert_eq!(buf.len(), 25 + 6 * 4);
        let g = read_ffld(&buf[..]).unwrap();
        assert_eq!(g.precision(), Precision::Single);
        assert_eq!(g.shape(), f.shape());
        for (a, b) in f.data().iter().zip(g.data()) {
            assert_eq!(*a as f32, *b as f32, "beyond f32 precision: {a} vs {b}");
        }
    }

    #[test]
    fn ffld_reads_legacy_f64_layout() {
        // Legacy tag 0/1 layout (f64 payload whatever the tag) still reads
        // back bit-exactly — including values beyond f32 precision.
        let f = sample_field();
        let mut buf = Vec::new();
        write_ffld_exact(&f, &mut buf).unwrap();
        assert_eq!(buf[4], 0u8, "single-precision legacy tag");
        assert_eq!(buf.len(), 25 + 6 * 8);
        let g = read_ffld(&buf[..]).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn ffld_rejects_bad_magic() {
        let buf = b"NOPE12345678".to_vec();
        assert!(read_ffld(&buf[..]).is_err());
    }

    #[test]
    fn raw_roundtrip_double() {
        let dir = std::env::temp_dir().join("ffcz_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("raw_f64.bin");
        let f = Field::new(&[4], vec![1.0, 2.0, -3.0, 4.5], Precision::Double);
        write_raw(&f, &p).unwrap();
        let g = read_raw(&p, &[4], Precision::Double).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn raw_roundtrip_single_loses_only_f32_precision() {
        let dir = std::env::temp_dir().join("ffcz_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("raw_f32.bin");
        let f = sample_field();
        write_raw(&f, &p).unwrap();
        let g = read_raw(&p, &[2, 3], Precision::Single).unwrap();
        for (a, b) in f.data().iter().zip(g.data()) {
            assert!((a - b).abs() <= (a.abs() * 1e-7).max(1e-12));
        }
    }

    #[test]
    fn raw_size_mismatch_errors() {
        let dir = std::env::temp_dir().join("ffcz_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("short.bin");
        std::fs::write(&p, [0u8; 10]).unwrap();
        assert!(read_raw(&p, &[4], Precision::Double).is_err());
    }
}
