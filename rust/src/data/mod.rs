//! N-dimensional scalar fields and dataset utilities.
//!
//! [`Field`] is the crate-wide data container: a dense row-major n-d array
//! of `f64` samples plus a [`Precision`] tag recording the precision of the
//! *source* data (the tag determines how many bytes the uncompressed
//! original occupies, which is what compression ratios are measured
//! against — Nyx is single precision, S3D/HEDM/EEG are double, Table I).

pub mod io;
pub mod synth;

/// Precision of the source dataset (affects original-size accounting only;
/// all in-memory processing is done in f64).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Single,
    Double,
}

impl Precision {
    /// Bytes per sample in the source representation.
    pub fn bytes(self) -> usize {
        match self {
            Precision::Single => 4,
            Precision::Double => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Single => "single",
            Precision::Double => "double",
        }
    }
}

/// A dense, row-major, n-dimensional scalar field.
#[derive(Debug, Clone, PartialEq)]
pub struct Field {
    shape: Vec<usize>,
    data: Vec<f64>,
    precision: Precision,
}

impl Field {
    /// Create a field from raw data; panics if `data.len() != prod(shape)`.
    pub fn new(shape: &[usize], data: Vec<f64>, precision: Precision) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(
            n,
            data.len(),
            "shape {:?} implies {} samples, got {}",
            shape,
            n,
            data.len()
        );
        assert!(!shape.is_empty(), "field must have at least one dimension");
        Self {
            shape: shape.to_vec(),
            data,
            precision,
        }
    }

    /// All-zero field.
    pub fn zeros(shape: &[usize], precision: Precision) -> Self {
        let n: usize = shape.iter().product();
        Self::new(shape, vec![0.0; n], precision)
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Total number of samples.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Size of the *source* (uncompressed) representation in bytes.
    pub fn original_bytes(&self) -> usize {
        self.len() * self.precision.bytes()
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// Row-major linear index of a multi-index.
    pub fn linear_index(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut lin = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < d, "index {x} out of bounds for dim {i} ({d})");
            lin = lin * d + x;
        }
        lin
    }

    /// Value range `(min, max)`; `(0, 0)` for empty fields.
    pub fn value_range(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in &self.data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if lo > hi {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }

    /// `max - min`; used to turn relative error bounds into absolute ones.
    pub fn value_span(&self) -> f64 {
        let (lo, hi) = self.value_range();
        hi - lo
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f64>() / self.data.len() as f64
        }
    }

    /// A new field with the same shape/precision and the given data.
    pub fn with_data(&self, data: Vec<f64>) -> Self {
        Self::new(&self.shape, data, self.precision)
    }

    /// Extract a 2D slice (plane at `z` of the first axis) from a 3D field.
    pub fn slice2d(&self, z: usize) -> Field {
        assert_eq!(self.ndim(), 3, "slice2d requires a 3D field");
        let (n1, n2) = (self.shape[1], self.shape[2]);
        let plane = n1 * n2;
        let start = z * plane;
        Field::new(
            &[n1, n2],
            self.data[start..start + plane].to_vec(),
            self.precision,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let f = Field::zeros(&[4, 3], Precision::Single);
        assert_eq!(f.len(), 12);
        assert_eq!(f.ndim(), 2);
        assert_eq!(f.original_bytes(), 48);
        assert_eq!(f.precision().name(), "single");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Field::new(&[2, 2], vec![0.0; 5], Precision::Double);
    }

    #[test]
    fn linear_index_row_major() {
        let f = Field::zeros(&[2, 3, 4], Precision::Double);
        assert_eq!(f.linear_index(&[0, 0, 0]), 0);
        assert_eq!(f.linear_index(&[0, 0, 3]), 3);
        assert_eq!(f.linear_index(&[0, 1, 0]), 4);
        assert_eq!(f.linear_index(&[1, 0, 0]), 12);
        assert_eq!(f.linear_index(&[1, 2, 3]), 23);
    }

    #[test]
    fn value_range_and_span() {
        let f = Field::new(&[4], vec![-1.0, 2.0, 0.5, 1.5], Precision::Double);
        assert_eq!(f.value_range(), (-1.0, 2.0));
        assert_eq!(f.value_span(), 3.0);
    }

    #[test]
    fn slice2d_extracts_plane() {
        let data: Vec<f64> = (0..24).map(|x| x as f64).collect();
        let f = Field::new(&[2, 3, 4], data, Precision::Double);
        let s = f.slice2d(1);
        assert_eq!(s.shape(), &[3, 4]);
        assert_eq!(s.data()[0], 12.0);
        assert_eq!(s.data()[11], 23.0);
    }
}
