//! Seeded synthetic dataset generators.
//!
//! The paper evaluates on proprietary / large-scale datasets (Nyx, S3D,
//! HEDM, EEG — Table I). None are redistributable or practical at 2048³ in
//! this environment, so each is replaced by a generator that reproduces the
//! *property FFCz interacts with*: the spectral shape and sparsity
//! structure of the field. See DESIGN.md §3 for the substitution rationale.

pub mod diffraction;
pub mod eeg;
pub mod grf;
pub mod turbulence;

use crate::data::{Field, Precision};

/// The benchmark suite of Table I, scaled to tractable sizes. Each entry is
/// `(name, generator)`; sizes follow the paper's dimensionality (3D / 3D /
/// 2D / 1D) with edge lengths reduced for CPU-scale runs.
pub fn benchmark_suite(scale: usize) -> Vec<(String, Field)> {
    let s3 = scale.max(16);
    let s2 = (scale * 4).max(64);
    let s1 = (scale * scale * 8).max(1024);
    vec![
        (
            "nyx-baryon".to_string(),
            grf::GrfBuilder::new(&[s3, s3, s3])
                .spectral_index(1.8)
                .cutoff_frac(0.45)
                .lognormal(2.4)
                .seed(101)
                .precision(Precision::Single)
                .build(),
        ),
        (
            "nyx-dm".to_string(),
            grf::GrfBuilder::new(&[s3, s3, s3])
                .spectral_index(2.2)
                .cutoff_frac(0.35)
                .lognormal(2.0)
                .seed(102)
                .precision(Precision::Single)
                .build(),
        ),
        (
            "s3d-co2".to_string(),
            turbulence::TurbulenceBuilder::new(&[s3, s3, s3])
                .seed(103)
                .build(),
        ),
        (
            "hedm".to_string(),
            diffraction::DiffractionBuilder::new([s2, s2]).seed(104).build(),
        ),
        (
            "eeg".to_string(),
            eeg::EegBuilder::new(s1).seed(105).build(),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_five_datasets_with_expected_dims() {
        let suite = benchmark_suite(16);
        assert_eq!(suite.len(), 5);
        assert_eq!(suite[0].1.ndim(), 3);
        assert_eq!(suite[2].1.ndim(), 3);
        assert_eq!(suite[3].1.ndim(), 2);
        assert_eq!(suite[4].1.ndim(), 1);
    }

    #[test]
    fn suite_is_deterministic() {
        let a = benchmark_suite(16);
        let b = benchmark_suite(16);
        for ((_, fa), (_, fb)) in a.iter().zip(&b) {
            assert_eq!(fa.data()[..32], fb.data()[..32]);
        }
    }
}
