//! Gaussian random fields with a prescribed power-law spectrum — the stand-in
//! for Nyx cosmology density fields.
//!
//! Construction: draw white Gaussian noise in real space, FFT, shape the
//! amplitude by `√P(k)` with `P(k) ∝ k^{-α} · e^{-k/k₀}`, IFFT, take the
//! real part (spectral filtering of real noise keeps the field real up to
//! rounding). An optional log-normal map `ρ = exp(σ·g)` mimics the strictly
//! positive, high-dynamic-range one-point distribution of baryon density.

use crate::data::{Field, Precision};
use crate::fourier::{fftn, ifftn, signed_freq, Complex};
use crate::util::XorShift;

/// Builder for a power-law Gaussian random field.
pub struct GrfBuilder {
    shape: Vec<usize>,
    alpha: f64,
    cutoff_frac: f64,
    lognormal_sigma: Option<f64>,
    seed: u64,
    precision: Precision,
}

impl GrfBuilder {
    pub fn new(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            alpha: 2.0,
            cutoff_frac: 0.5,
            lognormal_sigma: None,
            seed: 0,
            precision: Precision::Single,
        }
    }

    /// Power-law slope α in `P(k) ∝ k^{-α}` (cosmology-like fields: 1.5–2.5).
    pub fn spectral_index(mut self, alpha: f64) -> Self {
        self.alpha = alpha;
        self
    }

    /// Exponential cutoff scale as a fraction of the Nyquist wavenumber
    /// (`k₀ = cutoff_frac · k_nyq`); smaller values give smoother fields.
    pub fn cutoff_frac(mut self, frac: f64) -> Self {
        self.cutoff_frac = frac;
        self
    }

    /// Apply `ρ = exp(σ·g)` to produce a positive, skewed field.
    pub fn lognormal(mut self, sigma: f64) -> Self {
        self.lognormal_sigma = Some(sigma);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    pub fn build(self) -> Field {
        let n: usize = self.shape.iter().product();
        let mut rng = XorShift::new(self.seed ^ 0xC05A0C05A0);
        // White noise in real space.
        let noise: Vec<Complex> = (0..n).map(|_| Complex::new(rng.normal(), 0.0)).collect();
        let mut spec = fftn(&noise, &self.shape);

        // Shape amplitudes by sqrt(P(k)).
        let k_nyq = self
            .shape
            .iter()
            .map(|&d| (d / 2) as f64)
            .fold(0.0f64, |a, b| a.max(b));
        let k0 = (self.cutoff_frac * k_nyq).max(1e-9);
        let ndim = self.shape.len();
        let mut idx = vec![0usize; ndim];
        for v in spec.iter_mut() {
            let mut k2 = 0.0f64;
            for d in 0..ndim {
                let f = signed_freq(idx[d], self.shape[d]) as f64;
                k2 += f * f;
            }
            let k = k2.sqrt();
            let amp = if k == 0.0 {
                0.0 // zero out DC: fluctuations only
            } else {
                (k.powf(-self.alpha) * (-k / k0).exp()).sqrt()
            };
            *v = v.scale(amp);
            for d in (0..ndim).rev() {
                idx[d] += 1;
                if idx[d] < self.shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }

        let real = ifftn(&spec, &self.shape);
        let mut g: Vec<f64> = real.iter().map(|c| c.re).collect();

        // Normalize to unit variance before the lognormal map so σ is
        // meaningful regardless of α/k₀.
        let mean = g.iter().sum::<f64>() / n as f64;
        let var = g.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let std = var.sqrt().max(1e-30);
        for x in g.iter_mut() {
            *x = (*x - mean) / std;
        }

        if let Some(sigma) = self.lognormal_sigma {
            for x in g.iter_mut() {
                *x = (sigma * *x).exp();
            }
        }
        Field::new(&self.shape, g, self.precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::power_spectrum;

    #[test]
    fn deterministic_per_seed() {
        let a = GrfBuilder::new(&[16, 16, 16]).seed(4).build();
        let b = GrfBuilder::new(&[16, 16, 16]).seed(4).build();
        assert_eq!(a.data(), b.data());
        let c = GrfBuilder::new(&[16, 16, 16]).seed(5).build();
        assert_ne!(a.data(), c.data());
    }

    #[test]
    fn lognormal_field_is_positive() {
        let f = GrfBuilder::new(&[16, 16, 16])
            .lognormal(1.5)
            .seed(1)
            .build();
        assert!(f.data().iter().all(|&v| v > 0.0));
    }

    #[test]
    fn spectrum_follows_power_law() {
        // Estimate the log-log slope of P(k) between k=2 and k_nyq/2 and
        // check it is near -α (binned GRF estimate: generous tolerance).
        let alpha = 2.0;
        let f = GrfBuilder::new(&[64, 64])
            .spectral_index(alpha)
            .cutoff_frac(10.0) // effectively no exponential cutoff
            .seed(3)
            .build();
        let ps = power_spectrum(&f);
        let lo = 2usize;
        let hi = 16usize;
        let (mut sx, mut sy, mut sxx, mut sxy, mut m) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for k in lo..=hi {
            if ps.power[k] <= 0.0 {
                continue;
            }
            let x = (k as f64).ln();
            // per-mode power removes the shell-area factor
            let y = (ps.power[k] / ps.count[k] as f64).ln();
            sx += x;
            sy += y;
            sxx += x * x;
            sxy += x * y;
            m += 1.0;
        }
        let slope = (m * sxy - sx * sy) / (m * sxx - sx * sx);
        assert!(
            (slope + alpha).abs() < 0.6,
            "slope {slope:.2} vs -{alpha}"
        );
    }

    #[test]
    fn zero_mean_without_lognormal() {
        let f = GrfBuilder::new(&[32, 32]).seed(9).build();
        assert!(f.mean().abs() < 1e-10);
    }
}
