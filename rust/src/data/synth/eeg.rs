//! EEG-like 1D time series — the stand-in for the paper's EEG database.
//!
//! Continuous brain-activity recordings are a sum of band-limited rhythms
//! (delta/theta/alpha/beta), 1/f "pink" background noise, and occasional
//! high-amplitude artifacts (blinks). The frequency-banded structure is
//! what the paper's EEG discussion (misinterpreting neural rhythms under
//! spectral distortion) relies on.

use crate::data::{Field, Precision};
use crate::util::XorShift;

pub struct EegBuilder {
    samples: usize,
    sample_rate: f64,
    artifact_rate: f64,
    seed: u64,
}

/// The classic EEG bands: (low Hz, high Hz, relative amplitude).
const BANDS: [(f64, f64, f64); 4] = [
    (0.5, 4.0, 40.0),  // delta
    (4.0, 8.0, 20.0),  // theta
    (8.0, 13.0, 30.0), // alpha
    (13.0, 30.0, 8.0), // beta
];

impl EegBuilder {
    pub fn new(samples: usize) -> Self {
        Self {
            samples,
            sample_rate: 250.0,
            artifact_rate: 0.05,
            seed: 0,
        }
    }

    pub fn sample_rate(mut self, hz: f64) -> Self {
        self.sample_rate = hz;
        self
    }

    /// Expected artifacts per second.
    pub fn artifact_rate(mut self, r: f64) -> Self {
        self.artifact_rate = r;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn build(self) -> Field {
        let n = self.samples;
        let mut rng = XorShift::new(self.seed ^ 0xEE6);
        let dt = 1.0 / self.sample_rate;
        let mut sig = vec![0.0f64; n];

        // Band rhythms: a handful of drifting oscillators per band.
        for &(lo, hi, amp) in &BANDS {
            for _ in 0..3 {
                let f = rng.uniform(lo, hi);
                let phase = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
                let a = amp * rng.uniform(0.5, 1.0) / 3.0;
                // Slow amplitude modulation (waxing/waning of rhythms).
                let fm = rng.uniform(0.05, 0.3);
                let pm = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
                for (i, s) in sig.iter_mut().enumerate() {
                    let t = i as f64 * dt;
                    let env = 0.5 * (1.0 + (2.0 * std::f64::consts::PI * fm * t + pm).sin());
                    *s += a * env * (2.0 * std::f64::consts::PI * f * t + phase).sin();
                }
            }
        }

        // Pink-ish background noise via a leaky integrator over white noise.
        let mut state = 0.0;
        for s in sig.iter_mut() {
            state = 0.98 * state + rng.normal() * 2.0;
            *s += state;
        }

        // Blink artifacts: sparse, high-amplitude, slow bumps.
        let expected = self.artifact_rate * n as f64 * dt;
        let n_artifacts = expected.round() as usize;
        for _ in 0..n_artifacts {
            let center = rng.below(n);
            let width = (0.2 * self.sample_rate) as i64; // 200 ms
            let amp = rng.uniform(80.0, 150.0);
            for d in -width..=width {
                let i = center as i64 + d;
                if i < 0 || i >= n as i64 {
                    continue;
                }
                let x = d as f64 / width as f64;
                sig[i as usize] += amp * (-4.0 * x * x).exp();
            }
        }
        Field::new(&[n], sig, Precision::Double)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::power_spectrum;

    #[test]
    fn alpha_band_is_prominent() {
        // With a 250 Hz rate and n samples, FFT bin k maps to k·250/n Hz.
        let n = 8192;
        let f = EegBuilder::new(n).artifact_rate(0.0).seed(1).build();
        let ps = power_spectrum(&f);
        let hz = |k: usize| k as f64 * 250.0 / n as f64;
        let band_power = |lo: f64, hi: f64| -> f64 {
            (1..ps.len())
                .filter(|&k| hz(k) >= lo && hz(k) < hi)
                .map(|k| ps.power[k])
                .sum()
        };
        let alpha = band_power(8.0, 13.0) / (13.0 - 8.0);
        let gamma = band_power(35.0, 60.0) / (60.0 - 35.0);
        assert!(alpha / gamma > 5.0, "alpha/gamma = {}", alpha / gamma);
    }

    #[test]
    fn artifacts_add_outliers() {
        let quiet = EegBuilder::new(4096).artifact_rate(0.0).seed(2).build();
        let blinky = EegBuilder::new(4096).artifact_rate(1.0).seed(2).build();
        assert!(blinky.value_span() > quiet.value_span());
    }

    #[test]
    fn deterministic() {
        let a = EegBuilder::new(1024).seed(3).build();
        let b = EegBuilder::new(1024).seed(3).build();
        assert_eq!(a.data(), b.data());
    }
}
