//! Sparse diffraction frames — the stand-in for HEDM X-ray data.
//!
//! High-Energy Diffraction Microscopy frames are mostly zero with sharp
//! Bragg peaks arranged on Debye–Scherrer rings. The generator reproduces
//! exactly that: a 2D frame of zeros (plus tiny detector noise on a small
//! fraction of pixels) with Gaussian peaks placed at random azimuths on a
//! few concentric rings. The overwhelming-zero structure is what drives the
//! paper's Observation 3 anomaly (ZFP's all-zero-block fast path).

use crate::data::{Field, Precision};
use crate::util::XorShift;

pub struct DiffractionBuilder {
    shape: [usize; 2],
    rings: usize,
    peaks_per_ring: usize,
    peak_sigma: f64,
    noise_fraction: f64,
    seed: u64,
}

impl DiffractionBuilder {
    pub fn new(shape: [usize; 2]) -> Self {
        Self {
            shape,
            rings: 4,
            peaks_per_ring: 12,
            peak_sigma: 1.8,
            noise_fraction: 0.002,
            seed: 0,
        }
    }

    pub fn rings(mut self, n: usize) -> Self {
        self.rings = n;
        self
    }

    pub fn peaks_per_ring(mut self, n: usize) -> Self {
        self.peaks_per_ring = n;
        self
    }

    pub fn peak_sigma(mut self, s: f64) -> Self {
        self.peak_sigma = s;
        self
    }

    /// Fraction of pixels carrying low-level detector noise.
    pub fn noise_fraction(mut self, f: f64) -> Self {
        self.noise_fraction = f;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn build(self) -> Field {
        let [h, w] = self.shape;
        let mut img = vec![0.0f64; h * w];
        let mut rng = XorShift::new(self.seed ^ 0xD1FF);
        let cy = h as f64 / 2.0;
        let cx = w as f64 / 2.0;
        let r_max = cy.min(cx) * 0.9;

        for ring in 0..self.rings {
            let r = r_max * (ring as f64 + 1.0) / (self.rings as f64 + 0.5);
            for _ in 0..self.peaks_per_ring {
                let theta = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
                let py = cy + r * theta.sin();
                let px = cx + r * theta.cos();
                let amp = rng.uniform(0.3, 1.0);
                let sigma = self.peak_sigma * rng.uniform(0.7, 1.4);
                // Stamp a truncated Gaussian peak (±4σ).
                let rad = (4.0 * sigma).ceil() as i64;
                let (pyi, pxi) = (py.round() as i64, px.round() as i64);
                for dy in -rad..=rad {
                    for dx in -rad..=rad {
                        let y = pyi + dy;
                        let x = pxi + dx;
                        if y < 0 || x < 0 || y >= h as i64 || x >= w as i64 {
                            continue;
                        }
                        let fy = y as f64 - py;
                        let fx = x as f64 - px;
                        let v = amp * (-(fy * fy + fx * fx) / (2.0 * sigma * sigma)).exp();
                        // Below the detector noise floor nothing registers —
                        // this keeps frames overwhelmingly zero (HEDM-like).
                        if v < 1e-3 {
                            continue;
                        }
                        let cell = &mut img[y as usize * w + x as usize];
                        *cell = (*cell + v).min(1.0); // saturating detector
                    }
                }
            }
        }
        // Sparse detector noise.
        let n_noise = ((h * w) as f64 * self.noise_fraction) as usize;
        for _ in 0..n_noise {
            let i = rng.below(h * w);
            img[i] = (img[i] + rng.uniform(0.0, 0.01)).min(1.0);
        }
        Field::new(&[h, w], img, Precision::Double)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_is_mostly_zero() {
        let f = DiffractionBuilder::new([256, 256]).seed(1).build();
        let zeros = f.data().iter().filter(|&&v| v == 0.0).count();
        assert!(
            zeros as f64 / f.len() as f64 > 0.9,
            "zero fraction {}",
            zeros as f64 / f.len() as f64
        );
    }

    #[test]
    fn normalized_to_unit_range() {
        let f = DiffractionBuilder::new([128, 128]).seed(2).build();
        let (lo, hi) = f.value_range();
        assert!(lo >= 0.0 && hi <= 1.0 && hi > 0.2);
    }

    #[test]
    fn peaks_exist_on_rings() {
        let f = DiffractionBuilder::new([200, 200]).rings(2).seed(3).build();
        // The brightest pixel should sit near one of the two ring radii.
        let (mut best, mut besti) = (0.0, 0);
        for (i, &v) in f.data().iter().enumerate() {
            if v > best {
                best = v;
                besti = i;
            }
        }
        let y = (besti / 200) as f64 - 100.0;
        let x = (besti % 200) as f64 - 100.0;
        let r = (y * y + x * x).sqrt();
        let r_max = 90.0;
        let r1 = r_max * 1.0 / 2.5;
        let r2 = r_max * 2.0 / 2.5;
        assert!(
            (r - r1).abs() < 6.0 || (r - r2).abs() < 6.0,
            "brightest at radius {r:.1}"
        );
    }

    #[test]
    fn deterministic() {
        let a = DiffractionBuilder::new([64, 64]).seed(7).build();
        let b = DiffractionBuilder::new([64, 64]).seed(7).build();
        assert_eq!(a.data(), b.data());
    }
}
