//! Turbulence-like scalar fields — the stand-in for S3D combustion species.
//!
//! A Kolmogorov-style spectrum: flat energy-containing range up to `k_L`,
//! inertial `k^{-5/3}` range, and an exponential dissipation tail
//! (`P(k) ∝ e^{-k/k_d}` at high k, the "smooth field" signature the paper
//! cites). The field is strictly positive (species mass fractions) via an
//! affine map to `[floor, floor + span]`, and double precision like S3D.

use crate::data::{Field, Precision};
use crate::fourier::{fftn, ifftn, signed_freq, Complex};
use crate::util::XorShift;

pub struct TurbulenceBuilder {
    shape: Vec<usize>,
    k_energy: f64,
    k_dissipation_frac: f64,
    floor: f64,
    span: f64,
    seed: u64,
}

impl TurbulenceBuilder {
    pub fn new(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            k_energy: 4.0,
            k_dissipation_frac: 0.3,
            floor: 0.01,
            span: 0.2,
            seed: 0,
        }
    }

    /// Wavenumber of the energy-containing scales.
    pub fn energy_scale(mut self, k: f64) -> Self {
        self.k_energy = k;
        self
    }

    /// Dissipation wavenumber as a fraction of Nyquist.
    pub fn dissipation_frac(mut self, f: f64) -> Self {
        self.k_dissipation_frac = f;
        self
    }

    /// Output value range `[floor, floor + span]` (mass-fraction-like).
    pub fn range(mut self, floor: f64, span: f64) -> Self {
        self.floor = floor;
        self.span = span;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn build(self) -> Field {
        let n: usize = self.shape.iter().product();
        let mut rng = XorShift::new(self.seed ^ 0x7EB0);
        let noise: Vec<Complex> = (0..n).map(|_| Complex::new(rng.normal(), 0.0)).collect();
        let mut spec = fftn(&noise, &self.shape);

        let k_nyq = self
            .shape
            .iter()
            .map(|&d| (d / 2) as f64)
            .fold(0.0f64, |a, b| a.max(b));
        let kd = (self.k_dissipation_frac * k_nyq).max(1e-9);
        let ndim = self.shape.len();
        let mut idx = vec![0usize; ndim];
        for v in spec.iter_mut() {
            let mut k2 = 0.0;
            for d in 0..ndim {
                let f = signed_freq(idx[d], self.shape[d]) as f64;
                k2 += f * f;
            }
            let k = k2.sqrt();
            let amp = if k == 0.0 {
                0.0
            } else {
                // von Kármán-like blend: flat below k_energy, -5/3 above,
                // exponential dissipation tail.
                let inertial = (1.0 + (k / self.k_energy).powi(2)).powf(-5.0 / 12.0);
                let dissip = (-0.5 * k / kd).exp();
                inertial * dissip
            };
            *v = v.scale(amp);
            for d in (0..ndim).rev() {
                idx[d] += 1;
                if idx[d] < self.shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        let real = ifftn(&spec, &self.shape);
        let mut g: Vec<f64> = real.iter().map(|c| c.re).collect();

        // Affine map to [floor, floor+span].
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in &g {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let scale = if hi > lo { self.span / (hi - lo) } else { 0.0 };
        for x in g.iter_mut() {
            *x = self.floor + (*x - lo) * scale;
        }
        Field::new(&self.shape, g, Precision::Double)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::power_spectrum;

    #[test]
    fn positive_and_bounded() {
        let f = TurbulenceBuilder::new(&[24, 24, 24])
            .range(0.05, 0.3)
            .seed(2)
            .build();
        let (lo, hi) = f.value_range();
        assert!(lo >= 0.05 - 1e-12 && hi <= 0.35 + 1e-12);
        assert_eq!(f.precision(), Precision::Double);
    }

    #[test]
    fn spectrum_decays_at_high_k() {
        let f = TurbulenceBuilder::new(&[64, 64]).seed(3).build();
        let ps = power_spectrum(&f);
        // Per-mode power at k=4 must dominate k=24 by a large factor
        // (inertial + dissipation decay).
        let p4 = ps.power[4] / ps.count[4] as f64;
        let p24 = ps.power[24] / ps.count[24] as f64;
        assert!(p4 / p24 > 30.0, "p4/p24 = {}", p4 / p24);
    }

    #[test]
    fn deterministic() {
        let a = TurbulenceBuilder::new(&[16, 16, 16]).seed(8).build();
        let b = TurbulenceBuilder::new(&[16, 16, 16]).seed(8).build();
        assert_eq!(a.data(), b.data());
    }
}
